//! Bench: paper Figure 5 — micro-benchmark REST calls by type
//! (Read-Only 50/500 GB, Teragen, Copy) under all six scenarios.

use stocator::harness::figures::render_rest_figure;
use stocator::harness::tables::Sweep;
use stocator::harness::{Scenario, Sizing, Workload};

fn main() {
    let t0 = std::time::Instant::now();
    let sweep = Sweep::run(&Sizing::paper(), 1, &Workload::MICRO);
    println!(
        "{}",
        render_rest_figure(&sweep, &Workload::MICRO, "Figure 5 — micro-benchmark REST calls")
    );
    // Stocator issues the fewest calls in every micro benchmark.
    for w in Workload::MICRO {
        let st = sweep.cell(Scenario::Stocator, w).unwrap().ops.total();
        for s in Scenario::ALL {
            let c = sweep.cell(s, w).unwrap().ops.total();
            assert!(c >= st, "{} beat stocator on {}", s.label(), w.label());
        }
    }
    println!("fig5 bench OK in {:.1}s wall", t0.elapsed().as_secs_f64());
}
