//! Bench: paper Table 2 — REST operations, by type, for a Spark job that
//! writes a single output object, per connector (measured vs paper).

use stocator::harness::tables::{render_table2, table2_single_object, TABLE2_PAPER};
use stocator::harness::Scenario;
use stocator::metrics::OpKind;

fn main() {
    let t0 = std::time::Instant::now();
    print!("{}", render_table2());
    println!(
        "paper reference: Hadoop-Swift 48, S3a 117, Stocator 8 total ops"
    );
    // Shape assertions (the reproduction claim).
    let sw = table2_single_object(Scenario::HadoopSwiftBase);
    let s3 = table2_single_object(Scenario::S3aBase);
    let st = table2_single_object(Scenario::Stocator);
    assert!(st.total() < sw.total() && sw.total() < s3.total());
    assert_eq!(st.get(OpKind::CopyObject), 0);
    assert_eq!(st.get(OpKind::DeleteObject), 0);
    assert!(
        (st.total() as i64 - TABLE2_PAPER[2].6 as i64).abs() <= 4,
        "stocator {} vs paper {}",
        st.total(),
        TABLE2_PAPER[2].6
    );
    println!("table2 bench OK in {:.2}s", t0.elapsed().as_secs_f64());
}
