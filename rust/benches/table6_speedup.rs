//! Bench: paper Table 6 — workload speedups relative to Stocator.

use stocator::harness::tables::Sweep;
use stocator::harness::{Scenario, Sizing, Workload};

fn main() {
    let t0 = std::time::Instant::now();
    let sweep = Sweep::run(&Sizing::paper(), 1, &Workload::ALL);
    println!("{}", sweep.render_table6());
    // Headline claims: Teragen ~18x vs base (we accept >=10x), ~1x read.
    let st = sweep.cell(Scenario::Stocator, Workload::Teragen).unwrap();
    let s3 = sweep.cell(Scenario::S3aBase, Workload::Teragen).unwrap();
    let speedup = s3.runtime_mean_s / st.runtime_mean_s;
    println!("Teragen speedup vs S3a Base: x{speedup:.1} (paper: x18.03)");
    assert!(speedup >= 10.0);
    println!("table6 bench OK in {:.1}s wall", t0.elapsed().as_secs_f64());
}
