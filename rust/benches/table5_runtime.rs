//! Bench: paper Table 5 — average runtime of all 7 workloads under all 6
//! scenarios (3 repetitions, ± stddev), virtual clock vs paper seconds.

use stocator::harness::tables::Sweep;
use stocator::harness::{Sizing, Workload};

fn main() {
    let t0 = std::time::Instant::now();
    let sweep = Sweep::run(&Sizing::paper(), 3, &Workload::ALL);
    println!("{}", sweep.render_table5());
    match sweep.check_shape() {
        Ok(()) => println!("shape check OK"),
        Err(v) => {
            for x in &v {
                println!("VIOLATION: {x}");
            }
            std::process::exit(1);
        }
    }
    println!("table5 bench OK in {:.1}s wall", t0.elapsed().as_secs_f64());
}
