//! Perf bench (not a paper artifact): wall-clock throughput of the
//! simulator's hot paths — the L3 optimization target of EXPERIMENTS.md
//! §Perf. Hand-rolled because criterion is unavailable offline.

use stocator::connectors::Stocator;
use stocator::fs::{FileSystem, FsInputStream, FsOutputStream, OpCtx, Path};
use stocator::harness::{run_cell, Scenario, Sizing, Workload};
use stocator::objectstore::{
    BackendKind, ConsistencyModel, LatencyModel, Metadata, ObjectStore, StoreConfig,
};
use stocator::simclock::SimInstant;
use stocator::util::json::Json;
use std::time::Instant;

fn bench<F: FnMut(u64)>(name: &str, iters: u64, mut f: F) -> f64 {
    // Warmup.
    for i in 0..iters / 10 {
        f(i);
    }
    let t0 = Instant::now();
    for i in 0..iters {
        f(i);
    }
    let dt = t0.elapsed().as_secs_f64();
    let rate = iters as f64 / dt;
    println!("{name:<32} {iters:>9} iters  {dt:>7.3}s  {rate:>12.0} ops/s");
    rate
}

fn main() {
    println!("simulator hot-path throughput (wall clock):");
    let store = ObjectStore::new(StoreConfig::default());
    store.create_container("c", SimInstant::EPOCH).0.unwrap();

    let put_rate = bench("PUT 1KiB", 200_000, |i| {
        let key = format!("d/part-{:06}", i % 100_000);
        store
            .put_object("c", &key, vec![7u8; 1024], Metadata::new(), SimInstant(i))
            .0
            .unwrap();
    });
    let head_rate = bench("HEAD (hit)", 500_000, |i| {
        let key = format!("d/part-{:06}", i % 100_000);
        store.head_object("c", &key).0.unwrap();
    });
    let get_rate = bench("GET 1KiB", 300_000, |i| {
        let key = format!("d/part-{:06}", i % 100_000);
        store.get_object("c", &key).0.unwrap();
    });
    let list_rate = bench("LIST prefix (1k entries)", 2_000, |i| {
        let prefix = format!("d/part-{:02}", i % 100);
        let (r, _) = store.list("c", &prefix, None, SimInstant(i));
        std::hint::black_box(r.unwrap());
    });
    // Perf targets (DESIGN.md §8): the simulator must stay far faster than
    // the protocols it measures.
    assert!(put_rate > 100_000.0, "PUT path too slow: {put_rate:.0}/s");
    assert!(head_rate > 300_000.0, "HEAD path too slow: {head_rate:.0}/s");
    assert!(get_rate > 200_000.0, "GET path too slow: {get_rate:.0}/s");
    assert!(list_rate > 200.0, "LIST path too slow: {list_rate:.0}/s");

    println!();
    println!("write contention ({WRITERS} writer threads, disjoint key prefixes):");
    let single = contended_put_rate("PUT 1KiB x8 (mem: 1 lock)", BackendKind::Mem);
    let sharded = contended_put_rate(
        "PUT 1KiB x8 (sharded: 16 locks)",
        BackendKind::Sharded(16),
    );
    println!(
        "sharded/single speedup: {:.2}x on {} cpus",
        sharded / single,
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
    // Correctness floor only: the achievable speedup is machine-dependent
    // (a single-core runner serialises everything), so the ratio is
    // reported, not asserted.
    assert!(sharded > 50_000.0, "sharded PUT too slow: {sharded:.0}/s");

    println!();
    println!("front-end contention sweep (eventual consistency, stripes 1 vs 16):");
    let contention = front_end_sweep();

    println!();
    println!("write path through the connector (streaming vs whole-buffer):");
    write_path_rates();

    println!();
    println!("read path through the connector (small reads: readahead vs naive):");
    read_path_rates();

    println!();
    println!("fault plane (zero-fault config must be free; faulted+retry for reference):");
    retry_path_rates();

    println!();
    println!("TB-scale trajectory cell (--paper-x 100 terasort, virtual time):");
    let tb = tb_scale_cell();

    let doc = Json::obj()
        .set("bench", "store_hotpath")
        .set("issue", 9u64)
        .set("contention", contention)
        .set("paper_x_cell", tb);
    let out = std::path::Path::new("BENCH_9.json");
    doc.write_file(out).expect("write BENCH_9.json");
    println!("wrote {}", out.display());
    println!("store_hotpath bench OK");
}

const SWEEP_THREADS: [usize; 4] = [1, 8, 16, 32];
const SWEEP_PUTS_PER_THREAD: u64 = 8_000;

/// One cell of the front-end sweep: `threads` real writer threads
/// hammering PUT (with a step-8 DELETE and a step-64 prefix LIST mixed
/// in) against an eventually consistent store. The backend is pinned at
/// `Sharded(16)` so the only variable is the *front end*: `stripes: 1`
/// reproduces the pre-PR-9 global visibility/multipart mutex, larger
/// values stripe it. Eventual consistency keeps the per-key
/// create-lag/delete-lag bookkeeping on the hot path (under strong
/// consistency the front end takes zero locks and there is nothing to
/// measure), and non-zero jitter keeps the per-thread RNG streams warm.
fn front_end_put_rate(stripes: usize, threads: usize) -> f64 {
    let latency = LatencyModel {
        jitter: 0.1,
        ..LatencyModel::paper_testbed()
    };
    let store = ObjectStore::new(StoreConfig {
        latency,
        consistency: ConsistencyModel::eventual(),
        backend: BackendKind::Sharded(16),
        stripes,
        seed: 9,
        ..StoreConfig::default()
    });
    store.create_container("c", SimInstant::EPOCH).0.unwrap();
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for w in 0..threads {
            let store = &store;
            scope.spawn(move || {
                for i in 0..SWEEP_PUTS_PER_THREAD {
                    let key = format!("w{w:02}/part-{i:06}");
                    store
                        .put_object("c", &key, vec![7u8; 64], Metadata::new(), SimInstant(i))
                        .0
                        .unwrap();
                    if i % 8 == 7 {
                        store.delete_object("c", &key, SimInstant(i)).0.unwrap();
                    }
                    if i % 64 == 63 {
                        let (r, _) = store.list("c", &format!("w{w:02}/"), None, SimInstant(i));
                        std::hint::black_box(r.unwrap());
                    }
                }
            });
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    (threads as u64 * SWEEP_PUTS_PER_THREAD) as f64 / dt
}

/// The PR 9 A/B: global-lock front end (`stripes: 1`) vs the striped
/// layout (`stripes: 16`) at 1/8/16/32 real threads. Gates:
///
/// * at 1 thread the striped layout must not be slower (10% timer
///   margin) — striping is pure overhead there, and it must be free;
/// * at 16 threads the striped layout must be >= 2x the global lock —
///   asserted only when the machine has >= 4 CPUs (a 1-2 core runner
///   serialises everything and the ratio is meaningless; it is still
///   printed and recorded).
fn front_end_sweep() -> Vec<Json> {
    let cpus = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let mut rows = Vec::new();
    for threads in SWEEP_THREADS {
        let baseline = front_end_put_rate(1, threads);
        let striped = front_end_put_rate(16, threads);
        let speedup = striped / baseline;
        println!(
            "{threads:>2} threads: global-lock {baseline:>12.0} ops/s   striped {striped:>12.0} ops/s   {speedup:>5.2}x"
        );
        if threads == 1 {
            assert!(
                striped >= baseline * 0.90,
                "striping must be free single-threaded: {striped:.0}/s vs {baseline:.0}/s"
            );
        }
        if threads == 16 {
            if cpus >= 4 {
                assert!(
                    speedup >= 2.0,
                    "striped front end must be >= 2x the global lock at 16 threads \
                     on a {cpus}-cpu machine: got {speedup:.2}x"
                );
            } else {
                println!(
                    "  (16-thread >= 2x gate skipped: only {cpus} cpu(s) available)"
                );
            }
        }
        rows.push(
            Json::obj()
                .set("threads", threads)
                .set("baseline_ops_per_s", baseline)
                .set("striped_ops_per_s", striped)
                .set("speedup", speedup),
        );
    }
    rows
}

/// One TB-scale harness cell for the perf trajectory: the full Stocator
/// terasort at `--paper-x 100` sizing (37 200 parts, ~4.6 TB logical).
/// Virtual runtime and op counts are deterministic; only the wall-clock
/// cost of *simulating* the cell varies by machine, which is exactly
/// the trajectory BENCH_9.json starts.
fn tb_scale_cell() -> Json {
    let sizing = Sizing::paper_x(100);
    let t0 = Instant::now();
    let cell = run_cell(Scenario::Stocator, Workload::Terasort, &sizing, 1);
    let wall_s = t0.elapsed().as_secs_f64();
    assert!(cell.valid, "TB-scale terasort failed validation: {}", cell.validation);
    println!(
        "paper-x 100 terasort: virtual {:.1}s  {} REST ops  {:.3}s wall",
        cell.runtime_mean_s,
        cell.ops.total(),
        wall_s
    );
    Json::obj()
        .set("scenario", cell.scenario.label())
        .set("workload", cell.workload.label())
        .set("paper_x", 100u64)
        .set("virtual_runtime_s", cell.runtime_mean_s)
        .set("rest_ops", cell.ops.total())
        .set("bytes_written", cell.ops.bytes_written)
        .set("bytes_read", cell.ops.bytes_read)
        .set("sim_wall_s", wall_s)
        .set("valid", cell.valid)
}

/// The transient-fault plane's hot-path tax: with NO faults armed the
/// injector check is one relaxed atomic load per op, so a store built
/// with a (never-firing) retry budget must match the plain write path —
/// that is the gate. A config that actually faults every object's PUT
/// once (and retries it) is measured for reference only: it does
/// strictly more store work by design.
fn retry_path_rates() {
    use stocator::objectstore::{FaultOp, FaultRule, FaultSpec, RetryPolicy};
    let mk = |faults: FaultSpec, retries: u32| {
        let store = ObjectStore::new(StoreConfig {
            faults,
            retry: RetryPolicy::with_retries(retries),
            ..StoreConfig::instant_strong()
        });
        store.create_container("c", SimInstant::EPOCH).0.unwrap();
        Stocator::with_defaults(store)
    };
    let path = |i: u64| {
        Path::parse(&format!("swift2d://c/bench/part-{:06}", i % 50_000)).unwrap()
    };
    let plain_fs = mk(FaultSpec::none(), 0);
    let plain = bench("write_all 64KiB (no fault plane)", 20_000, |i| {
        let mut ctx = OpCtx::new(SimInstant(i));
        plain_fs.write_all(&path(i), vec![5u8; WRITE_BYTES], true, &mut ctx)
            .unwrap();
    });
    let armed_fs = mk(FaultSpec::none(), 2);
    let armed = bench("write_all 64KiB (retries armed)", 20_000, |i| {
        let mut ctx = OpCtx::new(SimInstant(i));
        armed_fs.write_all(&path(i), vec![5u8; WRITE_BYTES], true, &mut ctx)
            .unwrap();
    });
    // Reference only: one scheduled fault fires during warmup, after
    // which the expired rule is dropped and the plane is idle again —
    // steady state must look like the plain path.
    let faulty_fs = mk(
        FaultSpec::none().with(FaultRule::new(FaultOp::Put, "", 1, 1)),
        1,
    );
    let faulted = bench("write_all 64KiB (after 1 fault fired)", 20_000, |i| {
        let mut ctx = OpCtx::new(SimInstant(i));
        faulty_fs.write_all(&path(i), vec![5u8; WRITE_BYTES], true, &mut ctx)
            .unwrap();
    });
    println!("armed/plain ratio: {:.2}x, post-fault/plain: {:.2}x", armed / plain, faulted / plain);
    // The gate: an idle fault plane must be wall-clock-neutral (10%
    // margin for timer noise on loaded shared runners).
    assert!(
        armed >= plain * 0.90,
        "idle fault plane slowed the write path: {armed:.0}/s vs {plain:.0}/s"
    );
    assert!(armed > 5_000.0, "armed write path too slow: {armed:.0}/s");
}

const WRITE_BYTES: usize = 64 * 1024;
const WRITE_CHUNK: usize = 1024;

/// Stocator's chunked-PUT write path, exercised both ways the API allows:
/// one whole-buffer `write_all` vs 64 separate 1 KiB `write` calls through
/// an `FsOutputStream`. Both are exactly one PUT; the streaming path's
/// per-call overhead must stay negligible next to the store hot path.
fn write_path_rates() {
    let store = ObjectStore::new(StoreConfig::instant_strong());
    store.create_container("c", SimInstant::EPOCH).0.unwrap();
    let fs = Stocator::with_defaults(store);
    let path = |i: u64| {
        Path::parse(&format!("swift2d://c/bench/part-{:06}", i % 50_000)).unwrap()
    };
    let whole = bench("write_all 64KiB (1 PUT)", 20_000, |i| {
        let mut ctx = OpCtx::new(SimInstant(i));
        fs.write_all(&path(i), vec![5u8; WRITE_BYTES], true, &mut ctx)
            .unwrap();
    });
    let chunk = [5u8; WRITE_CHUNK];
    let streamed = bench("stream 64x1KiB (1 chunked PUT)", 20_000, |i| {
        let mut ctx = OpCtx::new(SimInstant(i));
        let mut out = fs.create(&path(i), true, &mut ctx).unwrap();
        for _ in 0..WRITE_BYTES / WRITE_CHUNK {
            out.write(&chunk, &mut ctx).unwrap();
        }
        out.close(&mut ctx).unwrap();
    });
    println!("streaming/whole-buffer ratio: {:.2}x", streamed / whole);
    // Same gating style as above: absolute floors, generous for loaded
    // shared runners.
    assert!(whole > 5_000.0, "whole-buffer write too slow: {whole:.0}/s");
    assert!(streamed > 5_000.0, "streamed write too slow: {streamed:.0}/s");
}

const READ_OBJ_BYTES: usize = 64 * 1024;
const READ_CHUNK: usize = 1024;

/// The small-reads hot loop both ways: 64 sequential 1 KiB `read_range`
/// calls per open, once as bare per-read GETs and once through a 16 KiB
/// readahead window (3 growing fills + 61 window hits). The wrapper does
/// strictly less store work per read, so it must not be slower
/// wall-clock — that is the gate; the speedup itself is
/// machine-dependent and only reported.
fn read_path_rates() {
    let mk = |readahead: u64| {
        let store = ObjectStore::new(StoreConfig {
            readahead,
            ..StoreConfig::instant_strong()
        });
        store.create_container("c", SimInstant::EPOCH).0.unwrap();
        let fs = Stocator::with_defaults(store);
        let mut ctx = OpCtx::new(SimInstant::EPOCH);
        fs.write_all(
            &Path::parse("swift2d://c/in/part-0").unwrap(),
            vec![9u8; READ_OBJ_BYTES],
            true,
            &mut ctx,
        )
        .unwrap();
        fs
    };
    let path = Path::parse("swift2d://c/in/part-0").unwrap();
    let reads = READ_OBJ_BYTES / READ_CHUNK;
    let naive_fs = mk(0);
    let naive = bench("64x1KiB reads (naive GETs)", 5_000, |i| {
        let mut ctx = OpCtx::new(SimInstant(i));
        let mut input = naive_fs.open(&path, &mut ctx).unwrap();
        for k in 0..reads {
            std::hint::black_box(
                input
                    .read_range((k * READ_CHUNK) as u64, READ_CHUNK as u64, &mut ctx)
                    .unwrap(),
            );
        }
    });
    let ra_fs = mk(16 * 1024);
    let ra = bench("64x1KiB reads (readahead 16KiB)", 5_000, |i| {
        let mut ctx = OpCtx::new(SimInstant(i));
        let mut input = ra_fs.open(&path, &mut ctx).unwrap();
        for k in 0..reads {
            std::hint::black_box(
                input
                    .read_range((k * READ_CHUNK) as u64, READ_CHUNK as u64, &mut ctx)
                    .unwrap(),
            );
        }
    });
    println!("readahead/naive ratio: {:.2}x", ra / naive);
    // The gate: coalescing must never cost wall-clock time (5% margin for
    // timer noise on loaded shared runners).
    assert!(
        ra >= naive * 0.95,
        "readahead read path slower than naive: {ra:.0}/s vs {naive:.0}/s"
    );
}

const WRITERS: usize = 8;
const PUTS_PER_WRITER: u64 = 25_000;

/// Aggregate PUT throughput with `WRITERS` threads writing disjoint key
/// prefixes — the Spark-executor pattern that the single global mutex
/// serialised and key sharding parallelises.
fn contended_put_rate(name: &str, backend: BackendKind) -> f64 {
    let store = ObjectStore::new(StoreConfig {
        backend,
        ..StoreConfig::instant_strong()
    });
    store.create_container("c", SimInstant::EPOCH).0.unwrap();
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for w in 0..WRITERS {
            let store = &store;
            scope.spawn(move || {
                for i in 0..PUTS_PER_WRITER {
                    let key = format!("w{w:02}/part-{i:06}");
                    store
                        .put_object("c", &key, vec![7u8; 1024], Metadata::new(), SimInstant(i))
                        .0
                        .unwrap();
                }
            });
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    let total = (WRITERS as u64 * PUTS_PER_WRITER) as f64;
    let rate = total / dt;
    println!("{name:<32} {total:>9.0} puts   {dt:>7.3}s  {rate:>12.0} ops/s");
    rate
}
