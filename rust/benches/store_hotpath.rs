//! Perf bench (not a paper artifact): wall-clock throughput of the
//! simulator's hot paths — the L3 optimization target of EXPERIMENTS.md
//! §Perf. Hand-rolled because criterion is unavailable offline.

use stocator::connectors::Stocator;
use stocator::fs::{FileSystem, FsInputStream, FsOutputStream, OpCtx, Path};
use stocator::objectstore::{BackendKind, Metadata, ObjectStore, StoreConfig};
use stocator::simclock::SimInstant;
use std::time::Instant;

fn bench<F: FnMut(u64)>(name: &str, iters: u64, mut f: F) -> f64 {
    // Warmup.
    for i in 0..iters / 10 {
        f(i);
    }
    let t0 = Instant::now();
    for i in 0..iters {
        f(i);
    }
    let dt = t0.elapsed().as_secs_f64();
    let rate = iters as f64 / dt;
    println!("{name:<32} {iters:>9} iters  {dt:>7.3}s  {rate:>12.0} ops/s");
    rate
}

fn main() {
    println!("simulator hot-path throughput (wall clock):");
    let store = ObjectStore::new(StoreConfig::default());
    store.create_container("c", SimInstant::EPOCH).0.unwrap();

    let put_rate = bench("PUT 1KiB", 200_000, |i| {
        let key = format!("d/part-{:06}", i % 100_000);
        store
            .put_object("c", &key, vec![7u8; 1024], Metadata::new(), SimInstant(i))
            .0
            .unwrap();
    });
    let head_rate = bench("HEAD (hit)", 500_000, |i| {
        let key = format!("d/part-{:06}", i % 100_000);
        store.head_object("c", &key).0.unwrap();
    });
    let get_rate = bench("GET 1KiB", 300_000, |i| {
        let key = format!("d/part-{:06}", i % 100_000);
        store.get_object("c", &key).0.unwrap();
    });
    let list_rate = bench("LIST prefix (1k entries)", 2_000, |i| {
        let prefix = format!("d/part-{:02}", i % 100);
        let (r, _) = store.list("c", &prefix, None, SimInstant(i));
        std::hint::black_box(r.unwrap());
    });
    // Perf targets (DESIGN.md §8): the simulator must stay far faster than
    // the protocols it measures.
    assert!(put_rate > 100_000.0, "PUT path too slow: {put_rate:.0}/s");
    assert!(head_rate > 300_000.0, "HEAD path too slow: {head_rate:.0}/s");
    assert!(get_rate > 200_000.0, "GET path too slow: {get_rate:.0}/s");
    assert!(list_rate > 200.0, "LIST path too slow: {list_rate:.0}/s");

    println!();
    println!("write contention ({WRITERS} writer threads, disjoint key prefixes):");
    let single = contended_put_rate("PUT 1KiB x8 (mem: 1 lock)", BackendKind::Mem);
    let sharded = contended_put_rate(
        "PUT 1KiB x8 (sharded: 16 locks)",
        BackendKind::Sharded(16),
    );
    println!(
        "sharded/single speedup: {:.2}x on {} cpus",
        sharded / single,
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
    // Correctness floor only: the achievable speedup is machine-dependent
    // (a single-core runner serialises everything), so the ratio is
    // reported, not asserted.
    assert!(sharded > 50_000.0, "sharded PUT too slow: {sharded:.0}/s");

    println!();
    println!("write path through the connector (streaming vs whole-buffer):");
    write_path_rates();

    println!();
    println!("read path through the connector (small reads: readahead vs naive):");
    read_path_rates();

    println!();
    println!("fault plane (zero-fault config must be free; faulted+retry for reference):");
    retry_path_rates();
    println!("store_hotpath bench OK");
}

/// The transient-fault plane's hot-path tax: with NO faults armed the
/// injector check is one relaxed atomic load per op, so a store built
/// with a (never-firing) retry budget must match the plain write path —
/// that is the gate. A config that actually faults every object's PUT
/// once (and retries it) is measured for reference only: it does
/// strictly more store work by design.
fn retry_path_rates() {
    use stocator::objectstore::{FaultOp, FaultRule, FaultSpec, RetryPolicy};
    let mk = |faults: FaultSpec, retries: u32| {
        let store = ObjectStore::new(StoreConfig {
            faults,
            retry: RetryPolicy::with_retries(retries),
            ..StoreConfig::instant_strong()
        });
        store.create_container("c", SimInstant::EPOCH).0.unwrap();
        Stocator::with_defaults(store)
    };
    let path = |i: u64| {
        Path::parse(&format!("swift2d://c/bench/part-{:06}", i % 50_000)).unwrap()
    };
    let plain_fs = mk(FaultSpec::none(), 0);
    let plain = bench("write_all 64KiB (no fault plane)", 20_000, |i| {
        let mut ctx = OpCtx::new(SimInstant(i));
        plain_fs.write_all(&path(i), vec![5u8; WRITE_BYTES], true, &mut ctx)
            .unwrap();
    });
    let armed_fs = mk(FaultSpec::none(), 2);
    let armed = bench("write_all 64KiB (retries armed)", 20_000, |i| {
        let mut ctx = OpCtx::new(SimInstant(i));
        armed_fs.write_all(&path(i), vec![5u8; WRITE_BYTES], true, &mut ctx)
            .unwrap();
    });
    // Reference only: one scheduled fault fires during warmup, after
    // which the expired rule is dropped and the plane is idle again —
    // steady state must look like the plain path.
    let faulty_fs = mk(
        FaultSpec::none().with(FaultRule::new(FaultOp::Put, "", 1, 1)),
        1,
    );
    let faulted = bench("write_all 64KiB (after 1 fault fired)", 20_000, |i| {
        let mut ctx = OpCtx::new(SimInstant(i));
        faulty_fs.write_all(&path(i), vec![5u8; WRITE_BYTES], true, &mut ctx)
            .unwrap();
    });
    println!("armed/plain ratio: {:.2}x, post-fault/plain: {:.2}x", armed / plain, faulted / plain);
    // The gate: an idle fault plane must be wall-clock-neutral (10%
    // margin for timer noise on loaded shared runners).
    assert!(
        armed >= plain * 0.90,
        "idle fault plane slowed the write path: {armed:.0}/s vs {plain:.0}/s"
    );
    assert!(armed > 5_000.0, "armed write path too slow: {armed:.0}/s");
}

const WRITE_BYTES: usize = 64 * 1024;
const WRITE_CHUNK: usize = 1024;

/// Stocator's chunked-PUT write path, exercised both ways the API allows:
/// one whole-buffer `write_all` vs 64 separate 1 KiB `write` calls through
/// an `FsOutputStream`. Both are exactly one PUT; the streaming path's
/// per-call overhead must stay negligible next to the store hot path.
fn write_path_rates() {
    let store = ObjectStore::new(StoreConfig::instant_strong());
    store.create_container("c", SimInstant::EPOCH).0.unwrap();
    let fs = Stocator::with_defaults(store);
    let path = |i: u64| {
        Path::parse(&format!("swift2d://c/bench/part-{:06}", i % 50_000)).unwrap()
    };
    let whole = bench("write_all 64KiB (1 PUT)", 20_000, |i| {
        let mut ctx = OpCtx::new(SimInstant(i));
        fs.write_all(&path(i), vec![5u8; WRITE_BYTES], true, &mut ctx)
            .unwrap();
    });
    let chunk = [5u8; WRITE_CHUNK];
    let streamed = bench("stream 64x1KiB (1 chunked PUT)", 20_000, |i| {
        let mut ctx = OpCtx::new(SimInstant(i));
        let mut out = fs.create(&path(i), true, &mut ctx).unwrap();
        for _ in 0..WRITE_BYTES / WRITE_CHUNK {
            out.write(&chunk, &mut ctx).unwrap();
        }
        out.close(&mut ctx).unwrap();
    });
    println!("streaming/whole-buffer ratio: {:.2}x", streamed / whole);
    // Same gating style as above: absolute floors, generous for loaded
    // shared runners.
    assert!(whole > 5_000.0, "whole-buffer write too slow: {whole:.0}/s");
    assert!(streamed > 5_000.0, "streamed write too slow: {streamed:.0}/s");
}

const READ_OBJ_BYTES: usize = 64 * 1024;
const READ_CHUNK: usize = 1024;

/// The small-reads hot loop both ways: 64 sequential 1 KiB `read_range`
/// calls per open, once as bare per-read GETs and once through a 16 KiB
/// readahead window (3 growing fills + 61 window hits). The wrapper does
/// strictly less store work per read, so it must not be slower
/// wall-clock — that is the gate; the speedup itself is
/// machine-dependent and only reported.
fn read_path_rates() {
    let mk = |readahead: u64| {
        let store = ObjectStore::new(StoreConfig {
            readahead,
            ..StoreConfig::instant_strong()
        });
        store.create_container("c", SimInstant::EPOCH).0.unwrap();
        let fs = Stocator::with_defaults(store);
        let mut ctx = OpCtx::new(SimInstant::EPOCH);
        fs.write_all(
            &Path::parse("swift2d://c/in/part-0").unwrap(),
            vec![9u8; READ_OBJ_BYTES],
            true,
            &mut ctx,
        )
        .unwrap();
        fs
    };
    let path = Path::parse("swift2d://c/in/part-0").unwrap();
    let reads = READ_OBJ_BYTES / READ_CHUNK;
    let naive_fs = mk(0);
    let naive = bench("64x1KiB reads (naive GETs)", 5_000, |i| {
        let mut ctx = OpCtx::new(SimInstant(i));
        let mut input = naive_fs.open(&path, &mut ctx).unwrap();
        for k in 0..reads {
            std::hint::black_box(
                input
                    .read_range((k * READ_CHUNK) as u64, READ_CHUNK as u64, &mut ctx)
                    .unwrap(),
            );
        }
    });
    let ra_fs = mk(16 * 1024);
    let ra = bench("64x1KiB reads (readahead 16KiB)", 5_000, |i| {
        let mut ctx = OpCtx::new(SimInstant(i));
        let mut input = ra_fs.open(&path, &mut ctx).unwrap();
        for k in 0..reads {
            std::hint::black_box(
                input
                    .read_range((k * READ_CHUNK) as u64, READ_CHUNK as u64, &mut ctx)
                    .unwrap(),
            );
        }
    });
    println!("readahead/naive ratio: {:.2}x", ra / naive);
    // The gate: coalescing must never cost wall-clock time (5% margin for
    // timer noise on loaded shared runners).
    assert!(
        ra >= naive * 0.95,
        "readahead read path slower than naive: {ra:.0}/s vs {naive:.0}/s"
    );
}

const WRITERS: usize = 8;
const PUTS_PER_WRITER: u64 = 25_000;

/// Aggregate PUT throughput with `WRITERS` threads writing disjoint key
/// prefixes — the Spark-executor pattern that the single global mutex
/// serialised and key sharding parallelises.
fn contended_put_rate(name: &str, backend: BackendKind) -> f64 {
    let store = ObjectStore::new(StoreConfig {
        backend,
        ..StoreConfig::instant_strong()
    });
    store.create_container("c", SimInstant::EPOCH).0.unwrap();
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for w in 0..WRITERS {
            let store = &store;
            scope.spawn(move || {
                for i in 0..PUTS_PER_WRITER {
                    let key = format!("w{w:02}/part-{i:06}");
                    store
                        .put_object("c", &key, vec![7u8; 1024], Metadata::new(), SimInstant(i))
                        .0
                        .unwrap();
                }
            });
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    let total = (WRITERS as u64 * PUTS_PER_WRITER) as f64;
    let rate = total / dt;
    println!("{name:<32} {total:>9.0} puts   {dt:>7.3}s  {rate:>12.0} ops/s");
    rate
}
