//! Bench: paper Table 8 — REST-call cost relative to Stocator, averaged
//! over the IBM/AWS/Google/Azure 2017 price sheets.

use stocator::harness::tables::{table8_paper_note, Sweep};
use stocator::harness::{Scenario, Sizing, Workload};
use stocator::objectstore::cost_usd;

fn main() {
    let t0 = std::time::Instant::now();
    let sweep = Sweep::run(&Sizing::paper(), 1, &Workload::ALL);
    println!("{}", sweep.render_table8());
    println!("{}", table8_paper_note());
    let st = sweep.cell(Scenario::Stocator, Workload::Teragen).unwrap();
    let s3 = sweep.cell(Scenario::S3aCv2, Workload::Teragen).unwrap();
    let ratio = cost_usd(&s3.ops) / cost_usd(&st.ops);
    println!("measured Teragen S3a-Cv2 cost ratio: x{ratio:.1} (paper x17.59)");
    assert!(ratio >= 8.0, "cost ratio {ratio:.1}");
    println!("table8 bench OK in {:.1}s wall", t0.elapsed().as_secs_f64());
}
