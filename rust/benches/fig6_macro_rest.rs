//! Bench: paper Figure 6 — macro-benchmark REST calls by type
//! (Wordcount, Terasort, TPC-DS) under all six scenarios.

use stocator::harness::figures::render_rest_figure;
use stocator::harness::tables::Sweep;
use stocator::harness::{Scenario, Sizing, Workload};

fn main() {
    let t0 = std::time::Instant::now();
    let sweep = Sweep::run(&Sizing::paper(), 1, &Workload::MACRO);
    println!(
        "{}",
        render_rest_figure(&sweep, &Workload::MACRO, "Figure 6 — macro-benchmark REST calls")
    );
    for w in Workload::MACRO {
        let st = sweep.cell(Scenario::Stocator, w).unwrap().ops.total();
        for s in Scenario::ALL {
            assert!(sweep.cell(s, w).unwrap().ops.total() >= st);
        }
    }
    println!("fig6 bench OK in {:.1}s wall", t0.elapsed().as_secs_f64());
}
