//! Bench: paper Table 7 — ratio of REST calls relative to Stocator.

use stocator::harness::tables::Sweep;
use stocator::harness::{Scenario, Sizing, Workload};

fn main() {
    let t0 = std::time::Instant::now();
    let sweep = Sweep::run(&Sizing::paper(), 1, &Workload::ALL);
    println!("{}", sweep.render_table7());
    println!(
        "paper: Teragen — H-S Base x11.51, S3a Base x33.74, H-S Cv2 x7.72, S3a Cv2 x21.15"
    );
    let st = sweep.cell(Scenario::Stocator, Workload::Teragen).unwrap();
    let s3 = sweep.cell(Scenario::S3aBase, Workload::Teragen).unwrap();
    let sw = sweep.cell(Scenario::HadoopSwiftBase, Workload::Teragen).unwrap();
    let r_s3 = s3.ops.total() as f64 / st.ops.total() as f64;
    let r_sw = sw.ops.total() as f64 / st.ops.total() as f64;
    println!("measured Teragen ratios: H-S x{r_sw:.1}, S3a x{r_s3:.1}");
    assert!(r_s3 > r_sw, "S3a must be the chattiest");
    assert!(r_s3 >= 15.0, "S3a/Stocator ratio {r_s3:.1} (paper 33.7)");
    assert!(r_sw >= 5.0, "H-S/Stocator ratio {r_sw:.1} (paper 11.5)");
    println!("table7 bench OK in {:.1}s wall", t0.elapsed().as_secs_f64());
}
