//! Bench: paper Figure 7 — bytes read / written / copied on the object
//! store for the workloads with a write phase. Headline: base connectors
//! move every output byte 3x (PUT + two COPYs), Cv2 2x, Stocator exactly
//! 1x.

use stocator::harness::figures::{render_fig7, write_amplification};
use stocator::harness::tables::Sweep;
use stocator::harness::{Scenario, Sizing, Workload};

fn main() {
    let t0 = std::time::Instant::now();
    let sweep = Sweep::run(&Sizing::paper(), 1, &Workload::WRITE);
    println!("{}", render_fig7(&sweep));
    for w in [Workload::Teragen, Workload::Copy] {
        let st = write_amplification(&sweep, w, Scenario::Stocator).unwrap();
        let cv2 = write_amplification(&sweep, w, Scenario::S3aCv2).unwrap();
        let base = write_amplification(&sweep, w, Scenario::S3aBase).unwrap();
        println!(
            "{}: write amplification stocator x{st:.2}, cv2 x{cv2:.2}, base x{base:.2}",
            w.label()
        );
        assert!((0.99..1.15).contains(&st));
        assert!((1.8..2.4).contains(&cv2));
        assert!((2.6..3.4).contains(&base));
    }
    println!("fig7 bench OK in {:.1}s wall", t0.elapsed().as_secs_f64());
}
