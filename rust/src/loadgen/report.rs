//! Aggregation and serialization for the stress load plane: merge the
//! worker-private recorders into per-op-class percentile summaries, fold
//! the sweep cells into the clients × shards × payload throughput
//! matrix, and serialize the whole run to `BENCH_<n>.json` — the
//! perf-trajectory convention (one benchmark JSON per PR, diffable
//! across sessions).

use super::workload::{OpClass, WorkerReport, OP_CLASSES};
use crate::metrics::{Histogram, LatencySummary, OpKind};
use crate::util::json::Json;

/// The BENCH file this PR's load plane writes by default.
pub const BENCH_FILE: &str = "BENCH_10.json";

/// One aggregated hammer run: N clients against one gateway.
#[derive(Debug)]
pub struct StressRun {
    pub clients: usize,
    /// Backend shard count for an in-process gateway; `None` when the
    /// run drove an external `--target` (whose sharding we can't see).
    pub shards: Option<usize>,
    /// Max payload bytes.
    pub payload: usize,
    pub seed: u64,
    /// Measured wall-clock from the start barrier to the last join.
    pub elapsed_s: f64,
    /// Executed ops per [`OpClass::index`].
    pub executed: [u64; OP_CLASSES],
    /// Merged per-class latency summaries, in [`OpClass::ALL`] order.
    pub summaries: [LatencySummary; OP_CLASSES],
    pub total_ops: u64,
    pub ops_per_sec: f64,
    pub bytes_written: u64,
    pub bytes_read: u64,
    /// Sample messages (capped); `violation_count` is exact.
    pub violations: Vec<String>,
    pub violation_count: u64,
    pub upload_ids_issued: u64,
    pub upload_ids_unique: u64,
    /// Real `429`s absorbed (slept + re-sent) by the workers' backends.
    pub throttled_429: u64,
    /// Over-capacity `503`s absorbed the same way.
    pub shed_503: u64,
    /// Send failures (killed/truncated/reset/stalled connections)
    /// survived by re-sending the same `x-request-id`.
    pub retried_sends: u64,
    /// Responses the gateway answered from its replay cache — proof a
    /// re-sent mutation was deduplicated rather than re-executed.
    pub replayed_responses: u64,
    /// Client-side completed wire ops per [`crate::metrics::OpKind`]
    /// index, summed across workers (the client half of the `--scrape`
    /// equality gate).
    pub wire_ops: [u64; 7],
}

/// Cap on violation sample messages carried in a run / the BENCH file.
const MAX_SAMPLES: usize = 32;

/// Fold joined worker reports into one [`StressRun`]. The multipart-id
/// uniqueness invariant is checked here, across ALL workers: the gateway
/// must never issue the same upload id to two racing initiates.
pub fn aggregate(
    reports: Vec<WorkerReport>,
    clients: usize,
    shards: Option<usize>,
    payload: usize,
    seed: u64,
    elapsed_s: f64,
) -> StressRun {
    let mut executed = [0u64; OP_CLASSES];
    let mut hists = vec![Histogram::new(); OP_CLASSES];
    let mut violations = Vec::new();
    let mut violation_count = 0u64;
    let mut ids: Vec<u64> = Vec::new();
    let mut bytes_written = 0u64;
    let mut bytes_read = 0u64;
    let mut throttled_429 = 0u64;
    let mut shed_503 = 0u64;
    let mut retried_sends = 0u64;
    let mut replayed_responses = 0u64;
    let mut wire_ops = [0u64; 7];
    for r in reports {
        for i in 0..OP_CLASSES {
            executed[i] += r.executed[i];
            hists[i].merge(&r.hists[i]);
        }
        for i in 0..7 {
            wire_ops[i] += r.wire_ops[i];
        }
        violation_count += r.violation_count;
        for v in r.violations {
            if violations.len() < MAX_SAMPLES {
                violations.push(v);
            }
        }
        ids.extend(r.upload_ids);
        bytes_written += r.bytes_written;
        bytes_read += r.bytes_read;
        throttled_429 += r.throttled_429;
        shed_503 += r.shed_503;
        retried_sends += r.retried_sends;
        replayed_responses += r.replayed_responses;
    }
    let issued = ids.len() as u64;
    ids.sort_unstable();
    ids.dedup();
    let unique = ids.len() as u64;
    if unique != issued {
        violation_count += issued - unique;
        if violations.len() < MAX_SAMPLES {
            violations.push(format!(
                "multipart-id collision: {issued} issued, {unique} unique"
            ));
        }
    }
    let total_ops: u64 = executed.iter().sum();
    let summaries = std::array::from_fn(|i| hists[i].summary());
    StressRun {
        clients,
        shards,
        payload,
        seed,
        elapsed_s,
        executed,
        summaries,
        total_ops,
        ops_per_sec: if elapsed_s > 0.0 {
            total_ops as f64 / elapsed_s
        } else {
            0.0
        },
        bytes_written,
        bytes_read,
        violations,
        violation_count,
        upload_ids_issued: issued,
        upload_ids_unique: unique,
        throttled_429,
        shed_503,
        retried_sends,
        replayed_responses,
        wire_ops,
    }
}

impl StressRun {
    pub fn summary_for(&self, class: OpClass) -> &LatencySummary {
        &self.summaries[class.index()]
    }

    /// PUT-side goodput in MiB/s of measured wall-clock.
    pub fn write_mib_per_sec(&self) -> f64 {
        if self.elapsed_s > 0.0 {
            self.bytes_written as f64 / (1024.0 * 1024.0) / self.elapsed_s
        } else {
            0.0
        }
    }
}

/// One cell of the clients × shards × payload throughput sweep.
#[derive(Debug, Clone)]
pub struct MatrixCell {
    pub clients: usize,
    /// `None` = external target (sharding not ours to vary).
    pub shards: Option<usize>,
    pub payload: usize,
    pub total_ops: u64,
    pub elapsed_s: f64,
    pub ops_per_sec: f64,
    pub write_mib_per_sec: f64,
    pub put_p95_us: f64,
    pub violation_count: u64,
}

impl MatrixCell {
    pub fn of(run: &StressRun) -> MatrixCell {
        MatrixCell {
            clients: run.clients,
            shards: run.shards,
            payload: run.payload,
            total_ops: run.total_ops,
            elapsed_s: run.elapsed_s,
            ops_per_sec: run.ops_per_sec,
            write_mib_per_sec: run.write_mib_per_sec(),
            put_p95_us: run.summary_for(OpClass::Put).p95_us,
            violation_count: run.violation_count,
        }
    }
}

/// One row of the reactor-vs-threaded core comparison: the same fixed
/// op budget driven at each server core, throughput and tail latency
/// side by side.
#[derive(Debug, Clone)]
pub struct CoreRow {
    /// `"reactor"` or `"threaded"`.
    pub core: String,
    pub clients: usize,
    pub total_ops: u64,
    pub elapsed_s: f64,
    pub ops_per_sec: f64,
    pub put_p95_us: f64,
    pub get_p95_us: f64,
    pub violation_count: u64,
}

impl CoreRow {
    pub fn of(core: &str, run: &StressRun) -> CoreRow {
        CoreRow {
            core: core.to_string(),
            clients: run.clients,
            total_ops: run.total_ops,
            elapsed_s: run.elapsed_s,
            ops_per_sec: run.ops_per_sec,
            put_p95_us: run.summary_for(OpClass::Put).p95_us,
            get_p95_us: run.summary_for(OpClass::Get).p95_us,
            violation_count: run.violation_count,
        }
    }
}

/// Server-side serve-latency quantiles for one op kind, read back off
/// the gateway's `gateway_serve_latency_us{op=...,q=...}` gauges.
#[derive(Debug, Clone, Default)]
pub struct ServerLatencyRow {
    /// [`crate::metrics::OpKind`] display name (`"PUT Object"` …).
    pub op: String,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub mean_us: f64,
    pub max_us: f64,
}

/// Server-side truth pulled off the gateway's own `/metricz` and
/// `/tracez` while the main hammer still holds the gateway
/// (`stress --scrape`). Lands in the BENCH JSON next to the
/// client-side percentiles, so one artifact carries both ends of the
/// wire — and the executed-op equality between them is checkable
/// offline.
#[derive(Debug, Clone, Default)]
pub struct ScrapeSummary {
    /// Gateway-executed ops per [`crate::metrics::OpKind`] index, from
    /// the final `store_ops{op=...}` scrape.
    pub server_ops: [u64; 7],
    /// The workers' completed wire ops, same indexing. Chaos-free,
    /// `server_ops == client_ops` exactly; [`ScrapeSummary::op_gap`]
    /// is the CI gate.
    pub client_ops: [u64; 7],
    /// Server-side serve-latency quantiles per op kind seen.
    pub server_latency: Vec<ServerLatencyRow>,
    /// Trace entries held in the `/tracez` ring at scrape time.
    pub tracez_entries: u64,
    /// Total traces ever pushed (`tracez_pushed` counter).
    pub tracez_pushed: u64,
    /// Mid-hammer `/metricz` polls the scrape thread completed.
    pub polls: u64,
}

impl ScrapeSummary {
    /// Sum of per-kind absolute differences between what the gateway
    /// executed and what the clients completed. Zero on a chaos-free
    /// run — the `stress --scrape` acceptance gate.
    pub fn op_gap(&self) -> u64 {
        self.server_ops
            .iter()
            .zip(self.client_ops.iter())
            .map(|(s, c)| s.abs_diff(*c))
            .sum()
    }
}

/// The whole deliverable: the main hammer run, the sweep matrix, and
/// the core comparison.
#[derive(Debug)]
pub struct StressReport {
    /// `"in-process"` or the `--target` address.
    pub target: String,
    pub run: StressRun,
    pub matrix: Vec<MatrixCell>,
    /// Reactor-vs-threaded comparison rows (empty when skipped).
    pub cores: Vec<CoreRow>,
    /// Idle keep-alive connections requested with `--open-conns`.
    pub open_conns: u64,
    /// How many of them were actually established and held.
    pub open_conns_held: u64,
    /// Server-side scrape (`--scrape`); `None` when not requested.
    pub scrape: Option<ScrapeSummary>,
}

fn shards_json(shards: Option<usize>) -> Json {
    match shards {
        Some(n) => Json::from(n),
        None => Json::Str("target".into()),
    }
}

fn summary_json(s: &LatencySummary) -> Json {
    Json::obj()
        .set("count", s.count)
        .set("mean_us", s.mean_us)
        .set("p50_us", s.p50_us)
        .set("p95_us", s.p95_us)
        .set("p99_us", s.p99_us)
        .set("max_us", s.max_us)
}

/// `{kind-name: count}` object over the nonzero entries of a per-kind
/// op array (`OpKind::ALL` indexing).
fn ops_json(ops: &[u64; 7]) -> Json {
    let mut o = Json::obj();
    for k in OpKind::ALL {
        if ops[k.index()] > 0 {
            o = o.set(k.name(), ops[k.index()]);
        }
    }
    o
}

impl StressReport {
    /// Serialize for `BENCH_10.json`: per-op-class wall-clock
    /// percentiles, the clients × shards × payload throughput matrix,
    /// the open-conns hold, backpressure + wire-chaos recovery
    /// counters, the core comparison, and (with `--scrape`) the
    /// server-side scrape summary.
    pub fn to_json(&self) -> Json {
        let run = &self.run;
        let mut classes = Json::obj();
        for c in OpClass::ALL {
            classes = classes.set(c.name(), summary_json(run.summary_for(c)));
        }
        let cores: Vec<Json> = self
            .cores
            .iter()
            .map(|r| {
                Json::obj()
                    .set("core", r.core.as_str())
                    .set("clients", r.clients)
                    .set("total_ops", r.total_ops)
                    .set("elapsed_s", r.elapsed_s)
                    .set("ops_per_sec", r.ops_per_sec)
                    .set("put_p95_us", r.put_p95_us)
                    .set("get_p95_us", r.get_p95_us)
                    .set("violations", r.violation_count)
            })
            .collect();
        let matrix: Vec<Json> = self
            .matrix
            .iter()
            .map(|m| {
                Json::obj()
                    .set("clients", m.clients)
                    .set("shards", shards_json(m.shards))
                    .set("payload_bytes", m.payload)
                    .set("total_ops", m.total_ops)
                    .set("elapsed_s", m.elapsed_s)
                    .set("ops_per_sec", m.ops_per_sec)
                    .set("write_mib_per_sec", m.write_mib_per_sec)
                    .set("put_p95_us", m.put_p95_us)
                    .set("violations", m.violation_count)
            })
            .collect();
        let mut doc = Json::obj()
            .set("bench", "stress-loadplane")
            .set("issue", 10u64)
            .set("target", self.target.as_str())
            .set("seed", run.seed)
            .set("clients", run.clients)
            .set("shards", shards_json(run.shards))
            .set("payload_bytes", run.payload)
            .set("elapsed_s", run.elapsed_s)
            .set("total_ops", run.total_ops)
            .set("ops_per_sec", run.ops_per_sec)
            .set("bytes_written", run.bytes_written)
            .set("bytes_read", run.bytes_read)
            .set("write_mib_per_sec", run.write_mib_per_sec())
            .set(
                "multipart_ids",
                Json::obj()
                    .set("issued", run.upload_ids_issued)
                    .set("unique", run.upload_ids_unique),
            )
            .set("violations", run.violation_count)
            .set(
                "violation_samples",
                Json::Arr(run.violations.iter().map(|v| Json::from(v.as_str())).collect()),
            )
            .set("throttled_429", run.throttled_429)
            .set("shed_503", run.shed_503)
            .set("retried_sends", run.retried_sends)
            .set("replayed_responses", run.replayed_responses)
            .set(
                "open_conns",
                Json::obj()
                    .set("requested", self.open_conns)
                    .set("held", self.open_conns_held),
            )
            .set("op_classes", classes)
            .set("matrix", Json::Arr(matrix))
            .set("cores", Json::Arr(cores));
        if let Some(s) = &self.scrape {
            let latency: Vec<Json> = s
                .server_latency
                .iter()
                .map(|r| {
                    Json::obj()
                        .set("op", r.op.as_str())
                        .set("p50_us", r.p50_us)
                        .set("p95_us", r.p95_us)
                        .set("p99_us", r.p99_us)
                        .set("mean_us", r.mean_us)
                        .set("max_us", r.max_us)
                })
                .collect();
            doc = doc.set(
                "scrape",
                Json::obj()
                    .set("server_ops", ops_json(&s.server_ops))
                    .set("client_ops", ops_json(&s.client_ops))
                    .set("op_gap", s.op_gap())
                    .set("server_latency_us", Json::Arr(latency))
                    .set(
                        "tracez",
                        Json::obj().set("entries", s.tracez_entries).set("pushed", s.tracez_pushed),
                    )
                    .set("polls", s.polls),
            );
        }
        doc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_report(ids: Vec<u64>) -> WorkerReport {
        let mut r = WorkerReport {
            executed: [0; OP_CLASSES],
            hists: vec![Histogram::new(); OP_CLASSES],
            violations: Vec::new(),
            violation_count: 0,
            upload_ids: ids,
            bytes_written: 1024,
            bytes_read: 512,
            throttled_429: 3,
            shed_503: 1,
            retried_sends: 2,
            replayed_responses: 1,
            wire_ops: [0, 0, 10, 0, 0, 0, 1],
        };
        r.executed[OpClass::Put.index()] = 10;
        r.hists[OpClass::Put.index()].record_nanos(5_000);
        r
    }

    #[test]
    fn aggregate_merges_and_checks_id_uniqueness() {
        let run = aggregate(
            vec![fake_report(vec![1, 2]), fake_report(vec![3, 4])],
            2,
            Some(4),
            1024,
            7,
            2.0,
        );
        assert_eq!(run.executed[OpClass::Put.index()], 20);
        assert_eq!(run.total_ops, 20);
        assert_eq!(run.ops_per_sec, 10.0);
        assert_eq!(run.bytes_written, 2048);
        assert_eq!(run.violation_count, 0);
        assert_eq!(run.upload_ids_issued, 4);
        assert_eq!(run.upload_ids_unique, 4);
        assert_eq!(run.summary_for(OpClass::Put).count, 20);
        assert_eq!(run.throttled_429, 6, "backpressure counters sum across workers");
        assert_eq!(run.shed_503, 2);
        assert_eq!(run.retried_sends, 4, "chaos recovery counters sum across workers");
        assert_eq!(run.replayed_responses, 2);
        assert_eq!(run.wire_ops, [0, 0, 20, 0, 0, 0, 2], "wire ops sum per kind");
        // A colliding id across workers is a violation.
        let bad = aggregate(
            vec![fake_report(vec![5]), fake_report(vec![5])],
            2,
            Some(4),
            1024,
            7,
            1.0,
        );
        assert_eq!(bad.violation_count, 1);
        assert!(bad.violations.iter().any(|v| v.contains("collision")));
    }

    #[test]
    fn bench_json_carries_percentiles_and_matrix() {
        let run = aggregate(vec![fake_report(vec![1])], 1, Some(2), 512, 9, 1.0);
        let scrape = ScrapeSummary {
            server_ops: run.wire_ops,
            client_ops: run.wire_ops,
            server_latency: vec![ServerLatencyRow {
                op: "PUT Object".into(),
                p50_us: 10.0,
                p95_us: 20.0,
                p99_us: 30.0,
                mean_us: 12.0,
                max_us: 40.0,
            }],
            tracez_entries: 11,
            tracez_pushed: 11,
            polls: 3,
        };
        assert_eq!(scrape.op_gap(), 0);
        let report = StressReport {
            target: "in-process".into(),
            matrix: vec![MatrixCell::of(&run)],
            cores: vec![CoreRow::of("reactor", &run), CoreRow::of("threaded", &run)],
            open_conns: 2000,
            open_conns_held: 2000,
            scrape: Some(scrape),
            run,
        };
        let j = report.to_json();
        let text = j.to_pretty();
        for field in [
            "\"bench\"", "\"op_classes\"", "\"put\"", "\"p50_us\"", "\"p95_us\"",
            "\"p99_us\"", "\"matrix\"", "\"ops_per_sec\"", "\"payload_bytes\"",
            "\"multipart_ids\"", "\"throttled_429\"", "\"shed_503\"",
            "\"retried_sends\"", "\"replayed_responses\"",
            "\"open_conns\"", "\"cores\"", "\"reactor\"", "\"threaded\"",
            "\"scrape\"", "\"server_ops\"", "\"client_ops\"", "\"op_gap\"",
            "\"server_latency_us\"", "\"tracez\"", "\"PUT Object\"",
        ] {
            assert!(text.contains(field), "missing {field} in {text}");
        }
        assert_eq!(j.get("violations").and_then(Json::as_f64), Some(0.0));
        assert_eq!(j.get("seed").and_then(Json::as_f64), Some(9.0));
        assert_eq!(j.get("issue").and_then(Json::as_f64), Some(10.0));
        assert_eq!(j.get("throttled_429").and_then(Json::as_f64), Some(3.0));
        assert_eq!(j.get("replayed_responses").and_then(Json::as_f64), Some(1.0));
        let s = j.get("scrape").expect("scrape object");
        assert_eq!(s.get("op_gap").and_then(Json::as_f64), Some(0.0));
        assert_eq!(s.get("polls").and_then(Json::as_f64), Some(3.0));
        // An asymmetric gap sums absolute per-kind differences.
        let gap = ScrapeSummary {
            server_ops: [1, 0, 5, 0, 0, 0, 0],
            client_ops: [0, 0, 7, 0, 0, 0, 0],
            ..ScrapeSummary::default()
        };
        assert_eq!(gap.op_gap(), 3);
    }
}
