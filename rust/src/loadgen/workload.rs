//! One load-generator worker: a seeded mixed workload against a gateway,
//! verifying correctness as it goes.
//!
//! Each worker owns its own [`HttpBackend`] (its own socket pool), its
//! own PCG32 stream derived from the run seed, and its own container on
//! the served store — so workers never contend above the gateway, and
//! every cross-thread effect they *do* observe (multipart-id allocation,
//! backend sharding) is the server's concurrency under test, not the
//! client's. With a fixed op budget the whole per-worker execution is a
//! pure function of `(seed, worker id)`: op-mix counts are reproducible
//! across runs, which `rust/tests/test_loadgen.rs` pins.
//!
//! Verification is inline: every GET round-trips exact bytes and the
//! content ETag, every ranged GET matches the expected slice and full
//! stat size, every listing entry must name a key the worker owns, a
//! completed multipart must assemble to the concatenated parts, an
//! aborted upload must reject further parts, and at quiesce a full
//! paginated listing must equal the worker's live-key set exactly.

use crate::gateway::HttpBackend;
use crate::metrics::Histogram;
use crate::objectstore::backend::{Backend, BackendError};
use crate::objectstore::object::{sampled_etag, Metadata, Object};
use crate::simclock::SimInstant;
use crate::util::rng::Pcg32;
use std::collections::BTreeMap;
use std::time::Instant;

/// The measured operation classes. `Multipart` times the whole
/// initiate→parts→complete→install lifecycle as one sample; `Abort`
/// times a deliberate initiate→part→abort.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpClass {
    Put,
    Get,
    RangedGet,
    List,
    Delete,
    Multipart,
    Abort,
}

/// Number of [`OpClass`] variants.
pub const OP_CLASSES: usize = 7;

impl OpClass {
    pub const ALL: [OpClass; OP_CLASSES] = [
        OpClass::Put,
        OpClass::Get,
        OpClass::RangedGet,
        OpClass::List,
        OpClass::Delete,
        OpClass::Multipart,
        OpClass::Abort,
    ];

    pub fn name(self) -> &'static str {
        match self {
            OpClass::Put => "put",
            OpClass::Get => "get",
            OpClass::RangedGet => "ranged-get",
            OpClass::List => "list",
            OpClass::Delete => "delete",
            OpClass::Multipart => "multipart",
            OpClass::Abort => "abort",
        }
    }

    pub fn index(self) -> usize {
        match self {
            OpClass::Put => 0,
            OpClass::Get => 1,
            OpClass::RangedGet => 2,
            OpClass::List => 3,
            OpClass::Delete => 4,
            OpClass::Multipart => 5,
            OpClass::Abort => 6,
        }
    }
}

/// Everything one worker needs to run.
#[derive(Debug, Clone)]
pub struct WorkerConfig {
    pub id: usize,
    /// Gateway address (`HOST:PORT`).
    pub addr: String,
    /// Run-wide container namespace on the served store.
    pub ns: Option<String>,
    /// The run seed; the worker derives its private stream from it.
    pub seed: u64,
    /// Maximum payload size in bytes (sizes draw uniformly from
    /// `1..=payload`).
    pub payload: usize,
    /// Fixed op budget (deterministic mode); `None` = run to `deadline`.
    pub ops: Option<u64>,
    /// Wall-clock stop time for duration mode.
    pub deadline: Option<Instant>,
    /// Bearer token for a gateway running with auth enabled.
    pub token: Option<String>,
}

/// What a worker brings home. Plain data, merged by the harness.
#[derive(Debug)]
pub struct WorkerReport {
    /// Executed-op counts per [`OpClass::index`].
    pub executed: [u64; OP_CLASSES],
    /// Per-class wall-clock histograms (worker-private; merged after join).
    pub hists: Vec<Histogram>,
    /// Correctness violations (messages capped; `violation_count` exact).
    pub violations: Vec<String>,
    pub violation_count: u64,
    /// Every multipart upload id this worker was issued (completed AND
    /// aborted) — the harness checks global uniqueness.
    pub upload_ids: Vec<u64>,
    pub bytes_written: u64,
    pub bytes_read: u64,
    /// Real `429`s the worker's `HttpBackend` absorbed (slept out the
    /// server's `Retry-After` and re-sent). Ops that recovered this way
    /// count normally in `executed` — backpressure is invisible above
    /// the Backend trait, which is the invariant under test.
    pub throttled_429: u64,
    /// Over-capacity `503`s absorbed the same way.
    pub shed_503: u64,
    /// Send failures (torn/reset/stalled connections) the worker's
    /// `HttpBackend` survived by re-sending the same request id. Under
    /// `--chaos` this must climb while `violations` stays zero.
    pub retried_sends: u64,
    /// Re-sent mutations the gateway answered from its replay cache
    /// instead of re-executing (`x-request-replayed: true`).
    pub replayed_responses: u64,
    /// Completed wire operations per [`crate::metrics::OpKind`] index,
    /// counted by the worker's `HttpBackend` with the gateway's own
    /// classification table — the client half of the `--scrape`
    /// equality gate against the server's executed-op counters.
    pub wire_ops: [u64; 7],
}

impl WorkerReport {
    pub(super) fn new() -> Self {
        Self {
            executed: [0; OP_CLASSES],
            hists: vec![Histogram::new(); OP_CLASSES],
            violations: Vec::new(),
            violation_count: 0,
            upload_ids: Vec::new(),
            bytes_written: 0,
            bytes_read: 0,
            throttled_429: 0,
            shed_503: 0,
            retried_sends: 0,
            replayed_responses: 0,
            wire_ops: [0; 7],
        }
    }
}

/// Compact descriptor of an object the worker wrote: enough to
/// regenerate the exact expected bytes without holding the payloads of
/// every live object in memory.
#[derive(Debug, Clone, Copy)]
struct Expected {
    size: usize,
    fill: u8,
    id: u64,
}

impl Expected {
    /// The exact bytes: the object id little-endian in the first 8 bytes
    /// (truncated for tiny objects), `fill` everywhere else.
    fn materialize(&self) -> Vec<u8> {
        let mut v = vec![self.fill; self.size];
        for (i, b) in self.id.to_le_bytes().iter().enumerate().take(self.size) {
            v[i] = *b;
        }
        v
    }

    fn etag(&self) -> u64 {
        sampled_etag(&self.materialize())
    }
}

const MAX_VIOLATION_MESSAGES: usize = 16;

struct Worker {
    cfg: WorkerConfig,
    backend: HttpBackend,
    container: String,
    rng: Pcg32,
    /// Live keys this worker owns, with their expected content.
    live: BTreeMap<String, Expected>,
    next_id: u64,
    report: WorkerReport,
}

/// Run one worker to completion. Connection failure is reported as a
/// violation rather than a panic so the harness can aggregate it.
pub fn run_worker(cfg: WorkerConfig) -> WorkerReport {
    // Request-id streams must never collide: not across the workers of
    // one run (distinct worker ids) and not across sequential runs
    // against one long-lived gateway whose replay cache is still warm
    // (the namespace is unique per run, so its hash decorrelates the
    // seeds). A collision would replay a stale cached response.
    let id_seed =
        cfg.seed ^ fnv64(cfg.ns.as_deref().unwrap_or("")) ^ ((cfg.id as u64) << 17);
    let backend = match HttpBackend::connect(&cfg.addr, cfg.ns.clone()) {
        Ok(b) => {
            let b = b.with_rng_seed(id_seed);
            match &cfg.token {
                Some(token) => b.with_token(token.clone()),
                None => b,
            }
        }
        Err(e) => {
            let mut report = WorkerReport::new();
            report.violation_count = 1;
            report
                .violations
                .push(format!("worker {}: connect {}: {e}", cfg.id, cfg.addr));
            return report;
        }
    };
    // Independent per-worker stream: same run seed, distinct stream id.
    let rng = Pcg32::with_stream(cfg.seed, 0x10ad_0000 ^ cfg.id as u64);
    let container = format!("c{}", cfg.id);
    let mut w = Worker {
        backend,
        container,
        rng,
        live: BTreeMap::new(),
        next_id: 0,
        report: WorkerReport::new(),
        cfg,
    };
    w.run();
    w.report.throttled_429 = w.backend.throttled_429s();
    w.report.shed_503 = w.backend.shed_503s();
    w.report.retried_sends = w.backend.retried_sends();
    w.report.replayed_responses = w.backend.replayed_responses();
    w.report.wire_ops = w.backend.wire_op_counts();
    w.report
}

/// FNV-1a over the namespace string — a tiny, dependency-free hash
/// that is stable across runs of the same binary.
fn fnv64(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

impl Worker {
    fn run(&mut self) {
        if let Err(e) = self.backend.create_container(&self.container) {
            self.violation(format!("create_container({}): {e}", self.container));
            return;
        }
        let mut done = 0u64;
        loop {
            match (self.cfg.ops, self.cfg.deadline) {
                (Some(budget), _) if done >= budget => break,
                (None, Some(deadline)) if Instant::now() >= deadline => break,
                (None, None) => {
                    if done >= 1 {
                        break; // misconfigured: no stop condition — do one op
                    }
                }
                _ => {}
            }
            self.step();
            done += 1;
        }
        self.verify_quiesce();
    }

    /// One op from the seeded mix. Weights: 30% PUT, 25% GET, 15% ranged
    /// GET, 10% list, 10% delete, 7% full multipart, 3% abort. Read-class
    /// ops fall back to a PUT while the worker owns no objects, so the
    /// executed mix is still a pure function of the rng stream.
    fn step(&mut self) {
        let roll = self.rng.next_below(100);
        match roll {
            0..=29 => self.do_put(),
            30..=54 => self.do_get(),
            55..=69 => self.do_ranged_get(),
            70..=79 => self.do_list(),
            80..=89 => self.do_delete(),
            90..=96 => self.do_multipart(),
            _ => self.do_abort(),
        }
    }

    fn violation(&mut self, msg: String) {
        self.report.violation_count += 1;
        if self.report.violations.len() < MAX_VIOLATION_MESSAGES {
            self.report
                .violations
                .push(format!("worker {}: {msg}", self.cfg.id));
        }
    }

    fn record(&mut self, class: OpClass, start: Instant) {
        self.report.executed[class.index()] += 1;
        self.report.hists[class.index()].record(start.elapsed());
    }

    fn fresh_expected(&mut self) -> Expected {
        let id = self.next_id;
        self.next_id += 1;
        Expected {
            size: 1 + self.rng.next_below(self.cfg.payload.max(1) as u32) as usize,
            fill: (self.rng.next_u32() & 0xFF) as u8,
            id,
        }
    }

    /// A uniformly random live key, or `None` when the worker owns
    /// nothing yet. Draws from the rng either way so the stream stays a
    /// pure function of the op sequence.
    fn pick_live(&mut self) -> Option<(String, Expected)> {
        let n = self.live.len();
        let draw = self.rng.next_below(n.max(1) as u32) as usize;
        if n == 0 {
            return None;
        }
        self.live
            .iter()
            .nth(draw)
            .map(|(k, e)| (k.clone(), *e))
    }

    fn do_put(&mut self) {
        let exp = self.fresh_expected();
        let key = format!("k/{:08}", exp.id);
        let data = exp.materialize();
        let len = data.len() as u64;
        let start = Instant::now();
        let res = self.backend.put(
            &self.container,
            &key,
            Object::new(data, Metadata::new(), SimInstant::EPOCH),
        );
        self.record(OpClass::Put, start);
        match res {
            Ok(replaced) => {
                // Key ids are monotone: a fresh key can never replace.
                if replaced {
                    self.violation(format!("put {key}: spurious replace"));
                }
                self.report.bytes_written += len;
                self.live.insert(key, exp);
            }
            Err(e) => self.violation(format!("put {key}: {e}")),
        }
    }

    fn do_get(&mut self) {
        let Some((key, exp)) = self.pick_live() else {
            return self.do_put();
        };
        let start = Instant::now();
        let res = self.backend.get(&self.container, &key);
        self.record(OpClass::Get, start);
        match res {
            Ok(obj) => {
                self.report.bytes_read += obj.size();
                if **obj.data != exp.materialize() {
                    self.violation(format!("get {key}: byte round-trip mismatch"));
                } else if obj.etag != exp.etag() {
                    self.violation(format!("get {key}: etag mismatch"));
                }
            }
            Err(e) => self.violation(format!("get {key}: {e}")),
        }
    }

    fn do_ranged_get(&mut self) {
        let Some((key, exp)) = self.pick_live() else {
            return self.do_put();
        };
        let offset = self.rng.next_below(exp.size as u32) as u64;
        let len = 1 + self.rng.next_below((exp.size as u64 - offset) as u32) as u64;
        let start = Instant::now();
        let res = self.backend.get_range(&self.container, &key, offset, len);
        self.record(OpClass::RangedGet, start);
        match res {
            Ok((bytes, stat)) => {
                self.report.bytes_read += bytes.len() as u64;
                let whole = exp.materialize();
                let want = &whole[offset as usize..(offset + len) as usize];
                if bytes != want {
                    self.violation(format!("get_range {key} [{offset},+{len}): slice mismatch"));
                }
                if stat.size != exp.size as u64 {
                    self.violation(format!(
                        "get_range {key}: stat size {} != {}",
                        stat.size, exp.size
                    ));
                }
            }
            Err(e) => self.violation(format!("get_range {key} [{offset},+{len}): {e}")),
        }
    }

    fn do_list(&mut self) {
        let start = Instant::now();
        let res = self.backend.list_page(&self.container, "k/", None, 50);
        self.record(OpClass::List, start);
        match res {
            Ok(page) => {
                // Single-writer container on a strongly consistent
                // backend: every listed entry must be a key this worker
                // owns, with the exact size and content etag.
                for e in &page.entries {
                    match self.live.get(&e.name).copied() {
                        None => {
                            let name = e.name.clone();
                            self.violation(format!("list: unknown key {name}"));
                        }
                        Some(exp) => {
                            if e.size != exp.size as u64 || e.etag != exp.etag() {
                                let name = e.name.clone();
                                self.violation(format!("list: stale entry for {name}"));
                            }
                        }
                    }
                }
            }
            Err(e) => self.violation(format!("list: {e}")),
        }
    }

    fn do_delete(&mut self) {
        let Some((key, exp)) = self.pick_live() else {
            return self.do_put();
        };
        let start = Instant::now();
        let res = self.backend.delete(&self.container, &key);
        self.record(OpClass::Delete, start);
        match res {
            Ok(stat) => {
                if stat.size != exp.size as u64 {
                    self.violation(format!(
                        "delete {key}: final stat size {} != {}",
                        stat.size, exp.size
                    ));
                }
                self.live.remove(&key);
            }
            Err(e) => self.violation(format!("delete {key}: {e}")),
        }
    }

    /// Full multipart lifecycle: initiate → 2-4 parts → complete →
    /// install the assembled object via the normal put path (what the
    /// store front end does with an `AssembledUpload`), timed as one
    /// sample.
    fn do_multipart(&mut self) {
        let exp = self.fresh_expected();
        let key = format!("mp/{:08}", exp.id);
        let whole = exp.materialize();
        let nparts = 2 + self.rng.next_below(3) as usize;
        let start = Instant::now();
        let id = match self
            .backend
            .initiate_multipart(&self.container, &key, Metadata::new())
        {
            Ok(id) => id,
            Err(e) => {
                self.record(OpClass::Multipart, start);
                return self.violation(format!("initiate {key}: {e}"));
            }
        };
        self.report.upload_ids.push(id);
        let base = (whole.len() / nparts).max(1);
        let mut uploaded = 0u64;
        for (i, chunk) in whole.chunks(base).enumerate() {
            uploaded += chunk.len() as u64;
            if let Err(e) = self.backend.upload_part(id, i as u32 + 1, chunk.to_vec()) {
                self.record(OpClass::Multipart, start);
                return self.violation(format!("upload_part {key}#{}: {e}", i + 1));
            }
        }
        self.report.bytes_written += uploaded;
        match self.backend.complete_multipart(id, 0) {
            Ok(asm) => {
                if asm.data != whole {
                    self.violation(format!("complete {key}: assembled bytes mismatch"));
                }
                if asm.container != self.container || asm.key != key {
                    self.violation(format!(
                        "complete {key}: target {}/{} mismatch",
                        asm.container, asm.key
                    ));
                }
                // Install, as the store front end would.
                let len = asm.data.len() as u64;
                match self.backend.put(
                    &self.container,
                    &key,
                    Object::new(asm.data, Metadata::new(), SimInstant::EPOCH),
                ) {
                    Ok(_) => {
                        self.report.bytes_written += len;
                        self.live.insert(key, exp);
                    }
                    Err(e) => self.violation(format!("install {key}: {e}")),
                }
            }
            Err(e) => self.violation(format!("complete {key}: {e}")),
        }
        self.record(OpClass::Multipart, start);
    }

    /// Deliberate abort: initiate → one part → abort, then verify the id
    /// is dead (a further part upload must be `NoSuchUpload`).
    fn do_abort(&mut self) {
        let exp = self.fresh_expected();
        let key = format!("ab/{:08}", exp.id);
        let start = Instant::now();
        let id = match self
            .backend
            .initiate_multipart(&self.container, &key, Metadata::new())
        {
            Ok(id) => id,
            Err(e) => {
                self.record(OpClass::Abort, start);
                return self.violation(format!("initiate(abort) {key}: {e}"));
            }
        };
        self.report.upload_ids.push(id);
        let chunk = exp.materialize();
        self.report.bytes_written += chunk.len() as u64;
        if let Err(e) = self.backend.upload_part(id, 1, chunk) {
            self.record(OpClass::Abort, start);
            return self.violation(format!("upload_part(abort) {key}: {e}"));
        }
        if let Err(e) = self.backend.abort_multipart(id) {
            self.record(OpClass::Abort, start);
            return self.violation(format!("abort {key}: {e}"));
        }
        self.record(OpClass::Abort, start);
        // The id must be dead now.
        match self.backend.upload_part(id, 2, vec![0u8]) {
            Err(BackendError::NoSuchUpload(got)) if got == id => {}
            Err(e) => self.violation(format!("post-abort part {key}: wrong error {e}")),
            Ok(()) => self.violation(format!("post-abort part {key}: accepted on dead upload")),
        }
    }

    /// Listing completeness at quiesce: a full paginated walk of the
    /// worker's container must equal its live-key set exactly — every
    /// owned key present with the right size and etag, nothing extra.
    fn verify_quiesce(&mut self) {
        let mut seen: BTreeMap<String, (u64, u64)> = BTreeMap::new();
        let mut marker: Option<String> = None;
        loop {
            match self
                .backend
                .list_page(&self.container, "", marker.as_deref(), 100)
            {
                Ok(page) => {
                    for e in page.entries {
                        seen.insert(e.name, (e.size, e.etag));
                    }
                    match page.next {
                        Some(next) => marker = Some(next),
                        None => break,
                    }
                }
                Err(e) => {
                    self.violation(format!("quiesce list: {e}"));
                    return;
                }
            }
        }
        if seen.len() != self.live.len() {
            self.violation(format!(
                "quiesce: listing has {} keys, worker owns {}",
                seen.len(),
                self.live.len()
            ));
        }
        // Collect messages first: `violation` needs `&mut self` while the
        // walks below borrow `self.live`.
        let mut msgs: Vec<String> = Vec::new();
        for (key, exp) in &self.live {
            match seen.get(key) {
                None => msgs.push(format!("quiesce: missing key {key}")),
                Some(&(size, etag)) => {
                    if size != exp.size as u64 || etag != exp.etag() {
                        msgs.push(format!("quiesce: wrong stat for {key}"));
                    }
                }
            }
        }
        for key in seen.keys() {
            if !self.live.contains_key(key) {
                msgs.push(format!("quiesce: phantom key {key}"));
            }
        }
        for m in msgs {
            self.violation(m);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_class_indexing_is_a_bijection() {
        for (i, c) in OpClass::ALL.iter().enumerate() {
            assert_eq!(c.index(), i);
        }
        let names: Vec<&str> = OpClass::ALL.iter().map(|c| c.name()).collect();
        let mut dedup = names.clone();
        dedup.dedup();
        assert_eq!(names.len(), OP_CLASSES);
        assert_eq!(dedup.len(), OP_CLASSES);
    }

    #[test]
    fn expected_materialization_is_deterministic_and_tagged() {
        let e = Expected { size: 100, fill: 0xAB, id: 7 };
        let a = e.materialize();
        let b = e.materialize();
        assert_eq!(a, b);
        assert_eq!(a.len(), 100);
        assert_eq!(&a[..8], &7u64.to_le_bytes());
        assert!(a[8..].iter().all(|&x| x == 0xAB));
        assert_eq!(e.etag(), sampled_etag(&a));
        // Tiny objects truncate the id header instead of panicking.
        let tiny = Expected { size: 3, fill: 0, id: u64::MAX };
        assert_eq!(tiny.materialize(), vec![0xFF, 0xFF, 0xFF]);
    }
}
