//! Real-concurrency load plane: N OS threads hammering one gateway.
//!
//! Everything else in the repo measures the simulator under a *virtual*
//! clock; this module is the measured-wall-clock counterpart the paper's
//! evaluation actually ran: real threads, real sockets, real latency.
//! `stocator-sim stress` spawns `--clients` workers, each owning its own
//! [`crate::gateway::HttpBackend`] against a served store — an
//! in-process [`GatewayServer`] over a [`ShardedMemBackend`] by default,
//! or any `--target HOST:PORT` (e.g. a `stocator-sim serve` in another
//! process) — and drives the seeded mixed workload of
//! [`workload::run_worker`]: PUT / GET / ranged GET / list / delete plus
//! full multipart lifecycles and deliberate aborts, drawn from
//! per-thread PCG32 streams derived from `--seed`.
//!
//! Design rules, in order:
//!
//! 1. **Measurement must not serialize the workers.** Every worker
//!    records into a private [`crate::metrics::Histogram`] per op class;
//!    the harness merges after join ([`report::aggregate`]). No shared
//!    recorder, no lock on the hot path.
//! 2. **Correctness is checked while the hammer swings**, not after:
//!    byte/ETag round-trips, multipart-id uniqueness across ALL threads,
//!    and exact listing completeness at quiesce. A run that goes fast by
//!    being wrong reports `violations > 0` and exits non-zero.
//! 3. **Reproducibility**: with a fixed op budget the executed op mix is
//!    a pure function of `(seed, worker id)`.
//!
//! Readiness is polled on the gateway's `/healthz` ([`wait_healthy`]) —
//! never a sleep. Every run serializes to `BENCH_10.json`
//! ([`report::StressReport`]), continuing the `BENCH_<n>.json`
//! perf-trajectory convention: one measured-performance artifact per PR,
//! diffable across the repo's history. With `--scrape` the run also
//! reads the gateway's *own* ledger: a background thread polls
//! `/metricz` while the hammer swings, and once the workers join (the
//! gateway still up) a final scrape pulls the server-side executed-op
//! counters and serve-latency quantiles, plus the `/tracez` ring —
//! embedded in the BENCH JSON next to the client-side percentiles.
//! Chaos-free, the server-side op counts must equal the client side
//! exactly ([`ScrapeSummary::op_gap`] `== 0`), which CI gates. Two knobs exercise the reactor
//! core specifically: `--open-conns N` holds N idle keep-alive
//! connections across the whole main hammer (the thread-per-connection
//! core would need N parked threads; the reactor holds them in one), and
//! in-process runs with `--matrix` append a reactor-vs-threaded
//! [`CoreRow`] comparison at identical op budgets.
//!
//! The robustness knobs: `--chaos kill-response@p=P,...` arms the wire
//! chaos plane on the in-process gateway for the **main hammer only**
//! (the matrix and core-comparison sweeps always run clean gateways, so
//! their throughput numbers stay comparable across PRs), and
//! `--backend fs:DIR` puts the in-process gateway over a real
//! [`LocalFsBackend`] instead of memory — chaos recovery exercised
//! against durable on-disk state. The headline acceptance run is
//! `violations: 0` under chaos with nonzero `retried_sends` and
//! `replayed_responses`.

pub mod report;
pub mod workload;

pub use report::{
    aggregate, CoreRow, MatrixCell, ScrapeSummary, ServerLatencyRow, StressReport, StressRun,
    BENCH_FILE,
};
pub use workload::{run_worker, OpClass, WorkerConfig, WorkerReport, OP_CLASSES};

use crate::gateway::http::{read_response, write_request, Headers};
use crate::gateway::{
    unique_namespace, ChaosConfig, GatewayConfig, GatewayHandle, GatewayMode, GatewayServer,
};
use crate::metrics::OpKind;
use crate::objectstore::backend::{unique_subroot, Backend, LocalFsBackend, ShardedMemBackend};
use std::io::BufReader;
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

/// How long [`run_stress`] waits for a gateway to answer `/healthz`.
const HEALTHY_TIMEOUT: Duration = Duration::from_secs(5);

/// Fixed per-client op budget for matrix sweep cells: small enough that
/// a full sweep stays interactive, large enough to exercise every op
/// class.
const MATRIX_OPS_PER_CLIENT: u64 = 64;

/// Everything `stocator-sim stress` configures.
#[derive(Debug, Clone)]
pub struct StressConfig {
    /// Number of worker threads (each with its own connection pool).
    pub clients: usize,
    /// Shard count for the in-process backend (ignored with `target`).
    pub shards: usize,
    /// External gateway `HOST:PORT`; `None` = spawn in-process.
    pub target: Option<String>,
    /// Maximum payload size in bytes.
    pub payload: usize,
    pub seed: u64,
    /// Wall-clock budget per worker (duration mode).
    pub duration: Option<Duration>,
    /// Fixed op budget per worker (deterministic mode; wins over
    /// `duration`).
    pub ops_per_client: Option<u64>,
    /// Run the clients × shards × payload sweep after the main hammer.
    /// For in-process runs this also runs the reactor-vs-threaded core
    /// comparison (the same fixed op budget at each server core).
    pub matrix: bool,
    /// Where to write the BENCH JSON; `None` = don't write.
    pub bench_path: Option<PathBuf>,
    /// Idle keep-alive connections to establish (one `/healthz`
    /// round-trip each, then held open) for the whole main hammer —
    /// `--open-conns`, the 10k-connection acceptance knob.
    pub open_conns: usize,
    /// Bearer token forwarded to every worker (`--token`), for gateways
    /// running with auth enabled.
    pub token: Option<String>,
    /// Which server core in-process gateways run (`--core`). External
    /// `--target` gateways chose their own at `serve` time.
    pub core: GatewayMode,
    /// Wire chaos armed on the in-process gateway for the main hammer
    /// (`--chaos`). Matrix/core sweeps always run clean gateways.
    /// Incompatible with `target` — chaos is injected server-side.
    pub chaos: ChaosConfig,
    /// `--backend fs:DIR`: run the in-process gateway over a
    /// [`LocalFsBackend`] in a fresh subdirectory of this root instead
    /// of sharded memory. `shards` is ignored when set.
    pub fs_root: Option<PathBuf>,
    /// `--scrape`: poll the gateway's `/metricz` during the main
    /// hammer and embed the server-side executed-op counters,
    /// serve-latency quantiles, and `/tracez` ring summary in the
    /// BENCH JSON ([`ScrapeSummary`]).
    pub scrape: bool,
}

impl Default for StressConfig {
    fn default() -> Self {
        Self {
            clients: 8,
            shards: 16,
            target: None,
            payload: 16 * 1024,
            seed: 7,
            duration: Some(Duration::from_secs(2)),
            ops_per_client: None,
            matrix: true,
            bench_path: Some(PathBuf::from(BENCH_FILE)),
            open_conns: 0,
            token: None,
            // The stress plane dogfoods the scalable core by default.
            core: GatewayMode::Reactor,
            chaos: ChaosConfig::default(),
            fs_root: None,
            scrape: false,
        }
    }
}

/// One `GET /healthz` probe; true iff the gateway answered 200.
fn probe_healthz(addr: &str) -> bool {
    let Ok(stream) = TcpStream::connect(addr) else {
        return false;
    };
    let Ok(mut write_half) = stream.try_clone() else {
        return false;
    };
    if write_request(&mut write_half, "GET", "/healthz", &Headers::new(), b"").is_err() {
        return false;
    }
    let mut reader = BufReader::new(stream);
    matches!(read_response(&mut reader), Ok(resp) if resp.status == 200)
}

/// Poll `/healthz` until the gateway answers 200 or `timeout` passes —
/// readiness without a blind sleep.
pub fn wait_healthy(addr: &str, timeout: Duration) -> Result<(), String> {
    let deadline = Instant::now() + timeout;
    loop {
        if probe_healthz(addr) {
            return Ok(());
        }
        if Instant::now() >= deadline {
            return Err(format!(
                "gateway at {addr} did not answer /healthz within {timeout:?}"
            ));
        }
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Spawn an in-process gateway running the given server core, over a
/// fresh sharded in-memory store — or, when `fs_root` is set, over a
/// [`LocalFsBackend`] in a fresh unique subdirectory of that root (so
/// repeated gateways never share multipart-id or container state).
/// `chaos` arms the wire chaos plane; pass `ChaosConfig::default()` for
/// a clean gateway.
fn serve_in_process(
    shards: usize,
    core: GatewayMode,
    fs_root: Option<&Path>,
    chaos: ChaosConfig,
) -> Result<(String, GatewayHandle), String> {
    let backend: Arc<dyn Backend> = match fs_root {
        Some(root) => {
            let sub = unique_subroot(root);
            Arc::new(
                LocalFsBackend::open(&sub)
                    .map_err(|e| format!("open fs backend at {}: {e}", sub.display()))?,
            )
        }
        None => Arc::new(ShardedMemBackend::new(shards)),
    };
    let config = GatewayConfig { mode: core, chaos, ..GatewayConfig::default() };
    let server = GatewayServer::bind_with("127.0.0.1:0", backend, config)
        .map_err(|e| format!("bind gateway: {e}"))?;
    let handle = server.spawn();
    Ok((handle.addr().to_string(), handle))
}

/// Establish `n` idle keep-alive connections: one `/healthz` round-trip
/// each (proving the server registered the connection), then hold the
/// socket open. Returns the held sockets — alive until dropped — plus
/// the count actually established; a connect/probe failure (e.g. the
/// gateway shedding at its connection cap) costs a hold, not an error.
fn open_idle_conns(addr: &str, n: usize) -> (Vec<TcpStream>, u64) {
    let mut held = Vec::with_capacity(n);
    for _ in 0..n {
        let Ok(stream) = TcpStream::connect(addr) else { continue };
        let Ok(mut write_half) = stream.try_clone() else { continue };
        if write_request(&mut write_half, "GET", "/healthz", &Headers::new(), b"").is_err() {
            continue;
        }
        let mut reader = BufReader::new(stream);
        match read_response(&mut reader) {
            Ok(resp) if resp.status == 200 => held.push(reader.into_inner()),
            _ => {}
        }
    }
    let count = held.len() as u64;
    (held, count)
}

/// One raw `GET {path}` against the gateway; `Some(body)` iff it
/// answered 200.
fn fetch_text(addr: &str, path: &str) -> Option<String> {
    let stream = TcpStream::connect(addr).ok()?;
    let mut write_half = stream.try_clone().ok()?;
    write_request(&mut write_half, "GET", path, &Headers::new(), b"").ok()?;
    let mut reader = BufReader::new(stream);
    let resp = read_response(&mut reader).ok()?;
    if resp.status != 200 {
        return None;
    }
    String::from_utf8(resp.body).ok()
}

/// Read the per-kind executed-op counters off a `/metricz` scrape
/// (`store_ops{op="NAME"} N` lines, `OpKind::ALL` indexing).
fn parse_store_ops(scrape: &str) -> [u64; 7] {
    let mut ops = [0u64; 7];
    for line in scrape.lines() {
        let Some(rest) = line.strip_prefix("store_ops{op=\"") else { continue };
        let Some((name, value)) = rest.split_once("\"} ") else { continue };
        if let (Some(kind), Ok(n)) = (
            OpKind::ALL.iter().find(|k| k.name() == name),
            value.trim().parse::<u64>(),
        ) {
            ops[kind.index()] = n;
        }
    }
    ops
}

/// Read the server-side serve-latency quantile gauges
/// (`gateway_serve_latency_us{op="NAME",q="Q"} V`) into per-op rows.
fn parse_server_latency(scrape: &str) -> Vec<ServerLatencyRow> {
    let mut rows: Vec<ServerLatencyRow> = Vec::new();
    for line in scrape.lines() {
        let Some(rest) = line.strip_prefix("gateway_serve_latency_us{op=\"") else { continue };
        let Some((name, rest)) = rest.split_once("\",q=\"") else { continue };
        let Some((q, value)) = rest.split_once("\"} ") else { continue };
        let Ok(v) = value.trim().parse::<f64>() else { continue };
        let row = match rows.iter_mut().find(|r| r.op == name) {
            Some(r) => r,
            None => {
                rows.push(ServerLatencyRow { op: name.to_string(), ..Default::default() });
                rows.last_mut().expect("just pushed")
            }
        };
        match q {
            "p50" => row.p50_us = v,
            "p95" => row.p95_us = v,
            "p99" => row.p99_us = v,
            "mean" => row.mean_us = v,
            "max" => row.max_us = v,
            _ => {}
        }
    }
    rows
}

/// Value of an exposition line whose metric name (before the space)
/// equals `name` exactly; 0 when absent.
fn parse_counter(scrape: &str, name: &str) -> u64 {
    scrape
        .lines()
        .find_map(|l| {
            let (n, v) = l.split_once(' ')?;
            (n == name).then(|| v.trim().parse().ok())?
        })
        .unwrap_or(0)
}

/// The `--scrape` plane: a background thread polling `/metricz` while
/// the hammer swings (proving scrapes are serveable *under* load),
/// then a final authoritative scrape once the workers have joined —
/// the gateway still up, so the counters are complete and quiescent.
struct Scraper {
    addr: String,
    stop: Arc<AtomicBool>,
    polls: Arc<AtomicU64>,
    thread: std::thread::JoinHandle<()>,
}

fn start_scraper(addr: &str) -> Scraper {
    let stop = Arc::new(AtomicBool::new(false));
    let polls = Arc::new(AtomicU64::new(0));
    let thread = {
        let addr = addr.to_string();
        let stop = stop.clone();
        let polls = polls.clone();
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                if fetch_text(&addr, "/metricz").is_some() {
                    polls.fetch_add(1, Ordering::Relaxed);
                }
                std::thread::sleep(Duration::from_millis(100));
            }
        })
    };
    Scraper { addr: addr.to_string(), stop, polls, thread }
}

impl Scraper {
    /// Stop polling, take the final scrape, and fold in the client-side
    /// wire ops. Fetches retry a bounded number of times: under
    /// `--chaos` the scrape response itself can be torn by the wire
    /// fault plane.
    fn finish(self, client_ops: [u64; 7]) -> Result<ScrapeSummary, String> {
        self.stop.store(true, Ordering::Relaxed);
        let _ = self.thread.join();
        let fetch = |path: &str| {
            (0..32)
                .find_map(|_| fetch_text(&self.addr, path))
                .ok_or_else(|| format!("scrape: GET {path} at {} never answered", self.addr))
        };
        let metricz = fetch("/metricz")?;
        let tracez = fetch("/tracez")?;
        Ok(ScrapeSummary {
            server_ops: parse_store_ops(&metricz),
            client_ops,
            server_latency: parse_server_latency(&metricz),
            tracez_entries: tracez.matches("\"seq\":").count() as u64,
            tracez_pushed: parse_counter(&metricz, "tracez_pushed"),
            polls: self.polls.load(Ordering::Relaxed),
        })
    }
}

/// One hammer run: `clients` workers against the gateway at `addr`,
/// started together behind a [`Barrier`] so the throughput clock only
/// measures concurrent execution. Returns the merged, verified run.
fn hammer(
    addr: &str,
    clients: usize,
    shards: Option<usize>,
    payload: usize,
    seed: u64,
    ops: Option<u64>,
    duration: Option<Duration>,
    token: Option<&str>,
) -> StressRun {
    // One namespace per run: repeated runs (and sweep cells) against a
    // long-lived served store never collide on container creation.
    let ns = Some(unique_namespace());
    let barrier = Arc::new(Barrier::new(clients + 1));
    let handles: Vec<_> = (0..clients)
        .map(|id| {
            let barrier = barrier.clone();
            let addr = addr.to_string();
            let ns = ns.clone();
            let token = token.map(str::to_string);
            std::thread::spawn(move || {
                barrier.wait();
                // Duration mode starts each worker's clock at the
                // barrier, not at spawn.
                let deadline = duration.map(|d| Instant::now() + d);
                run_worker(WorkerConfig {
                    id,
                    addr,
                    ns,
                    seed,
                    payload,
                    ops,
                    deadline,
                    token,
                })
            })
        })
        .collect();
    barrier.wait();
    let t0 = Instant::now();
    let reports: Vec<WorkerReport> = handles
        .into_iter()
        .enumerate()
        .map(|(id, h)| {
            h.join().unwrap_or_else(|_| {
                let mut r = WorkerReport::new();
                r.violations = vec![format!("worker {id}: panicked")];
                r.violation_count = 1;
                r
            })
        })
        .collect();
    let elapsed = t0.elapsed().as_secs_f64();
    aggregate(reports, clients, shards, payload, seed, elapsed)
}

/// Deduplicated, ascending sweep axis.
fn axis(values: Vec<usize>) -> Vec<usize> {
    let mut v: Vec<usize> = values.into_iter().filter(|&x| x > 0).collect();
    v.sort_unstable();
    v.dedup();
    v
}

/// The clients × shards × payload sweep. Each shard count gets one fresh
/// in-process gateway reused across its clients × payload cells (each
/// cell runs in its own namespace); an external target contributes a
/// single `shards = as-served` plane. Cells run a fixed op budget so the
/// matrix is comparable across machines.
fn sweep_matrix(cfg: &StressConfig) -> Result<Vec<MatrixCell>, String> {
    let clients_axis = axis(vec![1, cfg.clients / 2, cfg.clients]);
    let payload_axis = axis(vec![
        (cfg.payload / 16).max(64),
        (cfg.payload / 4).max(64),
        cfg.payload,
    ]);
    let shard_axis: Vec<Option<usize>> = match (&cfg.target, &cfg.fs_root) {
        (Some(_), _) => vec![None],
        // An fs-backed store has no shard knob to vary: one plane.
        (None, Some(_)) => vec![Some(cfg.shards)],
        (None, None) => axis(vec![1, 4, cfg.shards]).into_iter().map(Some).collect(),
    };
    let mut cells = Vec::new();
    let mut cell_idx = 0u64;
    for &shards in &shard_axis {
        let (addr, handle) = match (cfg.target.as_deref(), shards) {
            (Some(t), _) => (t.to_string(), None),
            (None, Some(n)) => {
                // Sweep gateways run clean (no chaos): the matrix is a
                // throughput artifact, comparable across PRs.
                let (a, h) = serve_in_process(
                    n,
                    cfg.core,
                    cfg.fs_root.as_deref(),
                    ChaosConfig::default(),
                )?;
                (a, Some(h))
            }
            (None, None) => unreachable!("in-process shard axis is always Some"),
        };
        wait_healthy(&addr, HEALTHY_TIMEOUT)?;
        for &clients in &clients_axis {
            for &payload in &payload_axis {
                cell_idx += 1;
                // Distinct seed per cell; still derived from --seed.
                let seed = cfg.seed.wrapping_add(cell_idx.wrapping_mul(0x9E37_79B9));
                let run = hammer(
                    &addr,
                    clients,
                    shards,
                    payload,
                    seed,
                    Some(MATRIX_OPS_PER_CLIENT),
                    None,
                    cfg.token.as_deref(),
                );
                cells.push(MatrixCell::of(&run));
            }
        }
        if let Some(h) = handle {
            h.shutdown();
        }
    }
    Ok(cells)
}

/// Head-to-head server-core comparison: the exact same fixed-budget
/// hammer against a fresh in-process gateway per [`GatewayMode`], so the
/// reactor's one-thread event loop and the legacy thread-per-connection
/// core answer for the same ops on the same machine. Only meaningful for
/// in-process runs — an external `--target` already chose its core.
fn core_comparison(cfg: &StressConfig) -> Result<Vec<CoreRow>, String> {
    let mut rows = Vec::new();
    for mode in [GatewayMode::Reactor, GatewayMode::Threaded] {
        let (addr, handle) = serve_in_process(
            cfg.shards,
            mode,
            cfg.fs_root.as_deref(),
            ChaosConfig::default(),
        )?;
        wait_healthy(&addr, HEALTHY_TIMEOUT)?;
        let run = hammer(
            &addr,
            cfg.clients,
            Some(cfg.shards),
            cfg.payload,
            cfg.seed,
            Some(2 * MATRIX_OPS_PER_CLIENT),
            None,
            cfg.token.as_deref(),
        );
        handle.shutdown();
        rows.push(CoreRow::of(mode.name(), &run));
    }
    Ok(rows)
}

/// Run the whole stress deliverable: the main hammer (with `open_conns`
/// idle connections held for its full span), the optional matrix sweep
/// and core comparison, and the BENCH JSON. Errors are infrastructure
/// failures (bind, readiness, file write); correctness *violations* come
/// back in the report for the caller to surface and turn into an exit
/// code.
pub fn run_stress(cfg: &StressConfig) -> Result<StressReport, String> {
    let ops = cfg.ops_per_client;
    // Op budget wins; otherwise duration, defaulting to 2s.
    let duration = if ops.is_some() {
        None
    } else {
        Some(cfg.duration.unwrap_or(Duration::from_secs(2)))
    };
    let (run, target_desc, open_conns_held, scrape) = match cfg.target.as_deref() {
        Some(addr) => {
            if cfg.chaos.is_active() {
                return Err(
                    "--chaos requires an in-process gateway; an external --target \
                     injects its own faults at serve time"
                        .to_string(),
                );
            }
            wait_healthy(addr, HEALTHY_TIMEOUT)?;
            let (held, held_n) = open_idle_conns(addr, cfg.open_conns);
            let scraper = cfg.scrape.then(|| start_scraper(addr));
            let run = hammer(
                addr,
                cfg.clients,
                None,
                cfg.payload,
                cfg.seed,
                ops,
                duration,
                cfg.token.as_deref(),
            );
            drop(held);
            let scrape = match scraper {
                Some(s) => Some(s.finish(run.wire_ops)?),
                None => None,
            };
            (run, addr.to_string(), held_n, scrape)
        }
        None => {
            // The main hammer is the only gateway that gets chaos.
            let (addr, handle) =
                serve_in_process(cfg.shards, cfg.core, cfg.fs_root.as_deref(), cfg.chaos)?;
            wait_healthy(&addr, HEALTHY_TIMEOUT)?;
            let (held, held_n) = open_idle_conns(&addr, cfg.open_conns);
            let scraper = cfg.scrape.then(|| start_scraper(&addr));
            let run = hammer(
                &addr,
                cfg.clients,
                Some(cfg.shards),
                cfg.payload,
                cfg.seed,
                ops,
                duration,
                cfg.token.as_deref(),
            );
            drop(held);
            // The final scrape must land before the gateway drains —
            // its counters die with the process.
            let scrape = match scraper {
                Some(s) => Some(s.finish(run.wire_ops)?),
                None => None,
            };
            handle.shutdown();
            let desc = match &cfg.fs_root {
                Some(root) => format!("in-process fs:{}", root.display()),
                None => "in-process".to_string(),
            };
            (run, desc, held_n, scrape)
        }
    };
    let matrix = if cfg.matrix {
        sweep_matrix(cfg)?
    } else {
        Vec::new()
    };
    let cores = if cfg.matrix && cfg.target.is_none() {
        core_comparison(cfg)?
    } else {
        Vec::new()
    };
    let report = StressReport {
        target: target_desc,
        run,
        matrix,
        cores,
        open_conns: cfg.open_conns as u64,
        open_conns_held,
        scrape,
    };
    if let Some(path) = &cfg.bench_path {
        report
            .to_json()
            .write_file(path)
            .map_err(|e| format!("write {}: {e}", path.display()))?;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axis_dedups_sorts_and_drops_zero() {
        assert_eq!(axis(vec![4, 1, 4, 0]), vec![1, 4]);
        assert_eq!(axis(vec![8, 8, 8]), vec![8]);
    }

    #[test]
    fn wait_healthy_succeeds_on_live_gateway_and_fails_fast_on_dead() {
        let (addr, handle) =
            serve_in_process(2, GatewayMode::Reactor, None, ChaosConfig::default()).unwrap();
        wait_healthy(&addr, Duration::from_secs(5)).expect("live gateway is healthy");
        handle.shutdown();
        // A port nothing listens on: bind-then-drop to find one.
        let dead = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        assert!(wait_healthy(&dead, Duration::from_millis(80)).is_err());
    }

    #[test]
    fn minimal_stress_run_is_clean() {
        let cfg = StressConfig {
            clients: 2,
            shards: 2,
            payload: 512,
            ops_per_client: Some(12),
            matrix: false,
            bench_path: None,
            ..StressConfig::default()
        };
        let report = run_stress(&cfg).expect("stress run");
        assert_eq!(report.run.violation_count, 0, "{:?}", report.run.violations);
        assert_eq!(report.run.total_ops, 24);
        assert_eq!(report.target, "in-process");
        assert!(report.matrix.is_empty());
    }

    #[test]
    fn scrape_parsers_read_the_exposition_format() {
        let scrape = "\
# TYPE store_ops counter
store_ops{op=\"PUT Object\"} 12
store_ops{op=\"GET Object\"} 7
store_ops{op=\"HEAD Container\"} 2
# TYPE gateway_serve_latency_us gauge
gateway_serve_latency_us{op=\"PUT Object\",q=\"p50\"} 41.5
gateway_serve_latency_us{op=\"PUT Object\",q=\"p99\"} 90
gateway_serve_latency_us{op=\"GET Object\",q=\"max\"} 12.25
tracez_pushed 21
tracez_dropped 0
";
        let ops = parse_store_ops(scrape);
        assert_eq!(ops[crate::metrics::OpKind::PutObject.index()], 12);
        assert_eq!(ops[crate::metrics::OpKind::GetObject.index()], 7);
        assert_eq!(ops[crate::metrics::OpKind::HeadContainer.index()], 2);
        assert_eq!(ops.iter().sum::<u64>(), 21);
        let rows = parse_server_latency(scrape);
        assert_eq!(rows.len(), 2);
        let put = rows.iter().find(|r| r.op == "PUT Object").unwrap();
        assert_eq!(put.p50_us, 41.5);
        assert_eq!(put.p99_us, 90.0);
        let get = rows.iter().find(|r| r.op == "GET Object").unwrap();
        assert_eq!(get.max_us, 12.25);
        assert_eq!(parse_counter(scrape, "tracez_pushed"), 21);
        assert_eq!(parse_counter(scrape, "tracez_dropped"), 0);
        assert_eq!(parse_counter(scrape, "no_such_counter"), 0);
    }

    #[test]
    fn scrape_embeds_matching_server_side_truth() {
        let cfg = StressConfig {
            clients: 2,
            shards: 2,
            payload: 512,
            ops_per_client: Some(16),
            matrix: false,
            bench_path: None,
            scrape: true,
            ..StressConfig::default()
        };
        let report = run_stress(&cfg).expect("stress run with scrape");
        assert_eq!(report.run.violation_count, 0, "{:?}", report.run.violations);
        let s = report.scrape.expect("scrape summary present");
        // The headline invariant: on a chaos-free run, the ops the
        // gateway executed are exactly the ops the clients completed.
        assert_eq!(s.server_ops, s.client_ops, "server/client op drift");
        assert_eq!(s.op_gap(), 0);
        assert!(s.server_ops.iter().sum::<u64>() > 0, "no ops recorded at all");
        assert!(s.tracez_entries > 0, "trace ring stayed empty");
        assert!(s.tracez_pushed >= s.tracez_entries);
        assert!(!s.server_latency.is_empty(), "no serve-latency gauges parsed");
    }

    #[test]
    fn chaos_against_an_external_target_is_rejected() {
        let cfg = StressConfig {
            target: Some("127.0.0.1:1".into()),
            chaos: ChaosConfig::parse("reset@p=0.5").unwrap(),
            matrix: false,
            bench_path: None,
            ..StressConfig::default()
        };
        let err = run_stress(&cfg).expect_err("chaos + --target must refuse");
        assert!(err.contains("in-process"), "{err}");
    }
}
