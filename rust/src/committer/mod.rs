//! Hadoop's `FileOutputCommitter` (paper §2.2.2) and the Databricks
//! `DirectOutputCommitter` baseline.
//!
//! The committer drives the temporary-file/rename commit protocol through
//! the [`crate::fs::FileSystem`] interface. Version 1 renames twice (task
//! commit: attempt dir → job-temp dir, executed by executors in parallel;
//! job commit: job-temp → final, executed **serially by the driver**).
//! Version 2 renames once, at task commit. The direct committer does not
//! rename at all — and is unsafe under speculation, which the tests
//! demonstrate.
//!
//! When the underlying connector is Stocator, every rename/list below is
//! intercepted and becomes free — the committer code is *identical*, which
//! is exactly the paper's deployment story (no Spark/Hadoop changes).

pub mod protocol;

pub use protocol::{CommitAlgorithm, Committer, JobContext, TaskAttemptContext};
