//! The commit protocol implementation (Hadoop 2.7.3 semantics).

use crate::connectors::naming::AttemptId;
use crate::fs::{FileSystem, FsError, FsOutputStream, OpCtx, Path};

/// Which commit algorithm a scenario runs (paper §4.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommitAlgorithm {
    /// `mapreduce.fileoutputcommitter.algorithm.version=1` (the 2.7.3
    /// default): task commit renames to a job-temporary dir; job commit
    /// renames everything to final names, serially, in the driver.
    V1,
    /// version=2: task commit renames directly to final names (parallel,
    /// in the executors); job commit only writes `_SUCCESS`.
    V2,
    /// The Databricks DirectOutputCommitter: tasks write final names
    /// directly. No fault-tolerance story — kept as a baseline.
    Direct,
}

impl CommitAlgorithm {
    pub fn name(self) -> &'static str {
        match self {
            CommitAlgorithm::V1 => "FileOutputCommitter v1",
            CommitAlgorithm::V2 => "FileOutputCommitter v2",
            CommitAlgorithm::Direct => "DirectOutputCommitter",
        }
    }
}

/// Job-scoped context: the output dataset path and the application attempt
/// (always 0 in our runs, as in the paper's traces).
#[derive(Debug, Clone)]
pub struct JobContext {
    pub output: Path,
    pub app_attempt: u32,
}

impl JobContext {
    pub fn new(output: Path) -> Self {
        Self {
            output,
            app_attempt: 0,
        }
    }

    /// `<out>/_temporary/<app>`
    pub fn temp_root(&self) -> Path {
        self.output
            .child(&format!("_temporary/{}", self.app_attempt))
    }

    pub fn success_path(&self) -> Path {
        self.output.child("_SUCCESS")
    }
}

/// Task-attempt-scoped context.
#[derive(Debug, Clone)]
pub struct TaskAttemptContext {
    pub job: JobContext,
    pub attempt: AttemptId,
}

impl TaskAttemptContext {
    pub fn new(job: &JobContext, attempt: AttemptId) -> Self {
        Self {
            job: job.clone(),
            attempt,
        }
    }

    /// `<out>/_temporary/<app>/_temporary/attempt_...` — where the task's
    /// output stream nominally writes.
    pub fn attempt_dir(&self) -> Path {
        self.job
            .temp_root()
            .child(&format!("_temporary/{}", self.attempt))
    }

    /// `<out>/_temporary/<app>/task_...` — v1 task-commit target.
    pub fn committed_task_dir(&self) -> Path {
        self.job.temp_root().child(&self.attempt.task_string())
    }

    /// Where this attempt writes a part file named `basename`.
    pub fn work_path(&self, algorithm: CommitAlgorithm, basename: &str) -> Path {
        match algorithm {
            CommitAlgorithm::Direct => self.job.output.child(basename),
            _ => self.attempt_dir().child(basename),
        }
    }
}

/// The committer. Stateless; all state lives in the filesystem, as in
/// Hadoop (paper §2.2.2: "Hadoop is highly distributed and thus it keeps
/// its state in its storage system").
#[derive(Debug, Clone, Copy)]
pub struct Committer {
    pub algorithm: CommitAlgorithm,
}

impl Committer {
    pub fn new(algorithm: CommitAlgorithm) -> Self {
        Self { algorithm }
    }

    /// Driver: create the output and temporary directory structure
    /// (Table 1, step 1).
    pub fn setup_job(&self, fs: &dyn FileSystem, job: &JobContext, ctx: &mut OpCtx) -> Result<(), FsError> {
        match self.algorithm {
            CommitAlgorithm::Direct => fs.mkdirs(&job.output, ctx),
            _ => fs.mkdirs(&job.temp_root(), ctx),
        }
    }

    /// Executor: create the attempt's working directory (Table 1, step 2).
    pub fn setup_task(
        &self,
        fs: &dyn FileSystem,
        task: &TaskAttemptContext,
        ctx: &mut OpCtx,
    ) -> Result<(), FsError> {
        match self.algorithm {
            CommitAlgorithm::Direct => Ok(()),
            _ => fs.mkdirs(&task.attempt_dir(), ctx),
        }
    }

    /// Executor: open this attempt's output stream for a part file. The
    /// task streams bytes through the connector's write path as it
    /// produces them; dropping the stream without `close` is the
    /// executor-crash abort path.
    pub fn create_part<'a>(
        &self,
        fs: &'a dyn FileSystem,
        task: &TaskAttemptContext,
        basename: &str,
        ctx: &mut OpCtx,
    ) -> Result<Box<dyn FsOutputStream + 'a>, FsError> {
        let path = task.work_path(self.algorithm, basename);
        fs.create(&path, true, ctx)
    }

    /// Executor: write one whole part file for this attempt (convenience
    /// over [`Committer::create_part`]; identical accounting).
    pub fn write_part(
        &self,
        fs: &dyn FileSystem,
        task: &TaskAttemptContext,
        basename: &str,
        data: Vec<u8>,
        ctx: &mut OpCtx,
    ) -> Result<(), FsError> {
        let mut out = self.create_part(fs, task, basename, ctx)?;
        out.write_owned(data, ctx)?;
        out.close(ctx)
    }

    /// Executor: does this attempt have output to commit?
    pub fn needs_task_commit(
        &self,
        fs: &dyn FileSystem,
        task: &TaskAttemptContext,
        ctx: &mut OpCtx,
    ) -> bool {
        match self.algorithm {
            CommitAlgorithm::Direct => false,
            _ => fs.exists(&task.attempt_dir(), ctx),
        }
    }

    /// Executor: task commit (Table 1, steps 4-5).
    pub fn commit_task(
        &self,
        fs: &dyn FileSystem,
        task: &TaskAttemptContext,
        ctx: &mut OpCtx,
    ) -> Result<(), FsError> {
        match self.algorithm {
            CommitAlgorithm::Direct => Ok(()),
            CommitAlgorithm::V1 => {
                // Rename the whole attempt dir to the job-temporary task
                // dir (after clobbering any earlier committed attempt).
                let dst = task.committed_task_dir();
                if fs.exists(&dst, ctx) {
                    fs.delete(&dst, true, ctx)?;
                }
                fs.rename(&task.attempt_dir(), &dst, ctx)?;
                Ok(())
            }
            CommitAlgorithm::V2 => {
                // Merge the attempt dir straight into the output dir.
                self.merge_paths(fs, &task.attempt_dir(), &task.job.output, ctx)
            }
        }
    }

    /// Executor-side cleanup after a FAILED attempt — how a task-body
    /// error maps onto the commit protocol. Crash-class failures mean
    /// the executor died mid-write: nobody is left to clean up, the
    /// attempt's debris stays, and the read-side strategies must
    /// tolerate it (paper §3.2). An exhausted transient budget
    /// ([`FsError::TransientExhausted`]) leaves the executor *alive*, so
    /// — like real Spark calling `abortTask` after a task failure — the
    /// attempt is aborted properly before the driver schedules the
    /// re-attempt. Returns whether an abort ran.
    pub fn cleanup_failed_attempt(
        &self,
        fs: &dyn FileSystem,
        task: &TaskAttemptContext,
        err: &FsError,
        ctx: &mut OpCtx,
    ) -> bool {
        match err {
            FsError::TransientExhausted(_) => {
                let _ = self.abort_task(fs, task, ctx);
                true
            }
            _ => false,
        }
    }

    /// Executor: abort an attempt — delete its working directory.
    pub fn abort_task(
        &self,
        fs: &dyn FileSystem,
        task: &TaskAttemptContext,
        ctx: &mut OpCtx,
    ) -> Result<(), FsError> {
        match self.algorithm {
            CommitAlgorithm::Direct => Ok(()), // nothing to clean: the damage is done
            _ => {
                fs.delete(&task.attempt_dir(), true, ctx)?;
                Ok(())
            }
        }
    }

    /// Driver: job commit (Table 1, steps 6-8).
    pub fn commit_job(&self, fs: &dyn FileSystem, job: &JobContext, ctx: &mut OpCtx) -> Result<(), FsError> {
        match self.algorithm {
            CommitAlgorithm::V1 => {
                // List the job-temporary dirs and merge each into the
                // output — serially, in the driver. THE bottleneck the
                // paper measures.
                let temp = job.temp_root();
                if let Ok(children) = fs.list_status(&temp, ctx) {
                    for child in children {
                        if child.is_dir && child.path.name().starts_with("task_") {
                            self.merge_paths(fs, &child.path, &job.output, ctx)?;
                        }
                    }
                }
                self.cleanup(fs, job, ctx)?;
                self.write_success(fs, job, ctx)
            }
            CommitAlgorithm::V2 => {
                self.cleanup(fs, job, ctx)?;
                self.write_success(fs, job, ctx)
            }
            CommitAlgorithm::Direct => self.write_success(fs, job, ctx),
        }
    }

    /// Driver: stream the zero-byte `_SUCCESS` object (a connector may
    /// substitute its own body — Stocator writes the manifest here).
    fn write_success(
        &self,
        fs: &dyn FileSystem,
        job: &JobContext,
        ctx: &mut OpCtx,
    ) -> Result<(), FsError> {
        let mut out = fs.create(&job.success_path(), true, ctx)?;
        out.close(ctx)
    }

    /// Driver: abort the whole job.
    pub fn abort_job(&self, fs: &dyn FileSystem, job: &JobContext, ctx: &mut OpCtx) -> Result<(), FsError> {
        self.cleanup(fs, job, ctx)
    }

    fn cleanup(&self, fs: &dyn FileSystem, job: &JobContext, ctx: &mut OpCtx) -> Result<(), FsError> {
        let tmp = job.output.child("_temporary");
        fs.delete(&tmp, true, ctx)?;
        Ok(())
    }

    /// Hadoop's `mergePaths`: move every file under `src` to the
    /// corresponding path under `dst` (rename per file; recurse into
    /// directories).
    fn merge_paths(
        &self,
        fs: &dyn FileSystem,
        src: &Path,
        dst: &Path,
        ctx: &mut OpCtx,
    ) -> Result<(), FsError> {
        let children = match fs.list_status(src, ctx) {
            Ok(c) => c,
            Err(FsError::NotFound(_)) => return Ok(()),
            Err(e) => return Err(e),
        };
        for child in children {
            let name = child.path.name().to_string();
            let target = dst.child(&name);
            if child.is_dir {
                self.merge_paths(fs, &child.path, &target, ctx)?;
            } else {
                if fs.exists(&target, ctx) {
                    fs.delete(&target, false, ctx)?;
                }
                fs.rename(&child.path, &target, ctx)?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::connectors::{HadoopSwift, Stocator};
    use crate::fs::hdfs::Hdfs;
    use crate::metrics::OpKind;
    use crate::objectstore::{ObjectStore, StoreConfig};
    use crate::simclock::SimInstant;

    fn attempt(task: u32, n: u32) -> AttemptId {
        AttemptId::new("201702221313", "0000", task, n)
    }

    fn ctx() -> OpCtx {
        OpCtx::new(SimInstant::EPOCH)
    }

    /// Run the full one-task protocol of the paper's §2.3 example.
    fn run_single_task(
        fs: &dyn FileSystem,
        scheme: &str,
        algorithm: CommitAlgorithm,
        ctx: &mut OpCtx,
    ) {
        let out = Path::parse(&format!("{scheme}://res/data.txt")).unwrap();
        let job = JobContext::new(out);
        let committer = Committer::new(algorithm);
        committer.setup_job(fs, &job, ctx).unwrap();
        let task = TaskAttemptContext::new(&job, attempt(1, 1));
        committer.setup_task(fs, &task, ctx).unwrap();
        committer
            .write_part(fs, &task, "part-00001", b"the output".to_vec(), ctx)
            .unwrap();
        if committer.needs_task_commit(fs, &task, ctx) {
            committer.commit_task(fs, &task, ctx).unwrap();
        }
        committer.commit_job(fs, &job, ctx).unwrap();
    }

    #[test]
    fn table1_trace_on_hdfs() {
        // The paper's Table 1: the file-system operations for a one-task
        // program. We assert the structural sequence.
        let fs = Hdfs::new();
        let mut c = OpCtx::traced(SimInstant::EPOCH);
        run_single_task(&*fs, "hdfs", CommitAlgorithm::V1, &mut c);
        let trace = c.take_trace();
        let joined = trace.join("\n");
        // mkdirs of temp root and attempt dir (steps 1-2)
        assert!(joined.contains("mkdirs: hdfs://res/data.txt/_temporary/0"));
        assert!(joined.contains("attempt_201702221313_0000_m_000001_1"));
        // task temp write (step 3)
        assert!(joined.contains("create: hdfs://res/data.txt/_temporary/0/_temporary/attempt_201702221313_0000_m_000001_1/part-00001"));
        // two renames (steps 5, 7)
        let renames: Vec<&str> = trace.iter().filter(|l| l.starts_with("rename:")).map(|s| s.as_str()).collect();
        assert_eq!(renames.len(), 2, "{joined}");
        assert!(renames[0].contains("task_201702221313_0000_m_000001"));
        assert!(renames[1].ends_with("data.txt/part-00001"));
        // _SUCCESS (step 8)
        assert!(joined.contains("create: hdfs://res/data.txt/_SUCCESS"));
        // final state
        let mut c2 = ctx();
        let out = Path::parse("hdfs://res/data.txt/part-00001").unwrap();
        assert_eq!(&*fs.read_all(&out, &mut c2).unwrap(), b"the output");
    }

    #[test]
    fn v1_on_swift_costs_copies_v1_on_stocator_costs_none() {
        // Core paper claim, miniature form.
        let store_sw = ObjectStore::new(StoreConfig::instant_strong());
        store_sw.create_container("res", SimInstant::EPOCH).0.unwrap();
        let swift = HadoopSwift::new(store_sw.clone());
        let mut c = ctx();
        run_single_task(&*swift, "swift", CommitAlgorithm::V1, &mut c);
        let sw = store_sw.counters();
        assert!(sw.get(OpKind::CopyObject) >= 2, "v1 = two renames: {sw}");

        let store_st = ObjectStore::new(StoreConfig::instant_strong());
        store_st.create_container("res", SimInstant::EPOCH).0.unwrap();
        let stoc = Stocator::with_defaults(store_st.clone());
        let mut c = ctx();
        run_single_task(&*stoc, "swift2d", CommitAlgorithm::V1, &mut c);
        let st = store_st.counters();
        assert_eq!(st.get(OpKind::CopyObject), 0);
        assert_eq!(st.get(OpKind::DeleteObject), 0);
        assert!(st.total() < sw.total() / 3, "stocator {st} vs swift {sw}");
        // Output exists under its attempt-qualified name (10 bytes of part
        // data plus the `_SUCCESS` manifest and the 0-byte marker):
        assert!(store_st.debug_live_bytes("res") >= 10);
        assert!(store_st
            .debug_names("res", "data.txt/")
            .iter()
            .any(|n| n.contains("part-00001_attempt_")));
    }

    #[test]
    fn v2_commits_at_task_level() {
        let store = ObjectStore::new(StoreConfig::instant_strong());
        store.create_container("res", SimInstant::EPOCH).0.unwrap();
        let swift = HadoopSwift::new(store.clone());
        let out = Path::parse("swift://res/out").unwrap();
        let job = JobContext::new(out.clone());
        let committer = Committer::new(CommitAlgorithm::V2);
        let mut c = ctx();
        committer.setup_job(&*swift, &job, &mut c).unwrap();
        let task = TaskAttemptContext::new(&job, attempt(0, 0));
        committer.setup_task(&*swift, &task, &mut c).unwrap();
        committer
            .write_part(&*swift, &task, "part-00000", b"xy".to_vec(), &mut c)
            .unwrap();
        committer.commit_task(&*swift, &task, &mut c).unwrap();
        // Already at its final location BEFORE job commit:
        assert!(swift.exists(&out.child("part-00000"), &mut c));
        committer.commit_job(&*swift, &job, &mut c).unwrap();
        assert!(swift.exists(&out.child("_SUCCESS"), &mut c));
        assert!(!swift.exists(&out.child("_temporary"), &mut c));
    }

    #[test]
    fn v1_duplicate_attempts_last_commit_wins() {
        // Two attempts of the same task both commit (rare but possible);
        // v1's delete-then-rename keeps exactly one.
        let store = ObjectStore::new(StoreConfig::instant_strong());
        store.create_container("res", SimInstant::EPOCH).0.unwrap();
        let swift = HadoopSwift::new(store.clone());
        let job = JobContext::new(Path::parse("swift://res/out").unwrap());
        let committer = Committer::new(CommitAlgorithm::V1);
        let mut c = ctx();
        committer.setup_job(&*swift, &job, &mut c).unwrap();
        for n in 0..2 {
            let t = TaskAttemptContext::new(&job, attempt(0, n));
            committer.setup_task(&*swift, &t, &mut c).unwrap();
            committer
                .write_part(&*swift, &t, "part-00000", format!("attempt{n}").into_bytes(), &mut c)
                .unwrap();
            committer.commit_task(&*swift, &t, &mut c).unwrap();
        }
        committer.commit_job(&*swift, &job, &mut c).unwrap();
        let data = swift
            .read_all(&Path::parse("swift://res/out/part-00000").unwrap(), &mut c)
            .unwrap();
        assert_eq!(&*data, b"attempt1");
        // No stray task-temp leftovers.
        assert!(!swift.exists(&Path::parse("swift://res/out/_temporary").unwrap(), &mut c));
    }

    #[test]
    fn aborted_attempt_leaves_no_output_with_v1_but_direct_leaks() {
        let store = ObjectStore::new(StoreConfig::instant_strong());
        store.create_container("res", SimInstant::EPOCH).0.unwrap();
        let swift = HadoopSwift::new(store.clone());
        let mut c = ctx();

        // V1: abort cleans the attempt dir.
        let job = JobContext::new(Path::parse("swift://res/safe").unwrap());
        let committer = Committer::new(CommitAlgorithm::V1);
        committer.setup_job(&*swift, &job, &mut c).unwrap();
        let t = TaskAttemptContext::new(&job, attempt(0, 0));
        committer.setup_task(&*swift, &t, &mut c).unwrap();
        committer
            .write_part(&*swift, &t, "part-00000", b"partial".to_vec(), &mut c)
            .unwrap();
        committer.abort_task(&*swift, &t, &mut c).unwrap();
        committer.commit_job(&*swift, &job, &mut c).unwrap();
        assert!(
            !swift.exists(&Path::parse("swift://res/safe/part-00000").unwrap(), &mut c),
            "v1 abort must remove partial output"
        );

        // Direct: the failed attempt's output is already live. THE hazard.
        let job2 = JobContext::new(Path::parse("swift://res/unsafe").unwrap());
        let direct = Committer::new(CommitAlgorithm::Direct);
        direct.setup_job(&*swift, &job2, &mut c).unwrap();
        let t2 = TaskAttemptContext::new(&job2, attempt(0, 0));
        direct.setup_task(&*swift, &t2, &mut c).unwrap();
        direct
            .write_part(&*swift, &t2, "part-00000", b"partial".to_vec(), &mut c)
            .unwrap();
        direct.abort_task(&*swift, &t2, &mut c).unwrap();
        assert!(
            swift.exists(&Path::parse("swift://res/unsafe/part-00000").unwrap(), &mut c),
            "direct committer cannot undo a failed attempt"
        );
    }

    #[test]
    fn cleanup_failed_attempt_aborts_only_transient_exhaustion() {
        let store = ObjectStore::new(StoreConfig::instant_strong());
        store.create_container("res", SimInstant::EPOCH).0.unwrap();
        let swift = HadoopSwift::new(store.clone());
        let job = JobContext::new(Path::parse("swift://res/out").unwrap());
        let committer = Committer::new(CommitAlgorithm::V1);
        let mut c = ctx();
        committer.setup_job(&*swift, &job, &mut c).unwrap();
        let t = TaskAttemptContext::new(&job, attempt(0, 0));
        committer.setup_task(&*swift, &t, &mut c).unwrap();
        committer
            .write_part(&*swift, &t, "part-00000", b"half-done".to_vec(), &mut c)
            .unwrap();
        // A crash-class failure: the executor died — nothing is cleaned.
        assert!(!committer.cleanup_failed_attempt(
            &*swift,
            &t,
            &FsError::Io("injected crash mid-stream".into()),
            &mut c,
        ));
        assert!(swift.exists(&t.attempt_dir(), &mut c));
        // Transient exhaustion: the live executor aborts the attempt.
        assert!(committer.cleanup_failed_attempt(
            &*swift,
            &t,
            &FsError::TransientExhausted("503".into()),
            &mut c,
        ));
        assert!(!swift.exists(&t.attempt_dir(), &mut c));
    }

    #[test]
    fn stocator_v2_also_works() {
        // Stocator intercepts both algorithms identically.
        let store = ObjectStore::new(StoreConfig::instant_strong());
        store.create_container("res", SimInstant::EPOCH).0.unwrap();
        let stoc = Stocator::with_defaults(store.clone());
        let mut c = ctx();
        run_single_task(&*stoc, "swift2d", CommitAlgorithm::V2, &mut c);
        assert_eq!(store.counters().get(OpKind::CopyObject), 0);
        let names = store.debug_names("res", "data.txt/");
        assert!(names.iter().any(|n| n.contains("part-00001_attempt_")));
        assert!(names.iter().any(|n| n.ends_with("_SUCCESS")));
    }
}
