//! The in-memory shuffle service.
//!
//! Between stages, map-task output is partitioned by reduce task and held
//! by the executors (Spark's external shuffle service). We model it as a
//! shared in-memory table plus a virtual-time transfer cost charged on the
//! reduce side (shuffle data crosses the 10 Gbps cluster network, not the
//! object store — the paper's REST-op counts exclude it, and so do ours).

use crate::simclock::SimDuration;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// Shuffle blocks grouped by reduce partition.
#[derive(Debug, Default)]
pub struct ShuffleStore {
    /// partition -> blocks (one per map task that produced output for it).
    blocks: Mutex<BTreeMap<usize, Vec<Arc<Vec<u8>>>>>,
    /// Cluster-network bandwidth for shuffle fetch, bytes/sec of virtual
    /// time (per reduce task stream).
    pub fetch_bw: u64,
    /// Simulated→paper byte scale (matches the latency model).
    pub data_scale: u64,
}

impl ShuffleStore {
    pub fn new(fetch_bw: u64, data_scale: u64) -> Arc<Self> {
        Arc::new(Self {
            blocks: Mutex::new(BTreeMap::new()),
            fetch_bw,
            data_scale,
        })
    }

    /// Unlimited-bandwidth store for protocol tests.
    pub fn instant() -> Arc<Self> {
        Self::new(u64::MAX, 1)
    }

    /// Map side: publish one block for `partition`.
    pub fn push(&self, partition: usize, data: Vec<u8>) {
        self.blocks
            .lock()
            .unwrap()
            .entry(partition)
            .or_default()
            .push(Arc::new(data));
    }

    /// Reduce side: fetch all blocks for `partition`, returning the blocks
    /// and the virtual fetch time.
    pub fn fetch(&self, partition: usize) -> (Vec<Arc<Vec<u8>>>, SimDuration) {
        let blocks = self
            .blocks
            .lock()
            .unwrap()
            .get(&partition)
            .cloned()
            .unwrap_or_default();
        let bytes: u64 = blocks.iter().map(|b| b.len() as u64).sum();
        let d = if self.fetch_bw == u64::MAX {
            SimDuration::ZERO
        } else {
            SimDuration::from_micros(
                bytes.saturating_mul(self.data_scale).saturating_mul(1_000_000) / self.fetch_bw,
            )
        };
        (blocks, d)
    }

    /// Total bytes currently held (diagnostics).
    pub fn total_bytes(&self) -> u64 {
        self.blocks
            .lock()
            .unwrap()
            .values()
            .flatten()
            .map(|b| b.len() as u64)
            .sum()
    }

    /// Number of partitions with data.
    pub fn partitions(&self) -> usize {
        self.blocks.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_fetch_roundtrip() {
        let s = ShuffleStore::instant();
        s.push(0, b"aa".to_vec());
        s.push(1, b"bb".to_vec());
        s.push(0, b"cc".to_vec());
        let (blocks, d) = s.fetch(0);
        assert_eq!(blocks.len(), 2);
        assert_eq!(d, SimDuration::ZERO);
        assert_eq!(s.total_bytes(), 6);
        assert_eq!(s.partitions(), 2);
        let (empty, _) = s.fetch(9);
        assert!(empty.is_empty());
    }

    #[test]
    fn fetch_charges_bandwidth() {
        let s = ShuffleStore::new(1_000, 1); // 1 KB/s
        s.push(0, vec![0u8; 2_000]);
        let (_, d) = s.fetch(0);
        assert_eq!(d, SimDuration::from_secs(2));
        // Scaled store inflates to paper bytes.
        let s2 = ShuffleStore::new(1_000, 10);
        s2.push(0, vec![0u8; 2_000]);
        let (_, d2) = s2.fetch(0);
        assert_eq!(d2, SimDuration::from_secs(20));
    }
}
