//! The driver: schedules task attempts onto executor slots on the virtual
//! clock, retries failures, speculatively duplicates stragglers, and runs
//! the commit protocol (paper §2.2).

use super::faults::{FaultKind, FaultPlan};
use super::shuffle::ShuffleStore;
use super::task::{ComputeModel, TaskBody, TaskResult, TaskRun};
use super::SparkConfig;
use crate::committer::{CommitAlgorithm, Committer, JobContext, TaskAttemptContext};
use crate::connectors::naming::AttemptId;
use crate::fs::{FileSystem, FsError, OpCtx, Path};
use crate::metrics::OpCounts;
use crate::objectstore::ObjectStore;
use crate::simclock::{SimClock, SimDuration, SimInstant};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::Arc;

/// One Spark job: a set of tasks plus its output dataset and commit
/// algorithm. Multi-stage applications chain jobs through a
/// [`ShuffleStore`].
pub struct SparkJob {
    pub name: String,
    /// Output dataset; `None` for jobs that only read/collect.
    pub output: Option<Path>,
    pub algorithm: CommitAlgorithm,
    /// Task bodies; index = task id = part number.
    pub tasks: Vec<TaskBody>,
    /// Where map output goes (if this is a map stage).
    pub shuffle_out: Option<Arc<ShuffleStore>>,
    /// Where reduce input comes from (partition = task id).
    pub shuffle_in: Option<Arc<ShuffleStore>>,
    pub faults: FaultPlan,
}

impl SparkJob {
    pub fn new(name: &str, output: Option<Path>, algorithm: CommitAlgorithm, tasks: Vec<TaskBody>) -> Self {
        Self {
            name: name.to_string(),
            output,
            algorithm,
            tasks,
            shuffle_out: None,
            shuffle_in: None,
            faults: FaultPlan::none(),
        }
    }

    pub fn with_shuffle_out(mut self, s: Arc<ShuffleStore>) -> Self {
        self.shuffle_out = Some(s);
        self
    }

    pub fn with_shuffle_in(mut self, s: Arc<ShuffleStore>) -> Self {
        self.shuffle_in = Some(s);
        self
    }

    pub fn with_faults(mut self, f: FaultPlan) -> Self {
        self.faults = f;
        self
    }
}

/// Post-run statistics for a job.
#[derive(Debug, Clone)]
pub struct JobStats {
    pub name: String,
    pub start: SimInstant,
    pub end: SimInstant,
    pub runtime: SimDuration,
    /// All attempts launched (originals + retries + speculative copies).
    pub attempts: u32,
    pub failed_attempts: u32,
    /// Subset of `failed_attempts` that failed by exhausting a transient
    /// retry budget (`FsError::TransientExhausted`) — the executor
    /// survived, aborted the attempt, and the driver re-scheduled.
    pub transient_exhausted_attempts: u32,
    pub speculative_attempts: u32,
    pub aborted_attempts: u32,
    /// REST ops issued during this job (zero if no object store attached).
    pub ops: OpCounts,
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub records: u64,
    /// Per-task driver-collected payloads (winner attempt's).
    pub collected: Vec<Option<Vec<u8>>>,
    pub success: bool,
}

struct AttemptRecord {
    task_id: u32,
    attempt_no: u32,
    start: SimInstant,
    end: SimInstant,
    result: Result<TaskResult, FsError>,
    #[allow(dead_code)]
    speculative: bool,
}

/// The driver. Owns the virtual clock; jobs run back to back on it.
pub struct Driver {
    pub cfg: SparkConfig,
    pub fs: Arc<dyn FileSystem>,
    /// Attached store for op accounting (None when running on HDFS).
    pub store: Option<Arc<ObjectStore>>,
    pub compute: ComputeModel,
    clock: SimClock,
}

impl Driver {
    pub fn new(
        cfg: SparkConfig,
        fs: Arc<dyn FileSystem>,
        store: Option<Arc<ObjectStore>>,
        compute: ComputeModel,
    ) -> Self {
        Self {
            cfg,
            fs,
            store,
            compute,
            clock: SimClock::new(),
        }
    }

    pub fn now(&self) -> SimInstant {
        self.clock.now()
    }

    /// Run a driver-side phase (e.g. input listing before a job): gives the
    /// closure an [`OpCtx`] at the current virtual time and advances the
    /// clock by whatever it consumed.
    pub fn driver_phase<T>(&mut self, f: impl FnOnce(&dyn FileSystem, &mut OpCtx) -> T) -> T {
        let mut ctx = OpCtx::new(self.clock.now());
        let out = f(self.fs.as_ref(), &mut ctx);
        self.clock.advance_to(ctx.now());
        out
    }

    /// Run one job to completion; the clock advances past its end.
    pub fn run_job(&mut self, job: &SparkJob) -> Result<JobStats, FsError> {
        assert!(!job.tasks.is_empty(), "job '{}' has no tasks", job.name);
        let ops_before = self.store.as_ref().map(|s| s.counters());
        let job_start = self.clock.now();
        let mut driver_ctx = OpCtx::new(job_start);

        let committer = Committer::new(job.algorithm);
        let job_ctx = job.output.as_ref().map(|out| JobContext::new(out.clone()));
        if let Some(jc) = &job_ctx {
            committer.setup_job(self.fs.as_ref(), jc, &mut driver_ctx)?;
        }
        let tasks_ready = driver_ctx.now();

        // Executor slots: a min-heap of next-free times.
        let mut slots: BinaryHeap<Reverse<u64>> = (0..self.cfg.slots.max(1))
            .map(|_| Reverse(tasks_ready.0))
            .collect();

        // Ready queue of (ready_time, task, attempt_no, speculative).
        let mut ready: BinaryHeap<Reverse<(u64, u32, u32, bool)>> = job
            .tasks
            .iter()
            .enumerate()
            .map(|(i, _)| Reverse((tasks_ready.0, i as u32, 0u32, false)))
            .collect();

        let mut stats = JobStats {
            name: job.name.clone(),
            start: job_start,
            end: job_start,
            runtime: SimDuration::ZERO,
            attempts: 0,
            failed_attempts: 0,
            transient_exhausted_attempts: 0,
            speculative_attempts: 0,
            aborted_attempts: 0,
            ops: OpCounts::default(),
            bytes_read: 0,
            bytes_written: 0,
            records: 0,
            collected: vec![None; job.tasks.len()],
            success: true,
        };

        // Per task: the best finished-but-uncommitted attempt awaiting a
        // speculation race, and whether the task is already done.
        let mut awaiting: HashMap<u32, AttemptRecord> = HashMap::new();
        let mut done: Vec<bool> = vec![false; job.tasks.len()];
        let mut durations: Vec<SimDuration> = Vec::new();
        let mut last_commit_end = tasks_ready;

        while let Some(Reverse((ready_at, task_id, attempt_no, speculative))) = ready.pop() {
            if done[task_id as usize] {
                continue; // task finished while this retry/copy was queued
            }
            let Reverse(slot_free) = slots.pop().expect("slot");
            let start = SimInstant(ready_at.max(slot_free));

            let rec = self.execute_attempt(job, &committer, &job_ctx, task_id, attempt_no, speculative, start);
            stats.attempts += 1;
            if speculative {
                stats.speculative_attempts += 1;
            }
            slots.push(Reverse(rec.end.0));

            match &rec.result {
                Err(e) => {
                    stats.failed_attempts += 1;
                    if matches!(e, FsError::TransientExhausted(_)) {
                        stats.transient_exhausted_attempts += 1;
                    }
                    // Decide retry. Speculative copies that fail simply
                    // lose the race; originals are retried.
                    let next_no = attempt_no + 1;
                    if let Some(orig) = awaiting.remove(&task_id) {
                        // A finished original was waiting on this copy:
                        // the original wins by default.
                        self.finish_task(job, &committer, &job_ctx, orig, &mut stats, &mut done, &mut durations, &mut last_commit_end);
                        continue;
                    }
                    if next_no >= self.cfg.max_failures {
                        stats.success = false;
                        done[task_id as usize] = true;
                    } else {
                        ready.push(Reverse((rec.end.0, task_id, next_no, false)));
                    }
                }
                Ok(_) => {
                    // Did a speculation race start for this task?
                    if let Some(other) = awaiting.remove(&task_id) {
                        // Race: earlier end wins.
                        let (winner, loser) = if rec.end <= other.end {
                            (rec, other)
                        } else {
                            (other, rec)
                        };
                        let decision = winner.end.max(SimInstant(ready_at));
                        self.abort_loser(job, &committer, &job_ctx, &loser, decision, &mut stats);
                        self.finish_task(job, &committer, &job_ctx, winner, &mut stats, &mut done, &mut durations, &mut last_commit_end);
                        continue;
                    }
                    // Straggler + speculation on → hold the result, launch
                    // a copy at the moment the driver would notice.
                    let is_straggler = matches!(
                        job.faults.get(task_id, attempt_no),
                        Some(FaultKind::Straggle { .. })
                    );
                    if self.cfg.speculation && is_straggler && !speculative {
                        let median = median_duration(&durations)
                            .unwrap_or_else(|| rec.end.elapsed_since(rec.start));
                        let trigger = rec.start
                            + SimDuration::from_secs_f64(
                                median.as_secs_f64() * self.cfg.speculation_multiplier,
                            );
                        ready.push(Reverse((trigger.0, task_id, attempt_no + 1, true)));
                        awaiting.insert(task_id, rec);
                    } else if self.cfg.speculation && is_straggler && speculative {
                        // A speculative copy that is itself straggling:
                        // chain one more copy (bounded by max_failures).
                        if attempt_no + 1 < self.cfg.max_failures {
                            let median = median_duration(&durations)
                                .unwrap_or_else(|| rec.end.elapsed_since(rec.start));
                            let trigger = rec.start
                                + SimDuration::from_secs_f64(
                                    median.as_secs_f64() * self.cfg.speculation_multiplier,
                                );
                            ready.push(Reverse((trigger.0, task_id, attempt_no + 1, true)));
                            awaiting.insert(task_id, rec);
                        } else {
                            self.finish_task(job, &committer, &job_ctx, rec, &mut stats, &mut done, &mut durations, &mut last_commit_end);
                        }
                    } else {
                        self.finish_task(job, &committer, &job_ctx, rec, &mut stats, &mut done, &mut durations, &mut last_commit_end);
                    }
                }
            }
        }

        // Any attempt still awaiting a race (copy never ran) wins now.
        let leftovers: Vec<AttemptRecord> = awaiting.drain().map(|(_, v)| v).collect();
        for rec in leftovers {
            self.finish_task(job, &committer, &job_ctx, rec, &mut stats, &mut done, &mut durations, &mut last_commit_end);
        }

        if done.iter().any(|d| !d) || !stats.success {
            stats.success = false;
        }

        // Job commit runs in the driver after all tasks finished.
        let mut commit_ctx = OpCtx::new(last_commit_end.max(driver_ctx.now()));
        if stats.success {
            if let Some(jc) = &job_ctx {
                committer.commit_job(self.fs.as_ref(), jc, &mut commit_ctx)?;
            }
        } else if let Some(jc) = &job_ctx {
            committer.abort_job(self.fs.as_ref(), jc, &mut commit_ctx)?;
        }
        let job_end = commit_ctx.now();
        stats.end = job_end;
        stats.runtime = job_end.elapsed_since(job_start);
        if let (Some(store), Some(before)) = (&self.store, ops_before) {
            stats.ops = store.counters().since(&before);
        }
        self.clock.advance_to(job_end);
        Ok(stats)
    }

    /// Run a single attempt (setup, body, faults, but NOT the commit).
    fn execute_attempt(
        &self,
        job: &SparkJob,
        committer: &Committer,
        job_ctx: &Option<JobContext>,
        task_id: u32,
        attempt_no: u32,
        #[allow(dead_code)]
    speculative: bool,
        start: SimInstant,
    ) -> AttemptRecord {
        let mut ctx = OpCtx::new(start);
        let attempt = AttemptId::new(&self.cfg.job_timestamp, "0000", task_id, attempt_no);
        let fault = job.faults.get(task_id, attempt_no).cloned();

        // CrashBeforeWrite fails before any filesystem interaction.
        if matches!(fault, Some(FaultKind::CrashBeforeWrite)) {
            ctx.add(SimDuration::from_millis(50)); // it got as far as starting
            return AttemptRecord {
                task_id,
                attempt_no,
                start,
                end: ctx.now(),
                result: Err(FsError::Io("injected crash before write".into())),
                speculative,
            };
        }

        // TransientOps arms flaky REST ops on the store for this attempt
        // (match counters run from here; attempts execute serially on the
        // virtual clock, so the armed rules hit this attempt's ops).
        if let Some(FaultKind::TransientOps { spec }) = &fault {
            if let Some(store) = &self.store {
                store.arm_faults(spec);
            }
        }

        let result = (|| -> Result<TaskResult, FsError> {
            let tac = match job_ctx {
                Some(jc) => {
                    let tac = TaskAttemptContext::new(jc, attempt.clone());
                    committer.setup_task(self.fs.as_ref(), &tac, &mut ctx)?;
                    tac
                }
                None => {
                    // Jobs without output still need an attempt context for
                    // naming; use a throwaway job context.
                    let fake = JobContext::new(Path::new(self.fs.scheme(), "none", "none"));
                    TaskAttemptContext::new(&fake, attempt.clone())
                }
            };
            let shuffle_in = match &job.shuffle_in {
                Some(s) => {
                    let (blocks, d) = s.fetch(task_id as usize);
                    ctx.add(d);
                    blocks
                }
                None => Vec::new(),
            };
            let drop_stream_after = match &fault {
                Some(FaultKind::CrashAfterPartialWrite { fraction }) => Some(*fraction),
                _ => None,
            };
            let mut run = TaskRun {
                fs: self.fs.as_ref(),
                ctx: &mut ctx,
                committer,
                attempt: &tac,
                compute: &self.compute,
                shuffle_in,
                drop_stream_after,
            };
            let body = &job.tasks[task_id as usize];
            body(&mut run)
        })();

        // A failed attempt whose executor is still alive (transient
        // budget exhausted, as opposed to a crash) aborts its own task
        // attempt before the driver reschedules — the committer decides
        // what that means per algorithm/connector.
        if let (Err(e), Some(jc)) = (&result, job_ctx) {
            let tac = TaskAttemptContext::new(jc, attempt.clone());
            committer.cleanup_failed_attempt(self.fs.as_ref(), &tac, e, &mut ctx);
        }

        if let Some(FaultKind::Straggle { extra }) = &fault {
            ctx.add(*extra);
        }
        AttemptRecord {
            task_id,
            attempt_no,
            start,
            end: ctx.now(),
            result,
            speculative,
        }
    }

    /// Commit the winning attempt and record its results.
    #[allow(clippy::too_many_arguments)]
    fn finish_task(
        &self,
        job: &SparkJob,
        committer: &Committer,
        job_ctx: &Option<JobContext>,
        rec: AttemptRecord,
        stats: &mut JobStats,
        done: &mut [bool],
        durations: &mut Vec<SimDuration>,
        last_commit_end: &mut SimInstant,
    ) {
        let task_id = rec.task_id;
        if done[task_id as usize] {
            return;
        }
        let result = match rec.result {
            Ok(r) => r,
            Err(_) => return,
        };
        let mut end = rec.end;
        if let Some(jc) = job_ctx {
            // Executor-side task commit, on this attempt's timeline.
            let attempt = AttemptId::new(&self.cfg.job_timestamp, "0000", task_id, rec.attempt_no);
            let tac = TaskAttemptContext::new(jc, attempt);
            let mut ctx = OpCtx::new(rec.end);
            if committer.needs_task_commit(self.fs.as_ref(), &tac, &mut ctx) {
                let _ = committer.commit_task(self.fs.as_ref(), &tac, &mut ctx);
            }
            end = ctx.now();
        }
        if let Some(out) = &job.shuffle_out {
            for (part, data) in &result.shuffle_out {
                out.push(*part, data.clone());
            }
        }
        stats.bytes_read += result.bytes_read;
        stats.bytes_written += result.bytes_written;
        stats.records += result.records;
        stats.collected[task_id as usize] = result.collected;
        durations.push(rec.end.elapsed_since(rec.start));
        done[task_id as usize] = true;
        if end > *last_commit_end {
            *last_commit_end = end;
        }
    }

    /// Abort the losing attempt of a speculation race (if cleanup is on).
    fn abort_loser(
        &self,
        _job: &SparkJob,
        committer: &Committer,
        job_ctx: &Option<JobContext>,
        loser: &AttemptRecord,
        decision: SimInstant,
        stats: &mut JobStats,
    ) {
        if !self.cfg.cleanup_speculation {
            return; // paper Table 3, lines 1-5 + 8-9: duplicates remain
        }
        if let Some(jc) = job_ctx {
            let attempt = AttemptId::new(
                &self.cfg.job_timestamp,
                "0000",
                loser.task_id,
                loser.attempt_no,
            );
            let tac = TaskAttemptContext::new(jc, attempt);
            let mut ctx = OpCtx::new(decision.max(loser.end));
            let _ = committer.abort_task(self.fs.as_ref(), &tac, &mut ctx);
            stats.aborted_attempts += 1;
        }
    }
}

fn median_duration(ds: &[SimDuration]) -> Option<SimDuration> {
    if ds.is_empty() {
        return None;
    }
    let mut v = ds.to_vec();
    v.sort();
    Some(v[v.len() / 2])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::connectors::{HadoopSwift, Stocator};
    use crate::metrics::OpKind;
    use crate::objectstore::{ObjectStore, StoreConfig};
    use crate::spark::task::body;

    fn stocator_driver(cfg: SparkConfig) -> (Arc<ObjectStore>, Driver) {
        let store = ObjectStore::new(StoreConfig::instant_strong());
        store.create_container("res", SimInstant::EPOCH).0.unwrap();
        let fs = Stocator::with_defaults(store.clone());
        let d = Driver::new(cfg, fs, Some(store.clone()), ComputeModel::free());
        (store, d)
    }

    fn writer_tasks(n: usize, bytes: usize) -> Vec<TaskBody> {
        (0..n)
            .map(|i| {
                body(move |run: &mut TaskRun<'_>| {
                    let data = vec![i as u8; bytes];
                    let name = run.part_basename();
                    let written = run.write_part(&name, data)?;
                    Ok(TaskResult {
                        bytes_written: written,
                        records: 1,
                        ..Default::default()
                    })
                })
            })
            .collect()
    }

    #[test]
    fn three_task_job_on_stocator_matches_paper_naming() {
        // Fig. 4 of the paper: three tasks each write a part.
        let (store, mut driver) = stocator_driver(SparkConfig {
            slots: 4,
            job_timestamp: "201512062056".into(),
            ..Default::default()
        });
        let out = Path::parse("swift2d://res/data.txt").unwrap();
        let job = SparkJob::new(
            "fig4",
            Some(out),
            CommitAlgorithm::V1,
            writer_tasks(3, 4),
        );
        let stats = driver.run_job(&job).unwrap();
        assert!(stats.success);
        assert_eq!(stats.attempts, 3);
        let names = store.debug_names("res", "data.txt/");
        // Table 3 lines 1-3 names:
        for t in 0..3 {
            assert!(
                names.contains(&format!(
                    "data.txt/part-0000{t}_attempt_201512062056_0000_m_00000{t}_0"
                )),
                "{names:?}"
            );
        }
        assert!(names.contains(&"data.txt/_SUCCESS".to_string()));
        // No COPY/DELETE at all (Table 3, line 8 = "no operations").
        assert_eq!(stats.ops.get(OpKind::CopyObject), 0);
        assert_eq!(stats.ops.get(OpKind::DeleteObject), 0);
    }

    #[test]
    fn retries_after_crash_produce_new_attempt_number() {
        let (store, mut driver) = stocator_driver(SparkConfig {
            slots: 2,
            job_timestamp: "201512062056".into(),
            ..Default::default()
        });
        let out = Path::parse("swift2d://res/d").unwrap();
        let job = SparkJob::new("retry", Some(out), CommitAlgorithm::V1, writer_tasks(2, 3))
            .with_faults(FaultPlan::none().with(1, 0, FaultKind::CrashBeforeWrite));
        let stats = driver.run_job(&job).unwrap();
        assert!(stats.success);
        assert_eq!(stats.failed_attempts, 1);
        assert_eq!(stats.attempts, 3); // 2 originals + 1 retry
        let names = store.debug_names("res", "d/");
        assert!(names.iter().any(|n| n.ends_with("m_000001_1")), "{names:?}");
        assert!(!names.iter().any(|n| n.ends_with("m_000001_0")));
    }

    #[test]
    fn partial_write_crash_is_masked_by_read_side_dedup() {
        // Attempt 0 crashes mid-write leaving a truncated final object;
        // attempt 1 completes. The List read strategy must pick attempt 1
        // (most data = fail-stop argument, §3.2).
        let (store, mut driver) = stocator_driver(SparkConfig {
            slots: 2,
            job_timestamp: "201512062056".into(),
            ..Default::default()
        });
        let out = Path::parse("swift2d://res/d").unwrap();
        let job = SparkJob::new("partial", Some(out), CommitAlgorithm::V1, writer_tasks(1, 100))
            .with_faults(FaultPlan::none().with(
                0,
                0,
                FaultKind::CrashAfterPartialWrite { fraction: 0.3 },
            ));
        let stats = driver.run_job(&job).unwrap();
        assert!(stats.success);
        // Both attempts' objects exist (crashed executors don't clean up):
        let names = store.debug_names("res", "d/");
        assert!(names.iter().any(|n| n.ends_with("m_000000_0")));
        assert!(names.iter().any(|n| n.ends_with("m_000000_1")));
        // The read path picks the complete one:
        let fs = Stocator::with_defaults(store.clone());
        let mut ctx = OpCtx::new(SimInstant(stats.end.0));
        let ls = fs
            .list_status(&Path::parse("swift2d://res/d").unwrap(), &mut ctx)
            .unwrap();
        let part = ls
            .iter()
            .find(|s| s.path.name().starts_with("part-00000"))
            .unwrap();
        assert!(part.path.name().ends_with("m_000000_1"));
        assert_eq!(part.len, 100);
    }

    #[test]
    fn transient_exhaustion_escalates_into_successful_reattempt() {
        use crate::objectstore::{FaultOp, FaultSpec};
        // No stream-level retries: the attempt's one PUT try fails, the
        // live executor aborts the attempt, and the driver's ordinary
        // re-attempt machinery produces the correct output under a fresh
        // attempt name.
        let (store, mut driver) = stocator_driver(SparkConfig {
            slots: 2,
            job_timestamp: "201512062056".into(),
            ..Default::default()
        });
        let out = Path::parse("swift2d://res/d").unwrap();
        let job = SparkJob::new("flaky", Some(out), CommitAlgorithm::V1, writer_tasks(1, 16))
            .with_faults(FaultPlan::none().with(
                0,
                0,
                FaultKind::TransientOps {
                    spec: FaultSpec::one(FaultOp::Put, "d/part-00000", 1),
                },
            ));
        let stats = driver.run_job(&job).unwrap();
        assert!(stats.success);
        assert_eq!(stats.attempts, 2);
        assert_eq!(stats.failed_attempts, 1);
        assert_eq!(stats.transient_exhausted_attempts, 1);
        let names = store.debug_names("res", "d/");
        assert!(
            names.iter().any(|n| n.ends_with("m_000000_1")),
            "re-attempt writes under a fresh attempt name: {names:?}"
        );
        assert!(
            !names.iter().any(|n| n.ends_with("m_000000_0")),
            "the failed transfer left no object: {names:?}"
        );
        // The dataset reads back correctly.
        let fs = Stocator::with_defaults(store.clone());
        let mut ctx = OpCtx::new(SimInstant(stats.end.0));
        let ls = fs
            .list_status(&Path::parse("swift2d://res/d").unwrap(), &mut ctx)
            .unwrap();
        let part = ls.iter().find(|s| s.path.name().starts_with("part-")).unwrap();
        assert_eq!(part.len, 16);
    }

    #[test]
    fn stream_retries_absorb_faults_without_task_failure() {
        use crate::objectstore::{FaultOp, FaultRule, FaultSpec, RetryPolicy};
        // With --retries 1, a single injected PUT fault is absorbed at
        // the stream layer: no failed attempt ever reaches the driver.
        let mut cfg = StoreConfig::instant_strong();
        cfg.faults = FaultSpec::none().with(FaultRule::new(FaultOp::Put, "d/part-00000", 1, 1));
        cfg.retry = RetryPolicy::with_retries(1);
        let store = ObjectStore::new(cfg);
        store.create_container("res", SimInstant::EPOCH).0.unwrap();
        let fs = Stocator::with_defaults(store.clone());
        let mut driver = Driver::new(
            SparkConfig {
                slots: 2,
                job_timestamp: "201512062056".into(),
                ..Default::default()
            },
            fs,
            Some(store.clone()),
            ComputeModel::free(),
        );
        let out = Path::parse("swift2d://res/d").unwrap();
        let job = SparkJob::new("absorbed", Some(out), CommitAlgorithm::V1, writer_tasks(1, 16));
        let stats = driver.run_job(&job).unwrap();
        assert!(stats.success);
        assert_eq!(stats.attempts, 1, "the retry hid the fault from the scheduler");
        assert_eq!(stats.failed_attempts, 0);
        let names = store.debug_names("res", "d/");
        assert!(names.iter().any(|n| n.ends_with("m_000000_0")), "{names:?}");
    }

    #[test]
    fn speculation_cleanup_aborts_loser() {
        // Table 3 lines 1-9 (with cleanup): the slow attempt's object is
        // DELETEd.
        let (store, mut driver) = stocator_driver(SparkConfig {
            slots: 4,
            speculation: true,
            cleanup_speculation: true,
            job_timestamp: "201512062056".into(),
            ..Default::default()
        });
        let out = Path::parse("swift2d://res/d").unwrap();
        let job = SparkJob::new("spec", Some(out), CommitAlgorithm::V1, writer_tasks(3, 8))
            .with_faults(FaultPlan::none().with(
                2,
                0,
                FaultKind::Straggle {
                    extra: SimDuration::from_secs(300),
                },
            ));
        let stats = driver.run_job(&job).unwrap();
        assert!(stats.success);
        assert_eq!(stats.speculative_attempts, 1);
        assert_eq!(stats.aborted_attempts, 1);
        let names = store.debug_names("res", "d/");
        // Winner is attempt 1; attempt 0's object was deleted.
        assert!(names.iter().any(|n| n.ends_with("m_000002_1")), "{names:?}");
        assert!(!names.iter().any(|n| n.ends_with("m_000002_0")), "{names:?}");
        assert!(stats.ops.get(OpKind::DeleteObject) >= 1);
    }

    #[test]
    fn speculation_without_cleanup_leaves_duplicates_yet_reads_stay_correct() {
        // Table 3 lines 1-5 + 8-9: Spark cannot clean up; both attempts'
        // objects remain; the read path still returns one part per task.
        let (store, mut driver) = stocator_driver(SparkConfig {
            slots: 4,
            speculation: true,
            cleanup_speculation: false,
            job_timestamp: "201512062056".into(),
            ..Default::default()
        });
        let out = Path::parse("swift2d://res/d").unwrap();
        let job = SparkJob::new("spec2", Some(out), CommitAlgorithm::V1, writer_tasks(3, 8))
            .with_faults(FaultPlan::none().with(
                2,
                0,
                FaultKind::Straggle {
                    extra: SimDuration::from_secs(300),
                },
            ));
        let stats = driver.run_job(&job).unwrap();
        assert!(stats.success);
        let names = store.debug_names("res", "d/");
        assert!(names.iter().any(|n| n.ends_with("m_000002_0")));
        assert!(names.iter().any(|n| n.ends_with("m_000002_1")));
        // Read side: exactly 3 parts.
        let fs = Stocator::with_defaults(store.clone());
        let mut ctx = OpCtx::new(SimInstant(stats.end.0));
        let ls = fs
            .list_status(&Path::parse("swift2d://res/d").unwrap(), &mut ctx)
            .unwrap();
        let parts: Vec<_> = ls
            .iter()
            .filter(|s| s.path.name().starts_with("part-"))
            .collect();
        assert_eq!(parts.len(), 3);
    }

    #[test]
    fn task_parallelism_bounds_runtime() {
        // 8 tasks × 1s compute on 4 slots = 2 waves ≈ 2s; on 8 slots ≈ 1s.
        let run = |slots: usize| -> SimDuration {
            let store = ObjectStore::new(StoreConfig::instant_strong());
            store.create_container("res", SimInstant::EPOCH).0.unwrap();
            let fs = Stocator::with_defaults(store.clone());
            let mut d = Driver::new(
                SparkConfig {
                    slots,
                    ..Default::default()
                },
                fs,
                Some(store),
                ComputeModel::new(1_000_000, 1),
            );
            let tasks: Vec<TaskBody> = (0..8)
                .map(|_| {
                    body(|run: &mut TaskRun<'_>| {
                        run.charge_compute(1_000_000); // 1s
                        Ok(TaskResult::default())
                    })
                })
                .collect();
            let job = SparkJob::new("par", None, CommitAlgorithm::V1, tasks);
            d.run_job(&job).unwrap().runtime
        };
        let t4 = run(4);
        let t8 = run(8);
        assert!(t4.as_secs_f64() >= 1.99 && t4.as_secs_f64() < 2.2, "{t4}");
        assert!(t8.as_secs_f64() >= 0.99 && t8.as_secs_f64() < 1.2, "{t8}");
    }

    #[test]
    fn v1_job_commit_is_serial_in_the_driver() {
        // With Hadoop-Swift + v1, the job-commit copies happen after all
        // tasks end, serially — runtime scales with task count even with
        // plenty of slots. THE effect behind Table 5.
        let run_with = |n_tasks: usize| -> SimDuration {
            let mut cfg = StoreConfig::instant_strong();
            cfg.latency.copy_base_us = 1_000_000; // 1s per COPY
            let store = ObjectStore::new(cfg);
            store.create_container("res", SimInstant::EPOCH).0.unwrap();
            let fs = HadoopSwift::new(store.clone());
            let mut d = Driver::new(
                SparkConfig {
                    slots: 64,
                    ..Default::default()
                },
                fs,
                Some(store),
                ComputeModel::free(),
            );
            let out = Path::parse("swift://res/out").unwrap();
            let job = SparkJob::new("serial", Some(out), CommitAlgorithm::V1, writer_tasks(n_tasks, 2));
            d.run_job(&job).unwrap().runtime
        };
        let t2 = run_with(2);
        let t8 = run_with(8);
        // Job commit does one COPY per part serially: runtime grows ~n.
        assert!(
            t8.as_secs_f64() > t2.as_secs_f64() + 4.0,
            "t2={t2} t8={t8} — job commit should serialize"
        );
    }

    #[test]
    fn shuffle_flows_between_stages() {
        let (_, mut driver) = stocator_driver(SparkConfig {
            slots: 4,
            ..Default::default()
        });
        let shuffle = ShuffleStore::instant();
        // Map stage: 4 tasks each push (task_id % 2) -> one byte.
        let map_tasks: Vec<TaskBody> = (0..4)
            .map(|i: u32| {
                body(move |_run: &mut TaskRun<'_>| {
                    Ok(TaskResult {
                        shuffle_out: vec![((i % 2) as usize, vec![i as u8])],
                        ..Default::default()
                    })
                })
            })
            .collect();
        let map_job = SparkJob::new("map", None, CommitAlgorithm::V1, map_tasks)
            .with_shuffle_out(shuffle.clone());
        driver.run_job(&map_job).unwrap();
        assert_eq!(shuffle.partitions(), 2);

        // Reduce stage: 2 tasks count their blocks.
        let reduce_tasks: Vec<TaskBody> = (0..2)
            .map(|_| {
                body(|run: &mut TaskRun<'_>| {
                    let n = run.shuffle_in.len() as u64;
                    Ok(TaskResult {
                        records: n,
                        collected: Some(vec![n as u8]),
                        ..Default::default()
                    })
                })
            })
            .collect();
        let reduce_job = SparkJob::new("reduce", None, CommitAlgorithm::V1, reduce_tasks)
            .with_shuffle_in(shuffle);
        let stats = driver.run_job(&reduce_job).unwrap();
        assert_eq!(stats.records, 4);
        assert_eq!(stats.collected[0], Some(vec![2]));
        assert_eq!(stats.collected[1], Some(vec![2]));
    }

    #[test]
    fn job_fails_after_max_failures() {
        let (_, mut driver) = stocator_driver(SparkConfig {
            slots: 2,
            max_failures: 3,
            ..Default::default()
        });
        let out = Path::parse("swift2d://res/d").unwrap();
        let job = SparkJob::new("doomed", Some(out), CommitAlgorithm::V1, writer_tasks(1, 2))
            .with_faults(
                FaultPlan::none()
                    .with(0, 0, FaultKind::CrashBeforeWrite)
                    .with(0, 1, FaultKind::CrashBeforeWrite)
                    .with(0, 2, FaultKind::CrashBeforeWrite),
            );
        let stats = driver.run_job(&job).unwrap();
        assert!(!stats.success);
        assert_eq!(stats.failed_attempts, 3);
    }

    #[test]
    fn clock_advances_across_jobs() {
        let (_, mut driver) = stocator_driver(SparkConfig {
            slots: 2,
            ..Default::default()
        });
        let j1 = SparkJob::new(
            "a",
            None,
            CommitAlgorithm::V1,
            vec![body(|run: &mut TaskRun<'_>| {
                run.ctx.add(SimDuration::from_secs(5));
                Ok(TaskResult::default())
            })],
        );
        let s1 = driver.run_job(&j1).unwrap();
        let s2 = driver.run_job(&j1).unwrap();
        assert!(s2.start >= s1.end);
        assert!(driver.now() >= s2.end);
    }
}
