//! Fault and straggler injection (paper §2.2.1: task attempts may fail or
//! be slow; §3.5 Table 3 exercises both). Crash faults model fail-stop
//! executor death; [`FaultKind::TransientOps`] models the *other* failure
//! class — flaky REST operations — by arming the object store's
//! [`crate::objectstore::FaultInjector`] for the scheduled attempt, so
//! one schedule can mix crashes, stragglers and 5xx storms.

use crate::objectstore::FaultSpec;
use crate::simclock::SimDuration;
use std::collections::HashMap;

/// What goes wrong with a specific (task, attempt).
#[derive(Debug, Clone, PartialEq)]
pub enum FaultKind {
    /// The attempt crashes before writing anything.
    CrashBeforeWrite,
    /// The attempt streams `fraction` of its output and then crashes: its
    /// output stream is dropped without `close` — no commit, no abort
    /// (the executor died). Whether a truncated object survives is the
    /// connector's write-path semantics.
    CrashAfterPartialWrite { fraction: f64 },
    /// The attempt runs but takes `extra` longer than it should — the
    /// speculation trigger.
    Straggle { extra: SimDuration },
    /// The attempt's REST operations hit injected transient failures:
    /// `spec`'s rules are armed on the object store when the attempt
    /// starts (match counters run from that moment). The executor stays
    /// alive — the connector retries under its `RetryPolicy`, and only
    /// an exhausted budget fails the attempt
    /// ([`crate::fs::FsError::TransientExhausted`]), which the driver
    /// escalates into the ordinary re-attempt machinery.
    TransientOps { spec: FaultSpec },
}

/// A deterministic fault schedule, keyed by (task id, attempt number).
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    faults: HashMap<(u32, u32), FaultKind>,
}

impl FaultPlan {
    pub fn none() -> Self {
        Self::default()
    }

    pub fn with(mut self, task: u32, attempt: u32, kind: FaultKind) -> Self {
        self.faults.insert((task, attempt), kind);
        self
    }

    pub fn get(&self, task: u32, attempt: u32) -> Option<&FaultKind> {
        self.faults.get(&(task, attempt))
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Number of scheduled faults (for reporting).
    pub fn len(&self) -> usize {
        self.faults.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_lookup() {
        let plan = FaultPlan::none()
            .with(2, 0, FaultKind::CrashBeforeWrite)
            .with(
                2,
                1,
                FaultKind::Straggle {
                    extra: SimDuration::from_secs(30),
                },
            );
        assert_eq!(plan.get(2, 0), Some(&FaultKind::CrashBeforeWrite));
        assert!(matches!(plan.get(2, 1), Some(FaultKind::Straggle { .. })));
        assert!(plan.get(2, 2).is_none());
        assert!(plan.get(0, 0).is_none());
        assert_eq!(plan.len(), 2);
        assert!(!plan.is_empty());
        assert!(FaultPlan::none().is_empty());
    }

    #[test]
    fn plans_can_mix_crashes_stragglers_and_transient_ops() {
        use crate::objectstore::{FaultOp, FaultSpec};
        let plan = FaultPlan::none()
            .with(0, 0, FaultKind::CrashBeforeWrite)
            .with(
                1,
                0,
                FaultKind::TransientOps {
                    spec: FaultSpec::one(FaultOp::Put, "d/", 1),
                },
            );
        assert!(matches!(
            plan.get(1, 0),
            Some(FaultKind::TransientOps { spec }) if spec.rules.len() == 1
        ));
        assert_eq!(plan.len(), 2);
    }
}
