//! A Spark-like execution engine on the virtual clock (paper §2.2.1).
//!
//! The driver divides a job into tasks; tasks run on a fixed pool of
//! executor slots (the paper's testbed: 3 servers × 12 executors × 4 cores
//! = 144-way parallelism). Each *attempt* of a task gets a unique
//! [`crate::connectors::naming::AttemptId`]; failed attempts are retried,
//! slow attempts are **speculatively** duplicated, and the commit protocol
//! ([`crate::committer`]) decides whose output survives. All storage I/O
//! goes through a [`crate::fs::FileSystem`] (one of the three connectors),
//! so the engine reproduces the paper's interaction patterns faithfully.

pub mod task;
pub mod faults;
pub mod shuffle;
pub mod driver;

pub use driver::{Driver, JobStats, SparkJob};
pub use faults::{FaultKind, FaultPlan};
pub use shuffle::ShuffleStore;
pub use task::{ComputeModel, TaskBody, TaskResult, TaskRun};

/// Cluster/engine configuration (paper §4.1-§4.2 defaults).
#[derive(Debug, Clone)]
pub struct SparkConfig {
    /// Total parallel task slots (paper: 144).
    pub slots: usize,
    /// Enable speculative execution of stragglers.
    pub speculation: bool,
    /// An attempt is a straggler once it has run `multiplier ×` the median
    /// successful duration (Spark's `spark.speculation.multiplier`).
    pub speculation_multiplier: f64,
    /// Max task attempts before the job fails (Spark's `spark.task.maxFailures`).
    pub max_failures: u32,
    /// Whether Spark manages to abort/clean up losing speculative attempts
    /// (paper Table 3 shows both outcomes).
    pub cleanup_speculation: bool,
    /// Job timestamp used in attempt ids.
    pub job_timestamp: String,
}

impl Default for SparkConfig {
    fn default() -> Self {
        Self {
            slots: 144,
            speculation: false,
            speculation_multiplier: 1.5,
            max_failures: 4,
            cleanup_speculation: true,
            job_timestamp: "201702221313".to_string(),
        }
    }
}
