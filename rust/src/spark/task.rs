//! Task bodies and the per-attempt execution environment.

use crate::committer::{Committer, TaskAttemptContext};
use crate::fs::{FileSystem, FsError, FsOutputStream, OpCtx};
use crate::simclock::SimDuration;
use std::sync::Arc;

/// CPU-side cost model for task compute, on the virtual clock. The real
/// numeric work in this repo runs through the XLA runtime (see
/// [`crate::runtime`]); virtual compute time is charged separately so that
/// simulated runtimes reflect the paper's testbed rather than this
/// machine's CPU.
#[derive(Debug, Clone)]
pub struct ComputeModel {
    /// Sustained per-core processing rate, bytes of input per second.
    pub bytes_per_sec: u64,
    /// Multiplier from simulated bytes to paper-scale bytes (must match
    /// the latency model's `data_scale`).
    pub data_scale: u64,
}

impl ComputeModel {
    pub fn new(bytes_per_sec: u64, data_scale: u64) -> Self {
        Self {
            bytes_per_sec,
            data_scale,
        }
    }

    /// A model that charges nothing (protocol-only tests).
    pub fn free() -> Self {
        Self {
            bytes_per_sec: u64::MAX,
            data_scale: 1,
        }
    }

    /// Virtual time to process `bytes` simulated bytes.
    pub fn time_for(&self, bytes: u64) -> SimDuration {
        if self.bytes_per_sec == u64::MAX {
            return SimDuration::ZERO;
        }
        SimDuration::from_micros(
            bytes
                .saturating_mul(self.data_scale)
                .saturating_mul(1_000_000)
                / self.bytes_per_sec,
        )
    }
}

/// What a task attempt hands back to the driver.
#[derive(Debug, Clone, Default)]
pub struct TaskResult {
    /// Input bytes this attempt consumed (simulated bytes).
    pub bytes_read: u64,
    /// Output bytes this attempt wrote through the committer.
    pub bytes_written: u64,
    /// Records processed (workload-defined unit).
    pub records: u64,
    /// Map-side shuffle output: (reduce partition, payload).
    pub shuffle_out: Vec<(usize, Vec<u8>)>,
    /// Small driver-collected payload (e.g. a count).
    pub collected: Option<Vec<u8>>,
}

/// The environment one task *attempt* runs in.
pub struct TaskRun<'a> {
    pub fs: &'a dyn FileSystem,
    pub ctx: &'a mut OpCtx,
    pub committer: &'a Committer,
    pub attempt: &'a TaskAttemptContext,
    pub compute: &'a ComputeModel,
    /// Reduce-side shuffle input for this task's partition.
    pub shuffle_in: Vec<Arc<Vec<u8>>>,
    /// Fault injection: when set, the next `write_part` streams only this
    /// fraction of its output and then **drops the stream without
    /// `close`** — the real executor-crash abort path. What (if anything)
    /// remains visible is the connector's semantics: Stocator's chunked
    /// PUT leaves a truncated object at the target name, buffer-to-disk
    /// connectors lose the local spool, fast-upload strands an orphaned
    /// multipart upload.
    pub drop_stream_after: Option<f64>,
}

impl<'a> TaskRun<'a> {
    /// Charge virtual compute time for processing `bytes`.
    pub fn charge_compute(&mut self, bytes: u64) {
        let d = self.compute.time_for(bytes);
        self.ctx.add(d);
    }

    /// Stream this task's output part through the commit protocol.
    ///
    /// Transient REST faults on the write path are invisible here while
    /// the connector's `RetryPolicy` absorbs them (re-PUT from spool,
    /// re-send one part, restart the chunked PUT); only an exhausted
    /// budget surfaces, as [`FsError::TransientExhausted`], failing this
    /// attempt — the stream is dropped un-closed, so connector-defined
    /// debris (e.g. a stranded fast-upload multipart) remains for the
    /// committer's abort / the multipart GC sweep to reap.
    pub fn write_part(&mut self, basename: &str, data: Vec<u8>) -> Result<u64, FsError> {
        let mut out = self
            .committer
            .create_part(self.fs, self.attempt, basename, self.ctx)?;
        if let Some(fraction) = self.drop_stream_after {
            // Injected crash mid-stream: part of the output goes onto the
            // wire, then the executor dies — the stream is dropped, never
            // closed.
            let cut = ((data.len() as f64) * fraction).floor() as usize;
            out.write(&data[..cut.min(data.len())], self.ctx)?;
            drop(out);
            return Err(FsError::Io("injected crash mid-stream".into()));
        }
        let n = data.len() as u64;
        // Whole-part fast path: the connector adopts the buffer (no
        // memcpy); REST ops and virtual-clock accounting are identical to
        // a borrowing `write`.
        out.write_owned(data, self.ctx)?;
        out.close(self.ctx)?;
        Ok(n)
    }

    /// The conventional basename for this task's part.
    pub fn part_basename(&self) -> String {
        format!("part-{:05}", self.attempt.attempt.task_id)
    }
}

/// A task body: the closure the driver runs once per attempt. Bodies must
/// be deterministic functions of (task id, inputs) — attempts of the same
/// task must produce identical output, as Spark assumes.
///
/// Not `Send`/`Sync`: bodies capture `Arc<Kernels>`, whose PJRT handles
/// are foreign pointers, and the engine schedules on virtual time from a
/// single real thread anyway.
pub type TaskBody = Arc<dyn Fn(&mut TaskRun<'_>) -> Result<TaskResult, FsError>>;

/// Convenience constructor.
pub fn body<F>(f: F) -> TaskBody
where
    F: Fn(&mut TaskRun<'_>) -> Result<TaskResult, FsError> + 'static,
{
    Arc::new(f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_model_scales() {
        let m = ComputeModel::new(1_000_000, 1);
        assert_eq!(m.time_for(2_000_000), SimDuration::from_secs(2));
        let scaled = ComputeModel::new(1_000_000, 100);
        assert_eq!(scaled.time_for(20_000), SimDuration::from_secs(2));
        assert_eq!(ComputeModel::free().time_for(u64::MAX / 4), SimDuration::ZERO);
    }
}
