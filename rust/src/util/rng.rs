//! Deterministic pseudo-random number generation.
//!
//! The simulator must be fully reproducible (same seed → same trace), so we
//! implement SplitMix64 (for seeding) and PCG32 (for the main stream) from
//! the published references rather than pulling in a crate. Both are
//! well-known, tiny, and statistically solid for simulation purposes.

/// SplitMix64 — used to expand a single `u64` seed into independent streams.
/// Reference: Steele, Lea, Flood, "Fast Splittable Pseudorandom Number
/// Generators", OOPSLA 2014.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR variant) — the workhorse generator.
/// Reference: O'Neill, "PCG: A Family of Simple Fast Space-Efficient
/// Statistically Good Algorithms for Random Number Generation", 2014.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Create a generator from a seed; the stream id is derived from the
    /// seed via SplitMix64 so two generators with different seeds are
    /// independent.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self::with_stream(sm.next_u64(), sm.next_u64())
    }

    /// Create a generator with an explicit stream id (sequence selector).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` using Lemire's multiply-shift rejection.
    #[inline]
    pub fn next_below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u32();
            let m = (x as u64) * (bound as u64);
            let l = m as u32;
            if l >= bound || l >= (bound.wrapping_neg() % bound) {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.next_below((hi - lo) as u32) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// Exponentially-distributed f64 with the given mean (for latency
    /// jitter in the virtual-time model).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.next_f64(); // avoid ln(0)
        -mean * u.ln()
    }

    /// Sample from a Zipf-like distribution over `[0, n)` with exponent `s`
    /// (used for skewed word frequencies in the Wordcount corpus). Uses the
    /// simple inverse-CDF-over-precomputed-table-free rejection method which
    /// is fine for the small `n` we use.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // Rejection sampling per Devroye; adequate for simulation.
        debug_assert!(n >= 1);
        let nf = n as f64;
        loop {
            let u = self.next_f64();
            let v = self.next_f64();
            let x = ((nf + 1.0).powf(1.0 - s) * u + 1.0 - u).powf(1.0 / (1.0 - s));
            let k = x.floor();
            if k < 1.0 || k > nf {
                continue;
            }
            let ratio = (1.0 + 1.0 / k).powf(s - 1.0) * k / (k + 1.0) * (k + 1.0) / x;
            if v * ratio <= 1.0 {
                return k as usize - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // First outputs for seed 0 (cross-checked against the reference C
        // implementation).
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(sm.next_u64(), 0x6E789E6AA1B965F4);
    }

    #[test]
    fn pcg_determinism_and_independence() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        let mut c = Pcg32::new(43);
        let va: Vec<u32> = (0..16).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..16).map(|_| b.next_u32()).collect();
        let vc: Vec<u32> = (0..16).map(|_| c.next_u32()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn next_below_bounds() {
        let mut rng = Pcg32::new(7);
        for bound in [1u32, 2, 3, 10, 1000, u32::MAX] {
            for _ in 0..200 {
                assert!(rng.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn uniformity_rough() {
        // chi-square-ish sanity: 10 buckets, 10k draws, each bucket within
        // 30% of the expectation.
        let mut rng = Pcg32::new(1234);
        let mut buckets = [0u32; 10];
        for _ in 0..10_000 {
            buckets[rng.next_below(10) as usize] += 1;
        }
        for b in buckets {
            assert!((700..=1300).contains(&b), "bucket count {b} out of range");
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg32::new(5);
        for _ in 0..1000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn zipf_skew() {
        // With s=1.2 the most frequent item should dominate.
        let mut rng = Pcg32::new(11);
        let mut counts = vec![0u32; 50];
        for _ in 0..20_000 {
            counts[rng.zipf(50, 1.2)] += 1;
        }
        assert!(counts[0] > counts[10] && counts[0] > counts[49]);
        assert!(counts[0] > 2000, "head item too rare: {}", counts[0]);
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg32::new(13);
        let mean: f64 = (0..20_000).map(|_| rng.exponential(5.0)).sum::<f64>() / 20_000.0;
        assert!((4.5..5.5).contains(&mean), "mean {mean}");
    }
}
