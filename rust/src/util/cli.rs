//! A small command-line argument parser (clap is unavailable offline).
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, and
//! positional arguments, with generated usage text.

use std::collections::BTreeMap;

/// Parsed arguments: subcommand, options, flags and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    /// `flag_names` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, flag_names: &[&str]) -> Result<Args, String> {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if rest.is_empty() {
                    // `--` terminator: everything after is positional.
                    args.positionals.extend(iter.by_ref());
                    break;
                }
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&rest) {
                    args.flags.push(rest.to_string());
                } else {
                    let v = iter
                        .next()
                        .ok_or_else(|| format!("option --{rest} requires a value"))?;
                    args.options.insert(rest.to_string(), v);
                }
            } else if args.subcommand.is_none() && args.positionals.is_empty() {
                args.subcommand = Some(tok);
            } else {
                args.positionals.push(tok);
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| format!("--{name} expects an integer, got '{s}'")),
        }
    }

    /// Error when two mutually exclusive flags were both given.
    pub fn flag_conflict(&self, a: &str, b: &str) -> Result<(), String> {
        if self.flag(a) && self.flag(b) {
            Err(format!("--{a} and --{b} are mutually exclusive"))
        } else {
            Ok(())
        }
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| format!("--{name} expects a number, got '{s}'")),
        }
    }
}

/// Parse a human-friendly duration: `2s`, `500ms`, `1.5s`, or bare
/// seconds (`2`, `0.25`).
pub fn parse_duration(s: &str) -> Result<std::time::Duration, String> {
    let err = || format!("expected a duration like '2s', '500ms' or '1.5', got '{s}'");
    let (num, is_ms) = if let Some(v) = s.strip_suffix("ms") {
        (v, true)
    } else if let Some(v) = s.strip_suffix('s') {
        (v, false)
    } else {
        (s, false)
    };
    let n: f64 = num.trim().parse().map_err(|_| err())?;
    // from_secs_f64 panics on negative/non-finite input; reject first.
    if !n.is_finite() || n < 0.0 {
        return Err(err());
    }
    let secs = if is_ms { n / 1000.0 } else { n };
    Ok(std::time::Duration::from_secs_f64(secs))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str], flags: &[&str]) -> Args {
        Args::parse(tokens.iter().map(|s| s.to_string()), flags).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(
            &["run", "--workload", "teragen", "--scenario=stocator", "extra"],
            &[],
        );
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.opt("workload"), Some("teragen"));
        assert_eq!(a.opt("scenario"), Some("stocator"));
        assert_eq!(a.positionals, vec!["extra"]);
    }

    #[test]
    fn flags_do_not_eat_values() {
        let a = parse(&["bench", "--verbose", "--iters", "3"], &["verbose"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.opt_u64("iters", 1).unwrap(), 3);
    }

    #[test]
    fn missing_value_errors() {
        let e = Args::parse(["--key".to_string()].into_iter(), &[]).unwrap_err();
        assert!(e.contains("requires a value"));
    }

    #[test]
    fn numeric_parsing() {
        let a = parse(&["x", "--n", "42", "--f", "2.5"], &[]);
        assert_eq!(a.opt_u64("n", 0).unwrap(), 42);
        assert_eq!(a.opt_f64("f", 0.0).unwrap(), 2.5);
        assert_eq!(a.opt_u64("absent", 7).unwrap(), 7);
        assert!(a.opt_u64("f", 0).is_err());
    }

    #[test]
    fn flag_conflicts() {
        let a = parse(&["run", "--small", "--paper"], &["small", "paper"]);
        assert!(a.flag_conflict("small", "paper").is_err());
        assert!(a.flag_conflict("small", "verbose").is_ok());
        let b = parse(&["run", "--small"], &["small", "paper"]);
        assert!(b.flag_conflict("small", "paper").is_ok());
    }

    #[test]
    fn double_dash_terminator() {
        let a = parse(&["cmd", "--", "--not-an-option"], &[]);
        assert_eq!(a.positionals, vec!["--not-an-option"]);
    }

    #[test]
    fn duration_spellings() {
        use std::time::Duration;
        assert_eq!(parse_duration("2s").unwrap(), Duration::from_secs(2));
        assert_eq!(parse_duration("500ms").unwrap(), Duration::from_millis(500));
        assert_eq!(parse_duration("1.5s").unwrap(), Duration::from_millis(1500));
        assert_eq!(parse_duration("3").unwrap(), Duration::from_secs(3));
        assert_eq!(parse_duration("0.25").unwrap(), Duration::from_millis(250));
        for bad in ["", "s", "ms", "-1s", "soon", "inf"] {
            assert!(parse_duration(bad).is_err(), "accepted '{bad}'");
        }
    }
}
