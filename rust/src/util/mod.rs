//! Small self-contained utilities built from scratch because the offline
//! environment provides no general-purpose crates (see DESIGN.md §9).

pub mod rng;
pub mod json;
pub mod table;
pub mod proptest;
pub mod cli;

/// Format a byte count using binary units, e.g. `1.50 MiB`.
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} B", n)
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Format a count with thousands separators, e.g. `1_234_567`.
pub fn human_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(human_bytes(0), "0 B");
        assert_eq!(human_bytes(1023), "1023 B");
        assert_eq!(human_bytes(1024), "1.00 KiB");
        assert_eq!(human_bytes(1536), "1.50 KiB");
        assert_eq!(human_bytes(48_849_920_000), "45.50 GiB");
    }

    #[test]
    fn count_formatting() {
        assert_eq!(human_count(0), "0");
        assert_eq!(human_count(999), "999");
        assert_eq!(human_count(1000), "1,000");
        assert_eq!(human_count(1234567), "1,234,567");
    }
}
