//! ASCII table and bar-chart rendering for the benchmark harness — the
//! harness reproduces the paper's *tables* as aligned text tables and its
//! *figures* (grouped bar charts of REST calls / bytes) as horizontal ASCII
//! bar charts.

/// A simple text table with a header row and aligned columns.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    /// Render with box-drawing separators; first column left-aligned,
    /// the rest right-aligned (numeric convention).
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push('|');
                }
                if i == 0 {
                    line.push_str(&format!(" {:<width$} ", cells[i], width = widths[i]));
                } else {
                    line.push_str(&format!(" {:>width$} ", cells[i], width = widths[i]));
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// A grouped horizontal bar chart: one group per label, one bar per series.
/// Used to render the paper's Figures 5–7.
#[derive(Debug, Clone, Default)]
pub struct BarChart {
    pub title: String,
    pub series: Vec<String>,
    /// (group label, values — one per series)
    pub groups: Vec<(String, Vec<f64>)>,
    /// Unit label printed after each value.
    pub unit: String,
}

impl BarChart {
    pub fn new(title: &str, series: &[&str], unit: &str) -> Self {
        Self {
            title: title.to_string(),
            series: series.iter().map(|s| s.to_string()).collect(),
            groups: Vec::new(),
            unit: unit.to_string(),
        }
    }

    pub fn group(&mut self, label: &str, values: Vec<f64>) -> &mut Self {
        assert_eq!(values.len(), self.series.len());
        self.groups.push((label.to_string(), values));
        self
    }

    /// Render; bar lengths are scaled to the global maximum.
    pub fn render(&self) -> String {
        const WIDTH: usize = 48;
        let max = self
            .groups
            .iter()
            .flat_map(|(_, vs)| vs.iter().copied())
            .fold(0.0_f64, f64::max)
            .max(1e-12);
        let series_w = self.series.iter().map(|s| s.len()).max().unwrap_or(0);
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        for (label, values) in &self.groups {
            out.push_str(&format!("{label}\n"));
            for (s, v) in self.series.iter().zip(values) {
                let n = ((v / max) * WIDTH as f64).round() as usize;
                out.push_str(&format!(
                    "  {:<sw$} |{:<w$}| {:.1} {}\n",
                    s,
                    "#".repeat(n.min(WIDTH)),
                    v,
                    self.unit,
                    sw = series_w,
                    w = WIDTH
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["scenario", "ops"]);
        t.row(vec!["Stocator".into(), "8".into()]);
        t.row(vec!["S3a Base".into(), "117".into()]);
        let r = t.render();
        assert!(r.contains("== Demo =="));
        assert!(r.contains("Stocator"));
        // numeric column right-aligned: "  8" under "ops" width 3
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 5); // title, header, sep, 2 rows
        assert!(lines[3].ends_with("  8 ") || lines[3].ends_with("  8"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn chart_scales_to_max() {
        let mut c = BarChart::new("Ops", &["S3a", "Stocator"], "ops");
        c.group("Teragen", vec![100.0, 10.0]);
        let r = c.render();
        // the 100-value bar should be full width (48 '#'), the 10-value ~5.
        assert!(r.contains(&"#".repeat(48)));
        assert!(r.contains("10.0 ops"));
    }

    #[test]
    fn chart_handles_zero_values() {
        let mut c = BarChart::new("z", &["a"], "x");
        c.group("g", vec![0.0]);
        let r = c.render();
        assert!(r.contains("0.0 x"));
    }
}
