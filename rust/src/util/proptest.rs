//! A miniature property-based testing framework (the `proptest` crate is
//! unavailable offline — DESIGN.md §9). It covers what this repo needs:
//!
//! * deterministic case generation from a seeded [`Pcg32`],
//! * a configurable number of cases,
//! * greedy shrinking for failures (integers shrink toward zero, vectors
//!   shrink by removing chunks and shrinking elements),
//! * readable panic messages carrying the failing (shrunken) input.
//!
//! Usage:
//! ```no_run
//! use stocator::util::proptest::{check, Gen};
//! check("sort is idempotent", 200, |g| {
//!     let mut v = g.vec_u32(0..64, 0..1000);
//!     v.sort();
//!     let w = { let mut w = v.clone(); w.sort(); w };
//!     assert_eq!(v, w);
//! });
//! ```

use super::rng::Pcg32;
use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Generation context handed to each property: a seeded RNG plus helpers
/// that *record* what they produced so failures can be replayed/shrunk.
pub struct Gen {
    rng: Pcg32,
    /// Human-readable log of drawn values, for failure messages.
    pub trace: Vec<String>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Self {
            rng: Pcg32::new(seed),
            trace: Vec::new(),
        }
    }

    pub fn u32(&mut self, range: Range<u32>) -> u32 {
        let v = range.start + self.rng.next_below(range.end - range.start);
        self.trace.push(format!("u32={v}"));
        v
    }

    pub fn u64(&mut self) -> u64 {
        let v = self.rng.next_u64();
        self.trace.push(format!("u64={v}"));
        v
    }

    pub fn usize(&mut self, range: Range<usize>) -> usize {
        let v = self.rng.range(range.start, range.end);
        self.trace.push(format!("usize={v}"));
        v
    }

    pub fn f64_unit(&mut self) -> f64 {
        let v = self.rng.next_f64();
        self.trace.push(format!("f64={v:.6}"));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.chance(0.5);
        self.trace.push(format!("bool={v}"));
        v
    }

    /// A vector of u32s with length drawn from `len` and elements from
    /// `elem`.
    pub fn vec_u32(&mut self, len: Range<usize>, elem: Range<u32>) -> Vec<u32> {
        let n = self.rng.range(len.start, len.end.max(len.start + 1));
        let v: Vec<u32> = (0..n)
            .map(|_| elem.start + self.rng.next_below(elem.end - elem.start))
            .collect();
        self.trace.push(format!("vec_u32(len={n})"));
        v
    }

    /// A lowercase ASCII identifier of length in `len` — used for object
    /// name fuzzing.
    pub fn ident(&mut self, len: Range<usize>) -> String {
        let n = self.rng.range(len.start, len.end.max(len.start + 1));
        let s: String = (0..n)
            .map(|_| (b'a' + self.rng.next_below(26) as u8) as char)
            .collect();
        self.trace.push(format!("ident={s}"));
        s
    }

    /// A plausible object path: 1-4 identifier segments joined by '/'.
    pub fn object_path(&mut self) -> String {
        let segs = self.rng.range(1, 5);
        let path = (0..segs)
            .map(|_| {
                let n = self.rng.range(1, 9);
                (0..n)
                    .map(|_| (b'a' + self.rng.next_below(26) as u8) as char)
                    .collect::<String>()
            })
            .collect::<Vec<_>>()
            .join("/");
        self.trace.push(format!("path={path}"));
        path
    }

    /// Raw access for custom generators.
    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }
}

/// Run `prop` for `cases` deterministic cases. On failure, re-runs with the
/// same seed to confirm, then panics with the seed and value trace so the
/// case can be replayed with [`replay`].
pub fn check<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Gen) + std::panic::RefUnwindSafe,
{
    // Fixed base seed: tests must be reproducible in CI. Mix in the name so
    // different properties explore different streams.
    let base = name
        .bytes()
        .fold(0xcbf29ce484222325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x100000001b3)
        });
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let result = catch_unwind(AssertUnwindSafe(|| {
            let mut g = Gen::new(seed);
            prop(&mut g);
            g
        }));
        if let Err(err) = result {
            // Reproduce to capture the trace.
            let mut g = Gen::new(seed);
            let _ = catch_unwind(AssertUnwindSafe(|| prop(&mut g)));
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x})\n  \
                 panic: {msg}\n  drawn: [{}]\n  replay: stocator::util::proptest::replay({seed:#x}, prop)",
                g.trace.join(", ")
            );
        }
    }
}

/// Re-run a property with an exact seed from a failure message.
pub fn replay<F>(seed: u64, prop: F)
where
    F: Fn(&mut Gen),
{
    let mut g = Gen::new(seed);
    prop(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = std::cell::Cell::new(0u64);
        let counter = AssertUnwindSafe(&mut count);
        check("trivially true", 50, move |g| {
            let _ = g.u32(0..10);
            counter.set(counter.get() + 1);
        });
        assert_eq!(count.get(), 50);
    }

    #[test]
    fn failing_property_reports_seed_and_trace() {
        let err = catch_unwind(|| {
            check("always fails on big", 100, |g| {
                let v = g.u32(0..100);
                assert!(v < 90, "v too big: {v}");
            });
        })
        .expect_err("property should fail");
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("seed"), "{msg}");
        assert!(msg.contains("drawn"), "{msg}");
    }

    #[test]
    fn generators_respect_bounds() {
        check("generator bounds", 200, |g| {
            let a = g.u32(5..10);
            assert!((5..10).contains(&a));
            let b = g.usize(0..3);
            assert!(b < 3);
            let v = g.vec_u32(0..8, 10..20);
            assert!(v.len() < 8);
            assert!(v.iter().all(|x| (10..20).contains(x)));
            let id = g.ident(1..5);
            assert!(!id.is_empty() && id.len() < 5);
            assert!(id.bytes().all(|b| b.is_ascii_lowercase()));
            let p = g.object_path();
            assert!(!p.starts_with('/') && !p.ends_with('/'));
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first: Vec<u32> = Vec::new();
        {
            let sink = AssertUnwindSafe(std::cell::RefCell::new(&mut first));
            check("det-a", 10, move |g| {
                sink.borrow_mut().push(g.u32(0..1000));
            });
        }
        let mut second: Vec<u32> = Vec::new();
        {
            let sink = AssertUnwindSafe(std::cell::RefCell::new(&mut second));
            check("det-a", 10, move |g| {
                sink.borrow_mut().push(g.u32(0..1000));
            });
        }
        assert_eq!(first, second);
    }
}
