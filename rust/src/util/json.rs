//! A minimal JSON document model + serializer (serde is unavailable
//! offline; see DESIGN.md §9). Only what the report writers need: objects,
//! arrays, strings, numbers, booleans, null — with stable key order.

use std::fmt::Write as _;

/// A JSON value. Object keys keep insertion order so reports diff cleanly.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert (or replace) a key in an object; panics on non-objects.
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(entries) => {
                if let Some(e) = entries.iter_mut().find(|(k, _)| k == key) {
                    e.1 = val.into();
                } else {
                    entries.push((key.to_string(), val.into()));
                }
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Fetch a key from an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(entries) => entries.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    /// Write the pretty-printed document to `path` (how the
    /// `BENCH_<n>.json` perf-trajectory files are emitted).
    pub fn write_file(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_pretty())
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                if xs.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    x.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(entries) => {
                if entries.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in entries.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_serialization() {
        assert_eq!(Json::Null.to_string(), "null");
        assert_eq!(Json::Bool(true).to_string(), "true");
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
        assert_eq!(Json::Str("hi".into()).to_string(), "\"hi\"");
    }

    #[test]
    fn string_escaping() {
        assert_eq!(
            Json::Str("a\"b\\c\nd".into()).to_string(),
            r#""a\"b\\c\nd""#
        );
        assert_eq!(Json::Str("\u{1}".into()).to_string(), "\"\\u0001\"");
    }

    #[test]
    fn nested_compact() {
        let j = Json::obj()
            .set("name", "stocator")
            .set("ops", vec![1u64, 2, 3])
            .set("nested", Json::obj().set("ok", true));
        assert_eq!(
            j.to_string(),
            r#"{"name":"stocator","ops":[1,2,3],"nested":{"ok":true}}"#
        );
    }

    #[test]
    fn pretty_print_shape() {
        let j = Json::obj().set("a", 1u64).set("b", Json::Arr(vec![]));
        let p = j.to_pretty();
        assert!(p.contains("{\n  \"a\": 1,\n  \"b\": []\n}\n"), "{p}");
    }

    #[test]
    fn set_replaces_existing_key() {
        let j = Json::obj().set("k", 1u64).set("k", 2u64);
        assert_eq!(j.get("k").and_then(Json::as_f64), Some(2.0));
    }

    #[test]
    fn write_file_round_trips_pretty_text() {
        let dir = std::env::temp_dir().join(format!("stocator-json-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bench.json");
        let j = Json::obj().set("bench", "x").set("n", 3u64);
        j.write_file(&path).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), j.to_pretty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn get_and_accessors() {
        let j = Json::obj().set("s", "x").set("n", 4u64);
        assert_eq!(j.get("s").and_then(Json::as_str), Some("x"));
        assert_eq!(j.get("n").and_then(Json::as_f64), Some(4.0));
        assert!(j.get("missing").is_none());
    }
}
