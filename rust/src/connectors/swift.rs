//! The stock Hadoop-Swift connector (`hadoop-openstack` swiftfs), as
//! shipped with Hadoop 2.7.3 — the paper's "H-S Base / H-S Cv2" subject.
//!
//! File-system semantics are emulated on the object store the way the real
//! connector does it (paper §2.3):
//!
//! * "directories" are zero-byte marker objects (`<key>/`), created level
//!   by level on `mkdirs` after HEAD-probing each level;
//! * `getFileStatus` probes: HEAD file, HEAD dir marker, then a prefix
//!   listing for implicit directories;
//! * `rename` = server-side COPY + DELETE, per object, including the
//!   directory markers — renaming a directory renames its whole subtree;
//! * output is buffered to the Spark server's **local disk** before the
//!   PUT (no chunked transfer encoding, §3.3);
//! * reads HEAD the object before GETting it.

use super::{
    container_key, map_store_error, marker_key, maybe_readahead, put_with_retry, StoreInputStream,
};
use crate::fs::{FileSystem, FsError, FsInputStream, FsOutputStream, OpCtx, Path};
use crate::fs::status::FileStatus;
use crate::objectstore::{Metadata, ObjectStore};
use crate::simclock::SimInstant;
use std::sync::Arc;

pub struct HadoopSwift {
    store: Arc<ObjectStore>,
    scheme: String,
}

impl HadoopSwift {
    pub fn new(store: Arc<ObjectStore>) -> Arc<Self> {
        Arc::new(Self {
            store,
            scheme: "swift".to_string(),
        })
    }

    /// The probe cascade behind `getFileStatus`:
    /// HEAD `<key>` → HEAD `<key>/` → GET container `?prefix=<key>/`.
    fn probe_status(&self, path: &Path, ctx: &mut OpCtx) -> Result<FileStatus, FsError> {
        let (cont, key) = container_key(path);
        if key.is_empty() {
            let (r, d) = self.store.head_container(cont);
            ctx.add(d);
            ctx.record("swift", || format!("HEAD container {cont}"));
            return r
                .map(|_| FileStatus::dir(path.clone(), SimInstant::EPOCH))
                .map_err(|e| map_store_error(e, path));
        }
        // 1. file probe
        let (r, d) = self.store.head_object(cont, key);
        ctx.add(d);
        ctx.record("swift", || format!("HEAD {cont}/{key}"));
        if let Ok(h) = r {
            return Ok(FileStatus::file(path.clone(), h.size, h.created_at));
        }
        // 2. dir-marker probe
        let mk = marker_key(key);
        let (r, d) = self.store.head_object(cont, &mk);
        ctx.add(d);
        ctx.record("swift", || format!("HEAD {cont}/{mk}"));
        if r.is_ok() {
            return Ok(FileStatus::dir(path.clone(), SimInstant::EPOCH));
        }
        // 3. implicit-directory probe (anything under the prefix?)
        let (r, d) = self.store.list(cont, &mk, None, ctx.now());
        ctx.add(d);
        ctx.record("swift", || format!("GET container ?prefix={mk}"));
        match r {
            Ok(l) if !l.is_empty() => Ok(FileStatus::dir(path.clone(), SimInstant::EPOCH)),
            _ => Err(FsError::NotFound(path.to_string())),
        }
    }
}

/// Hadoop-Swift output stream (paper §3.3): every `write` spools to the
/// Spark server's **local disk** (no chunked transfer encoding); the one
/// PUT happens at `close`, after the whole part is on disk. Disk time is
/// charged on the *cumulative* spool size (telescoping), so the total
/// cost — including the scale-threshold decision — is identical however
/// callers chunk their writes. Dropping the stream without close — an
/// executor crash — loses the local spool: nothing ever reaches the
/// object store.
struct SwiftOutputStream<'a> {
    fs: &'a HadoopSwift,
    path: Path,
    buf: Vec<u8>,
    closed: bool,
}

impl FsOutputStream for SwiftOutputStream<'_> {
    fn write(&mut self, data: &[u8], ctx: &mut OpCtx) -> Result<(), FsError> {
        if self.closed {
            return Err(FsError::Io(format!("write on closed stream {}", self.path)));
        }
        let latency = &self.fs.store.config.latency;
        let old = self.buf.len() as u64;
        self.buf.extend_from_slice(data);
        ctx.add_spool_delta(old, self.buf.len() as u64, |b| latency.local_disk_time(b));
        Ok(())
    }

    fn write_owned(&mut self, data: Vec<u8>, ctx: &mut OpCtx) -> Result<(), FsError> {
        if self.closed {
            return Err(FsError::Io(format!("write on closed stream {}", self.path)));
        }
        // Whole-part writers hand over their buffer: adopt it instead of
        // copying into the spool. Accounting is identical to `write`.
        let latency = &self.fs.store.config.latency;
        let old = self.buf.len() as u64;
        crate::fs::interface::adopt_buf(&mut self.buf, data);
        ctx.add_spool_delta(old, self.buf.len() as u64, |b| latency.local_disk_time(b));
        Ok(())
    }

    fn close(&mut self, ctx: &mut OpCtx) -> Result<(), FsError> {
        if self.closed {
            return Err(FsError::Io(format!("double close on {}", self.path)));
        }
        self.closed = true;
        let (cont, key) = container_key(&self.path);
        let data = std::mem::take(&mut self.buf);
        // The whole part sits on local disk, so a transient PUT failure
        // resumes cheaply: re-PUT the spool — no disk time is re-paid
        // (the spool survives), only the wire transfer repeats.
        put_with_retry(
            &self.fs.store,
            "swift",
            &self.path,
            cont,
            key,
            data,
            Metadata::new(),
            &format!("PUT {cont}/{key}"),
            ctx,
        )
    }
}

impl FileSystem for HadoopSwift {
    fn scheme(&self) -> &str {
        &self.scheme
    }

    fn mkdirs(&self, path: &Path, ctx: &mut OpCtx) -> Result<(), FsError> {
        // Probe every level from the top; PUT a marker for each missing
        // level (the real connector creates the full pseudo-directory
        // chain).
        let (cont, _) = container_key(path);
        let mut levels = path.ancestors();
        levels.push(path.clone());
        for level in levels {
            if level.is_root() {
                continue;
            }
            match self.probe_status(&level, ctx) {
                Ok(st) if !st.is_dir => {
                    return Err(FsError::NotADirectory(level.to_string()));
                }
                Ok(_) => {} // already a directory
                Err(FsError::NotFound(_)) => {
                    let mk = marker_key(&level.key);
                    put_with_retry(
                        &self.store,
                        "swift",
                        &level,
                        cont,
                        &mk,
                        Vec::new(),
                        Metadata::new(),
                        &format!("PUT {cont}/{mk} (dir marker)"),
                        ctx,
                    )?;
                }
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }

    fn create(
        &self,
        path: &Path,
        overwrite: bool,
        ctx: &mut OpCtx,
    ) -> Result<Box<dyn FsOutputStream + '_>, FsError> {
        if !overwrite {
            match self.probe_status(path, ctx) {
                Ok(st) if st.is_dir => return Err(FsError::IsADirectory(path.to_string())),
                Ok(_) => return Err(FsError::AlreadyExists(path.to_string())),
                Err(FsError::NotFound(_)) => {}
                Err(e) => return Err(e),
            }
        }
        // Writes spool to local disk; the PUT happens at close (§3.3).
        Ok(Box::new(SwiftOutputStream {
            fs: self,
            path: path.clone(),
            buf: Vec::new(),
            closed: false,
        }))
    }

    fn open(&self, path: &Path, ctx: &mut OpCtx) -> Result<Box<dyn FsInputStream + '_>, FsError> {
        let (cont, key) = container_key(path);
        // The legacy connectors HEAD before GET (paper §3.4 — the naive
        // two-op pattern Stocator removes). The GETs themselves happen per
        // read call on the returned handle.
        let (h, d) = self.store.head_object(cont, key);
        ctx.add(d);
        ctx.record("swift", || format!("HEAD {cont}/{key}"));
        let h = h.map_err(|e| map_store_error(e, path))?;
        Ok(maybe_readahead(
            &self.store,
            StoreInputStream::new(&self.store, "swift", path, h.size),
        ))
    }

    fn get_file_status(&self, path: &Path, ctx: &mut OpCtx) -> Result<FileStatus, FsError> {
        self.probe_status(path, ctx)
    }

    fn list_status(&self, path: &Path, ctx: &mut OpCtx) -> Result<Vec<FileStatus>, FsError> {
        let st = self.probe_status(path, ctx)?;
        if !st.is_dir {
            return Ok(vec![st]);
        }
        let (cont, key) = container_key(path);
        let prefix = if key.is_empty() {
            String::new()
        } else {
            marker_key(key)
        };
        let (r, d) = self.store.list(cont, &prefix, Some('/'), ctx.now());
        ctx.add(d);
        ctx.record("swift", || format!("GET container ?prefix={prefix}&delimiter=/"));
        let l = r.map_err(|e| map_store_error(e, path))?;
        let mut out = Vec::new();
        for o in l.objects {
            if o.name == prefix {
                continue; // the directory's own marker
            }
            let child = Path::new(&path.scheme, cont, &o.name);
            out.push(FileStatus::file(child, o.size, SimInstant::EPOCH));
        }
        for cp in l.common_prefixes {
            let trimmed = cp.trim_end_matches('/');
            let child = Path::new(&path.scheme, cont, trimmed);
            out.push(FileStatus::dir(child, SimInstant::EPOCH));
        }
        Ok(out)
    }

    fn rename(&self, src: &Path, dst: &Path, ctx: &mut OpCtx) -> Result<bool, FsError> {
        let (cont, skey) = container_key(src);
        let dkey = dst.key.clone();
        let st = match self.probe_status(src, ctx) {
            Ok(st) => st,
            Err(FsError::NotFound(_)) => return Ok(false),
            Err(e) => return Err(e),
        };
        // Probe the destination (the real connector checks for conflicts).
        let _ = self.probe_status(dst, ctx);
        if !st.is_dir {
            // File: COPY + DELETE.
            let (r, d) = self.store.copy_object(cont, skey, cont, &dkey, ctx.now());
            ctx.add(d);
            ctx.record("swift", || format!("COPY {skey} -> {dkey}"));
            r.map_err(|e| map_store_error(e, src))?;
            let (r, d) = self.store.delete_object(cont, skey, ctx.now());
            ctx.add(d);
            ctx.record("swift", || format!("DELETE {skey}"));
            r.map_err(|e| map_store_error(e, src))?;
            return Ok(true);
        }
        // Directory: list the subtree (eventual consistency risk lives
        // HERE — a listing may miss fresh objects) and copy each object,
        // markers included.
        let sprefix = marker_key(skey);
        let (r, d) = self.store.list(cont, &sprefix, None, ctx.now());
        ctx.add(d);
        ctx.record("swift", || format!("GET container ?prefix={sprefix}"));
        let l = r.map_err(|e| map_store_error(e, src))?;
        for o in l.objects {
            let suffix = &o.name[sprefix.len()..];
            let new_key = if suffix.is_empty() {
                marker_key(&dkey)
            } else {
                format!("{dkey}/{suffix}")
            };
            let (r, d) = self.store.copy_object(cont, &o.name, cont, &new_key, ctx.now());
            ctx.add(d);
            ctx.record("swift", || format!("COPY {} -> {new_key}", o.name));
            // A listed-but-deleted ghost fails the copy; the real connector
            // would throw here. We skip it, which mirrors the "some output
            // silently missing" failure mode.
            if r.is_err() {
                continue;
            }
            let (_, d) = self.store.delete_object(cont, &o.name, ctx.now());
            ctx.add(d);
            ctx.record("swift", || format!("DELETE {}", o.name));
        }
        // The source dir marker itself (if it wasn't in the listing).
        let (r, d) = self.store.delete_object(cont, &sprefix, ctx.now());
        ctx.add(d);
        ctx.record("swift", || format!("DELETE {sprefix}"));
        let _ = r;
        Ok(true)
    }

    fn delete(&self, path: &Path, recursive: bool, ctx: &mut OpCtx) -> Result<bool, FsError> {
        let (cont, key) = container_key(path);
        let st = match self.probe_status(path, ctx) {
            Ok(st) => st,
            Err(FsError::NotFound(_)) => return Ok(false),
            Err(e) => return Err(e),
        };
        if !st.is_dir {
            let (r, d) = self.store.delete_object(cont, key, ctx.now());
            ctx.add(d);
            ctx.record("swift", || format!("DELETE {key}"));
            r.map_err(|e| map_store_error(e, path))?;
            return Ok(true);
        }
        let prefix = marker_key(key);
        let (r, d) = self.store.list(cont, &prefix, None, ctx.now());
        ctx.add(d);
        ctx.record("swift", || format!("GET container ?prefix={prefix}"));
        let l = r.map_err(|e| map_store_error(e, path))?;
        if !recursive && l.objects.iter().any(|o| o.name != prefix) {
            return Err(FsError::Io(format!("directory {path} not empty")));
        }
        for o in l.objects {
            let (_, d) = self.store.delete_object(cont, &o.name, ctx.now());
            ctx.add(d);
            ctx.record("swift", || format!("DELETE {}", o.name));
        }
        // The marker itself, if the (eventually consistent) listing missed
        // it.
        let (_, d) = self.store.delete_object(cont, &prefix, ctx.now());
        ctx.add(d);
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::OpKind;
    use crate::objectstore::StoreConfig;

    fn setup() -> (Arc<ObjectStore>, Arc<HadoopSwift>) {
        let store = ObjectStore::new(StoreConfig::instant_strong());
        store.create_container("res", SimInstant::EPOCH).0.unwrap();
        let fs = HadoopSwift::new(store.clone());
        (store, fs)
    }

    fn p(s: &str) -> Path {
        Path::parse(s).unwrap()
    }

    fn ctx() -> OpCtx {
        OpCtx::new(SimInstant::EPOCH)
    }

    #[test]
    fn mkdirs_creates_marker_chain() {
        let (store, fs) = setup();
        let mut c = ctx();
        fs.mkdirs(&p("swift://res/d/_temporary/0"), &mut c).unwrap();
        let names = store.debug_names("res", "");
        assert_eq!(names, vec!["d/", "d/_temporary/", "d/_temporary/0/"]);
        // Three marker PUTs happened.
        assert_eq!(store.counters().get(OpKind::PutObject), 3 + 1 /*container*/);
    }

    #[test]
    fn create_and_open_roundtrip() {
        let (_, fs) = setup();
        let mut c = ctx();
        fs.write_all(&p("swift://res/d/f"), b"hello".to_vec(), true, &mut c)
            .unwrap();
        let data = fs.read_all(&p("swift://res/d/f"), &mut c).unwrap();
        assert_eq!(&*data, b"hello");
        // Implicit directory now visible:
        let st = fs.get_file_status(&p("swift://res/d"), &mut c).unwrap();
        assert!(st.is_dir);
    }

    #[test]
    fn create_no_overwrite_fails_on_existing() {
        let (_, fs) = setup();
        let mut c = ctx();
        fs.write_all(&p("swift://res/f"), b"1".to_vec(), true, &mut c).unwrap();
        assert!(matches!(
            fs.write_all(&p("swift://res/f"), b"2".to_vec(), false, &mut c),
            Err(FsError::AlreadyExists(_))
        ));
    }

    #[test]
    fn rename_file_is_copy_plus_delete() {
        let (store, fs) = setup();
        let mut c = ctx();
        fs.write_all(&p("swift://res/a"), b"xyz".to_vec(), true, &mut c).unwrap();
        let before = store.counters();
        assert!(fs.rename(&p("swift://res/a"), &p("swift://res/b"), &mut c).unwrap());
        let d = store.counters().since(&before);
        assert_eq!(d.get(OpKind::CopyObject), 1);
        assert_eq!(d.get(OpKind::DeleteObject), 1);
        assert_eq!(d.bytes_copied, 3);
        assert_eq!(&*fs.read_all(&p("swift://res/b"), &mut c).unwrap(), b"xyz");
        assert!(fs.read_all(&p("swift://res/a"), &mut c).is_err());
    }

    #[test]
    fn rename_directory_moves_subtree_with_copies() {
        let (store, fs) = setup();
        let mut c = ctx();
        fs.mkdirs(&p("swift://res/t/src"), &mut c).unwrap();
        fs.write_all(&p("swift://res/t/src/p0"), b"00".to_vec(), true, &mut c).unwrap();
        fs.write_all(&p("swift://res/t/src/p1"), b"11".to_vec(), true, &mut c).unwrap();
        assert!(fs
            .rename(&p("swift://res/t/src"), &p("swift://res/t/dst"), &mut c)
            .unwrap());
        assert!(fs.read_all(&p("swift://res/t/dst/p0"), &mut c).is_ok());
        assert!(fs.read_all(&p("swift://res/t/dst/p1"), &mut c).is_ok());
        assert!(fs.read_all(&p("swift://res/t/src/p0"), &mut c).is_err());
        // 2 files + 1 marker copied.
        assert_eq!(store.counters().get(OpKind::CopyObject), 3);
    }

    #[test]
    fn rename_missing_source_is_false() {
        let (_, fs) = setup();
        let mut c = ctx();
        assert!(!fs.rename(&p("swift://res/no"), &p("swift://res/x"), &mut c).unwrap());
    }

    #[test]
    fn list_status_files_and_dirs() {
        let (_, fs) = setup();
        let mut c = ctx();
        fs.write_all(&p("swift://res/d/f1"), b"1".to_vec(), true, &mut c).unwrap();
        fs.mkdirs(&p("swift://res/d/sub"), &mut c).unwrap();
        let ls = fs.list_status(&p("swift://res/d"), &mut c).unwrap();
        let mut names: Vec<(&str, bool)> =
            ls.iter().map(|s| (s.path.name(), s.is_dir)).collect();
        names.sort();
        assert_eq!(names, vec![("f1", false), ("sub", true)]);
    }

    #[test]
    fn delete_recursive_removes_markers_too() {
        let (store, fs) = setup();
        let mut c = ctx();
        fs.mkdirs(&p("swift://res/d/sub"), &mut c).unwrap();
        fs.write_all(&p("swift://res/d/f"), b"1".to_vec(), true, &mut c).unwrap();
        assert!(fs.delete(&p("swift://res/d"), true, &mut c).unwrap());
        assert!(store.debug_names("res", "").is_empty());
        assert!(!fs.exists(&p("swift://res/d"), &mut c));
    }

    #[test]
    fn buffers_to_local_disk_on_write() {
        // With a slow local disk, create() must be charged disk time.
        let mut cfg = StoreConfig::instant_strong();
        cfg.latency.local_disk_bw = 1_000; // 1 KB/s
        let store = ObjectStore::new(cfg);
        store.create_container("res", SimInstant::EPOCH).0.unwrap();
        let fs = HadoopSwift::new(store);
        let mut c = ctx();
        fs.write_all(&p("swift://res/f"), vec![0u8; 2_000], true, &mut c).unwrap();
        assert!(c.elapsed.as_secs_f64() >= 2.0, "disk time not charged");
    }

    #[test]
    fn dropped_stream_loses_the_local_spool() {
        // Executor crash mid-write: the part was spooling to local disk,
        // so NOTHING reaches the object store — no object, no REST op.
        let (store, fs) = setup();
        let mut c = ctx();
        let before = store.counters();
        {
            let mut out = fs.create(&p("swift://res/doomed"), true, &mut c).unwrap();
            out.write(b"partial bytes", &mut c).unwrap();
            // dropped without close
        }
        assert_eq!(store.counters().since(&before).total(), 0);
        assert!(store.debug_names("res", "").is_empty());
    }

    #[test]
    fn transient_put_resumes_from_spool_without_repaying_disk() {
        use crate::objectstore::{FaultOp, FaultSpec, RetryPolicy};
        // Slow local disk + a fault on the part PUT: the retry re-sends
        // from the spool, so disk time is paid ONCE and the recovery
        // cost is one extra PUT + the backoff.
        let mut cfg = StoreConfig::instant_strong();
        cfg.latency.local_disk_bw = 1_000; // 1 KB/s
        cfg.faults = FaultSpec::one(FaultOp::Put, "d/f", 1);
        cfg.retry = RetryPolicy::with_retries(1);
        let store = ObjectStore::new(cfg);
        store.create_container("res", SimInstant::EPOCH).0.unwrap();
        let fs = HadoopSwift::new(store.clone());
        let mut c = OpCtx::traced(SimInstant::EPOCH);
        fs.write_all(&p("swift://res/d/f"), vec![0u8; 2_000], true, &mut c)
            .unwrap();
        // 2 KB at 1 KB/s = 2s of disk, once; plus the 0.1s retry backoff.
        assert_eq!(c.elapsed.as_micros(), 2_000_000 + 100_000);
        let trace = c.take_trace();
        assert_eq!(
            trace,
            vec![
                "swift: PUT res/d/f (503 transient)",
                "swift: PUT res/d/f",
            ]
        );
        // Both PUTs burned wire bytes; exactly one object landed.
        let counts = store.counters();
        assert_eq!(counts.get(crate::metrics::OpKind::PutObject), 2 + 1 /*container*/);
        assert_eq!(counts.bytes_written, 4_000);
        let mut c2 = OpCtx::new(SimInstant::EPOCH);
        assert_eq!(fs.read_all(&p("swift://res/d/f"), &mut c2).unwrap().len(), 2_000);
    }

    #[test]
    fn exhausted_retries_surface_as_transient_exhausted() {
        use crate::objectstore::{FaultOp, FaultRule, FaultSpec, RetryPolicy};
        let mut cfg = StoreConfig::instant_strong();
        cfg.faults = FaultSpec::none().with(FaultRule::new(FaultOp::Put, "d/f", 1, 2));
        cfg.retry = RetryPolicy::with_retries(1);
        let store = ObjectStore::new(cfg);
        store.create_container("res", SimInstant::EPOCH).0.unwrap();
        let fs = HadoopSwift::new(store);
        let mut c = ctx();
        assert!(matches!(
            fs.write_all(&p("swift://res/d/f"), b"x".to_vec(), true, &mut c),
            Err(FsError::TransientExhausted(_))
        ));
    }

    #[test]
    fn range_read_is_one_head_plus_one_ranged_get() {
        let (store, fs) = setup();
        let mut c = ctx();
        fs.write_all(&p("swift://res/f"), (0u8..50).collect(), true, &mut c).unwrap();
        let before = store.counters();
        let mut input = fs.open(&p("swift://res/f"), &mut c).unwrap();
        assert_eq!(input.size_hint(), Some(50));
        let mid = input.read_range(10, 4, &mut c).unwrap();
        assert_eq!(mid, vec![10, 11, 12, 13]);
        let d = store.counters().since(&before);
        assert_eq!(d.get(OpKind::HeadObject), 1, "HEAD at open (§3.4 legacy)");
        assert_eq!(d.get(OpKind::GetObject), 1);
        assert_eq!(d.bytes_read, 4, "only the slice crosses the wire");
        assert!(matches!(
            input.read_range(51, 1, &mut c),
            Err(FsError::InvalidRange(_))
        ));
    }

    #[test]
    fn eventual_consistency_can_lose_renamed_output() {
        // The §2.2.2 failure: a directory rename right after creating a
        // file misses it because the listing lags.
        let store = ObjectStore::new(StoreConfig::instant_eventual());
        store.create_container("res", SimInstant::EPOCH).0.unwrap();
        let fs = HadoopSwift::new(store.clone());
        let mut c = ctx();
        fs.mkdirs(&p("swift://res/d/src"), &mut c).unwrap();
        fs.write_all(&p("swift://res/d/src/part-0"), b"data".to_vec(), true, &mut c)
            .unwrap();
        // Rename immediately (listing lag is 2s of virtual time; zero
        // virtual time has passed).
        fs.rename(&p("swift://res/d/src"), &p("swift://res/d/dst"), &mut c)
            .unwrap();
        // The part was silently left behind:
        assert!(
            !store.debug_names("res", "d/dst").iter().any(|n| n.ends_with("part-0")),
            "part should have been missed by the lagging listing"
        );
    }
}
