//! The attempt-name codec (paper §3.1).
//!
//! HMRCC asks connectors to write task output at temporary paths of the
//! form
//!
//! ```text
//! <ds>/_temporary/<app>/_temporary/attempt_<jobts>_<jobid>_m_<task>_<n>/<basename>
//! ```
//!
//! and, for FileOutputCommitter v1, to rename committed task output to a
//! job-temporary directory `<ds>/_temporary/<app>/task_<jobts>_<jobid>_m_<task>`.
//!
//! Stocator recognizes these patterns and maps the task temporary file
//! directly to its **final, attempt-qualified name**:
//!
//! ```text
//! <ds>/<basename>_attempt_<jobts>_<jobid>_m_<task>_<n>
//! ```
//!
//! so that every execution attempt of every task writes a *distinct* object
//! and no rename is ever needed. This module implements the pattern
//! classification and the final-name codec, both directions.

use std::fmt;

/// A Spark/Hadoop task *attempt* identity:
/// `attempt_<job-ts>_<job-id>_m_<task-id>_<attempt-number>`.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AttemptId {
    pub job_ts: String,
    pub job_id: String,
    pub task_id: u32,
    pub attempt: u32,
}

impl AttemptId {
    pub fn new(job_ts: &str, job_id: &str, task_id: u32, attempt: u32) -> Self {
        Self {
            job_ts: job_ts.to_string(),
            job_id: job_id.to_string(),
            task_id,
            attempt,
        }
    }

    /// The `task_...` form used for job-temporary directories (no attempt
    /// number).
    pub fn task_string(&self) -> String {
        format!("task_{}_{}_m_{:06}", self.job_ts, self.job_id, self.task_id)
    }

    /// Parse `attempt_<ts>_<id>_m_<task>_<n>`.
    pub fn parse(s: &str) -> Option<AttemptId> {
        let rest = s.strip_prefix("attempt_")?;
        let parts: Vec<&str> = rest.split('_').collect();
        // <ts>_<jobid>_m_<task>_<n>
        if parts.len() != 5 || parts[2] != "m" {
            return None;
        }
        Some(AttemptId {
            job_ts: parts[0].to_string(),
            job_id: parts[1].to_string(),
            task_id: parts[3].parse().ok()?,
            attempt: parts[4].parse().ok()?,
        })
    }
}

impl fmt::Display for AttemptId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "attempt_{}_{}_m_{:06}_{}",
            self.job_ts, self.job_id, self.task_id, self.attempt
        )
    }
}

/// Classification of an object key against the HMRCC temporary-path
/// grammar. `dataset` is always the key of the output dataset root.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TempPath {
    /// `<ds>/_temporary` or `<ds>/_temporary/<app>` (and the nested bare
    /// `<ds>/_temporary/<app>/_temporary`).
    TemporaryRoot { dataset: String },
    /// `<ds>/_temporary/<app>/_temporary/attempt_...` — a task attempt's
    /// working directory.
    AttemptDir { dataset: String, attempt: AttemptId },
    /// `<ds>/_temporary/<app>/_temporary/attempt_.../<basename>` — a task
    /// temporary file.
    TaskTempFile {
        dataset: String,
        attempt: AttemptId,
        basename: String,
    },
    /// `<ds>/_temporary/<app>/task_...` — a job-temporary (task-committed)
    /// directory, v1 only.
    JobTempDir { dataset: String, task: String },
    /// `<ds>/_temporary/<app>/task_.../<basename>` — a job-temporary file.
    JobTempFile {
        dataset: String,
        task: String,
        basename: String,
    },
}

impl TempPath {
    pub fn dataset(&self) -> &str {
        match self {
            TempPath::TemporaryRoot { dataset }
            | TempPath::AttemptDir { dataset, .. }
            | TempPath::TaskTempFile { dataset, .. }
            | TempPath::JobTempDir { dataset, .. }
            | TempPath::JobTempFile { dataset, .. } => dataset,
        }
    }
}

/// Classify an object key against the temp grammar. Returns `None` for
/// ordinary (non-temporary) keys.
pub fn classify(key: &str) -> Option<TempPath> {
    let idx = key.find("/_temporary")?;
    let dataset = key[..idx].to_string();
    let rest = &key[idx + "/_temporary".len()..]; // "" | "/<app>..." etc.
    if rest.is_empty() {
        return Some(TempPath::TemporaryRoot { dataset });
    }
    let rest = rest.strip_prefix('/')?;
    let mut segs = rest.split('/');
    let _app = segs.next()?; // app attempt id, usually "0"
    let Some(second) = segs.next() else {
        // "<ds>/_temporary/<app>"
        return Some(TempPath::TemporaryRoot { dataset });
    };
    if second == "_temporary" {
        let Some(attempt_seg) = segs.next() else {
            // "<ds>/_temporary/<app>/_temporary"
            return Some(TempPath::TemporaryRoot { dataset });
        };
        let attempt = AttemptId::parse(attempt_seg)?;
        match segs.next() {
            None => Some(TempPath::AttemptDir { dataset, attempt }),
            Some(basename) => {
                // Deeper nesting is not part of the grammar; join remainder.
                let mut base = basename.to_string();
                for s in segs {
                    base.push('/');
                    base.push_str(s);
                }
                Some(TempPath::TaskTempFile {
                    dataset,
                    attempt,
                    basename: base,
                })
            }
        }
    } else if second.starts_with("task_") {
        let task = second.to_string();
        match segs.next() {
            None => Some(TempPath::JobTempDir { dataset, task }),
            Some(basename) => {
                let mut base = basename.to_string();
                for s in segs {
                    base.push('/');
                    base.push_str(s);
                }
                Some(TempPath::JobTempFile {
                    dataset,
                    task,
                    basename: base,
                })
            }
        }
    } else {
        // Something odd under _temporary; treat as temp root content.
        Some(TempPath::TemporaryRoot { dataset })
    }
}

/// The final, attempt-qualified object key Stocator writes for a task
/// temporary file (paper §3.1).
pub fn stocator_final_key(dataset: &str, basename: &str, attempt: &AttemptId) -> String {
    format!("{dataset}/{basename}_{attempt}")
}

/// Parse a Stocator final key back into (basename, attempt). `key` must be
/// directly under `dataset`. Returns `None` for non-part objects such as
/// `_SUCCESS` or the dataset marker itself.
pub fn parse_stocator_key(dataset: &str, key: &str) -> Option<(String, AttemptId)> {
    let rel = key.strip_prefix(dataset)?.strip_prefix('/')?;
    if rel.contains('/') {
        return None; // nested object, not a part
    }
    let at = rel.find("_attempt_")?;
    let basename = rel[..at].to_string();
    let attempt = AttemptId::parse(&rel[at + 1..])?;
    Some((basename, attempt))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attempt_id_roundtrip() {
        let a = AttemptId::new("201702221313", "0000", 1, 2);
        let s = a.to_string();
        assert_eq!(s, "attempt_201702221313_0000_m_000001_2");
        assert_eq!(AttemptId::parse(&s).unwrap(), a);
        assert_eq!(a.task_string(), "task_201702221313_0000_m_000001");
    }

    #[test]
    fn attempt_id_rejects_malformed() {
        for bad in [
            "attempt_x",
            "attempt_1_2_r_3_4",
            "attempt_1_2_m_x_4",
            "task_1_2_m_3",
            "attempt_1_2_m_3_4_5",
        ] {
            assert!(AttemptId::parse(bad).is_none(), "{bad}");
        }
    }

    #[test]
    fn classify_the_paper_examples() {
        // Table 1 / §3.1 pattern.
        let key = "res0/data.txt/_temporary/0/_temporary/attempt_201702221313_0000_m_000001_1/part-00001";
        // NOTE: dataset key here is "res0/data.txt" (container handled
        // separately by the connectors).
        match classify(key).unwrap() {
            TempPath::TaskTempFile {
                dataset,
                attempt,
                basename,
            } => {
                assert_eq!(dataset, "res0/data.txt");
                assert_eq!(attempt.task_id, 1);
                assert_eq!(attempt.attempt, 1);
                assert_eq!(basename, "part-00001");
            }
            other => panic!("misclassified: {other:?}"),
        }
    }

    #[test]
    fn classify_attempt_dir_and_roots() {
        assert!(matches!(
            classify("d/_temporary").unwrap(),
            TempPath::TemporaryRoot { .. }
        ));
        assert!(matches!(
            classify("d/_temporary/0").unwrap(),
            TempPath::TemporaryRoot { .. }
        ));
        assert!(matches!(
            classify("d/_temporary/0/_temporary").unwrap(),
            TempPath::TemporaryRoot { .. }
        ));
        match classify("d/_temporary/0/_temporary/attempt_1_0000_m_000002_0").unwrap() {
            TempPath::AttemptDir { attempt, .. } => {
                assert_eq!(attempt.task_id, 2);
                assert_eq!(attempt.attempt, 0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn classify_job_temp() {
        match classify("d/_temporary/0/task_1_0000_m_000002").unwrap() {
            TempPath::JobTempDir { task, .. } => assert_eq!(task, "task_1_0000_m_000002"),
            other => panic!("{other:?}"),
        }
        match classify("d/_temporary/0/task_1_0000_m_000002/part-00002").unwrap() {
            TempPath::JobTempFile { basename, .. } => assert_eq!(basename, "part-00002"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn ordinary_keys_are_not_temp() {
        assert!(classify("data.txt/part-0").is_none());
        assert!(classify("data.txt/_SUCCESS").is_none());
        assert!(classify("x/y/z").is_none());
    }

    #[test]
    fn final_key_roundtrip() {
        let a = AttemptId::new("201512062056", "0000", 2, 1);
        let k = stocator_final_key("data.txt", "part-00002", &a);
        assert_eq!(
            k,
            "data.txt/part-00002_attempt_201512062056_0000_m_000002_1"
        );
        let (base, parsed) = parse_stocator_key("data.txt", &k).unwrap();
        assert_eq!(base, "part-00002");
        assert_eq!(parsed, a);
    }

    #[test]
    fn parse_stocator_key_rejects_non_parts() {
        assert!(parse_stocator_key("d", "d/_SUCCESS").is_none());
        assert!(parse_stocator_key("d", "d/sub/part-0_attempt_1_0_m_000000_0").is_none());
        assert!(parse_stocator_key("d", "other/part-0_attempt_1_0_m_000000_0").is_none());
        assert!(parse_stocator_key("d", "d/part-0").is_none());
    }

    #[test]
    fn final_names_of_distinct_attempts_differ() {
        // The core safety property of the naming scheme (speculation).
        let k1 = stocator_final_key("d", "part-0", &AttemptId::new("1", "0000", 0, 0));
        let k2 = stocator_final_key("d", "part-0", &AttemptId::new("1", "0000", 0, 1));
        assert_ne!(k1, k2);
    }
}
