//! The Hadoop S3a connector, 2.7.x behaviour — the paper's "S3a Base /
//! S3a Cv2 / S3a Cv2+FU" subject.
//!
//! S3a is chattier than Hadoop-Swift (paper Table 2: 117 REST ops vs 48 for
//! a one-object job):
//!
//! * `getFileStatus` is the notorious **triple probe**: HEAD `<key>`, HEAD
//!   `<key>/`, then GET container `?prefix=<key>/` — and because S3a
//!   deletes parent "fake directories" after every file PUT, directory
//!   probes almost always fall through to the listing;
//! * after every file PUT or COPY it walks every ancestor and deletes the
//!   now-"unnecessary" fake directory markers (HEAD + DELETE per level);
//! * after a DELETE/rename empties a directory it re-creates the fake
//!   marker (LIST + PUT);
//! * `rename` COPYes + DELETEs each object, with full probes on both ends;
//! * output is buffered to local disk, unless **fast upload**
//!   (`S3AFastOutputStream`, §3.3) is on, which streams via multipart
//!   upload at the cost of ≥5 MB in-memory parts.

use super::{container_key, marker_key};
use crate::fs::status::FileStatus;
use crate::fs::{FileSystem, FsError, OpCtx, Path};
use crate::objectstore::{Metadata, ObjectStore, StoreError};
use crate::simclock::SimInstant;
use std::sync::Arc;

/// S3a tuning knobs (subset the paper exercises).
#[derive(Debug, Clone)]
pub struct S3aConfig {
    /// `fs.s3a.fast.upload` — stream via multipart instead of buffering the
    /// whole part on local disk.
    pub fast_upload: bool,
    /// `fs.s3a.multipart.size` in *simulated* bytes (the harness sets this
    /// to 100 MB / data_scale to mirror the 2.7 default).
    pub multipart_size: u64,
}

impl Default for S3aConfig {
    fn default() -> Self {
        Self {
            fast_upload: false,
            multipart_size: 100 * 1024 * 1024,
        }
    }
}

pub struct S3a {
    store: Arc<ObjectStore>,
    cfg: S3aConfig,
    scheme: String,
}

impl S3a {
    pub fn new(store: Arc<ObjectStore>, cfg: S3aConfig) -> Arc<Self> {
        Arc::new(Self {
            store,
            cfg,
            scheme: "s3a".to_string(),
        })
    }

    fn not_found(e: StoreError, path: &Path) -> FsError {
        match e {
            StoreError::NoSuchKey(_) | StoreError::NoSuchContainer(_) => {
                FsError::NotFound(path.to_string())
            }
            other => FsError::Io(other.to_string()),
        }
    }

    /// The triple probe: HEAD key, HEAD key/, LIST prefix=key/.
    fn probe_status(&self, path: &Path, ctx: &mut OpCtx) -> Result<FileStatus, FsError> {
        let (cont, key) = container_key(path);
        if key.is_empty() {
            let (r, d) = self.store.head_container(cont);
            ctx.add(d);
            ctx.record("s3a", || format!("HEAD container {cont}"));
            return r
                .map(|_| FileStatus::dir(path.clone(), SimInstant::EPOCH))
                .map_err(|e| Self::not_found(e, path));
        }
        let (r, d) = self.store.head_object(cont, key);
        ctx.add(d);
        ctx.record("s3a", || format!("HEAD {cont}/{key}"));
        if let Ok(h) = r {
            return Ok(FileStatus::file(path.clone(), h.size, h.created_at));
        }
        let mk = marker_key(key);
        let (r, d) = self.store.head_object(cont, &mk);
        ctx.add(d);
        ctx.record("s3a", || format!("HEAD {cont}/{mk}"));
        if r.is_ok() {
            return Ok(FileStatus::dir(path.clone(), SimInstant::EPOCH));
        }
        let (r, d) = self.store.list(cont, &mk, None, ctx.now());
        ctx.add(d);
        ctx.record("s3a", || format!("GET container ?prefix={mk}&max-keys=1"));
        match r {
            Ok(l) if !l.is_empty() => Ok(FileStatus::dir(path.clone(), SimInstant::EPOCH)),
            _ => Err(FsError::NotFound(path.to_string())),
        }
    }

    /// `deleteUnnecessaryFakeDirectories`: after a file lands at `path`,
    /// every ancestor's fake-dir marker is probed and deleted.
    fn delete_unnecessary_fake_directories(&self, path: &Path, ctx: &mut OpCtx) {
        let (cont, _) = container_key(path);
        let mut cur = path.parent();
        while let Some(dir) = cur {
            if dir.is_root() {
                break;
            }
            let mk = marker_key(&dir.key);
            let (r, d) = self.store.head_object(cont, &mk);
            ctx.add(d);
            ctx.record("s3a", || format!("HEAD {cont}/{mk} (fake-dir check)"));
            if r.is_ok() {
                let (_, d) = self.store.delete_object(cont, &mk, ctx.now());
                ctx.add(d);
                ctx.record("s3a", || format!("DELETE {cont}/{mk} (fake dir)"));
            }
            cur = dir.parent();
        }
    }

    /// `createFakeDirectoryIfNecessary`: after removing the last object
    /// under `dir`, S3a re-creates the marker so the directory keeps
    /// existing.
    fn create_fake_directory_if_necessary(&self, dir: &Path, ctx: &mut OpCtx) {
        if dir.is_root() {
            return;
        }
        let (cont, key) = container_key(dir);
        let mk = marker_key(key);
        let (r, d) = self.store.list(cont, &mk, None, ctx.now());
        ctx.add(d);
        ctx.record("s3a", || format!("GET container ?prefix={mk} (empty check)"));
        if matches!(r, Ok(l) if l.is_empty()) {
            let (_, d) = self
                .store
                .put_object(cont, &mk, Vec::new(), Metadata::new(), ctx.now());
            ctx.add(d);
            ctx.record("s3a", || format!("PUT {cont}/{mk} (fake dir)"));
        }
    }

    /// Upload a file's content: plain PUT via local-disk buffer, or
    /// multipart when fast upload is enabled and the object is large.
    fn upload(&self, cont: &str, key: &str, data: Vec<u8>, ctx: &mut OpCtx) -> Result<(), FsError> {
        if self.cfg.fast_upload && data.len() as u64 > self.cfg.multipart_size {
            // S3AFastOutputStream: stream parts as they fill (no disk).
            let (r, d) = self.store.initiate_multipart(cont, key, Metadata::new());
            ctx.add(d);
            ctx.record("s3a", || format!("POST {cont}/{key}?uploads (initiate)"));
            let id = r.map_err(|e| FsError::Io(e.to_string()))?;
            let psize = self.cfg.multipart_size as usize;
            for (i, chunk) in data.chunks(psize.max(1)).enumerate() {
                let (r, d) = self.store.upload_part(id, i as u32 + 1, chunk.to_vec());
                ctx.add(d);
                ctx.record("s3a", || format!("PUT {cont}/{key}?partNumber={}", i + 1));
                r.map_err(|e| FsError::Io(e.to_string()))?;
            }
            let (r, d) = self.store.complete_multipart(id, ctx.now());
            ctx.add(d);
            ctx.record("s3a", || format!("POST {cont}/{key} (complete)"));
            r.map_err(|e| FsError::Io(e.to_string()))
        } else {
            if !self.cfg.fast_upload {
                // Buffer the whole part on local disk first (paper §3.3).
                ctx.add(self.store.config.latency.local_disk_time(data.len() as u64));
            }
            let (r, d) = self
                .store
                .put_object(cont, key, data, Metadata::new(), ctx.now());
            ctx.add(d);
            ctx.record("s3a", || format!("PUT {cont}/{key}"));
            r.map_err(|e| FsError::Io(e.to_string()))
        }
    }
}

impl FileSystem for S3a {
    fn scheme(&self) -> &str {
        &self.scheme
    }

    fn mkdirs(&self, path: &Path, ctx: &mut OpCtx) -> Result<(), FsError> {
        // Probe the target, then walk ancestors checking none is a file,
        // then PUT a fake marker for the leaf only (S3a 2.7 semantics).
        match self.probe_status(path, ctx) {
            Ok(st) if st.is_dir => return Ok(()),
            Ok(_) => return Err(FsError::NotADirectory(path.to_string())),
            Err(FsError::NotFound(_)) => {}
            Err(e) => return Err(e),
        }
        for anc in path.ancestors().iter().rev() {
            match self.probe_status(anc, ctx) {
                Ok(st) if !st.is_dir => {
                    return Err(FsError::NotADirectory(anc.to_string()))
                }
                Ok(_) => break, // found an existing dir; all above exist
                Err(FsError::NotFound(_)) => continue,
                Err(e) => return Err(e),
            }
        }
        let (cont, key) = container_key(path);
        let mk = marker_key(key);
        let (r, d) = self
            .store
            .put_object(cont, &mk, Vec::new(), Metadata::new(), ctx.now());
        ctx.add(d);
        ctx.record("s3a", || format!("PUT {cont}/{mk} (fake dir)"));
        r.map_err(|e| Self::not_found(e, path))
    }

    fn create(
        &self,
        path: &Path,
        data: Vec<u8>,
        overwrite: bool,
        ctx: &mut OpCtx,
    ) -> Result<(), FsError> {
        let (cont, key) = container_key(path);
        // S3a always probes the target (even with overwrite=true it checks
        // it isn't a directory).
        match self.probe_status(path, ctx) {
            Ok(st) if st.is_dir => return Err(FsError::IsADirectory(path.to_string())),
            Ok(_) if !overwrite => return Err(FsError::AlreadyExists(path.to_string())),
            _ => {}
        }
        self.upload(cont, key, data, ctx)?;
        self.delete_unnecessary_fake_directories(path, ctx);
        Ok(())
    }

    fn open(&self, path: &Path, ctx: &mut OpCtx) -> Result<Arc<Vec<u8>>, FsError> {
        let (cont, key) = container_key(path);
        // getFileStatus first (S3AInputStream does), then GET.
        let st = self.probe_status(path, ctx)?;
        if st.is_dir {
            return Err(FsError::IsADirectory(path.to_string()));
        }
        let (r, d) = self.store.get_object(cont, key);
        ctx.add(d);
        ctx.record("s3a", || format!("GET {cont}/{key}"));
        r.map(|g| g.data).map_err(|e| Self::not_found(e, path))
    }

    fn get_file_status(&self, path: &Path, ctx: &mut OpCtx) -> Result<FileStatus, FsError> {
        self.probe_status(path, ctx)
    }

    fn list_status(&self, path: &Path, ctx: &mut OpCtx) -> Result<Vec<FileStatus>, FsError> {
        let st = self.probe_status(path, ctx)?;
        if !st.is_dir {
            return Ok(vec![st]);
        }
        let (cont, key) = container_key(path);
        let prefix = if key.is_empty() {
            String::new()
        } else {
            marker_key(key)
        };
        let (r, d) = self.store.list(cont, &prefix, Some('/'), ctx.now());
        ctx.add(d);
        ctx.record("s3a", || format!("GET container ?prefix={prefix}&delimiter=/"));
        let l = r.map_err(|e| Self::not_found(e, path))?;
        let mut out = Vec::new();
        for o in l.objects {
            if o.name == prefix {
                continue;
            }
            out.push(FileStatus::file(
                Path::new(&path.scheme, cont, &o.name),
                o.size,
                SimInstant::EPOCH,
            ));
        }
        for cp in l.common_prefixes {
            out.push(FileStatus::dir(
                Path::new(&path.scheme, cont, cp.trim_end_matches('/')),
                SimInstant::EPOCH,
            ));
        }
        Ok(out)
    }

    fn rename(&self, src: &Path, dst: &Path, ctx: &mut OpCtx) -> Result<bool, FsError> {
        let (cont, skey) = container_key(src);
        let dkey = dst.key.clone();
        let st = match self.probe_status(src, ctx) {
            Ok(st) => st,
            Err(FsError::NotFound(_)) => return Ok(false),
            Err(e) => return Err(e),
        };
        // Probe destination and destination parent (S3a checks both).
        let _ = self.probe_status(dst, ctx);
        if let Some(dparent) = dst.parent() {
            if !dparent.is_root() {
                let _ = self.probe_status(&dparent, ctx);
            }
        }
        if !st.is_dir {
            let (r, d) = self.store.copy_object(cont, skey, cont, &dkey, ctx.now());
            ctx.add(d);
            ctx.record("s3a", || format!("COPY {skey} -> {dkey}"));
            r.map_err(|e| Self::not_found(e, src))?;
            let (r, d) = self.store.delete_object(cont, skey, ctx.now());
            ctx.add(d);
            ctx.record("s3a", || format!("DELETE {skey}"));
            r.map_err(|e| Self::not_found(e, src))?;
            self.delete_unnecessary_fake_directories(dst, ctx);
            if let Some(sparent) = src.parent() {
                self.create_fake_directory_if_necessary(&sparent, ctx);
            }
            return Ok(true);
        }
        // Directory rename: list the subtree and move each object.
        let sprefix = marker_key(skey);
        let (r, d) = self.store.list(cont, &sprefix, None, ctx.now());
        ctx.add(d);
        ctx.record("s3a", || format!("GET container ?prefix={sprefix}"));
        let l = r.map_err(|e| Self::not_found(e, src))?;
        for o in l.objects {
            let suffix = &o.name[sprefix.len()..];
            let new_key = if suffix.is_empty() {
                marker_key(&dkey)
            } else {
                format!("{dkey}/{suffix}")
            };
            let (r, d) = self.store.copy_object(cont, &o.name, cont, &new_key, ctx.now());
            ctx.add(d);
            ctx.record("s3a", || format!("COPY {} -> {new_key}", o.name));
            if r.is_err() {
                continue; // ghost entry from an eventually-consistent listing
            }
            let (_, d) = self.store.delete_object(cont, &o.name, ctx.now());
            ctx.add(d);
            ctx.record("s3a", || format!("DELETE {}", o.name));
        }
        let (_, d) = self.store.delete_object(cont, &sprefix, ctx.now());
        ctx.add(d);
        self.delete_unnecessary_fake_directories(dst, ctx);
        if let Some(sparent) = src.parent() {
            self.create_fake_directory_if_necessary(&sparent, ctx);
        }
        Ok(true)
    }

    fn delete(&self, path: &Path, recursive: bool, ctx: &mut OpCtx) -> Result<bool, FsError> {
        let (cont, key) = container_key(path);
        let st = match self.probe_status(path, ctx) {
            Ok(st) => st,
            Err(FsError::NotFound(_)) => return Ok(false),
            Err(e) => return Err(e),
        };
        if !st.is_dir {
            let (r, d) = self.store.delete_object(cont, key, ctx.now());
            ctx.add(d);
            ctx.record("s3a", || format!("DELETE {key}"));
            r.map_err(|e| Self::not_found(e, path))?;
            if let Some(parent) = path.parent() {
                self.create_fake_directory_if_necessary(&parent, ctx);
            }
            return Ok(true);
        }
        let prefix = marker_key(key);
        let (r, d) = self.store.list(cont, &prefix, None, ctx.now());
        ctx.add(d);
        ctx.record("s3a", || format!("GET container ?prefix={prefix}"));
        let l = r.map_err(|e| Self::not_found(e, path))?;
        if !recursive && l.objects.iter().any(|o| o.name != prefix) {
            return Err(FsError::Io(format!("directory {path} not empty")));
        }
        for o in l.objects {
            let (_, d) = self.store.delete_object(cont, &o.name, ctx.now());
            ctx.add(d);
            ctx.record("s3a", || format!("DELETE {}", o.name));
        }
        let (_, d) = self.store.delete_object(cont, &prefix, ctx.now());
        ctx.add(d);
        if let Some(parent) = path.parent() {
            self.create_fake_directory_if_necessary(&parent, ctx);
        }
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::OpKind;
    use crate::objectstore::StoreConfig;

    fn setup(cfg: S3aConfig) -> (Arc<ObjectStore>, Arc<S3a>) {
        let store = ObjectStore::new(StoreConfig::instant_strong());
        store.create_container("res", SimInstant::EPOCH).0.unwrap();
        let fs = S3a::new(store.clone(), cfg);
        (store, fs)
    }

    fn p(s: &str) -> Path {
        Path::parse(s).unwrap()
    }

    fn ctx() -> OpCtx {
        OpCtx::new(SimInstant::EPOCH)
    }

    #[test]
    fn triple_probe_on_missing_path() {
        let (store, fs) = setup(S3aConfig::default());
        let mut c = ctx();
        let before = store.counters();
        assert!(fs.get_file_status(&p("s3a://res/missing"), &mut c).is_err());
        let d = store.counters().since(&before);
        assert_eq!(d.get(OpKind::HeadObject), 2, "HEAD key + HEAD key/");
        assert_eq!(d.get(OpKind::GetContainer), 1, "list fallback");
    }

    #[test]
    fn put_deletes_parent_fake_dirs() {
        let (store, fs) = setup(S3aConfig::default());
        let mut c = ctx();
        fs.mkdirs(&p("s3a://res/d"), &mut c).unwrap();
        assert!(store.debug_names("res", "").contains(&"d/".to_string()));
        fs.create(&p("s3a://res/d/f"), b"x".to_vec(), true, &mut c).unwrap();
        // The fake marker for d/ is gone after the file PUT.
        assert!(!store.debug_names("res", "").contains(&"d/".to_string()));
        // The directory still "exists" via the implicit-list probe:
        assert!(fs.get_file_status(&p("s3a://res/d"), &mut c).unwrap().is_dir);
    }

    #[test]
    fn delete_last_file_recreates_parent_marker() {
        let (store, fs) = setup(S3aConfig::default());
        let mut c = ctx();
        fs.create(&p("s3a://res/d/f"), b"x".to_vec(), true, &mut c).unwrap();
        fs.delete(&p("s3a://res/d/f"), false, &mut c).unwrap();
        assert!(
            store.debug_names("res", "").contains(&"d/".to_string()),
            "marker must be restored so the dir keeps existing"
        );
    }

    #[test]
    fn fast_upload_uses_multipart_above_threshold() {
        let (store, fs) = setup(S3aConfig {
            fast_upload: true,
            multipart_size: 4,
        });
        let mut c = ctx();
        let before = store.counters();
        fs.create(&p("s3a://res/big"), vec![7u8; 10], true, &mut c).unwrap();
        let d = store.counters().since(&before);
        // initiate + 3 parts (4+4+2) + complete = 5 PUT-class ops.
        assert_eq!(d.get(OpKind::PutObject), 5);
        let mut c2 = ctx();
        assert_eq!(*fs.open(&p("s3a://res/big"), &mut c2).unwrap(), vec![7u8; 10]);
    }

    #[test]
    fn fast_upload_small_object_single_put() {
        let (store, fs) = setup(S3aConfig {
            fast_upload: true,
            multipart_size: 1024,
        });
        let mut c = ctx();
        let before = store.counters();
        fs.create(&p("s3a://res/small"), vec![1u8; 10], true, &mut c).unwrap();
        assert_eq!(store.counters().since(&before).get(OpKind::PutObject), 1);
    }

    #[test]
    fn fast_upload_skips_local_disk() {
        let mut cfg = StoreConfig::instant_strong();
        cfg.latency.local_disk_bw = 1; // pathologically slow disk
        let store = ObjectStore::new(cfg);
        store.create_container("res", SimInstant::EPOCH).0.unwrap();
        let fast = S3a::new(
            store.clone(),
            S3aConfig {
                fast_upload: true,
                multipart_size: 1 << 30,
            },
        );
        let mut c = ctx();
        fast.create(&p("s3a://res/f"), vec![0u8; 1000], true, &mut c).unwrap();
        assert_eq!(c.elapsed.as_micros(), 0, "fast upload must not touch disk");
        let slow = S3a::new(store, S3aConfig::default());
        let mut c2 = ctx();
        slow.create(&p("s3a://res/g"), vec![0u8; 1000], true, &mut c2).unwrap();
        assert!(c2.elapsed.as_secs_f64() > 100.0, "buffered path must pay disk time");
    }

    #[test]
    fn rename_file_and_marker_maintenance() {
        let (store, fs) = setup(S3aConfig::default());
        let mut c = ctx();
        fs.create(&p("s3a://res/a/f"), b"zz".to_vec(), true, &mut c).unwrap();
        assert!(fs
            .rename(&p("s3a://res/a/f"), &p("s3a://res/b/f"), &mut c)
            .unwrap());
        assert!(fs.open(&p("s3a://res/b/f"), &mut c).is_ok());
        assert!(fs.open(&p("s3a://res/a/f"), &mut c).is_err());
        // Source parent "a" became empty: marker restored.
        assert!(store.debug_names("res", "").contains(&"a/".to_string()));
        assert_eq!(store.counters().get(OpKind::CopyObject), 1);
    }

    #[test]
    fn s3a_is_chattier_than_swift() {
        // The structural claim behind Table 2: for the same logical work,
        // S3a issues more REST calls than Hadoop-Swift.
        let store_s = ObjectStore::new(StoreConfig::instant_strong());
        store_s.create_container("res", SimInstant::EPOCH).0.unwrap();
        let swift = crate::connectors::swift::HadoopSwift::new(store_s.clone());
        let store_a = ObjectStore::new(StoreConfig::instant_strong());
        store_a.create_container("res", SimInstant::EPOCH).0.unwrap();
        let s3a = S3a::new(store_a.clone(), S3aConfig::default());

        let work = |fs: &dyn FileSystem, scheme: &str| {
            let mut c = ctx();
            let d = Path::parse(&format!("{scheme}://res/out")).unwrap();
            fs.mkdirs(&d.child("_temporary/0"), &mut c).unwrap();
            fs.create(&d.child("_temporary/0/part-0"), b"x".to_vec(), true, &mut c)
                .unwrap();
            fs.rename(&d.child("_temporary/0/part-0"), &d.child("part-0"), &mut c)
                .unwrap();
            fs.delete(&d.child("_temporary"), true, &mut c).unwrap();
            fs.create(&d.child("_SUCCESS"), vec![], true, &mut c).unwrap();
        };
        work(&*swift, "swift");
        work(&*s3a, "s3a");
        let swift_total = store_s.counters().total();
        let s3a_total = store_a.counters().total();
        assert!(
            s3a_total > swift_total,
            "s3a={s3a_total} should exceed swift={swift_total}"
        );
    }
}
