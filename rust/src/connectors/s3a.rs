//! The Hadoop S3a connector, 2.7.x behaviour — the paper's "S3a Base /
//! S3a Cv2 / S3a Cv2+FU" subject.
//!
//! S3a is chattier than Hadoop-Swift (paper Table 2: 117 REST ops vs 48 for
//! a one-object job):
//!
//! * `getFileStatus` is the notorious **triple probe**: HEAD `<key>`, HEAD
//!   `<key>/`, then GET container `?prefix=<key>/` — and because S3a
//!   deletes parent "fake directories" after every file PUT, directory
//!   probes almost always fall through to the listing;
//! * after every file PUT or COPY it walks every ancestor and deletes the
//!   now-"unnecessary" fake directory markers (HEAD + DELETE per level);
//! * after a DELETE/rename empties a directory it re-creates the fake
//!   marker (LIST + PUT);
//! * `rename` COPYes + DELETEs each object, with full probes on both ends;
//! * output is buffered to local disk, unless **fast upload**
//!   (`S3AFastOutputStream`, §3.3) is on, which streams via multipart
//!   upload at the cost of ≥5 MB in-memory parts.

use super::{
    container_key, map_store_error, marker_key, maybe_readahead, put_with_retry, StoreInputStream,
};
use crate::fs::status::FileStatus;
use crate::fs::{FileSystem, FsError, FsInputStream, FsOutputStream, OpCtx, Path};
use crate::objectstore::{Metadata, ObjectStore};
use crate::simclock::SimInstant;
use std::sync::Arc;

/// S3a tuning knobs (subset the paper exercises).
#[derive(Debug, Clone)]
pub struct S3aConfig {
    /// `fs.s3a.fast.upload` — stream via multipart instead of buffering the
    /// whole part on local disk.
    pub fast_upload: bool,
    /// `fs.s3a.multipart.size` in *simulated* bytes (the harness sets this
    /// to 100 MB / data_scale to mirror the 2.7 default).
    pub multipart_size: u64,
}

impl Default for S3aConfig {
    fn default() -> Self {
        Self {
            fast_upload: false,
            multipart_size: 100 * 1024 * 1024,
        }
    }
}

pub struct S3a {
    store: Arc<ObjectStore>,
    cfg: S3aConfig,
    scheme: String,
}

impl S3a {
    pub fn new(store: Arc<ObjectStore>, cfg: S3aConfig) -> Arc<Self> {
        Arc::new(Self {
            store,
            cfg,
            scheme: "s3a".to_string(),
        })
    }

    /// The triple probe: HEAD key, HEAD key/, LIST prefix=key/.
    fn probe_status(&self, path: &Path, ctx: &mut OpCtx) -> Result<FileStatus, FsError> {
        let (cont, key) = container_key(path);
        if key.is_empty() {
            let (r, d) = self.store.head_container(cont);
            ctx.add(d);
            ctx.record("s3a", || format!("HEAD container {cont}"));
            return r
                .map(|_| FileStatus::dir(path.clone(), SimInstant::EPOCH))
                .map_err(|e| map_store_error(e, path));
        }
        let (r, d) = self.store.head_object(cont, key);
        ctx.add(d);
        ctx.record("s3a", || format!("HEAD {cont}/{key}"));
        if let Ok(h) = r {
            return Ok(FileStatus::file(path.clone(), h.size, h.created_at));
        }
        let mk = marker_key(key);
        let (r, d) = self.store.head_object(cont, &mk);
        ctx.add(d);
        ctx.record("s3a", || format!("HEAD {cont}/{mk}"));
        if r.is_ok() {
            return Ok(FileStatus::dir(path.clone(), SimInstant::EPOCH));
        }
        let (r, d) = self.store.list(cont, &mk, None, ctx.now());
        ctx.add(d);
        ctx.record("s3a", || format!("GET container ?prefix={mk}&max-keys=1"));
        match r {
            Ok(l) if !l.is_empty() => Ok(FileStatus::dir(path.clone(), SimInstant::EPOCH)),
            _ => Err(FsError::NotFound(path.to_string())),
        }
    }

    /// `deleteUnnecessaryFakeDirectories`: after a file lands at `path`,
    /// every ancestor's fake-dir marker is probed and deleted.
    fn delete_unnecessary_fake_directories(&self, path: &Path, ctx: &mut OpCtx) {
        let (cont, _) = container_key(path);
        let mut cur = path.parent();
        while let Some(dir) = cur {
            if dir.is_root() {
                break;
            }
            let mk = marker_key(&dir.key);
            let (r, d) = self.store.head_object(cont, &mk);
            ctx.add(d);
            ctx.record("s3a", || format!("HEAD {cont}/{mk} (fake-dir check)"));
            if r.is_ok() {
                let (_, d) = self.store.delete_object(cont, &mk, ctx.now());
                ctx.add(d);
                ctx.record("s3a", || format!("DELETE {cont}/{mk} (fake dir)"));
            }
            cur = dir.parent();
        }
    }

    /// `createFakeDirectoryIfNecessary`: after removing the last object
    /// under `dir`, S3a re-creates the marker so the directory keeps
    /// existing.
    fn create_fake_directory_if_necessary(&self, dir: &Path, ctx: &mut OpCtx) {
        if dir.is_root() {
            return;
        }
        let (cont, key) = container_key(dir);
        let mk = marker_key(key);
        let (r, d) = self.store.list(cont, &mk, None, ctx.now());
        ctx.add(d);
        ctx.record("s3a", || format!("GET container ?prefix={mk} (empty check)"));
        if matches!(r, Ok(l) if l.is_empty()) {
            // Best-effort like the real connector (failure is swallowed),
            // but transients still get the shared retry budget so a
            // flaky PUT doesn't silently lose the marker.
            let _ = put_with_retry(
                &self.store,
                "s3a",
                dir,
                cont,
                &mk,
                Vec::new(),
                Metadata::new(),
                &format!("PUT {cont}/{mk} (fake dir)"),
                ctx,
            );
        }
    }

}

/// S3a output stream. Two §3.3 personalities:
///
/// * **base** (`fast_upload = false`): every `write` spools to local
///   disk; one PUT uploads the whole part at `close`. A dropped stream
///   loses the spool — nothing reaches the store.
/// * **fast upload** (`S3AFastOutputStream`): writes buffer in memory
///   and, the moment the buffer exceeds `multipart_size`, the upload is
///   initiated and full parts are PUT *during* `write` — multipart REST
///   ops interleave with task compute on the virtual clock instead of
///   bundling at close. `close` uploads the final partial part and
///   completes the upload; only the complete makes the object visible. A
///   dropped stream strands an **orphaned multipart upload** (the real
///   S3 hazard — crashed writers leave uploads in flight), with no
///   visible object.
struct S3aOutputStream<'a> {
    fs: &'a S3a,
    path: Path,
    buf: Vec<u8>,
    upload: Option<u64>,
    next_part: u32,
    closed: bool,
}

impl S3aOutputStream<'_> {
    /// PUT one part under the shared retry contract: fast upload's
    /// recovery advantage is that a transient part failure re-sends
    /// ONLY that part (the bytes are still in memory) — the initiated
    /// upload, all previously accepted parts, and the rest of the
    /// buffer are untouched. Exhausted budgets leave the upload in
    /// flight (the stranded-upload hazard the `--multipart-ttl` sweep
    /// reaps).
    fn upload_part_with_retry(
        &self,
        upload: u64,
        part: u32,
        data: Vec<u8>,
        ctx: &mut OpCtx,
    ) -> Result<(), FsError> {
        let (cont, key) = container_key(&self.path);
        // Idle injector = no possible 503 = one attempt, zero clones.
        let attempts = if self.fs.store.faults_idle() {
            1
        } else {
            self.fs.store.config.retry.attempts()
        };
        let mut body = Some(data);
        for attempt in 1..=attempts {
            // Clone only when a later re-send might need the part again.
            let payload = if attempt == attempts {
                body.take().expect("part payload")
            } else {
                body.clone().expect("part payload")
            };
            let (r, d) = self.fs.store.upload_part(upload, part, payload);
            ctx.add(d);
            match r {
                Ok(()) => {
                    ctx.record("s3a", || format!("PUT {cont}/{key}?partNumber={part}"));
                    return Ok(());
                }
                Err(e) if e.is_transient() => {
                    super::note_transient(
                        &self.fs.store,
                        e,
                        attempt,
                        attempts,
                        "s3a",
                        || format!("PUT {cont}/{key}?partNumber={part}"),
                        ctx,
                    )?;
                }
                Err(e) => {
                    ctx.record("s3a", || format!("PUT {cont}/{key}?partNumber={part}"));
                    return Err(FsError::Io(e.to_string()));
                }
            }
        }
        unreachable!("retry loop returns on its final attempt")
    }

    /// Complete the upload under the retry contract. A transient
    /// completion failure leaves the upload (and every part) intact on
    /// the store, so the retry is a bare re-POST — nothing is re-sent.
    fn complete_with_retry(&self, upload: u64, ctx: &mut OpCtx) -> Result<(), FsError> {
        let (cont, key) = container_key(&self.path);
        let attempts = self.fs.store.config.retry.attempts();
        for attempt in 1..=attempts {
            let (r, d) = self.fs.store.complete_multipart(upload, ctx.now());
            ctx.add(d);
            match r {
                Ok(()) => {
                    ctx.record("s3a", || format!("POST {cont}/{key} (complete)"));
                    return Ok(());
                }
                Err(e) if e.is_transient() => {
                    super::note_transient(
                        &self.fs.store,
                        e,
                        attempt,
                        attempts,
                        "s3a",
                        || format!("POST {cont}/{key} (complete)"),
                        ctx,
                    )?;
                }
                Err(e) => {
                    ctx.record("s3a", || format!("POST {cont}/{key} (complete)"));
                    return Err(FsError::Io(e.to_string()));
                }
            }
        }
        unreachable!("retry loop returns on its final attempt")
    }

    /// Flush every full `multipart_size` chunk, initiating the upload on
    /// the first flush. Chunk boundaries depend only on the byte count,
    /// never on how callers split their `write`s, so op accounting is
    /// chunking-invariant. Flushed bytes are consumed by index and the
    /// buffer compacted once at the end — one memmove per `write`, not
    /// one per part.
    fn flush_full_parts(&mut self, ctx: &mut OpCtx) -> Result<(), FsError> {
        let psize = self.fs.cfg.multipart_size.max(1) as usize;
        let (cont, key) = container_key(&self.path);
        let mut consumed = 0usize;
        let mut failure = None;
        while self.buf.len() - consumed > psize {
            if self.upload.is_none() {
                let (r, d) = self
                    .fs
                    .store
                    .initiate_multipart(cont, key, Metadata::new(), ctx.now());
                ctx.add(d);
                ctx.record("s3a", || format!("POST {cont}/{key}?uploads (initiate)"));
                match r {
                    Ok(id) => self.upload = Some(id),
                    Err(e) => {
                        failure = Some(FsError::Io(e.to_string()));
                        break;
                    }
                }
            }
            let part = self.next_part;
            let upload = self.upload.unwrap();
            let chunk = self.buf[consumed..consumed + psize].to_vec();
            if let Err(e) = self.upload_part_with_retry(upload, part, chunk, ctx) {
                failure = Some(e);
                break;
            }
            consumed += psize;
            self.next_part += 1;
        }
        if consumed > 0 {
            self.buf.drain(..consumed);
        }
        match failure {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

impl FsOutputStream for S3aOutputStream<'_> {
    fn write(&mut self, data: &[u8], ctx: &mut OpCtx) -> Result<(), FsError> {
        if self.closed {
            return Err(FsError::Io(format!("write on closed stream {}", self.path)));
        }
        if self.fs.cfg.fast_upload {
            self.buf.extend_from_slice(data);
            self.flush_full_parts(ctx)
        } else {
            // Buffer to local disk first (paper §3.3); disk time accrues
            // on the cumulative spool size, chunking-invariantly.
            let latency = &self.fs.store.config.latency;
            let old = self.buf.len() as u64;
            self.buf.extend_from_slice(data);
            ctx.add_spool_delta(old, self.buf.len() as u64, |b| latency.local_disk_time(b));
            Ok(())
        }
    }

    fn write_owned(&mut self, data: Vec<u8>, ctx: &mut OpCtx) -> Result<(), FsError> {
        if self.closed {
            return Err(FsError::Io(format!("write on closed stream {}", self.path)));
        }
        // Zero-copy fast path: an empty buffer adopts the caller's vector
        // outright; accounting (spool delta / part flushes) is unchanged.
        if self.fs.cfg.fast_upload {
            crate::fs::interface::adopt_buf(&mut self.buf, data);
            self.flush_full_parts(ctx)
        } else {
            let latency = &self.fs.store.config.latency;
            let old = self.buf.len() as u64;
            crate::fs::interface::adopt_buf(&mut self.buf, data);
            ctx.add_spool_delta(old, self.buf.len() as u64, |b| latency.local_disk_time(b));
            Ok(())
        }
    }

    fn close(&mut self, ctx: &mut OpCtx) -> Result<(), FsError> {
        if self.closed {
            return Err(FsError::Io(format!("double close on {}", self.path)));
        }
        self.closed = true;
        let (cont, key) = container_key(&self.path);
        let data = std::mem::take(&mut self.buf);
        match self.upload {
            Some(id) => {
                if !data.is_empty() {
                    let part = self.next_part;
                    self.upload_part_with_retry(id, part, data, ctx)?;
                    self.next_part += 1;
                }
                self.complete_with_retry(id, ctx)?;
            }
            None => {
                // Base path: the whole part is spooled on local disk, so
                // a transient PUT failure resumes cheaply — re-PUT the
                // spool (wire transfer repeats; disk time does not).
                put_with_retry(
                    &self.fs.store,
                    "s3a",
                    &self.path,
                    cont,
                    key,
                    data,
                    Metadata::new(),
                    &format!("PUT {cont}/{key}"),
                    ctx,
                )?;
            }
        }
        self.fs.delete_unnecessary_fake_directories(&self.path, ctx);
        Ok(())
    }
}

impl FileSystem for S3a {
    fn scheme(&self) -> &str {
        &self.scheme
    }

    fn mkdirs(&self, path: &Path, ctx: &mut OpCtx) -> Result<(), FsError> {
        // Probe the target, then walk ancestors checking none is a file,
        // then PUT a fake marker for the leaf only (S3a 2.7 semantics).
        match self.probe_status(path, ctx) {
            Ok(st) if st.is_dir => return Ok(()),
            Ok(_) => return Err(FsError::NotADirectory(path.to_string())),
            Err(FsError::NotFound(_)) => {}
            Err(e) => return Err(e),
        }
        for anc in path.ancestors().iter().rev() {
            match self.probe_status(anc, ctx) {
                Ok(st) if !st.is_dir => {
                    return Err(FsError::NotADirectory(anc.to_string()))
                }
                Ok(_) => break, // found an existing dir; all above exist
                Err(FsError::NotFound(_)) => continue,
                Err(e) => return Err(e),
            }
        }
        let (cont, key) = container_key(path);
        let mk = marker_key(key);
        put_with_retry(
            &self.store,
            "s3a",
            path,
            cont,
            &mk,
            Vec::new(),
            Metadata::new(),
            &format!("PUT {cont}/{mk} (fake dir)"),
            ctx,
        )
    }

    fn create(
        &self,
        path: &Path,
        overwrite: bool,
        ctx: &mut OpCtx,
    ) -> Result<Box<dyn FsOutputStream + '_>, FsError> {
        // S3a always probes the target (even with overwrite=true it checks
        // it isn't a directory).
        match self.probe_status(path, ctx) {
            Ok(st) if st.is_dir => return Err(FsError::IsADirectory(path.to_string())),
            Ok(_) if !overwrite => return Err(FsError::AlreadyExists(path.to_string())),
            _ => {}
        }
        Ok(Box::new(S3aOutputStream {
            fs: self,
            path: path.clone(),
            buf: Vec::new(),
            upload: None,
            next_part: 1,
            closed: false,
        }))
    }

    fn open(&self, path: &Path, ctx: &mut OpCtx) -> Result<Box<dyn FsInputStream + '_>, FsError> {
        // getFileStatus first (S3AInputStream does); GETs happen per read
        // call on the returned handle.
        let st = self.probe_status(path, ctx)?;
        if st.is_dir {
            return Err(FsError::IsADirectory(path.to_string()));
        }
        Ok(maybe_readahead(
            &self.store,
            StoreInputStream::new(&self.store, "s3a", path, st.len),
        ))
    }

    fn get_file_status(&self, path: &Path, ctx: &mut OpCtx) -> Result<FileStatus, FsError> {
        self.probe_status(path, ctx)
    }

    fn list_status(&self, path: &Path, ctx: &mut OpCtx) -> Result<Vec<FileStatus>, FsError> {
        let st = self.probe_status(path, ctx)?;
        if !st.is_dir {
            return Ok(vec![st]);
        }
        let (cont, key) = container_key(path);
        let prefix = if key.is_empty() {
            String::new()
        } else {
            marker_key(key)
        };
        let (r, d) = self.store.list(cont, &prefix, Some('/'), ctx.now());
        ctx.add(d);
        ctx.record("s3a", || format!("GET container ?prefix={prefix}&delimiter=/"));
        let l = r.map_err(|e| map_store_error(e, path))?;
        let mut out = Vec::new();
        for o in l.objects {
            if o.name == prefix {
                continue;
            }
            out.push(FileStatus::file(
                Path::new(&path.scheme, cont, &o.name),
                o.size,
                SimInstant::EPOCH,
            ));
        }
        for cp in l.common_prefixes {
            out.push(FileStatus::dir(
                Path::new(&path.scheme, cont, cp.trim_end_matches('/')),
                SimInstant::EPOCH,
            ));
        }
        Ok(out)
    }

    fn rename(&self, src: &Path, dst: &Path, ctx: &mut OpCtx) -> Result<bool, FsError> {
        let (cont, skey) = container_key(src);
        let dkey = dst.key.clone();
        let st = match self.probe_status(src, ctx) {
            Ok(st) => st,
            Err(FsError::NotFound(_)) => return Ok(false),
            Err(e) => return Err(e),
        };
        // Probe destination and destination parent (S3a checks both).
        let _ = self.probe_status(dst, ctx);
        if let Some(dparent) = dst.parent() {
            if !dparent.is_root() {
                let _ = self.probe_status(&dparent, ctx);
            }
        }
        if !st.is_dir {
            let (r, d) = self.store.copy_object(cont, skey, cont, &dkey, ctx.now());
            ctx.add(d);
            ctx.record("s3a", || format!("COPY {skey} -> {dkey}"));
            r.map_err(|e| map_store_error(e, src))?;
            let (r, d) = self.store.delete_object(cont, skey, ctx.now());
            ctx.add(d);
            ctx.record("s3a", || format!("DELETE {skey}"));
            r.map_err(|e| map_store_error(e, src))?;
            self.delete_unnecessary_fake_directories(dst, ctx);
            if let Some(sparent) = src.parent() {
                self.create_fake_directory_if_necessary(&sparent, ctx);
            }
            return Ok(true);
        }
        // Directory rename: list the subtree and move each object.
        let sprefix = marker_key(skey);
        let (r, d) = self.store.list(cont, &sprefix, None, ctx.now());
        ctx.add(d);
        ctx.record("s3a", || format!("GET container ?prefix={sprefix}"));
        let l = r.map_err(|e| map_store_error(e, src))?;
        for o in l.objects {
            let suffix = &o.name[sprefix.len()..];
            let new_key = if suffix.is_empty() {
                marker_key(&dkey)
            } else {
                format!("{dkey}/{suffix}")
            };
            let (r, d) = self.store.copy_object(cont, &o.name, cont, &new_key, ctx.now());
            ctx.add(d);
            ctx.record("s3a", || format!("COPY {} -> {new_key}", o.name));
            if r.is_err() {
                continue; // ghost entry from an eventually-consistent listing
            }
            let (_, d) = self.store.delete_object(cont, &o.name, ctx.now());
            ctx.add(d);
            ctx.record("s3a", || format!("DELETE {}", o.name));
        }
        let (_, d) = self.store.delete_object(cont, &sprefix, ctx.now());
        ctx.add(d);
        self.delete_unnecessary_fake_directories(dst, ctx);
        if let Some(sparent) = src.parent() {
            self.create_fake_directory_if_necessary(&sparent, ctx);
        }
        Ok(true)
    }

    fn delete(&self, path: &Path, recursive: bool, ctx: &mut OpCtx) -> Result<bool, FsError> {
        let (cont, key) = container_key(path);
        let st = match self.probe_status(path, ctx) {
            Ok(st) => st,
            Err(FsError::NotFound(_)) => return Ok(false),
            Err(e) => return Err(e),
        };
        if !st.is_dir {
            let (r, d) = self.store.delete_object(cont, key, ctx.now());
            ctx.add(d);
            ctx.record("s3a", || format!("DELETE {key}"));
            r.map_err(|e| map_store_error(e, path))?;
            if let Some(parent) = path.parent() {
                self.create_fake_directory_if_necessary(&parent, ctx);
            }
            return Ok(true);
        }
        let prefix = marker_key(key);
        let (r, d) = self.store.list(cont, &prefix, None, ctx.now());
        ctx.add(d);
        ctx.record("s3a", || format!("GET container ?prefix={prefix}"));
        let l = r.map_err(|e| map_store_error(e, path))?;
        if !recursive && l.objects.iter().any(|o| o.name != prefix) {
            return Err(FsError::Io(format!("directory {path} not empty")));
        }
        for o in l.objects {
            let (_, d) = self.store.delete_object(cont, &o.name, ctx.now());
            ctx.add(d);
            ctx.record("s3a", || format!("DELETE {}", o.name));
        }
        let (_, d) = self.store.delete_object(cont, &prefix, ctx.now());
        ctx.add(d);
        if let Some(parent) = path.parent() {
            self.create_fake_directory_if_necessary(&parent, ctx);
        }
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::OpKind;
    use crate::objectstore::StoreConfig;

    fn setup(cfg: S3aConfig) -> (Arc<ObjectStore>, Arc<S3a>) {
        let store = ObjectStore::new(StoreConfig::instant_strong());
        store.create_container("res", SimInstant::EPOCH).0.unwrap();
        let fs = S3a::new(store.clone(), cfg);
        (store, fs)
    }

    fn p(s: &str) -> Path {
        Path::parse(s).unwrap()
    }

    fn ctx() -> OpCtx {
        OpCtx::new(SimInstant::EPOCH)
    }

    #[test]
    fn triple_probe_on_missing_path() {
        let (store, fs) = setup(S3aConfig::default());
        let mut c = ctx();
        let before = store.counters();
        assert!(fs.get_file_status(&p("s3a://res/missing"), &mut c).is_err());
        let d = store.counters().since(&before);
        assert_eq!(d.get(OpKind::HeadObject), 2, "HEAD key + HEAD key/");
        assert_eq!(d.get(OpKind::GetContainer), 1, "list fallback");
    }

    #[test]
    fn put_deletes_parent_fake_dirs() {
        let (store, fs) = setup(S3aConfig::default());
        let mut c = ctx();
        fs.mkdirs(&p("s3a://res/d"), &mut c).unwrap();
        assert!(store.debug_names("res", "").contains(&"d/".to_string()));
        fs.write_all(&p("s3a://res/d/f"), b"x".to_vec(), true, &mut c).unwrap();
        // The fake marker for d/ is gone after the file PUT.
        assert!(!store.debug_names("res", "").contains(&"d/".to_string()));
        // The directory still "exists" via the implicit-list probe:
        assert!(fs.get_file_status(&p("s3a://res/d"), &mut c).unwrap().is_dir);
    }

    #[test]
    fn delete_last_file_recreates_parent_marker() {
        let (store, fs) = setup(S3aConfig::default());
        let mut c = ctx();
        fs.write_all(&p("s3a://res/d/f"), b"x".to_vec(), true, &mut c).unwrap();
        fs.delete(&p("s3a://res/d/f"), false, &mut c).unwrap();
        assert!(
            store.debug_names("res", "").contains(&"d/".to_string()),
            "marker must be restored so the dir keeps existing"
        );
    }

    #[test]
    fn fast_upload_uses_multipart_above_threshold() {
        let (store, fs) = setup(S3aConfig {
            fast_upload: true,
            multipart_size: 4,
        });
        let mut c = ctx();
        let before = store.counters();
        fs.write_all(&p("s3a://res/big"), vec![7u8; 10], true, &mut c).unwrap();
        let d = store.counters().since(&before);
        // initiate + 3 parts (4+4+2) + complete = 5 PUT-class ops.
        assert_eq!(d.get(OpKind::PutObject), 5);
        let mut c2 = ctx();
        assert_eq!(*fs.read_all(&p("s3a://res/big"), &mut c2).unwrap(), vec![7u8; 10]);
    }

    #[test]
    fn fast_upload_flushes_parts_during_write() {
        // The §3.3 point of S3AFastOutputStream: part PUTs happen while
        // the task is still producing bytes, not bundled at close.
        let (store, fs) = setup(S3aConfig {
            fast_upload: true,
            multipart_size: 4,
        });
        let mut c = ctx();
        let mut out = fs.create(&p("s3a://res/big"), true, &mut c).unwrap();
        let before = store.counters();
        out.write(&[1u8; 5], &mut c).unwrap(); // buffer exceeds 4: initiate + part 1
        let mid = store.counters().since(&before);
        assert_eq!(mid.get(OpKind::PutObject), 2, "initiate + part 1 during write");
        out.write(&[2u8; 5], &mut c).unwrap(); // part 2 flushes mid-write
        assert_eq!(store.counters().since(&before).get(OpKind::PutObject), 3);
        out.close(&mut c).unwrap(); // final part + complete
        assert_eq!(store.counters().since(&before).get(OpKind::PutObject), 5);
        let mut c2 = ctx();
        let data = fs.read_all(&p("s3a://res/big"), &mut c2).unwrap();
        assert_eq!(data.len(), 10);
        // Chunking must not change op counts vs the whole-buffer wrapper:
        let before = store.counters();
        fs.write_all(&p("s3a://res/big2"), {
            let mut v = vec![1u8; 5];
            v.extend_from_slice(&[2u8; 5]);
            v
        }, true, &mut c).unwrap();
        assert_eq!(
            store.counters().since(&before).get(OpKind::PutObject),
            5,
            "same 10 bytes, same multipart shape"
        );
    }

    #[test]
    fn dropped_fast_upload_stream_strands_the_upload() {
        let (store, fs) = setup(S3aConfig {
            fast_upload: true,
            multipart_size: 4,
        });
        let mut c = ctx();
        {
            let mut out = fs.create(&p("s3a://res/crashed"), true, &mut c).unwrap();
            out.write(&[9u8; 9], &mut c).unwrap(); // initiate + 2 parts
            // dropped without close: executor died
        }
        // No visible object — only the orphaned in-flight upload remains.
        assert!(fs.get_file_status(&p("s3a://res/crashed"), &mut c).is_err());
        assert_eq!(store.debug_multipart_in_flight(), 1);
    }

    #[test]
    fn dropped_buffered_stream_leaves_nothing() {
        let (store, fs) = setup(S3aConfig::default());
        let mut c = ctx();
        {
            let mut out = fs.create(&p("s3a://res/crashed"), true, &mut c).unwrap();
            out.write(b"spooled to disk", &mut c).unwrap();
        }
        assert!(store.debug_names("res", "crashed").is_empty());
        assert_eq!(store.debug_multipart_in_flight(), 0);
    }

    #[test]
    fn fast_upload_small_object_single_put() {
        let (store, fs) = setup(S3aConfig {
            fast_upload: true,
            multipart_size: 1024,
        });
        let mut c = ctx();
        let before = store.counters();
        fs.write_all(&p("s3a://res/small"), vec![1u8; 10], true, &mut c).unwrap();
        assert_eq!(store.counters().since(&before).get(OpKind::PutObject), 1);
    }

    #[test]
    fn fast_upload_skips_local_disk() {
        let mut cfg = StoreConfig::instant_strong();
        cfg.latency.local_disk_bw = 1; // pathologically slow disk
        let store = ObjectStore::new(cfg);
        store.create_container("res", SimInstant::EPOCH).0.unwrap();
        let fast = S3a::new(
            store.clone(),
            S3aConfig {
                fast_upload: true,
                multipart_size: 1 << 30,
            },
        );
        let mut c = ctx();
        fast.write_all(&p("s3a://res/f"), vec![0u8; 1000], true, &mut c).unwrap();
        assert_eq!(c.elapsed.as_micros(), 0, "fast upload must not touch disk");
        let slow = S3a::new(store, S3aConfig::default());
        let mut c2 = ctx();
        slow.write_all(&p("s3a://res/g"), vec![0u8; 1000], true, &mut c2).unwrap();
        assert!(c2.elapsed.as_secs_f64() > 100.0, "buffered path must pay disk time");
    }

    #[test]
    fn fast_upload_retries_only_the_failed_part() {
        use crate::objectstore::{FaultOp, FaultSpec, RetryPolicy};
        let store = ObjectStore::new(StoreConfig {
            faults: FaultSpec::one(FaultOp::UploadPart, "big", 2),
            retry: RetryPolicy::with_retries(1),
            ..StoreConfig::instant_strong()
        });
        store.create_container("res", SimInstant::EPOCH).0.unwrap();
        let fs = S3a::new(
            store.clone(),
            S3aConfig {
                fast_upload: true,
                multipart_size: 4,
            },
        );
        let mut c = OpCtx::traced(SimInstant::EPOCH);
        fs.write_all(&p("s3a://res/big"), vec![7u8; 10], true, &mut c).unwrap();
        let rest: Vec<String> = c
            .take_trace()
            .into_iter()
            .filter(|l| l.contains("partNumber") || l.contains("uploads") || l.contains("complete"))
            .collect();
        assert_eq!(
            rest,
            vec![
                "s3a: POST res/big?uploads (initiate)",
                "s3a: PUT res/big?partNumber=1",
                "s3a: PUT res/big?partNumber=2 (503 transient)",
                "s3a: PUT res/big?partNumber=2",
                "s3a: PUT res/big?partNumber=3",
                "s3a: POST res/big (complete)",
            ],
            "only part 2 is re-sent"
        );
        // Wire bytes: 10 object bytes + the 4-byte re-sent part.
        assert_eq!(store.counters().bytes_written, 14);
        let mut c2 = OpCtx::new(SimInstant::EPOCH);
        assert_eq!(*fs.read_all(&p("s3a://res/big"), &mut c2).unwrap(), vec![7u8; 10]);
    }

    #[test]
    fn exhausted_part_retries_strand_the_upload() {
        use crate::objectstore::{FaultOp, FaultRule, FaultSpec, RetryPolicy};
        // Part 2 fails on every try: the stream errors with
        // TransientExhausted and the initiated upload stays in flight —
        // the stranded-upload debris the multipart GC sweep reaps.
        let store = ObjectStore::new(StoreConfig {
            faults: FaultSpec::none().with(FaultRule::new(FaultOp::UploadPart, "big", 2, 10)),
            retry: RetryPolicy::with_retries(2),
            ..StoreConfig::instant_strong()
        });
        store.create_container("res", SimInstant::EPOCH).0.unwrap();
        let fs = S3a::new(
            store.clone(),
            S3aConfig {
                fast_upload: true,
                multipart_size: 4,
            },
        );
        let mut c = ctx();
        let err = fs.write_all(&p("s3a://res/big"), vec![7u8; 10], true, &mut c);
        assert!(matches!(err, Err(FsError::TransientExhausted(_))));
        assert!(fs.get_file_status(&p("s3a://res/big"), &mut c).is_err());
        assert_eq!(store.debug_multipart_in_flight(), 1);
        // Part 1 (4 bytes) is parked in the stranded upload...
        assert_eq!(store.debug_stranded_multipart_bytes(), 4);
        // ...until the lifecycle sweep aborts it.
        let (sweep, _) = store.sweep_stale_multiparts(
            SimInstant(10_000_000),
            crate::simclock::SimDuration::from_secs(1),
        );
        assert_eq!((sweep.aborted, sweep.freed_bytes), (1, 4));
        assert_eq!(store.debug_multipart_in_flight(), 0);
    }

    #[test]
    fn transient_complete_is_reposted_without_resending_parts() {
        use crate::objectstore::{FaultOp, FaultSpec, RetryPolicy};
        let store = ObjectStore::new(StoreConfig {
            faults: FaultSpec::one(FaultOp::CompleteMultipart, "big", 1),
            retry: RetryPolicy::with_retries(1),
            ..StoreConfig::instant_strong()
        });
        store.create_container("res", SimInstant::EPOCH).0.unwrap();
        let fs = S3a::new(
            store.clone(),
            S3aConfig {
                fast_upload: true,
                multipart_size: 4,
            },
        );
        let mut c = ctx();
        let before = store.counters();
        fs.write_all(&p("s3a://res/big"), vec![7u8; 10], true, &mut c).unwrap();
        let d = store.counters().since(&before);
        // initiate + 3 parts + failed complete + retried complete.
        assert_eq!(d.get(OpKind::PutObject), 6);
        assert_eq!(d.bytes_written, 10, "no part is ever re-sent");
        let mut c2 = ctx();
        assert_eq!(fs.read_all(&p("s3a://res/big"), &mut c2).unwrap().len(), 10);
    }

    #[test]
    fn rename_file_and_marker_maintenance() {
        let (store, fs) = setup(S3aConfig::default());
        let mut c = ctx();
        fs.write_all(&p("s3a://res/a/f"), b"zz".to_vec(), true, &mut c).unwrap();
        assert!(fs
            .rename(&p("s3a://res/a/f"), &p("s3a://res/b/f"), &mut c)
            .unwrap());
        assert!(fs.read_all(&p("s3a://res/b/f"), &mut c).is_ok());
        assert!(fs.read_all(&p("s3a://res/a/f"), &mut c).is_err());
        // Source parent "a" became empty: marker restored.
        assert!(store.debug_names("res", "").contains(&"a/".to_string()));
        assert_eq!(store.counters().get(OpKind::CopyObject), 1);
    }

    #[test]
    fn s3a_is_chattier_than_swift() {
        // The structural claim behind Table 2: for the same logical work,
        // S3a issues more REST calls than Hadoop-Swift.
        let store_s = ObjectStore::new(StoreConfig::instant_strong());
        store_s.create_container("res", SimInstant::EPOCH).0.unwrap();
        let swift = crate::connectors::swift::HadoopSwift::new(store_s.clone());
        let store_a = ObjectStore::new(StoreConfig::instant_strong());
        store_a.create_container("res", SimInstant::EPOCH).0.unwrap();
        let s3a = S3a::new(store_a.clone(), S3aConfig::default());

        let work = |fs: &dyn FileSystem, scheme: &str| {
            let mut c = ctx();
            let d = Path::parse(&format!("{scheme}://res/out")).unwrap();
            fs.mkdirs(&d.child("_temporary/0"), &mut c).unwrap();
            fs.write_all(&d.child("_temporary/0/part-0"), b"x".to_vec(), true, &mut c)
                .unwrap();
            fs.rename(&d.child("_temporary/0/part-0"), &d.child("part-0"), &mut c)
                .unwrap();
            fs.delete(&d.child("_temporary"), true, &mut c).unwrap();
            fs.write_all(&d.child("_SUCCESS"), vec![], true, &mut c).unwrap();
        };
        work(&*swift, "swift");
        work(&*s3a, "s3a");
        let swift_total = store_s.counters().total();
        let s3a_total = store_a.counters().total();
        assert!(
            s3a_total > swift_total,
            "s3a={s3a_total} should exceed swift={swift_total}"
        );
    }
}
