//! The three storage connectors under study (paper Fig. 1, §3, §4.2):
//!
//! * [`swift::HadoopSwift`] — the stock Hadoop-Swift connector: directory
//!   marker objects, HEAD-probe chatter, rename = COPY + DELETE, output
//!   buffered to local disk before upload.
//! * [`s3a::S3a`] — the Hadoop S3a connector (2.7.x behaviour): the
//!   notorious triple-probe `getFileStatus`, fake-directory maintenance
//!   after every mutation, optional `S3AFastOutputStream` multipart upload
//!   ("fast upload").
//! * [`stocator::Stocator`] — the paper's contribution: intercepts HMRCC's
//!   temporary-path pattern and writes parts directly to their final,
//!   attempt-qualified names; no COPY, no DELETE, no commit-time listings;
//!   `_SUCCESS` optionally carries a manifest of committed attempts.
//!
//! All three implement [`crate::fs::FileSystem`] over the same simulated
//! [`crate::objectstore::ObjectStore`], so the REST-operation counts the
//! harness reports are produced by *executing the actual protocols*.

pub mod naming;
pub mod head_cache;
pub mod swift;
pub mod s3a;
pub mod stocator;

pub use s3a::{S3a, S3aConfig};
pub use stocator::{ReadStrategy, Stocator, StocatorConfig};
pub use swift::HadoopSwift;

use crate::fs::interface::{FsError, FsInputStream, OpCtx};
use crate::fs::readahead::ReadaheadStream;
use crate::fs::Path;
use crate::objectstore::store::HeadResult;
use crate::objectstore::{ObjectStore, StoreError};
use head_cache::HeadCache;
use std::sync::Arc;

/// Map a Hadoop path onto (container, object key).
pub(crate) fn container_key(path: &Path) -> (&str, &str) {
    (&path.container, &path.key)
}

/// The key of a directory *marker* object for `key` (trailing slash, the
/// S3a "fake directory" convention; we use it for Swift too).
pub(crate) fn marker_key(key: &str) -> String {
    format!("{key}/")
}

/// Map a store error onto the filesystem error space. Shared by every
/// connector so 404s surface as `NotFound` and 416s as `InvalidRange`
/// uniformly, whichever connector a caller reads through. A
/// `TransientFailure` or `Throttled` that reaches this map was not (or
/// no longer) retryable on its path — by definition its retry budget is
/// exhausted, so it surfaces as [`FsError::TransientExhausted`] and the
/// scheduler's task re-attempt machinery takes over.
pub(crate) fn map_store_error(e: StoreError, path: &Path) -> FsError {
    match e {
        StoreError::NoSuchKey(_) | StoreError::NoSuchContainer(_) => {
            FsError::NotFound(path.to_string())
        }
        StoreError::InvalidRange(m) => FsError::InvalidRange(m),
        StoreError::TransientFailure(m) | StoreError::Throttled(m) => {
            FsError::TransientExhausted(m)
        }
        other => FsError::Io(other.to_string()),
    }
}

/// Handle one transient failure (503 or 429) inside a connector retry
/// loop: record the class-tagged trace line, surface
/// [`FsError::TransientExhausted`] when this was the final attempt, and
/// otherwise charge the class-appropriate virtual-clock pause
/// (exponential backoff for 503s, flat Retry-After for 429s).
/// `Ok(())` means: go re-attempt. Shared by every uniform retry site so
/// a new transient class is one edit, not six.
pub(crate) fn note_transient(
    store: &ObjectStore,
    e: StoreError,
    attempt: u32,
    attempts: u32,
    actor: &'static str,
    label: impl FnOnce() -> String,
    ctx: &mut OpCtx,
) -> Result<(), FsError> {
    ctx.record(actor, || format!("{} ({})", label(), e.transient_tag()));
    if attempt == attempts {
        return Err(FsError::TransientExhausted(e.into_msg()));
    }
    ctx.add(store.config.retry.retry_delay(attempt, &e));
    Ok(())
}

/// Drive one whole-object PUT under the store's [`RetryPolicy`]
/// (`StoreConfig::retry`): on an injected `TransientFailure` the failed
/// request is visible in the trace as `"<label> (503 transient)"`, the
/// exponential virtual-clock backoff is charged, and the PUT is
/// re-issued with the same body — callers whose bytes survive locally
/// (spool connectors, markers, Stocator's buffered chunked PUT) all
/// resume by re-sending, which is exactly what the wire sees. Exhausted
/// budgets surface as [`FsError::TransientExhausted`]. With zero
/// retries (the default) and no injected faults this is byte-for-byte
/// the old single-PUT path: same ops, same trace lines, same clock.
#[allow(clippy::too_many_arguments)]
pub(crate) fn put_with_retry(
    store: &ObjectStore,
    actor: &'static str,
    path: &Path,
    cont: &str,
    key: &str,
    data: Vec<u8>,
    metadata: crate::objectstore::Metadata,
    label: &str,
    ctx: &mut OpCtx,
) -> Result<(), FsError> {
    // An idle injector can never produce a TransientFailure, so a single
    // attempt suffices and the payload is moved, never cloned — the
    // fault-free hot path stays copy-free whatever the retry budget.
    let attempts = if store.faults_idle() {
        1
    } else {
        store.config.retry.attempts()
    };
    let mut body = Some(data);
    for attempt in 1..=attempts {
        // Clone only when a later re-send might need the bytes again.
        let payload = if attempt == attempts {
            body.take().expect("payload")
        } else {
            body.clone().expect("payload")
        };
        let (r, d) = store.put_object(cont, key, payload, metadata.clone(), ctx.now());
        ctx.add(d);
        match r {
            Ok(()) => {
                ctx.record(actor, || label.to_string());
                return Ok(());
            }
            Err(e) if e.is_transient() => {
                note_transient(store, e, attempt, attempts, actor, || label.to_string(), ctx)?;
            }
            Err(e) => {
                ctx.record(actor, || label.to_string());
                return Err(map_store_error(e, path));
            }
        }
    }
    unreachable!("retry loop returns on its final attempt")
}

/// Unwrap an `Arc<Vec<u8>>` without copying when this is the only holder
/// (ranged GETs build a fresh buffer, so this is the common case).
pub(crate) fn unwrap_bytes(data: Arc<Vec<u8>>) -> Vec<u8> {
    Arc::try_unwrap(data).unwrap_or_else(|a| a.as_ref().clone())
}

/// Apply the store's readahead policy to a freshly opened stream: with
/// `StoreConfig::readahead > 0` the handle is wrapped in a
/// [`ReadaheadStream`] (prefetch window, misses coalesce into single
/// ranged GETs); with 0 the bare handle is returned and every read stays
/// its own GET. Shared by all three connectors so the knob means the same
/// thing everywhere.
pub(crate) fn maybe_readahead<'a>(
    store: &ObjectStore,
    inner: StoreInputStream<'a>,
) -> Box<dyn FsInputStream + 'a> {
    match store.config.readahead {
        0 => Box::new(inner),
        window => Box::new(ReadaheadStream::new(Box::new(inner), window)),
    }
}

/// The shared read handle over one store object. Two personalities:
///
/// * **HEAD-on-open** (Hadoop-Swift, S3a, via [`StoreInputStream::new`]):
///   the existence/size probe already happened in `open`, so the size is
///   known up front.
/// * **Lazy** (Stocator, via [`StoreInputStream::lazy_with_cache`]): no
///   request until the first read (§3.4 — never a HEAD before GET); the
///   GET response's head warms the connector's HEAD cache.
///
/// Every read issues its own GET — full or ranged — against the store;
/// GET coalescing lives a layer up, in the optional [`ReadaheadStream`]
/// wrapper (see [`maybe_readahead`]).
pub(crate) struct StoreInputStream<'a> {
    store: &'a ObjectStore,
    /// Trace actor name ("swift" / "s3a" / "stocator").
    actor: &'static str,
    path: Path,
    /// Known object size (from open-time HEAD or a previous read).
    size: Option<u64>,
    /// When present, every read's response head is cached (Stocator).
    cache: Option<&'a HeadCache>,
}

impl<'a> StoreInputStream<'a> {
    pub(crate) fn new(store: &'a ObjectStore, actor: &'static str, path: &Path, size: u64) -> Self {
        Self {
            store,
            actor,
            path: path.clone(),
            size: Some(size),
            cache: None,
        }
    }

    pub(crate) fn lazy_with_cache(
        store: &'a ObjectStore,
        actor: &'static str,
        path: &Path,
        cache: &'a HeadCache,
    ) -> Self {
        Self {
            store,
            actor,
            path: path.clone(),
            size: None,
            cache: Some(cache),
        }
    }

    /// Note a GET response's head: remember the size, warm the cache.
    fn note_head(&mut self, head: &HeadResult) {
        self.size = Some(head.size);
        if let Some(cache) = self.cache {
            let (_, key) = container_key(&self.path);
            cache.put(key, head.clone());
        }
    }
}

impl FsInputStream for StoreInputStream<'_> {
    fn size_hint(&self) -> Option<u64> {
        if let Some(size) = self.size {
            return Some(size);
        }
        let cache = self.cache?;
        let (_, key) = container_key(&self.path);
        cache.get(key).map(|h| h.size)
    }

    fn read_range(&mut self, offset: u64, len: u64, ctx: &mut OpCtx) -> Result<Vec<u8>, FsError> {
        let (cont, key) = container_key(&self.path);
        // GETs are idempotent, so the stream retry contract is simple:
        // re-issue the same ranged GET after the backoff, up to the
        // shared retry budget.
        let attempts = self.store.config.retry.attempts();
        for attempt in 1..=attempts {
            let (r, d) = self.store.get_object_range(cont, key, offset, len);
            ctx.add(d);
            match r {
                Ok(g) => {
                    ctx.record(self.actor, || {
                        format!("GET {cont}/{key} bytes={offset}+{len}")
                    });
                    self.note_head(&g.head);
                    return Ok(unwrap_bytes(g.data));
                }
                Err(e) if e.is_transient() => {
                    note_transient(
                        self.store,
                        e,
                        attempt,
                        attempts,
                        self.actor,
                        || format!("GET {cont}/{key} bytes={offset}+{len}"),
                        ctx,
                    )?;
                }
                Err(e) => {
                    ctx.record(self.actor, || {
                        format!("GET {cont}/{key} bytes={offset}+{len}")
                    });
                    return Err(map_store_error(e, &self.path));
                }
            }
        }
        unreachable!("retry loop returns on its final attempt")
    }

    fn read_to_end(&mut self, ctx: &mut OpCtx) -> Result<Arc<Vec<u8>>, FsError> {
        let (cont, key) = container_key(&self.path);
        let attempts = self.store.config.retry.attempts();
        for attempt in 1..=attempts {
            let (r, d) = self.store.get_object(cont, key);
            ctx.add(d);
            match r {
                Ok(g) => {
                    ctx.record(self.actor, || format!("GET {cont}/{key}"));
                    self.note_head(&g.head);
                    return Ok(g.data);
                }
                Err(e) if e.is_transient() => {
                    note_transient(
                        self.store,
                        e,
                        attempt,
                        attempts,
                        self.actor,
                        || format!("GET {cont}/{key}"),
                        ctx,
                    )?;
                }
                Err(e) => {
                    ctx.record(self.actor, || format!("GET {cont}/{key}"));
                    return Err(map_store_error(e, &self.path));
                }
            }
        }
        unreachable!("retry loop returns on its final attempt")
    }
}
