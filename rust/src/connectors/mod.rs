//! The three storage connectors under study (paper Fig. 1, §3, §4.2):
//!
//! * [`swift::HadoopSwift`] — the stock Hadoop-Swift connector: directory
//!   marker objects, HEAD-probe chatter, rename = COPY + DELETE, output
//!   buffered to local disk before upload.
//! * [`s3a::S3a`] — the Hadoop S3a connector (2.7.x behaviour): the
//!   notorious triple-probe `getFileStatus`, fake-directory maintenance
//!   after every mutation, optional `S3AFastOutputStream` multipart upload
//!   ("fast upload").
//! * [`stocator::Stocator`] — the paper's contribution: intercepts HMRCC's
//!   temporary-path pattern and writes parts directly to their final,
//!   attempt-qualified names; no COPY, no DELETE, no commit-time listings;
//!   `_SUCCESS` optionally carries a manifest of committed attempts.
//!
//! All three implement [`crate::fs::FileSystem`] over the same simulated
//! [`crate::objectstore::ObjectStore`], so the REST-operation counts the
//! harness reports are produced by *executing the actual protocols*.

pub mod naming;
pub mod head_cache;
pub mod swift;
pub mod s3a;
pub mod stocator;

pub use s3a::{S3a, S3aConfig};
pub use stocator::{ReadStrategy, Stocator, StocatorConfig};
pub use swift::HadoopSwift;

use crate::fs::Path;

/// Map a Hadoop path onto (container, object key).
pub(crate) fn container_key(path: &Path) -> (&str, &str) {
    (&path.container, &path.key)
}

/// The key of a directory *marker* object for `key` (trailing slash, the
/// S3a "fake directory" convention; we use it for Swift too).
pub(crate) fn marker_key(key: &str) -> String {
    format!("{key}/")
}
