//! **Stocator** — the paper's contribution (§3).
//!
//! Strategy: never rename. When HMRCC asks to write the task temporary
//! file `<ds>/_temporary/0/_temporary/attempt_X/part-N`, Stocator
//! recognizes the pattern and PUTs the object **directly at its final,
//! attempt-qualified name** `<ds>/part-N_attempt_X` using chunked transfer
//! encoding (single streaming PUT, no local-disk buffer). Task/job commit
//! renames become metadata-free no-ops; aborting an attempt deletes the
//! attempt's objects by *constructed* name (no listing). Which attempt's
//! objects constitute the dataset is decided at **read** time:
//!
//! * [`ReadStrategy::List`] (the paper's implemented option): list the
//!   dataset prefix once and, per part, pick the attempt with the most
//!   data — correct under fail-stop since every successful attempt writes
//!   identical output;
//! * [`ReadStrategy::Manifest`] (the paper's second option): the
//!   `_SUCCESS` object carries a manifest of committed attempts, so part
//!   names are *reconstructed* rather than listed — immune to eventual
//!   consistency.
//!
//! Read-path optimizations (§3.4): GET carries metadata, so `open` never
//! issues a prior HEAD; HEAD results are cached under the
//! immutable-input assumption.

use super::head_cache::HeadCache;
use super::naming::{self, AttemptId, TempPath};
use super::{container_key, map_store_error, marker_key, maybe_readahead, StoreInputStream};
use crate::fs::status::FileStatus;
use crate::fs::{FileSystem, FsError, FsInputStream, FsOutputStream, OpCtx, Path};
use crate::objectstore::store::HeadResult;
use crate::objectstore::{Metadata, ObjectStore, StoreError};
use crate::simclock::SimInstant;
use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::{Arc, Mutex};

/// Object-metadata key marking datasets written by Stocator.
pub const ORIGIN_KEY: &str = "X-Stocator-Origin";
/// Value written for the marker (connector name + version).
pub const ORIGIN_VALUE: &str = "stocator/1.0";
/// First line of a manifest-bearing `_SUCCESS` object.
pub const MANIFEST_HEADER: &str = "stocator-manifest-v1";

/// How a dataset's constituent parts are determined at read time (§3.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReadStrategy {
    /// One GET Container; duplicate attempts deduplicated by size
    /// (fail-stop assumption). The paper's shipped option.
    List,
    /// Reconstruct part names from the `_SUCCESS` manifest; zero listings.
    Manifest,
}

#[derive(Debug, Clone)]
pub struct StocatorConfig {
    pub read_strategy: ReadStrategy,
    /// HEAD-cache capacity (entries).
    pub cache_capacity: usize,
}

impl Default for StocatorConfig {
    fn default() -> Self {
        Self {
            read_strategy: ReadStrategy::List,
            cache_capacity: 2048,
        }
    }
}

/// One part object written by some attempt.
#[derive(Debug, Clone)]
struct PartRecord {
    basename: String,
    key: String,
    size: u64,
}

/// Per-dataset write-side state. In the real connector this state lives in
/// the per-JVM FileSystem instance and the driver learns committed attempts
/// from Spark's task-completion events; our simulator shares one connector
/// instance, which is equivalent for protocol purposes.
#[derive(Debug, Default)]
struct DatasetState {
    /// attempt string -> parts written by that attempt.
    written: HashMap<String, Vec<PartRecord>>,
    /// attempt strings whose task commit succeeded.
    committed: BTreeSet<String>,
    /// Whether the zero-byte dataset marker object has been PUT (§3.1).
    marker_written: bool,
}

pub struct Stocator {
    store: Arc<ObjectStore>,
    cfg: StocatorConfig,
    cache: HeadCache,
    state: Mutex<HashMap<String, DatasetState>>,
    scheme: String,
}

impl Stocator {
    pub fn new(store: Arc<ObjectStore>, cfg: StocatorConfig) -> Arc<Self> {
        let cache = HeadCache::new(cfg.cache_capacity);
        Arc::new(Self {
            store,
            cfg,
            cache,
            state: Mutex::new(HashMap::new()),
            scheme: "swift2d".to_string(),
        })
    }

    pub fn with_defaults(store: Arc<ObjectStore>) -> Arc<Self> {
        Self::new(store, StocatorConfig::default())
    }

    /// HEAD-cache hit count (for the §3.4-optimization tests/benches).
    pub fn cache_hits(&self) -> u64 {
        self.cache.hits()
    }

    /// Record one written part in the per-dataset write-tracking state.
    fn register_part(&self, dataset: &str, attempt: &str, rec: PartRecord) {
        let mut state = self.state.lock().unwrap();
        state
            .entry(dataset.to_string())
            .or_default()
            .written
            .entry(attempt.to_string())
            .or_default()
            .push(rec);
    }

    /// HEAD through the cache.
    fn head_cached(
        &self,
        cont: &str,
        key: &str,
        ctx: &mut OpCtx,
    ) -> Result<HeadResult, FsError> {
        if let Some(hit) = self.cache.get(key) {
            return Ok(hit);
        }
        let (r, d) = self.store.head_object(cont, key);
        ctx.add(d);
        ctx.record("stocator", || format!("HEAD {cont}/{key}"));
        match r {
            Ok(h) => {
                self.cache.put(key, h.clone());
                Ok(h)
            }
            Err(e) => Err(map_store_error(
                e,
                &Path::new(&self.scheme, cont, key),
            )),
        }
    }

    fn is_dataset_marker(head: &HeadResult) -> bool {
        head.size == 0 && head.metadata.get(ORIGIN_KEY).is_some()
    }

    /// Build the `_SUCCESS` manifest body for a dataset from committed
    /// attempts (§3.2, second option).
    fn manifest_body(&self, dataset: &str) -> Vec<u8> {
        let state = self.state.lock().unwrap();
        let mut lines = vec![MANIFEST_HEADER.to_string()];
        if let Some(ds) = state.get(dataset) {
            for attempt in &ds.committed {
                if let Some(parts) = ds.written.get(attempt) {
                    for p in parts {
                        lines.push(format!("{}\t{}\t{}", p.basename, attempt, p.size));
                    }
                }
            }
        }
        let mut body = lines.join("\n");
        body.push('\n');
        body.into_bytes()
    }

    /// Parse a manifest body into (basename, attempt-string, size) records.
    pub fn parse_manifest(body: &[u8]) -> Option<Vec<(String, String, u64)>> {
        let text = std::str::from_utf8(body).ok()?;
        let mut lines = text.lines();
        if lines.next()? != MANIFEST_HEADER {
            return None;
        }
        let mut out = Vec::new();
        for line in lines {
            if line.is_empty() {
                continue;
            }
            let mut cols = line.split('\t');
            let basename = cols.next()?.to_string();
            let attempt = cols.next()?.to_string();
            let size: u64 = cols.next()?.parse().ok()?;
            out.push((basename, attempt, size));
        }
        Some(out)
    }

    /// The §3.2 read path: given a dataset root, determine the constituent
    /// part objects.
    fn read_dataset(
        &self,
        path: &Path,
        ctx: &mut OpCtx,
    ) -> Result<Vec<FileStatus>, FsError> {
        let (cont, dskey) = container_key(path);
        let success_key = format!("{dskey}/_SUCCESS");
        match self.cfg.read_strategy {
            ReadStrategy::Manifest => {
                // GET _SUCCESS (carries the manifest); reconstruct names.
                // Transient failures are retried under the shared policy
                // first — only an exhausted budget (or a real miss)
                // degrades to the listing fallback.
                let attempts = self.store.config.retry.attempts();
                let mut fetched = None;
                for attempt in 1..=attempts {
                    let (r, d) = self.store.get_object(cont, &success_key);
                    ctx.add(d);
                    if let Err(e) = &r {
                        if e.is_transient() {
                            let tag = e.transient_tag();
                            ctx.record("stocator", || {
                                format!("GET {cont}/{success_key} (manifest) ({tag})")
                            });
                            if attempt < attempts {
                                ctx.add(self.store.config.retry.retry_delay(attempt, e));
                                continue;
                            }
                            break;
                        }
                    }
                    ctx.record("stocator", || format!("GET {cont}/{success_key} (manifest)"));
                    fetched = Some(r);
                    break;
                }
                match fetched {
                    Some(Ok(g)) => {
                        if let Some(records) = Self::parse_manifest(&g.data) {
                            let mut out = Vec::new();
                            for (basename, attempt, size) in records {
                                let att = AttemptId::parse(&attempt).ok_or_else(|| {
                                    FsError::Io(format!("bad manifest attempt '{attempt}'"))
                                })?;
                                let key = naming::stocator_final_key(dskey, &basename, &att);
                                out.push(FileStatus::file(
                                    Path::new(&path.scheme, cont, &key),
                                    size,
                                    SimInstant::EPOCH,
                                ));
                            }
                            out.push(FileStatus::file(
                                Path::new(&path.scheme, cont, &success_key),
                                g.head.size,
                                SimInstant::EPOCH,
                            ));
                            return Ok(out);
                        }
                        // _SUCCESS exists but carries no manifest (written
                        // by someone else): fall back to listing.
                        self.list_dataset(path, ctx)
                    }
                    // Missing _SUCCESS or an exhausted transient budget:
                    // degrade to the listing read path.
                    _ => self.list_dataset(path, ctx),
                }
            }
            ReadStrategy::List => {
                // HEAD _SUCCESS to confirm complete output, then one
                // listing.
                let _ = self.head_cached(cont, &success_key, ctx);
                self.list_dataset(path, ctx)
            }
        }
    }

    /// One GET Container over the dataset prefix with attempt
    /// deduplication: for each basename keep the attempt with the most
    /// data (§3.2, fail-stop argument).
    fn list_dataset(&self, path: &Path, ctx: &mut OpCtx) -> Result<Vec<FileStatus>, FsError> {
        let (cont, dskey) = container_key(path);
        let prefix = if dskey.is_empty() {
            String::new()
        } else {
            marker_key(dskey)
        };
        let (r, d) = self.store.list(cont, &prefix, Some('/'), ctx.now());
        ctx.add(d);
        ctx.record("stocator", || format!("GET container ?prefix={prefix}&delimiter=/"));
        let l = r.map_err(|e| map_store_error(e, path))?;
        // Group attempt-qualified parts by basename; pass through plain
        // objects (inputs not written by Stocator) unchanged.
        let mut winners: BTreeMap<String, (String, u64)> = BTreeMap::new();
        let mut plain: Vec<FileStatus> = Vec::new();
        for o in l.objects {
            if o.name == prefix {
                continue;
            }
            match naming::parse_stocator_key(dskey, &o.name) {
                Some((basename, _attempt)) => {
                    let e = winners.entry(basename).or_insert((o.name.clone(), o.size));
                    // Most data wins; ties broken toward the
                    // lexicographically earlier key for determinism.
                    if o.size > e.1 || (o.size == e.1 && o.name < e.0) {
                        *e = (o.name.clone(), o.size);
                    }
                }
                None => plain.push(FileStatus::file(
                    Path::new(&path.scheme, cont, &o.name),
                    o.size,
                    SimInstant::EPOCH,
                )),
            }
        }
        let mut out: Vec<FileStatus> = winners
            .into_values()
            .map(|(key, size)| {
                FileStatus::file(Path::new(&path.scheme, cont, &key), size, SimInstant::EPOCH)
            })
            .collect();
        out.extend(plain);
        for cp in l.common_prefixes {
            out.push(FileStatus::dir(
                Path::new(&path.scheme, cont, cp.trim_end_matches('/')),
                SimInstant::EPOCH,
            ));
        }
        Ok(out)
    }
}

/// What a Stocator output stream is writing.
enum StocTarget {
    /// An intercepted task temporary file, streaming to its final,
    /// attempt-qualified name (§3.1).
    Part {
        final_key: String,
        dataset: String,
        attempt: String,
        basename: String,
    },
    /// `_SUCCESS`: the body written by the caller is ignored — the
    /// manifest of committed attempts is generated at close (§3.2).
    Success { dataset: String },
    /// Any other plain object.
    Plain,
}

/// Stocator output stream: a single chunked-transfer PUT with **zero
/// local-disk cost** (§3.3). The HTTP request is conceptually open from
/// the first `write`; `close` ends the chunked body, which is when the
/// object (and the one PUT op, on the caller's clock) completes.
///
/// Dropping the stream without close models the executor dying
/// mid-transfer: the object store keeps the bytes that already arrived,
/// so a **truncated object lands at the target name** — exactly the
/// fail-stop debris the §3.2 read strategies are built to tolerate
/// (List picks the attempt with the most data; Manifest only lists
/// committed attempts).
struct StocatorOutputStream<'a> {
    fs: &'a Stocator,
    cont: String,
    key: String,
    path: Path,
    target: StocTarget,
    buf: Vec<u8>,
    /// Whether any `write` happened (an untouched stream leaves nothing).
    wrote: bool,
    closed: bool,
    /// Virtual instant of the last write — the crash time used when the
    /// stream is dropped without close.
    last_now: SimInstant,
}

impl StocatorOutputStream<'_> {
    /// The object key this stream ultimately lands at.
    fn put_key(&self) -> &str {
        match &self.target {
            StocTarget::Part { final_key, .. } => final_key,
            _ => &self.key,
        }
    }
}

impl FsOutputStream for StocatorOutputStream<'_> {
    fn write(&mut self, data: &[u8], ctx: &mut OpCtx) -> Result<(), FsError> {
        if self.closed {
            return Err(FsError::Io(format!("write on closed stream {}", self.path)));
        }
        // Chunked transfer: bytes go straight onto the wire — no disk.
        self.buf.extend_from_slice(data);
        self.wrote = true;
        self.last_now = ctx.now();
        Ok(())
    }

    fn write_owned(&mut self, data: Vec<u8>, ctx: &mut OpCtx) -> Result<(), FsError> {
        if self.closed {
            return Err(FsError::Io(format!("write on closed stream {}", self.path)));
        }
        // Zero-copy: a whole-part writer's buffer becomes the chunked-PUT
        // body directly (no memcpy — the common shape for task output).
        crate::fs::interface::adopt_buf(&mut self.buf, data);
        self.wrote = true;
        self.last_now = ctx.now();
        Ok(())
    }

    fn close(&mut self, ctx: &mut OpCtx) -> Result<(), FsError> {
        if self.closed {
            return Err(FsError::Io(format!("double close on {}", self.path)));
        }
        self.closed = true;
        let data = match &self.target {
            StocTarget::Success { dataset } => self.fs.manifest_body(dataset),
            _ => std::mem::take(&mut self.buf),
        };
        let size = data.len() as u64;
        let put_key = self.put_key().to_string();
        let cont = self.cont.clone();
        let intercepted = matches!(self.target, StocTarget::Part { .. });
        let label = if intercepted {
            format!("(intercept) PUT {cont}/{put_key}")
        } else {
            format!("PUT {cont}/{put_key}")
        };
        // THE paper's fragility footnote (§3.3): a chunked-transfer PUT
        // cannot be resumed. On a transient failure the whole streamed
        // body — which Stocator never spooled to disk — must be re-sent
        // from offset 0, so every retry re-pays the full object's wire
        // bytes (visible in Fig 7-style accounting), where fast upload
        // re-sends one part and the spool connectors re-PUT for free
        // disk-wise. The restart targets the same attempt-qualified
        // name (an atomic overwrite of whatever partial state the
        // failed transfer left); a *genuinely* fresh attempt name
        // arrives only when retries exhaust and the scheduler launches
        // a new task attempt.
        super::put_with_retry(
            &self.fs.store,
            "stocator",
            &self.path,
            &cont,
            &put_key,
            data,
            Metadata::new(),
            &label,
            ctx,
        )?;
        self.fs.cache.invalidate(&put_key);
        if let StocTarget::Part {
            final_key,
            dataset,
            attempt,
            basename,
        } = &self.target
        {
            self.fs.register_part(
                dataset,
                attempt,
                PartRecord {
                    basename: basename.clone(),
                    key: final_key.clone(),
                    size,
                },
            );
        }
        Ok(())
    }
}

impl Drop for StocatorOutputStream<'_> {
    fn drop(&mut self) {
        if self.closed || !self.wrote {
            return;
        }
        // Executor crash mid-chunked-PUT: the store keeps what arrived —
        // a truncated object at the target name. (_SUCCESS bodies are
        // generated at close, so a dropped one leaves nothing.)
        if matches!(self.target, StocTarget::Success { .. }) {
            return;
        }
        let put_key = self.put_key().to_string();
        let data = std::mem::take(&mut self.buf);
        let size = data.len() as u64;
        let _ = self
            .fs
            .store
            .put_object(&self.cont, &put_key, data, Metadata::new(), self.last_now)
            .0;
        self.fs.cache.invalidate(&put_key);
        if let StocTarget::Part {
            final_key,
            dataset,
            attempt,
            basename,
        } = &self.target
        {
            // Track the debris so a later abort-by-constructed-name can
            // still delete it (mirrors the real connector, whose write
            // state outlives the stream).
            self.fs.register_part(
                dataset,
                attempt,
                PartRecord {
                    basename: basename.clone(),
                    key: final_key.clone(),
                    size,
                },
            );
        }
    }
}

impl FileSystem for Stocator {
    fn scheme(&self) -> &str {
        &self.scheme
    }

    fn mkdirs(&self, path: &Path, ctx: &mut OpCtx) -> Result<(), FsError> {
        let (cont, key) = container_key(path);
        match naming::classify(key) {
            Some(tp) => {
                // Temporary directories are virtual, but the *dataset
                // root* marker is real: the first mkdirs under a dataset
                // writes the zero-byte object with the dataset's name and
                // the Stocator origin metadata (§3.1).
                let dataset = tp.dataset().to_string();
                let need_marker = {
                    let mut state = self.state.lock().unwrap();
                    let ds = state.entry(dataset.clone()).or_default();
                    if ds.marker_written {
                        false
                    } else {
                        ds.marker_written = true;
                        true
                    }
                };
                if need_marker && !dataset.is_empty() {
                    let mut md = Metadata::new();
                    md.insert(ORIGIN_KEY.into(), ORIGIN_VALUE.into());
                    let r = super::put_with_retry(
                        &self.store,
                        "stocator",
                        path,
                        cont,
                        &dataset,
                        Vec::new(),
                        md,
                        &format!("PUT {cont}/{dataset} (dataset marker)"),
                        ctx,
                    );
                    self.cache.invalidate(&dataset);
                    if r.is_err() {
                        // The marker never landed: release the latch so a
                        // task re-attempt (or the next mkdirs) re-writes
                        // it instead of permanently losing the §3.1
                        // origin marker.
                        self.state
                            .lock()
                            .unwrap()
                            .entry(dataset.clone())
                            .or_default()
                            .marker_written = false;
                    }
                    r?;
                }
                ctx.record("stocator", || {
                    format!("(intercept) mkdirs {key} -> no-op")
                });
                Ok(())
            }
            None => {
                // Dataset root: write the zero-byte marker object carrying
                // the Stocator origin metadata (§3.1).
                let mut md = Metadata::new();
                md.insert(ORIGIN_KEY.into(), ORIGIN_VALUE.into());
                let r = super::put_with_retry(
                    &self.store,
                    "stocator",
                    path,
                    cont,
                    key,
                    Vec::new(),
                    md,
                    &format!("PUT {cont}/{key} (dataset marker)"),
                    ctx,
                );
                self.cache.invalidate(key);
                // Latch only a marker that actually landed, so a failed
                // PUT is re-driven by the next mkdirs/attempt.
                let mut state = self.state.lock().unwrap();
                state.entry(key.to_string()).or_default().marker_written = r.is_ok();
                drop(state);
                r
            }
        }
    }

    fn create(
        &self,
        path: &Path,
        _overwrite: bool,
        ctx: &mut OpCtx,
    ) -> Result<Box<dyn FsOutputStream + '_>, FsError> {
        let (cont, key) = container_key(path);
        let target = match naming::classify(key) {
            Some(TempPath::TaskTempFile {
                dataset,
                attempt,
                basename,
            }) => {
                // THE interception (§3.1): the stream writes directly to
                // the final, attempt-qualified name.
                let final_key = naming::stocator_final_key(&dataset, &basename, &attempt);
                StocTarget::Part {
                    final_key,
                    dataset,
                    attempt: attempt.to_string(),
                    basename,
                }
            }
            Some(other) => {
                return Err(FsError::Io(format!(
                    "create on non-file temporary path {other:?}"
                )))
            }
            None if path.name() == "_SUCCESS" => {
                // `_SUCCESS` gets the manifest body, built at close (§3.2).
                let dataset = path.parent().map(|p| p.key).unwrap_or_default();
                StocTarget::Success { dataset }
            }
            None => StocTarget::Plain,
        };
        Ok(Box::new(StocatorOutputStream {
            fs: self,
            cont: cont.to_string(),
            key: key.to_string(),
            path: path.clone(),
            target,
            buf: Vec::new(),
            wrote: false,
            closed: false,
            last_now: ctx.now(),
        }))
    }

    fn open(&self, path: &Path, _ctx: &mut OpCtx) -> Result<Box<dyn FsInputStream + '_>, FsError> {
        // §3.4 optimization 1: no HEAD before GET. The handle is fully
        // lazy — the first read call issues the (possibly ranged) GET,
        // whose response carries the metadata and warms the cache. With
        // readahead on, that first GET is the first prefetch fill.
        Ok(maybe_readahead(
            &self.store,
            StoreInputStream::lazy_with_cache(&self.store, "stocator", path, &self.cache),
        ))
    }

    fn get_file_status(&self, path: &Path, ctx: &mut OpCtx) -> Result<FileStatus, FsError> {
        let (cont, key) = container_key(path);
        if key.is_empty() {
            let (r, d) = self.store.head_container(cont);
            ctx.add(d);
            ctx.record("stocator", || format!("HEAD container {cont}"));
            return r
                .map(|_| FileStatus::dir(path.clone(), SimInstant::EPOCH))
                .map_err(|e| map_store_error(e, path));
        }
        if let Some(tp) = naming::classify(key) {
            // Temporary paths are virtual. Attempt dirs "exist" iff the
            // attempt wrote something (so needsTaskCommit is meaningful);
            // roots always exist.
            let exists = match &tp {
                TempPath::AttemptDir { dataset, attempt } => self
                    .state
                    .lock()
                    .unwrap()
                    .get(dataset)
                    .map(|d| d.written.contains_key(&attempt.to_string()))
                    .unwrap_or(false),
                _ => true,
            };
            ctx.record("stocator", || {
                format!("(intercept) getFileStatus {key} -> {exists}")
            });
            return if exists {
                Ok(FileStatus::dir(path.clone(), SimInstant::EPOCH))
            } else {
                Err(FsError::NotFound(path.to_string()))
            };
        }
        match self.head_cached(cont, key, ctx) {
            Ok(h) if Self::is_dataset_marker(&h) => {
                Ok(FileStatus::dir(path.clone(), SimInstant::EPOCH))
            }
            Ok(h) => Ok(FileStatus::file(path.clone(), h.size, h.created_at)),
            Err(FsError::NotFound(_)) => {
                // Not an object: maybe an implicit directory (dataset
                // written by another tool). One listing probe.
                let mk = marker_key(key);
                let (r, d) = self.store.list(cont, &mk, None, ctx.now());
                ctx.add(d);
                ctx.record("stocator", || format!("GET container ?prefix={mk}"));
                match r {
                    Ok(l) if !l.is_empty() => {
                        Ok(FileStatus::dir(path.clone(), SimInstant::EPOCH))
                    }
                    _ => Err(FsError::NotFound(path.to_string())),
                }
            }
            Err(e) => Err(e),
        }
    }

    fn list_status(&self, path: &Path, ctx: &mut OpCtx) -> Result<Vec<FileStatus>, FsError> {
        let (_cont, key) = container_key(path);
        if let Some(tp) = naming::classify(key) {
            // Commit-time listings are intercepted — answered from the
            // connector's write-tracking state with ZERO REST ops (§3.1:
            // no eventual-consistency hazard on the commit path). An
            // attempt directory lists its written parts *virtually*, so
            // FileOutputCommitter v2's merge sees files to "rename" (each
            // rename is itself an intercepted no-op that marks the
            // attempt committed).
            if let TempPath::AttemptDir { dataset, attempt } = &tp {
                let state = self.state.lock().unwrap();
                let parts = state
                    .get(dataset)
                    .and_then(|d| d.written.get(&attempt.to_string()))
                    .cloned()
                    .unwrap_or_default();
                ctx.record("stocator", || {
                    format!("(intercept) list {key} -> {} virtual parts", parts.len())
                });
                return Ok(parts
                    .iter()
                    .map(|p| {
                        FileStatus::file(
                            path.child(&p.basename),
                            p.size,
                            SimInstant::EPOCH,
                        )
                    })
                    .collect());
            }
            ctx.record("stocator", || format!("(intercept) list {key} -> []"));
            return Ok(Vec::new());
        }
        self.read_dataset(path, ctx)
    }

    fn rename(&self, src: &Path, dst: &Path, ctx: &mut OpCtx) -> Result<bool, FsError> {
        let (cont, skey) = container_key(src);
        match naming::classify(skey) {
            Some(TempPath::AttemptDir { dataset, attempt }) => {
                // Task commit (v1 renames the attempt dir to the job-temp
                // dir; v2's merge renames land on TaskTempFile below).
                // Mark the attempt committed. Zero REST ops.
                let mut state = self.state.lock().unwrap();
                state
                    .entry(dataset)
                    .or_default()
                    .committed
                    .insert(attempt.to_string());
                ctx.record("stocator", || {
                    format!("(intercept) commit rename {skey} -> no-op")
                });
                Ok(true)
            }
            Some(TempPath::TaskTempFile {
                dataset, attempt, ..
            }) => {
                let mut state = self.state.lock().unwrap();
                state
                    .entry(dataset)
                    .or_default()
                    .committed
                    .insert(attempt.to_string());
                ctx.record("stocator", || {
                    format!("(intercept) commit rename {skey} -> no-op")
                });
                Ok(true)
            }
            Some(_) => {
                // Job-temp renames (v1 job commit) and temp-root moves:
                // everything is already at its final name.
                ctx.record("stocator", || {
                    format!("(intercept) rename {skey} -> no-op")
                });
                Ok(true)
            }
            None => {
                // Generic rename of a plain object: COPY + DELETE
                // fallback (rare; not on the commit path).
                let dkey = dst.key.clone();
                let (r, d) = self.store.copy_object(cont, skey, cont, &dkey, ctx.now());
                ctx.add(d);
                ctx.record("stocator", || format!("COPY {skey} -> {dkey}"));
                match r {
                    Ok(()) => {
                        let (_, d) = self.store.delete_object(cont, skey, ctx.now());
                        ctx.add(d);
                        ctx.record("stocator", || format!("DELETE {skey}"));
                        self.cache.invalidate(skey);
                        self.cache.invalidate(&dkey);
                        Ok(true)
                    }
                    Err(StoreError::NoSuchKey(_)) => Ok(false),
                    Err(e) => Err(map_store_error(e, src)),
                }
            }
        }
    }

    fn delete(&self, path: &Path, recursive: bool, ctx: &mut OpCtx) -> Result<bool, FsError> {
        let (cont, key) = container_key(path);
        match naming::classify(key) {
            Some(TempPath::AttemptDir { dataset, attempt }) => {
                // Task abort (paper Table 3, lines 6-7): delete the
                // attempt's objects by *constructed* name — no listing.
                let records = {
                    let mut state = self.state.lock().unwrap();
                    state
                        .entry(dataset.clone())
                        .or_default()
                        .written
                        .remove(&attempt.to_string())
                        .unwrap_or_default()
                };
                for rec in &records {
                    let (_, d) = self.store.delete_object(cont, &rec.key, ctx.now());
                    ctx.add(d);
                    ctx.record("stocator", || {
                        format!("(intercept) DELETE {cont}/{}", rec.key)
                    });
                    self.cache.invalidate(&rec.key);
                }
                self.state
                    .lock()
                    .unwrap()
                    .entry(dataset)
                    .or_default()
                    .committed
                    .remove(&attempt.to_string());
                Ok(true)
            }
            Some(TempPath::TaskTempFile {
                dataset, attempt, basename,
            }) => {
                let final_key = naming::stocator_final_key(&dataset, &basename, &attempt);
                let (r, d) = self.store.delete_object(cont, &final_key, ctx.now());
                ctx.add(d);
                ctx.record("stocator", || {
                    format!("(intercept) DELETE {cont}/{final_key}")
                });
                self.cache.invalidate(&final_key);
                let mut state = self.state.lock().unwrap();
                if let Some(ds) = state.get_mut(&dataset) {
                    if let Some(parts) = ds.written.get_mut(&attempt.to_string()) {
                        parts.retain(|p| p.key != final_key);
                    }
                }
                Ok(r.is_ok())
            }
            Some(_) => {
                // Deleting _temporary at job cleanup: nothing exists.
                ctx.record("stocator", || {
                    format!("(intercept) delete {key} -> no-op")
                });
                Ok(true)
            }
            None => {
                // Plain object or dataset root.
                match self.head_cached(cont, key, ctx) {
                    Ok(h) if Self::is_dataset_marker(&h) || recursive => {
                        // Dataset delete: one listing, then delete every
                        // object plus the marker.
                        let prefix = marker_key(key);
                        let (r, d) = self.store.list(cont, &prefix, None, ctx.now());
                        ctx.add(d);
                        ctx.record("stocator", || {
                            format!("GET container ?prefix={prefix}")
                        });
                        if let Ok(l) = r {
                            for o in l.objects {
                                let (_, d) = self.store.delete_object(cont, &o.name, ctx.now());
                                ctx.add(d);
                                ctx.record("stocator", || format!("DELETE {}", o.name));
                            }
                        }
                        let (_, d) = self.store.delete_object(cont, key, ctx.now());
                        ctx.add(d);
                        ctx.record("stocator", || format!("DELETE {key}"));
                        self.cache.invalidate_prefix(key);
                        self.state.lock().unwrap().remove(key);
                        Ok(true)
                    }
                    Ok(_) => {
                        let (r, d) = self.store.delete_object(cont, key, ctx.now());
                        ctx.add(d);
                        ctx.record("stocator", || format!("DELETE {key}"));
                        self.cache.invalidate(key);
                        Ok(r.is_ok())
                    }
                    Err(FsError::NotFound(_)) => Ok(false),
                    Err(e) => Err(e),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::OpKind;
    use crate::objectstore::StoreConfig;

    fn setup(strategy: ReadStrategy) -> (Arc<ObjectStore>, Arc<Stocator>) {
        let store = ObjectStore::new(StoreConfig::instant_strong());
        store.create_container("res", SimInstant::EPOCH).0.unwrap();
        let fs = Stocator::new(
            store.clone(),
            StocatorConfig {
                read_strategy: strategy,
                cache_capacity: 64,
            },
        );
        (store, fs)
    }

    fn p(s: &str) -> Path {
        Path::parse(s).unwrap()
    }

    fn ctx() -> OpCtx {
        OpCtx::new(SimInstant::EPOCH)
    }

    fn temp_file(ds: &str, task: u32, attempt: u32, base: &str) -> Path {
        p(&format!(
            "swift2d://res/{ds}/_temporary/0/_temporary/attempt_201512062056_0000_m_{task:06}_{attempt}/{base}"
        ))
    }

    fn attempt_dir(ds: &str, task: u32, attempt: u32) -> Path {
        p(&format!(
            "swift2d://res/{ds}/_temporary/0/_temporary/attempt_201512062056_0000_m_{task:06}_{attempt}"
        ))
    }

    #[test]
    fn temp_write_lands_at_final_attempt_qualified_name() {
        let (store, fs) = setup(ReadStrategy::List);
        let mut c = ctx();
        fs.write_all(&temp_file("data.txt", 0, 0, "part-00000"), b"hello".to_vec(), true, &mut c)
            .unwrap();
        let names = store.debug_names("res", "data.txt/");
        assert_eq!(
            names,
            vec!["data.txt/part-00000_attempt_201512062056_0000_m_000000_0"]
        );
        // Exactly one PUT; zero COPY/DELETE/list.
        let cts = store.counters();
        assert_eq!(cts.get(OpKind::PutObject), 1 + 1 /* container */);
        assert_eq!(cts.get(OpKind::CopyObject), 0);
        assert_eq!(cts.get(OpKind::DeleteObject), 0);
        assert_eq!(cts.get(OpKind::GetContainer), 0);
    }

    #[test]
    fn commit_renames_are_free() {
        let (store, fs) = setup(ReadStrategy::List);
        let mut c = ctx();
        fs.write_all(&temp_file("d", 0, 0, "part-0"), b"x".to_vec(), true, &mut c).unwrap();
        let before = store.counters();
        // Task commit (v1 shape): rename attempt dir -> job temp dir.
        assert!(fs
            .rename(
                &attempt_dir("d", 0, 0),
                &p("swift2d://res/d/_temporary/0/task_201512062056_0000_m_000000"),
                &mut c,
            )
            .unwrap());
        // Job commit: rename job temp file -> final.
        assert!(fs
            .rename(
                &p("swift2d://res/d/_temporary/0/task_201512062056_0000_m_000000/part-0"),
                &p("swift2d://res/d/part-0"),
                &mut c,
            )
            .unwrap());
        assert_eq!(
            store.counters().since(&before).total(),
            0,
            "commit must be zero REST ops"
        );
    }

    #[test]
    fn mkdirs_on_dataset_writes_marker_with_origin() {
        let (store, fs) = setup(ReadStrategy::List);
        let mut c = ctx();
        fs.mkdirs(&p("swift2d://res/data.txt"), &mut c).unwrap();
        let (h, _) = store.head_object("res", "data.txt");
        let h = h.unwrap();
        assert_eq!(h.size, 0);
        assert_eq!(h.metadata.get(ORIGIN_KEY).map(String::as_str), Some(ORIGIN_VALUE));
        // And getFileStatus sees it as a directory.
        let st = fs.get_file_status(&p("swift2d://res/data.txt"), &mut c).unwrap();
        assert!(st.is_dir);
    }

    #[test]
    fn mkdirs_on_temp_paths_writes_marker_once_then_free() {
        let (store, fs) = setup(ReadStrategy::List);
        let mut c = ctx();
        let before = store.counters();
        // First mkdirs under the dataset writes the zero-byte marker...
        fs.mkdirs(&p("swift2d://res/d/_temporary/0"), &mut c).unwrap();
        let d1 = store.counters().since(&before);
        assert_eq!(d1.get(OpKind::PutObject), 1, "dataset marker PUT");
        assert_eq!(d1.total(), 1);
        // ...and every further temp mkdirs is free.
        let before = store.counters();
        fs.mkdirs(&attempt_dir("d", 3, 1), &mut c).unwrap();
        fs.mkdirs(&p("swift2d://res/d/_temporary/0"), &mut c).unwrap();
        assert_eq!(store.counters().since(&before).total(), 0);
        // The marker carries the Stocator origin metadata.
        let (h, _) = store.head_object("res", "d");
        assert_eq!(
            h.unwrap().metadata.get(ORIGIN_KEY).map(String::as_str),
            Some(ORIGIN_VALUE)
        );
    }

    #[test]
    fn abort_deletes_by_constructed_name() {
        let (store, fs) = setup(ReadStrategy::List);
        let mut c = ctx();
        fs.write_all(&temp_file("d", 2, 0, "part-2"), b"aa".to_vec(), true, &mut c).unwrap();
        fs.write_all(&temp_file("d", 2, 2, "part-2"), b"bb".to_vec(), true, &mut c).unwrap();
        fs.write_all(&temp_file("d", 2, 1, "part-2"), b"cc".to_vec(), true, &mut c).unwrap();
        let before = store.counters();
        // Abort attempts 0 and 2 (paper Table 3 lines 6-7).
        fs.delete(&attempt_dir("d", 2, 0), true, &mut c).unwrap();
        fs.delete(&attempt_dir("d", 2, 2), true, &mut c).unwrap();
        let d = store.counters().since(&before);
        assert_eq!(d.get(OpKind::DeleteObject), 2);
        assert_eq!(d.get(OpKind::GetContainer), 0, "no listing needed");
        let names = store.debug_names("res", "d/");
        assert_eq!(names, vec!["d/part-2_attempt_201512062056_0000_m_000002_1"]);
    }

    #[test]
    fn read_dedups_attempts_by_most_data() {
        let (_store, fs) = setup(ReadStrategy::List);
        let mut c = ctx();
        // Task 2 ran three times; attempt 1 wrote the most data (fail-stop:
        // the completed attempt's object is complete, dead attempts may
        // have truncated objects).
        fs.write_all(&temp_file("d", 0, 0, "part-0"), b"full0".to_vec(), true, &mut c).unwrap();
        fs.write_all(&temp_file("d", 2, 0, "part-2"), b"xy".to_vec(), true, &mut c).unwrap();
        fs.write_all(&temp_file("d", 2, 1, "part-2"), b"complete".to_vec(), true, &mut c).unwrap();
        fs.write_all(&temp_file("d", 2, 2, "part-2"), b"z".to_vec(), true, &mut c).unwrap();
        fs.rename(&attempt_dir("d", 0, 0), &p("swift2d://res/d/_temporary/0/task_x"), &mut c)
            .unwrap();
        fs.rename(&attempt_dir("d", 2, 1), &p("swift2d://res/d/_temporary/0/task_y"), &mut c)
            .unwrap();
        fs.write_all(&p("swift2d://res/d/_SUCCESS"), vec![], true, &mut c).unwrap();

        let ls = fs.list_status(&p("swift2d://res/d"), &mut c).unwrap();
        let parts: Vec<&str> = ls
            .iter()
            .filter(|s| s.path.name() != "_SUCCESS")
            .map(|s| s.path.name())
            .collect();
        assert_eq!(
            parts,
            vec![
                "part-0_attempt_201512062056_0000_m_000000_0",
                "part-2_attempt_201512062056_0000_m_000002_1",
            ]
        );
    }

    #[test]
    fn manifest_roundtrip_and_reconstruction() {
        let (store, fs) = setup(ReadStrategy::Manifest);
        let mut c = ctx();
        fs.mkdirs(&p("swift2d://res/d"), &mut c).unwrap();
        fs.write_all(&temp_file("d", 0, 0, "part-0"), b"AA".to_vec(), true, &mut c).unwrap();
        fs.write_all(&temp_file("d", 1, 0, "part-1"), b"BBB".to_vec(), true, &mut c).unwrap();
        // Extra uncommitted attempt — must NOT appear via manifest.
        fs.write_all(&temp_file("d", 1, 1, "part-1"), b"ZZZZ".to_vec(), true, &mut c).unwrap();
        fs.rename(&attempt_dir("d", 0, 0), &p("swift2d://res/d/_temporary/0/task_a"), &mut c)
            .unwrap();
        fs.rename(&attempt_dir("d", 1, 0), &p("swift2d://res/d/_temporary/0/task_b"), &mut c)
            .unwrap();
        fs.write_all(&p("swift2d://res/d/_SUCCESS"), vec![], true, &mut c).unwrap();

        // The manifest body landed in _SUCCESS:
        let (g, _) = store.get_object("res", "d/_SUCCESS");
        let body = g.unwrap().data;
        let records = Stocator::parse_manifest(&body).unwrap();
        assert_eq!(records.len(), 2);

        let before = store.counters();
        let ls = fs.list_status(&p("swift2d://res/d"), &mut c).unwrap();
        let d = store.counters().since(&before);
        assert_eq!(d.get(OpKind::GetContainer), 0, "manifest mode must not list");
        let parts: Vec<&str> = ls
            .iter()
            .filter(|s| s.path.name() != "_SUCCESS")
            .map(|s| s.path.name())
            .collect();
        assert_eq!(
            parts,
            vec![
                "part-0_attempt_201512062056_0000_m_000000_0",
                "part-1_attempt_201512062056_0000_m_000001_0",
            ]
        );
    }

    #[test]
    fn manifest_read_is_correct_under_adversarial_listing_lag() {
        // The eventual-consistency crown jewel (§3.2): with listings
        // lagging arbitrarily, manifest mode still reads the right parts.
        let store = ObjectStore::new(StoreConfig {
            consistency: crate::objectstore::ConsistencyModel::adversarial(
                crate::simclock::SimDuration::from_secs(3600),
            ),
            ..StoreConfig::instant_strong()
        });
        store.create_container("res", SimInstant::EPOCH).0.unwrap();
        let fs = Stocator::new(
            store.clone(),
            StocatorConfig {
                read_strategy: ReadStrategy::Manifest,
                cache_capacity: 64,
            },
        );
        let mut c = ctx();
        fs.write_all(&temp_file("d", 0, 0, "part-0"), b"DATA".to_vec(), true, &mut c).unwrap();
        fs.rename(&attempt_dir("d", 0, 0), &p("swift2d://res/d/_temporary/0/task_a"), &mut c)
            .unwrap();
        fs.write_all(&p("swift2d://res/d/_SUCCESS"), vec![], true, &mut c).unwrap();
        // A listing would see NOTHING (1-hour lag):
        let (l, _) = store.list("res", "d/", None, SimInstant(0));
        assert!(l.unwrap().is_empty());
        // ...but the manifest read path finds the part:
        let ls = fs.list_status(&p("swift2d://res/d"), &mut c).unwrap();
        let parts: Vec<&str> = ls
            .iter()
            .filter(|s| s.path.name() != "_SUCCESS")
            .map(|s| s.path.name())
            .collect();
        assert_eq!(parts, vec!["part-0_attempt_201512062056_0000_m_000000_0"]);
        // And the data is readable (GET is read-after-write consistent):
        let data = fs.read_all(&ls[0].path, &mut c).unwrap();
        assert_eq!(&*data, b"DATA");
    }

    #[test]
    fn open_skips_head_and_warms_cache() {
        let (store, fs) = setup(ReadStrategy::List);
        let mut c = ctx();
        fs.write_all(&p("swift2d://res/in/part-0"), b"input".to_vec(), true, &mut c).unwrap();
        let before = store.counters();
        let _ = fs.read_all(&p("swift2d://res/in/part-0"), &mut c).unwrap();
        let d = store.counters().since(&before);
        assert_eq!(d.get(OpKind::HeadObject), 0, "no HEAD before GET (§3.4)");
        assert_eq!(d.get(OpKind::GetObject), 1);
        // Follow-up getFileStatus served from the cache: zero ops.
        let before = store.counters();
        let st = fs.get_file_status(&p("swift2d://res/in/part-0"), &mut c).unwrap();
        assert_eq!(st.len, 5);
        assert_eq!(store.counters().since(&before).total(), 0);
        assert!(fs.cache_hits() >= 1);
    }

    #[test]
    fn dropped_part_stream_leaves_truncated_object_that_read_side_rejects() {
        // Executor dies mid-chunked-PUT: the bytes that reached the store
        // form a truncated object at the attempt-qualified name (§3.2
        // fail-stop debris). A complete later attempt wins the dedup.
        let (store, fs) = setup(ReadStrategy::List);
        let mut c = ctx();
        {
            let mut out = fs.create(&temp_file("d", 0, 0, "part-0"), true, &mut c).unwrap();
            out.write(b"trunc", &mut c).unwrap();
            // dropped without close — attempt 0 crashed
        }
        fs.write_all(&temp_file("d", 0, 1, "part-0"), b"complete!".to_vec(), true, &mut c)
            .unwrap();
        let names = store.debug_names("res", "d/");
        assert!(names.iter().any(|n| n.ends_with("m_000000_0")), "{names:?}");
        assert!(names.iter().any(|n| n.ends_with("m_000000_1")), "{names:?}");
        // Commit attempt 1, then read: exactly one part-0, the full one.
        fs.rename(&attempt_dir("d", 0, 1), &p("swift2d://res/d/_temporary/0/task_a"), &mut c)
            .unwrap();
        let ls = fs.list_status(&p("swift2d://res/d"), &mut c).unwrap();
        let parts: Vec<_> = ls
            .iter()
            .filter(|s| s.path.name().starts_with("part-0"))
            .collect();
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0].len, 9, "the truncated attempt must lose");
        assert!(parts[0].path.name().ends_with("m_000000_1"));
    }

    #[test]
    fn transient_put_restarts_the_whole_chunked_transfer() {
        use crate::objectstore::{FaultOp, FaultSpec, RetryPolicy, StoreConfig};
        // The §3.3 fragility footnote: the chunked PUT cannot resume, so
        // the retry re-sends the ENTIRE object — wire bytes double.
        let store = ObjectStore::new(StoreConfig {
            faults: FaultSpec::one(FaultOp::Put, "d/part-0_attempt", 1),
            retry: RetryPolicy::with_retries(1),
            ..StoreConfig::instant_strong()
        });
        store.create_container("res", SimInstant::EPOCH).0.unwrap();
        let fs = Stocator::with_defaults(store.clone());
        let mut c = OpCtx::traced(SimInstant::EPOCH);
        fs.write_all(&temp_file("d", 0, 0, "part-0"), vec![9u8; 100], true, &mut c)
            .unwrap();
        let trace = c.take_trace();
        let key = "d/part-0_attempt_201512062056_0000_m_000000_0";
        assert_eq!(
            trace,
            vec![
                format!("stocator: (intercept) PUT res/{key} (503 transient)"),
                format!("stocator: (intercept) PUT res/{key}"),
            ]
        );
        // Full-object re-send: 100 bytes twice over the wire, vs fast
        // upload's single-part re-send.
        assert_eq!(store.counters().bytes_written, 200);
        // Exactly one (complete) object landed, at the same
        // attempt-qualified name, and the read side sees it.
        assert_eq!(store.debug_names("res", "d/"), vec![key.to_string()]);
        let mut c2 = ctx();
        let data = fs
            .read_all(&p(&format!("swift2d://res/{key}")), &mut c2)
            .unwrap();
        assert_eq!(data.len(), 100);
    }

    #[test]
    fn exhausted_chunked_put_leaves_no_object_but_burns_wire_bytes() {
        use crate::objectstore::{FaultOp, FaultRule, FaultSpec, RetryPolicy, StoreConfig};
        let store = ObjectStore::new(StoreConfig {
            faults: FaultSpec::none()
                .with(FaultRule::new(FaultOp::Put, "d/part-0_attempt", 1, 5)),
            retry: RetryPolicy::with_retries(2),
            ..StoreConfig::instant_strong()
        });
        store.create_container("res", SimInstant::EPOCH).0.unwrap();
        let fs = Stocator::with_defaults(store.clone());
        let mut c = ctx();
        let err = fs.write_all(&temp_file("d", 0, 0, "part-0"), vec![9u8; 50], true, &mut c);
        assert!(matches!(err, Err(FsError::TransientExhausted(_))));
        // 3 failed attempts × 50 bytes each went onto the wire...
        assert_eq!(store.counters().bytes_written, 150);
        // ...but the store rejected each transfer: no debris object.
        assert!(store.debug_names("res", "d/").is_empty());
    }

    #[test]
    fn transient_get_retries_and_reads_identical_bytes() {
        use crate::objectstore::{FaultOp, FaultSpec, RetryPolicy, StoreConfig};
        let store = ObjectStore::new(StoreConfig {
            faults: FaultSpec::one(FaultOp::Get, "in/", 1),
            retry: RetryPolicy::with_retries(1),
            ..StoreConfig::instant_strong()
        });
        store.create_container("res", SimInstant::EPOCH).0.unwrap();
        let fs = Stocator::with_defaults(store.clone());
        let mut c = ctx();
        fs.write_all(&p("swift2d://res/in/part-0"), (0u8..80).collect(), true, &mut c)
            .unwrap();
        let before = store.counters();
        let data = fs.read_all(&p("swift2d://res/in/part-0"), &mut c).unwrap();
        assert_eq!(&*data, &(0u8..80).collect::<Vec<u8>>()[..]);
        let d = store.counters().since(&before);
        assert_eq!(d.get(OpKind::GetObject), 2, "failed GET + retried GET");
        assert_eq!(d.bytes_read, 80, "only the successful GET moves bytes");
        assert_eq!(d.get(OpKind::HeadObject), 0, "still no HEAD before GET (§3.4)");
    }

    #[test]
    fn dropped_untouched_stream_leaves_nothing() {
        let (store, fs) = setup(ReadStrategy::List);
        let mut c = ctx();
        let before = store.counters();
        {
            let _out = fs.create(&temp_file("d", 1, 0, "part-1"), true, &mut c).unwrap();
            // dropped before any write
        }
        assert_eq!(store.counters().since(&before).total(), 0);
        assert!(store.debug_names("res", "d/").is_empty());
    }

    #[test]
    fn range_read_skips_head_and_moves_only_the_slice() {
        let (store, fs) = setup(ReadStrategy::List);
        let mut c = ctx();
        fs.write_all(&p("swift2d://res/in/part-0"), (0u8..80).collect(), true, &mut c)
            .unwrap();
        let before = store.counters();
        let mut input = fs.open(&p("swift2d://res/in/part-0"), &mut c).unwrap();
        assert_eq!(input.size_hint(), None, "lazy handle: nothing issued yet");
        let slice = input.read_range(16, 8, &mut c).unwrap();
        assert_eq!(slice, (16u8..24).collect::<Vec<u8>>());
        let d = store.counters().since(&before);
        assert_eq!(d.get(OpKind::HeadObject), 0, "no HEAD before GET (§3.4)");
        assert_eq!(d.get(OpKind::GetObject), 1);
        assert_eq!(d.bytes_read, 8);
        // The ranged GET's response warmed the cache with the FULL size.
        assert_eq!(input.size_hint(), Some(80));
        let before = store.counters();
        let st = fs.get_file_status(&p("swift2d://res/in/part-0"), &mut c).unwrap();
        assert_eq!(st.len, 80);
        assert_eq!(store.counters().since(&before).total(), 0, "served from cache");
        // Past-EOF offset surfaces uniformly as InvalidRange.
        assert!(matches!(
            input.read_range(81, 1, &mut c),
            Err(FsError::InvalidRange(_))
        ));
    }

    #[test]
    fn head_cache_dedups_repeat_probes() {
        let (store, fs) = setup(ReadStrategy::List);
        let mut c = ctx();
        fs.write_all(&p("swift2d://res/in/f"), b"abc".to_vec(), true, &mut c).unwrap();
        let before = store.counters();
        for _ in 0..5 {
            fs.get_file_status(&p("swift2d://res/in/f"), &mut c).unwrap();
        }
        assert_eq!(
            store.counters().since(&before).get(OpKind::HeadObject),
            1,
            "4 of 5 probes must hit the cache"
        );
    }

    #[test]
    fn dataset_delete_cleans_everything() {
        let (store, fs) = setup(ReadStrategy::List);
        let mut c = ctx();
        fs.mkdirs(&p("swift2d://res/d"), &mut c).unwrap();
        fs.write_all(&temp_file("d", 0, 0, "part-0"), b"x".to_vec(), true, &mut c).unwrap();
        fs.write_all(&p("swift2d://res/d/_SUCCESS"), vec![], true, &mut c).unwrap();
        assert!(fs.delete(&p("swift2d://res/d"), true, &mut c).unwrap());
        assert!(store.debug_names("res", "d").is_empty());
        assert!(!fs.exists(&p("swift2d://res/d"), &mut c));
    }
}
