//! The HEAD-result cache (paper §3.4, second read-path optimization).
//!
//! Spark inputs are immutable by assumption, so repeated HEADs on the same
//! object must return the same result; Stocator caches them. The cache is
//! invalidated on any local mutation of the key (PUT/DELETE through this
//! connector) to stay safe in tests that rewrite objects.

use crate::objectstore::store::HeadResult;
use std::collections::HashMap;
use std::sync::Mutex;

/// A small bounded cache of HEAD results keyed by object key.
pub struct HeadCache {
    map: Mutex<HashMap<String, HeadResult>>,
    capacity: usize,
    hits: Mutex<u64>,
}

impl HeadCache {
    pub fn new(capacity: usize) -> Self {
        Self {
            map: Mutex::new(HashMap::new()),
            capacity,
            hits: Mutex::new(0),
        }
    }

    pub fn get(&self, key: &str) -> Option<HeadResult> {
        let found = self.map.lock().unwrap().get(key).cloned();
        if found.is_some() {
            *self.hits.lock().unwrap() += 1;
        }
        found
    }

    pub fn put(&self, key: &str, head: HeadResult) {
        let mut map = self.map.lock().unwrap();
        // Cheap bound: drop everything when full. The working set of a
        // Spark job's metadata probes is tiny compared to the capacity.
        if map.len() >= self.capacity {
            map.clear();
        }
        map.insert(key.to_string(), head);
    }

    /// Invalidate a key after a local mutation.
    pub fn invalidate(&self, key: &str) {
        self.map.lock().unwrap().remove(key);
    }

    /// Invalidate every cached key with the given prefix (dataset deletes).
    pub fn invalidate_prefix(&self, prefix: &str) {
        self.map.lock().unwrap().retain(|k, _| !k.starts_with(prefix));
    }

    pub fn hits(&self) -> u64 {
        *self.hits.lock().unwrap()
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objectstore::Metadata;
    use crate::simclock::SimInstant;

    fn head(size: u64) -> HeadResult {
        HeadResult {
            size,
            etag: size * 7,
            metadata: Metadata::new(),
            created_at: SimInstant::EPOCH,
        }
    }

    #[test]
    fn hit_and_miss() {
        let c = HeadCache::new(8);
        assert!(c.get("a").is_none());
        c.put("a", head(3));
        assert_eq!(c.get("a").unwrap().size, 3);
        assert_eq!(c.hits(), 1);
    }

    #[test]
    fn invalidation() {
        let c = HeadCache::new(8);
        c.put("d/part-0", head(1));
        c.put("d/part-1", head(2));
        c.put("e/part-0", head(3));
        c.invalidate("d/part-0");
        assert!(c.get("d/part-0").is_none());
        c.invalidate_prefix("d/");
        assert!(c.get("d/part-1").is_none());
        assert!(c.get("e/part-0").is_some());
    }

    #[test]
    fn capacity_bound() {
        let c = HeadCache::new(4);
        for i in 0..4 {
            c.put(&format!("k{i}"), head(i));
        }
        assert_eq!(c.len(), 4);
        c.put("k4", head(4)); // triggers clear-then-insert
        assert_eq!(c.len(), 1);
        assert!(c.get("k4").is_some());
    }
}
