//! Read-only workload (paper §4.3): read a text dataset and count its
//! lines. One job, one task per input part; compute runs on the
//! `readonly_chunk` kernel.

use super::{WorkloadEnv, WorkloadReport};
use crate::committer::CommitAlgorithm;
use crate::fs::FsInputStream;
use crate::runtime::{pad_chunk, CHUNK};
use crate::spark::task::{body, TaskBody, TaskResult};
use crate::spark::SparkJob;

/// Discover the input parts of `dataset` driver-side (Hadoop's
/// FileInputFormat: list, drop `_`-prefixed entries, sort).
pub fn discover_parts(env: &mut WorkloadEnv, dataset: &str) -> Vec<(crate::fs::Path, u64)> {
    let ds_path = env.path(dataset);
    env.driver.driver_phase(|fs, ctx| {
        let mut parts: Vec<(crate::fs::Path, u64)> = fs
            .list_status(&ds_path, ctx)
            .unwrap_or_default()
            .into_iter()
            .filter(|s| !s.is_dir && !s.path.name().starts_with('_') && !s.path.name().starts_with('.'))
            .map(|s| (s.path, s.len))
            .collect();
        parts.sort();
        parts
    })
}

/// Run the Read-only workload over `dataset`. `expected_lines` is the
/// generator's oracle.
pub fn run(env: &mut WorkloadEnv, dataset: &str, expected_lines: u64) -> WorkloadReport {
    let ops_before = env.store.counters();
    let parts = discover_parts(env, dataset);
    assert!(!parts.is_empty(), "no input parts under {dataset}");
    let kernels = env.kernels.clone();
    let tasks: Vec<TaskBody> = parts
        .iter()
        .map(|(path, _)| {
            let path = path.clone();
            let kernels = kernels.clone();
            body(move |run| {
                let data = run.fs.open(&path, run.ctx)?.read_to_end(run.ctx)?;
                run.charge_compute(data.len() as u64);
                let mut lines = 0i64;
                for chunk in data.chunks(CHUNK) {
                    let ints: Vec<i32> = chunk.iter().map(|&b| b as i32).collect();
                    let padded = pad_chunk(&ints, 0);
                    let [nl, _nz] = kernels
                        .readonly_chunk(&padded)
                        .map_err(|e| crate::fs::FsError::Io(e.to_string()))?;
                    lines += nl as i64;
                }
                Ok(TaskResult {
                    bytes_read: data.len() as u64,
                    records: lines as u64,
                    collected: Some(lines.to_le_bytes().to_vec()),
                    ..Default::default()
                })
            })
        })
        .collect();
    let job = SparkJob::new("readonly", None, CommitAlgorithm::V1, tasks);
    let stats = env.driver.run_job(&job).expect("readonly job");
    let total: i64 = stats
        .collected
        .iter()
        .flatten()
        .map(|b| i64::from_le_bytes(b[..8].try_into().unwrap()))
        .sum();
    let ops_window = env.store.counters().since(&ops_before);
    let validation = if !stats.success {
        Err("job failed".into())
    } else if total as u64 == expected_lines {
        Ok(format!("counted {total} lines (matches oracle)"))
    } else {
        Err(format!("counted {total} lines, expected {expected_lines}"))
    };
    WorkloadReport::from_jobs("readonly", vec![stats], validation).with_ops(ops_window)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::input::upload_text_dataset;
    use crate::workloads::tests_support::make_env;

    #[test]
    fn readonly_counts_lines_exactly() {
        let mut env = make_env("swift2d", 4, 2000);
        let (lines, _, _) = upload_text_dataset(&env.store, "res", "in.txt", 4, 2000, 5);
        let report = run(&mut env, "in.txt", lines);
        assert!(report.is_valid(), "{:?}", report.validation);
        assert_eq!(report.jobs.len(), 1);
        assert!(report.ops.total() > 0);
        assert_eq!(report.ops.get(crate::metrics::OpKind::PutObject), 0);
    }

    #[test]
    fn readonly_detects_wrong_oracle() {
        let mut env = make_env("swift2d", 2, 1000);
        let (lines, _, _) = upload_text_dataset(&env.store, "res", "in.txt", 2, 1000, 5);
        let report = run(&mut env, "in.txt", lines + 1);
        assert!(!report.is_valid());
    }
}
