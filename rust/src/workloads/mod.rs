//! The paper's benchmark workloads (§4.3, Table 4): Read-only, Teragen
//! (write-only), Copy, Wordcount, Terasort and the TPC-DS subset.
//!
//! Every workload runs real bytes through the full stack: data generated
//! by [`input`], stored through a connector, computed through the XLA
//! kernels ([`crate::runtime::Kernels`]), committed through
//! [`crate::committer`], and validated against an independent oracle.

pub mod input;
pub mod readonly;
pub mod teragen;
pub mod copy;
pub mod wordcount;
pub mod terasort;
pub mod tpcds;

use crate::committer::CommitAlgorithm;
use crate::fs::Path;
use crate::metrics::OpCounts;
use crate::objectstore::ObjectStore;
use crate::runtime::Kernels;
use crate::simclock::SimDuration;
use crate::spark::{Driver, JobStats};
use std::rc::Rc;
use std::sync::Arc;

/// Everything a workload needs to run.
pub struct WorkloadEnv {
    pub driver: Driver,
    pub store: Arc<ObjectStore>,
    pub container: String,
    /// Path scheme of the connector under test.
    pub scheme: String,
    pub algorithm: CommitAlgorithm,
    pub kernels: Rc<Kernels>,
    /// Number of input/output parts (paper: 372 for the 46.5 GB dataset).
    pub parts: usize,
    /// Simulated bytes per part (scaled by the latency model's data_scale).
    pub part_bytes: usize,
    pub seed: u64,
}

impl WorkloadEnv {
    pub fn path(&self, key: &str) -> Path {
        Path::new(&self.scheme, &self.container, key)
    }
}

/// A completed workload run.
#[derive(Debug, Clone)]
pub struct WorkloadReport {
    pub workload: String,
    pub jobs: Vec<JobStats>,
    /// End-to-end virtual runtime (sum of job runtimes).
    pub runtime: SimDuration,
    /// REST ops across all jobs (input preparation excluded).
    pub ops: OpCounts,
    /// Ok(summary) if the output validated against the oracle.
    pub validation: Result<String, String>,
    /// Paper-scaled bytes parked in orphaned multipart uploads when the
    /// workload finished (fast-upload crash/fault debris; 0 unless
    /// faults stranded an upload).
    pub stranded_mp_bytes: u64,
    /// The same figure after the `--multipart-ttl` lifecycle sweep
    /// (equal to `stranded_mp_bytes` when the sweep is off).
    pub stranded_mp_bytes_after_sweep: u64,
}

impl WorkloadReport {
    pub fn from_jobs(workload: &str, jobs: Vec<JobStats>, validation: Result<String, String>) -> Self {
        let runtime = jobs.iter().map(|j| j.runtime).sum();
        let ops = jobs
            .iter()
            .fold(OpCounts::default(), |acc, j| acc.plus(&j.ops));
        WorkloadReport {
            workload: workload.to_string(),
            jobs,
            runtime,
            ops,
            validation,
            stranded_mp_bytes: 0,
            stranded_mp_bytes_after_sweep: 0,
        }
    }

    /// Override the op counts with an explicitly measured window (jobs +
    /// driver-side input discovery, validation reads excluded).
    pub fn with_ops(mut self, ops: OpCounts) -> Self {
        self.ops = ops;
        self
    }

    pub fn is_valid(&self) -> bool {
        self.validation.is_ok()
    }
}

#[cfg(test)]
pub(crate) mod tests_support {
    use super::*;
    use crate::objectstore::StoreConfig;
    use crate::runtime::fallback::Fallback;
    use crate::simclock::SimInstant;
    use crate::spark::{ComputeModel, SparkConfig};

    /// Build a small test environment on the given connector scheme with
    /// FileOutputCommitter v1 semantics.
    pub fn make_env(scheme: &str, parts: usize, part_bytes: usize) -> WorkloadEnv {
        make_env_with(scheme, CommitAlgorithm::V1, parts, part_bytes)
    }

    pub fn make_env_with(
        scheme: &str,
        algorithm: CommitAlgorithm,
        parts: usize,
        part_bytes: usize,
    ) -> WorkloadEnv {
        let store = ObjectStore::new(StoreConfig::instant_strong());
        store.create_container("res", SimInstant::EPOCH).0.unwrap();
        let fs: Arc<dyn crate::fs::FileSystem> = match scheme {
            "swift2d" => crate::connectors::Stocator::with_defaults(store.clone()),
            "swift" => crate::connectors::HadoopSwift::new(store.clone()),
            "s3a" => crate::connectors::S3a::new(store.clone(), Default::default()),
            other => panic!("unknown scheme {other}"),
        };
        let driver = Driver::new(
            SparkConfig {
                slots: 8,
                ..Default::default()
            },
            fs,
            Some(store.clone()),
            ComputeModel::free(),
        );
        WorkloadEnv {
            driver,
            store,
            container: "res".into(),
            scheme: scheme.into(),
            algorithm,
            kernels: Rc::new(Kernels::Native(Fallback)),
            parts,
            part_bytes,
            seed: 42,
        }
    }
}
