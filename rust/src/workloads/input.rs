//! Deterministic input generation + direct upload (the "data is already in
//! the object store" precondition of every benchmark; upload ops happen
//! before the measurement window).

use crate::objectstore::{Metadata, ObjectStore};
use crate::simclock::SimInstant;
use crate::util::rng::Pcg32;

/// Size of one Teragen-style record (10-byte key + 90-byte payload).
pub const RECORD_BYTES: usize = 100;

/// Vocabulary size for the Zipf-distributed Wordcount corpus.
pub const VOCAB: usize = 10_000;

/// Generate one part of line-oriented text (words drawn Zipf(1.1) from a
/// `w<id>` vocabulary, ~8 words per line). Deterministic in (seed, part).
/// Returns (bytes, line count, word count).
pub fn text_part(seed: u64, part: usize, part_bytes: usize) -> (Vec<u8>, u64, u64) {
    let mut rng = Pcg32::with_stream(seed, part as u64);
    let mut out = Vec::with_capacity(part_bytes + 16);
    let mut lines = 0u64;
    let mut words = 0u64;
    let mut col = 0usize;
    while out.len() < part_bytes {
        let w = rng.zipf(VOCAB, 1.1);
        let token = format!("w{w}");
        out.extend_from_slice(token.as_bytes());
        words += 1;
        col += 1;
        if col == 8 {
            out.push(b'\n');
            lines += 1;
            col = 0;
        } else {
            out.push(b' ');
        }
    }
    if col != 0 {
        out.push(b'\n');
        lines += 1;
    }
    (out, lines, words)
}

/// Generate one part of Teragen-style binary records. Keys are the first
/// 4 bytes, big-endian, non-negative (so they sort as i32). Deterministic
/// in (seed, part). Returns (bytes, record count).
pub fn tera_part(seed: u64, part: usize, part_bytes: usize) -> (Vec<u8>, u64) {
    let mut rng = Pcg32::with_stream(seed ^ 0x7E7A, part as u64);
    let records = (part_bytes / RECORD_BYTES).max(1);
    let mut out = Vec::with_capacity(records * RECORD_BYTES);
    for _ in 0..records {
        let key = (rng.next_u32() >> 1) as i32; // non-negative
        out.extend_from_slice(&key.to_be_bytes());
        let mut rest = [0u8; RECORD_BYTES - 4];
        for b in rest.iter_mut() {
            *b = b'A' + rng.next_below(26) as u8;
        }
        out.extend_from_slice(&rest);
    }
    (out, records as u64)
}

/// Extract the i32 sort keys from a Teragen-format byte buffer.
pub fn tera_keys(data: &[u8]) -> Vec<i32> {
    data.chunks_exact(RECORD_BYTES)
        .map(|r| i32::from_be_bytes(r[..4].try_into().unwrap()))
        .collect()
}

/// Upload a text dataset directly to the store (outside any measurement
/// window). Returns (total lines, total words, total bytes).
pub fn upload_text_dataset(
    store: &ObjectStore,
    container: &str,
    dataset: &str,
    parts: usize,
    part_bytes: usize,
    seed: u64,
) -> (u64, u64, u64) {
    let mut lines = 0;
    let mut words = 0;
    let mut bytes = 0;
    for p in 0..parts {
        let (data, l, w) = text_part(seed, p, part_bytes);
        lines += l;
        words += w;
        bytes += data.len() as u64;
        store
            .put_object(
                container,
                &format!("{dataset}/part-{p:05}"),
                data,
                Metadata::new(),
                SimInstant::EPOCH,
            )
            .0
            .expect("upload");
    }
    (lines, words, bytes)
}

/// Upload a Teragen-format dataset directly. Returns total records.
pub fn upload_tera_dataset(
    store: &ObjectStore,
    container: &str,
    dataset: &str,
    parts: usize,
    part_bytes: usize,
    seed: u64,
) -> u64 {
    let mut records = 0;
    for p in 0..parts {
        let (data, r) = tera_part(seed, p, part_bytes);
        records += r;
        store
            .put_object(
                container,
                &format!("{dataset}/part-{p:05}"),
                data,
                Metadata::new(),
                SimInstant::EPOCH,
            )
            .0
            .expect("upload");
    }
    records
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objectstore::StoreConfig;

    #[test]
    fn text_part_deterministic_and_counted() {
        let (a, l1, w1) = text_part(1, 0, 1000);
        let (b, l2, w2) = text_part(1, 0, 1000);
        assert_eq!(a, b);
        assert_eq!((l1, w1), (l2, w2));
        let (c, _, _) = text_part(1, 1, 1000);
        assert_ne!(a, c);
        // Count lines/words independently.
        let text = String::from_utf8(a).unwrap();
        assert_eq!(text.lines().count() as u64, l1);
        assert_eq!(text.split_whitespace().count() as u64, w1);
    }

    #[test]
    fn tera_part_structure() {
        let (data, n) = tera_part(2, 0, 1000);
        assert_eq!(n, 10);
        assert_eq!(data.len(), 1000);
        let keys = tera_keys(&data);
        assert_eq!(keys.len(), 10);
        assert!(keys.iter().all(|&k| k >= 0));
        // Payload is printable.
        assert!(data[4..100].iter().all(|b| b.is_ascii_uppercase()));
    }

    #[test]
    fn upload_helpers_populate_store() {
        let store = ObjectStore::new(StoreConfig::instant_strong());
        store.create_container("c", SimInstant::EPOCH).0.unwrap();
        let (lines, words, bytes) = upload_text_dataset(&store, "c", "in", 3, 500, 9);
        assert_eq!(store.debug_live_count("c"), 3);
        assert_eq!(store.debug_live_bytes("c"), bytes);
        assert!(lines > 0 && words > lines);
        let recs = upload_tera_dataset(&store, "c", "tin", 2, 1000, 9);
        assert_eq!(recs, 20);
        assert_eq!(store.debug_live_count("c"), 5);
    }
}
