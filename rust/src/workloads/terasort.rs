//! Terasort (paper §4.3): sort a Teragen dataset globally.
//!
//! Three phases: (1) the driver samples input keys and derives 63 range
//! splitters; (2) a map job assigns every record to a partition on the
//! `terasort_partition_chunk` XLA kernel and shuffles record bytes; (3) a
//! reduce job sorts each partition and writes output parts through the
//! commit protocol. Validation checks global order and key conservation.

use super::input::{tera_keys, RECORD_BYTES};
use super::readonly::discover_parts;
use super::{WorkloadEnv, WorkloadReport};
use crate::committer::CommitAlgorithm;
use crate::fs::FsInputStream;
use crate::runtime::{pad_chunk, CHUNK, PARTS};
use crate::spark::task::{body, TaskBody, TaskResult};
use crate::spark::{ShuffleStore, SparkJob};

/// How many input parts the driver samples for splitters, and how many
/// bytes of each (Spark's RangePartitioner samples a bounded number of
/// records per partition, not whole partitions). 32 parts × 80 records
/// keeps the sampled-key count at the level the Table 5 calibration was
/// done against (8 whole 327-record parts ≈ 2616 keys → 2560), so bucket
/// balance — and with it the reduce-wave time — is statistically
/// unchanged, while the driver now moves a prefix instead of 8 full
/// parts over the wire. Records are i.i.d. across a part, so a prefix is
/// an unbiased sample. Parts smaller than the prefix (test sizings) are
/// read whole via the EOF clamp — identical splitters to the old code.
const SAMPLE_PARTS: usize = 32;
const SAMPLE_PREFIX_BYTES: u64 = 80 * RECORD_BYTES as u64;

/// Sample splitters driver-side with prefix `read_range` reads — one
/// ranged GET per sampled part, never a whole-part download (with
/// `--readahead` the GET is the stream's first prefetch fill).
fn sample_splitters(env: &mut WorkloadEnv, parts: &[(crate::fs::Path, u64)]) -> Vec<i32> {
    let sample: Vec<crate::fs::Path> = parts
        .iter()
        .take(SAMPLE_PARTS)
        .map(|(p, _)| p.clone())
        .collect();
    env.driver.driver_phase(|fs, ctx| {
        let mut keys = Vec::new();
        for path in &sample {
            let mut stream = fs.open(path, ctx).expect("sample part");
            let data = stream
                .read_range(0, SAMPLE_PREFIX_BYTES, ctx)
                .expect("sample part prefix");
            keys.extend(tera_keys(&data));
        }
        keys.sort_unstable();
        (1..PARTS)
            .map(|i| keys[i * keys.len() / PARTS])
            .collect()
    })
}

pub fn run(env: &mut WorkloadEnv, input: &str, output: &str) -> WorkloadReport {
    let ops_before = env.store.counters();
    let parts = discover_parts(env, input);
    assert!(!parts.is_empty(), "no input under {input}");
    let splitters = sample_splitters(env, &parts);
    assert_eq!(splitters.len(), PARTS - 1);
    // Reducers fetch from many map outputs in parallel; the paper's
    // 10 Gbps NICs sustain ~4 concurrent shuffle streams per reduce task.
    let shuffle = ShuffleStore::new(
        env.store.config.latency.stream_bw.saturating_mul(4),
        env.store.config.latency.data_scale,
    );

    // --- map: partition records by key range.
    let kernels = env.kernels.clone();
    let map_tasks: Vec<TaskBody> = parts
        .iter()
        .map(|(path, _)| {
            let path = path.clone();
            let kernels = kernels.clone();
            let splitters = splitters.clone();
            body(move |run| {
                let data = run.fs.open(&path, run.ctx)?.read_to_end(run.ctx)?;
                run.charge_compute(data.len() as u64);
                let keys = tera_keys(&data);
                let mut buckets: Vec<Vec<u8>> = vec![Vec::new(); PARTS];
                for (chunk_idx, chunk) in keys.chunks(CHUNK).enumerate() {
                    // Padding keys = MAX routes to the last partition, but
                    // we only consume `chunk.len()` assignments.
                    let padded = pad_chunk(chunk, i32::MAX);
                    let (assign, _hist) = kernels
                        .terasort_partition_chunk(&padded, &splitters)
                        .map_err(|e| crate::fs::FsError::Io(e.to_string()))?;
                    for (i, &p) in assign[..chunk.len()].iter().enumerate() {
                        let rec = chunk_idx * CHUNK + i;
                        let off = rec * RECORD_BYTES;
                        buckets[p as usize]
                            .extend_from_slice(&data[off..off + RECORD_BYTES]);
                    }
                }
                let shuffle_out = buckets
                    .into_iter()
                    .enumerate()
                    .filter(|(_, b)| !b.is_empty())
                    .collect();
                Ok(TaskResult {
                    bytes_read: data.len() as u64,
                    records: keys.len() as u64,
                    shuffle_out,
                    ..Default::default()
                })
            })
        })
        .collect();
    let map_job = SparkJob::new("terasort-map", None, CommitAlgorithm::V1, map_tasks)
        .with_shuffle_out(shuffle.clone());
    let map_stats = env.driver.run_job(&map_job).expect("map stage");
    let total_records = map_stats.records;

    // --- reduce: sort each partition, write output part.
    let reduce_tasks: Vec<TaskBody> = (0..PARTS)
        .map(|_| {
            body(move |run| {
                let mut records: Vec<&[u8]> = Vec::new();
                let blocks = run.shuffle_in.clone();
                for block in &blocks {
                    for rec in block.chunks_exact(RECORD_BYTES) {
                        records.push(rec);
                    }
                }
                let bytes: u64 = (records.len() * RECORD_BYTES) as u64;
                run.charge_compute(bytes);
                records.sort_by_key(|r| i32::from_be_bytes(r[..4].try_into().unwrap()));
                let mut out = Vec::with_capacity(records.len() * RECORD_BYTES);
                for r in &records {
                    out.extend_from_slice(r);
                }
                let name = run.part_basename();
                let written = run.write_part(&name, out)?;
                Ok(TaskResult {
                    bytes_written: written,
                    records: records.len() as u64,
                    ..Default::default()
                })
            })
        })
        .collect();
    let out_path = env.path(output);
    let reduce_job = SparkJob::new("terasort-reduce", Some(out_path), env.algorithm, reduce_tasks)
        .with_shuffle_in(shuffle);
    let reduce_stats = env.driver.run_job(&reduce_job).expect("reduce stage");

    let ops_window = env.store.counters().since(&ops_before);
    let validation = validate(env, output, total_records, &map_stats, &reduce_stats);
    WorkloadReport::from_jobs("terasort", vec![map_stats, reduce_stats], validation).with_ops(ops_window)
}

fn validate(
    env: &mut WorkloadEnv,
    output: &str,
    total_records: u64,
    map_stats: &crate::spark::JobStats,
    reduce_stats: &crate::spark::JobStats,
) -> Result<String, String> {
    if !map_stats.success || !reduce_stats.success {
        return Err("a stage failed".into());
    }
    if reduce_stats.records != total_records {
        return Err(format!(
            "reduce wrote {} records, map read {total_records}",
            reduce_stats.records
        ));
    }
    let out_path = env.path(output);
    env.driver.driver_phase(|fs, ctx| {
        let mut listing: Vec<_> = fs
            .list_status(&out_path, ctx)
            .map_err(|e| e.to_string())?
            .into_iter()
            .filter(|s| !s.is_dir && !s.path.name().starts_with('_'))
            .collect();
        listing.sort_by_key(|s| s.path.clone());
        let mut prev_max = i32::MIN;
        let mut count = 0u64;
        for st in listing {
            let mut stream = fs.open(&st.path, ctx).map_err(|e| e.to_string())?;
            let data = stream.read_to_end(ctx).map_err(|e| e.to_string())?;
            let keys = tera_keys(&data);
            count += keys.len() as u64;
            for w in keys.windows(2) {
                if w[0] > w[1] {
                    return Err(format!("{} not sorted", st.path));
                }
            }
            if let (Some(&first), Some(&last)) = (keys.first(), keys.last()) {
                if first < prev_max {
                    return Err(format!(
                        "partition boundary violated at {} ({first} < {prev_max})",
                        st.path
                    ));
                }
                prev_max = last;
            }
        }
        if count != total_records {
            return Err(format!("output holds {count} records, expected {total_records}"));
        }
        Ok(format!("{count} records globally sorted across {PARTS} partitions"))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::OpKind;
    use crate::workloads::input::upload_tera_dataset;
    use crate::workloads::tests_support::make_env;

    #[test]
    fn terasort_produces_globally_sorted_output() {
        let mut env = make_env("swift2d", 4, 5_000);
        let records = upload_tera_dataset(&env.store, "res", "tin", 4, 5_000, 55);
        assert_eq!(records, 200);
        let report = run(&mut env, "tin", "tsorted");
        assert!(report.is_valid(), "{:?}", report.validation);
        assert_eq!(report.jobs.len(), 2);
        assert_eq!(report.ops.get(OpKind::CopyObject), 0, "stocator never copies");
    }

    #[test]
    fn terasort_conserves_key_multiset() {
        let mut env = make_env("swift2d", 3, 3_000);
        upload_tera_dataset(&env.store, "res", "tin", 3, 3_000, 56);
        let report = run(&mut env, "tin", "tsorted");
        assert!(report.is_valid());
        // Key checksum in == out.
        let sum_keys = |prefix: &str| -> (u64, u64) {
            let mut sum = 0u64;
            let mut n = 0u64;
            for key in env.store.debug_names("res", prefix) {
                if key.contains("_SUCCESS") || key.ends_with('/') || !key.contains("part-") {
                    continue;
                }
                let (obj, _) = env.store.get_object("res", &key);
                for k in tera_keys(&obj.unwrap().data) {
                    sum = sum.wrapping_add(k as u64);
                    n += 1;
                }
            }
            (sum, n)
        };
        let (in_sum, in_n) = sum_keys("tin/");
        let (out_sum, out_n) = sum_keys("tsorted/");
        assert_eq!(in_n, out_n);
        assert_eq!(in_sum, out_sum);
    }
}
