//! Copy workload (paper §4.3): read every input part and write it back to
//! a new dataset — equal parts read and write.

use super::readonly::discover_parts;
use crate::fs::FsInputStream;
use super::{WorkloadEnv, WorkloadReport};
use crate::spark::task::{body, TaskBody, TaskResult};
use crate::spark::SparkJob;

pub fn run(env: &mut WorkloadEnv, input: &str, output: &str) -> WorkloadReport {
    let ops_before = env.store.counters();
    let parts = discover_parts(env, input);
    assert!(!parts.is_empty(), "no input under {input}");
    let expected_bytes: u64 = parts.iter().map(|(_, len)| len).sum();
    let tasks: Vec<TaskBody> = parts
        .iter()
        .map(|(path, _)| {
            let path = path.clone();
            body(move |run| {
                let data = run.fs.open(&path, run.ctx)?.read_to_end(run.ctx)?;
                run.charge_compute(data.len() as u64);
                let name = run.part_basename();
                let written = run.write_part(&name, data.as_ref().clone())?;
                Ok(TaskResult {
                    bytes_read: data.len() as u64,
                    bytes_written: written,
                    records: 1,
                    ..Default::default()
                })
            })
        })
        .collect();
    let out_path = env.path(output);
    let job = SparkJob::new("copy", Some(out_path), env.algorithm, tasks);
    let stats = env.driver.run_job(&job).expect("copy job");

    let ops_window = env.store.counters().since(&ops_before);
    let validation = if !stats.success {
        Err("job failed".into())
    } else {
        // Re-read both datasets and compare content byte-for-byte.
        let in_path = env.path(input);
        let out_path = env.path(output);
        env.driver.driver_phase(|fs, ctx| {
            let read_all = |ds: &crate::fs::Path, ctx: &mut crate::fs::OpCtx| -> Result<Vec<Vec<u8>>, String> {
                let mut listing = fs.list_status(ds, ctx).map_err(|e| e.to_string())?;
                listing.sort_by_key(|s| s.path.clone());
                let mut out = Vec::new();
                for st in listing {
                    if st.is_dir || st.path.name().starts_with('_') {
                        continue;
                    }
                    let mut stream = fs.open(&st.path, ctx).map_err(|e| e.to_string())?;
                    let data = stream.read_to_end(ctx).map_err(|e| e.to_string())?;
                    out.push(data.as_ref().clone());
                }
                Ok(out)
            };
            let src = read_all(&in_path, ctx)?;
            let dst = read_all(&out_path, ctx)?;
            if src.len() != dst.len() {
                return Err(format!("{} input parts vs {} output parts", src.len(), dst.len()));
            }
            let total: u64 = dst.iter().map(|d| d.len() as u64).sum();
            if total != expected_bytes {
                return Err(format!("copied {total} bytes, expected {expected_bytes}"));
            }
            // Parts may be renumbered but the multiset of contents must
            // match; both sides are sorted by part index so compare 1:1.
            for (i, (a, b)) in src.iter().zip(&dst).enumerate() {
                if a != b {
                    return Err(format!("part {i} differs after copy"));
                }
            }
            Ok(format!("{} parts, {expected_bytes} bytes copied intact", dst.len()))
        })
    };
    WorkloadReport::from_jobs("copy", vec![stats], validation).with_ops(ops_window)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::OpKind;
    use crate::workloads::input::upload_text_dataset;
    use crate::workloads::tests_support::make_env;

    #[test]
    fn copy_roundtrips_content() {
        let mut env = make_env("swift2d", 3, 1500);
        upload_text_dataset(&env.store, "res", "src", 3, 1500, 21);
        let report = run(&mut env, "src", "dst");
        assert!(report.is_valid(), "{:?}", report.validation);
        assert_eq!(report.ops.get(OpKind::CopyObject), 0);
        assert!(report.jobs[0].bytes_read > 0);
        assert_eq!(report.jobs[0].bytes_read, report.jobs[0].bytes_written);
    }
}
