//! Wordcount (Intel HiBench flavour, paper §4.3): read a text dataset,
//! count word occurrences, write a small output — the read-intensive
//! macro benchmark.
//!
//! Counting runs on the `wordcount_chunk` XLA kernel over hashed token
//! ids; the reduce stage aggregates per-bucket counts (the fixed-width
//! histogram is the kernel-friendly representation; the oracle recomputes
//! it independently from the generator's text).

use super::readonly::discover_parts;
use super::{WorkloadEnv, WorkloadReport};
use crate::committer::CommitAlgorithm;
use crate::fs::FsInputStream;
use crate::objectstore::object::fnv1a;
use crate::runtime::{fallback::bucket_of, pad_chunk, BUCKETS, CHUNK};
use crate::spark::task::{body, TaskBody, TaskResult};
use crate::spark::{ShuffleStore, SparkJob};

/// Default reduce-stage width for tests; the harness uses one reducer
/// per input part (Spark's default parallelism keeps the parent
/// partition count, which is what makes the v1 job commit expensive on
/// this workload in the paper).
pub const DEFAULT_REDUCERS: usize = 4;

/// Token id for a word: a 31-bit FNV hash, never 0 (0 = padding).
pub fn token_id(word: &str) -> i32 {
    ((fnv1a(word.as_bytes()) & 0x7fff_fffe) + 1) as i32
}

/// Buckets are assigned to reducers round-robin: reducer r owns buckets
/// {b : b mod R == r} (works for any R <= BUCKETS).
fn buckets_of(r: usize, reducers: usize) -> Vec<usize> {
    (r..BUCKETS).step_by(reducers).collect()
}

/// Serialize a histogram slice as little-endian i64s.
fn encode_hist(hist: &[i64]) -> Vec<u8> {
    hist.iter().flat_map(|v| v.to_le_bytes()).collect()
}

fn decode_hist(bytes: &[u8]) -> Vec<i64> {
    bytes
        .chunks_exact(8)
        .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

/// Run Wordcount: `input` text dataset -> `output` bucket-count dataset.
/// `expected_words` is the generator oracle.
pub fn run(env: &mut WorkloadEnv, input: &str, output: &str, expected_words: u64) -> WorkloadReport {
    let ops_before = env.store.counters();
    let parts = discover_parts(env, input);
    assert!(!parts.is_empty(), "no input under {input}");
    // Spark's default parallelism: as many reducers as parent partitions.
    let reducers = parts.len().clamp(1, BUCKETS);
    // Shuffle blocks are fixed-width histograms (metadata, not dataset
    // bytes): never scaled.
    let shuffle = ShuffleStore::new(env.store.config.latency.stream_bw, 1);

    // --- map stage: tokenize + kernel histogram, shuffle by bucket range.
    let kernels = env.kernels.clone();
    let map_tasks: Vec<TaskBody> = parts
        .iter()
        .map(|(path, _)| {
            let path = path.clone();
            let kernels = kernels.clone();
            body(move |run| {
                let data = run.fs.open(&path, run.ctx)?.read_to_end(run.ctx)?;
                run.charge_compute(data.len() as u64);
                let text = String::from_utf8_lossy(&data);
                let tokens: Vec<i32> = text.split_whitespace().map(token_id).collect();
                let mut hist = vec![0i64; BUCKETS];
                let mut total = 0u64;
                for chunk in tokens.chunks(CHUNK) {
                    let padded = pad_chunk(chunk, 0);
                    let (h, n) = kernels
                        .wordcount_chunk(&padded)
                        .map_err(|e| crate::fs::FsError::Io(e.to_string()))?;
                    for (acc, x) in hist.iter_mut().zip(&h) {
                        *acc += *x as i64;
                    }
                    total += n as u64;
                }
                // Shuffle: one block per reducer holding its buckets
                // (round-robin assignment).
                let shuffle_out = (0..reducers)
                    .map(|r| {
                        let slice: Vec<i64> =
                            buckets_of(r, reducers).iter().map(|&b| hist[b]).collect();
                        (r, encode_hist(&slice))
                    })
                    .collect();
                Ok(TaskResult {
                    bytes_read: data.len() as u64,
                    records: total,
                    shuffle_out,
                    ..Default::default()
                })
            })
        })
        .collect();
    let map_job = SparkJob::new("wordcount-map", None, CommitAlgorithm::V1, map_tasks)
        .with_shuffle_out(shuffle.clone());
    let map_stats = env.driver.run_job(&map_job).expect("map stage");
    let total_words = map_stats.records;

    // --- reduce stage: sum histograms, write "bucket,count" text parts.
    let reduce_tasks: Vec<TaskBody> = (0..reducers)
        .map(|r| {
            body(move |run| {
                let my_buckets = buckets_of(r, reducers);
                let mut hist = vec![0i64; my_buckets.len()];
                for block in &run.shuffle_in {
                    for (acc, x) in hist.iter_mut().zip(decode_hist(block)) {
                        *acc += x;
                    }
                }
                // Summing a few hundred small histograms is cheap and
                // does not grow with the (scaled) dataset.
                run.ctx.add(crate::simclock::SimDuration::from_millis(100));
                let mut out = String::new();
                for (i, c) in hist.iter().enumerate() {
                    out.push_str(&format!("{},{}\n", my_buckets[i], c));
                }
                let name = run.part_basename();
                let written = run.write_part(&name, out.into_bytes())?;
                Ok(TaskResult {
                    bytes_written: written,
                    records: hist.iter().map(|&c| c as u64).sum(),
                    ..Default::default()
                })
            })
        })
        .collect();
    let out_path = env.path(output);
    let reduce_job = SparkJob::new("wordcount-reduce", Some(out_path), env.algorithm, reduce_tasks)
        .with_shuffle_in(shuffle);
    let reduce_stats = env.driver.run_job(&reduce_job).expect("reduce stage");

    let ops_window = env.store.counters().since(&ops_before);
    let validation = validate(env, output, total_words, expected_words, &map_stats, &reduce_stats);
    WorkloadReport::from_jobs("wordcount", vec![map_stats, reduce_stats], validation).with_ops(ops_window)
}

fn validate(
    env: &mut WorkloadEnv,
    output: &str,
    total_words: u64,
    expected_words: u64,
    map_stats: &crate::spark::JobStats,
    reduce_stats: &crate::spark::JobStats,
) -> Result<String, String> {
    if !map_stats.success || !reduce_stats.success {
        return Err("a stage failed".into());
    }
    if total_words != expected_words {
        return Err(format!("map saw {total_words} words, oracle says {expected_words}"));
    }
    if reduce_stats.records != expected_words {
        return Err(format!(
            "reduce output sums to {} counts, oracle says {expected_words}",
            reduce_stats.records
        ));
    }
    // Read the output back and re-sum the counts.
    let out_path = env.path(output);
    env.driver.driver_phase(|fs, ctx| {
        let listing = fs.list_status(&out_path, ctx).map_err(|e| e.to_string())?;
        let mut sum = 0u64;
        let mut buckets_seen = 0usize;
        for st in listing {
            if st.is_dir || st.path.name().starts_with('_') {
                continue;
            }
            let mut stream = fs.open(&st.path, ctx).map_err(|e| e.to_string())?;
            let data = stream.read_to_end(ctx).map_err(|e| e.to_string())?;
            for line in String::from_utf8_lossy(&data).lines() {
                let (_, c) = line.split_once(',').ok_or("bad output line")?;
                sum += c.parse::<u64>().map_err(|e| e.to_string())?;
                buckets_seen += 1;
            }
        }
        if buckets_seen != BUCKETS {
            return Err(format!("output has {buckets_seen} buckets, expected {BUCKETS}"));
        }
        if sum != expected_words {
            return Err(format!("output counts sum to {sum}, expected {expected_words}"));
        }
        Ok(format!("{expected_words} words across {BUCKETS} buckets verified"))
    })
}

/// Oracle helper: the reference bucket histogram of a text corpus.
pub fn reference_histogram(texts: &[Vec<u8>]) -> Vec<i64> {
    let mut hist = vec![0i64; BUCKETS];
    for t in texts {
        for word in String::from_utf8_lossy(t).split_whitespace() {
            hist[bucket_of(token_id(word))] += 1;
        }
    }
    hist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::input::{text_part, upload_text_dataset};
    use crate::workloads::tests_support::make_env;

    #[test]
    fn wordcount_end_to_end_counts_match() {
        let mut env = make_env("swift2d", 3, 3000);
        let (_, words, _) = upload_text_dataset(&env.store, "res", "corpus", 3, 3000, 33);
        let report = run(&mut env, "corpus", "wc-out", words);
        assert!(report.is_valid(), "{:?}", report.validation);
        assert_eq!(report.jobs.len(), 2);
    }

    #[test]
    fn output_histogram_matches_reference() {
        let mut env = make_env("swift2d", 2, 2000);
        let (_, words, _) = upload_text_dataset(&env.store, "res", "corpus", 2, 2000, 34);
        let report = run(&mut env, "corpus", "wc-out", words);
        assert!(report.is_valid());
        // Rebuild the corpus and compare the full histogram bucket by
        // bucket against the job output.
        let texts: Vec<Vec<u8>> = (0..2).map(|p| text_part(34, p, 2000).0).collect();
        let expect = reference_histogram(&texts);
        let mut got = vec![0i64; BUCKETS];
        for key in env.store.debug_names("res", "wc-out/") {
            if key.contains("_SUCCESS") || !key.contains("part-") {
                continue;
            }
            let (obj, _) = env.store.get_object("res", &key);
            for line in String::from_utf8_lossy(&obj.unwrap().data).lines() {
                let (b, c) = line.split_once(',').unwrap();
                got[b.parse::<usize>().unwrap()] = c.parse().unwrap();
            }
        }
        assert_eq!(got, expect);
    }

    #[test]
    fn token_ids_are_never_padding() {
        for w in ["", "a", "the", "w999", "zzzz"] {
            assert!(token_id(w) > 0);
        }
    }
}
