//! TPC-DS subset workload (paper §4.3): run the 8 Impala-subset queries
//! over a parquetish star schema on the object store.
//!
//! Prep (outside the measurement window) writes the fact shards; each
//! query is then one read-only job scanning every shard, aggregating on
//! the `tpcds_agg_chunk` XLA kernel, merged in the driver and validated
//! against [`crate::query::queries::reference_eval`].

use super::{WorkloadEnv, WorkloadReport};
use crate::columnar::RowGroup;
use crate::committer::CommitAlgorithm;
use crate::fs::{FsInputStream, Path};
use crate::metrics::OpCounts;
use crate::objectstore::Metadata;
use crate::query::datagen::StarSchema;
use crate::query::queries::{
    self, finalize, merge_partials, merge_scalar, Broadcast, QueryResult, QUERIES,
};
use crate::runtime::{pad_chunk, CHUNK, GROUPS};
use crate::simclock::SimInstant;
use crate::spark::task::{body, TaskBody, TaskResult};
use crate::spark::SparkJob;
use std::rc::Rc;

/// Upload the fact table as parquetish shards (prep phase).
pub fn upload_star_schema(env: &WorkloadEnv, dataset: &str, schema: &StarSchema) -> u64 {
    let mut bytes = 0;
    for shard in 0..schema.shards {
        let rg = schema.fact_shard(shard);
        let data = rg.encode();
        bytes += data.len() as u64;
        env.store
            .put_object(
                &env.container,
                &format!("{dataset}/part-{shard:05}.pqsh"),
                data,
                Metadata::new(),
                SimInstant::EPOCH,
            )
            .0
            .expect("upload shard");
    }
    bytes
}

/// Serialized per-task partial: [sums f64; GROUPS] + [counts i64; GROUPS]
/// + rows u64, or for ss_max: [max_sk i32, max_profit f32, rows u64].
fn encode_groups(sums: &[f64], counts: &[i64], rows: u64) -> Vec<u8> {
    let mut out = Vec::with_capacity(GROUPS * 16 + 8);
    for s in sums {
        out.extend_from_slice(&s.to_le_bytes());
    }
    for c in counts {
        out.extend_from_slice(&c.to_le_bytes());
    }
    out.extend_from_slice(&rows.to_le_bytes());
    out
}

fn decode_groups(bytes: &[u8]) -> (Vec<f64>, Vec<i64>, u64) {
    let mut sums = Vec::with_capacity(GROUPS);
    let mut counts = Vec::with_capacity(GROUPS);
    for g in 0..GROUPS {
        sums.push(f64::from_le_bytes(bytes[g * 8..g * 8 + 8].try_into().unwrap()));
    }
    let base = GROUPS * 8;
    for g in 0..GROUPS {
        counts.push(i64::from_le_bytes(
            bytes[base + g * 8..base + g * 8 + 8].try_into().unwrap(),
        ));
    }
    let rows = u64::from_le_bytes(bytes[GROUPS * 16..GROUPS * 16 + 8].try_into().unwrap());
    (sums, counts, rows)
}

/// Run one query as a Spark job over the shard objects.
fn run_query(
    env: &mut WorkloadEnv,
    query: &'static str,
    shard_paths: &[Path],
    bc: Rc<Broadcast>,
) -> (crate::spark::JobStats, QueryResult) {
    let kernels = env.kernels.clone();
    let tasks: Vec<TaskBody> = shard_paths
        .iter()
        .map(|path| {
            let path = path.clone();
            let kernels = kernels.clone();
            let bc = bc.clone();
            body(move |run| {
                let data = run.fs.open(&path, run.ctx)?.read_to_end(run.ctx)?;
                run.charge_compute(data.len() as u64);
                let rg = RowGroup::decode(&data)
                    .map_err(|e| crate::fs::FsError::Io(format!("{path}: {e}")))?;
                let rows = rg.rows as u64;
                let collected = if query == "ss_max" {
                    let (sk, p) = queries::scalar_max(&rg);
                    let mut out = sk.to_le_bytes().to_vec();
                    out.extend_from_slice(&p.to_le_bytes());
                    out.extend_from_slice(&rows.to_le_bytes());
                    out
                } else {
                    let (keys, vals) = queries::plan_rows(query, &rg, &bc);
                    let mut sums = vec![0f64; GROUPS];
                    let mut counts = vec![0i64; GROUPS];
                    for (kc, vc) in keys.chunks(CHUNK).zip(vals.chunks(CHUNK)) {
                        let kp = pad_chunk(kc, -1);
                        let vp = pad_chunk(vc, 0.0);
                        let (s, c) = kernels
                            .tpcds_agg_chunk(&kp, &vp)
                            .map_err(|e| crate::fs::FsError::Io(e.to_string()))?;
                        for g in 0..GROUPS {
                            sums[g] += s[g] as f64;
                            counts[g] += c[g] as i64;
                        }
                    }
                    encode_groups(&sums, &counts, rows)
                };
                Ok(TaskResult {
                    bytes_read: data.len() as u64,
                    records: rows,
                    collected: Some(collected),
                    ..Default::default()
                })
            })
        })
        .collect();
    let job = SparkJob::new(&format!("tpcds-{query}"), None, CommitAlgorithm::V1, tasks);
    let stats = env.driver.run_job(&job).expect("query job");

    // Driver-side merge.
    let mut acc = QueryResult::empty(query);
    for payload in stats.collected.iter().flatten() {
        if query == "ss_max" {
            let sk = i32::from_le_bytes(payload[..4].try_into().unwrap());
            let p = f32::from_le_bytes(payload[4..8].try_into().unwrap());
            acc.rows_scanned += u64::from_le_bytes(payload[8..16].try_into().unwrap());
            merge_scalar(&mut acc, (sk, p));
        } else {
            let (sums, counts, rows) = decode_groups(payload);
            acc.rows_scanned += rows;
            let sums_f32: Vec<f32> = sums.iter().map(|&s| s as f32).collect();
            let counts_i32: Vec<i32> = counts.iter().map(|&c| c as i32).collect();
            merge_partials(&mut acc, &sums_f32, &counts_i32);
        }
    }
    (stats, finalize(acc))
}

/// Run all 8 queries over `dataset` (previously uploaded via
/// [`upload_star_schema`] from `schema`).
pub fn run(env: &mut WorkloadEnv, dataset: &str, schema: &StarSchema) -> WorkloadReport {
    let ops_before = env.store.counters();
    // Discover shards through the connector (read path under test).
    let parts = super::readonly::discover_parts(env, dataset);
    assert_eq!(parts.len(), schema.shards, "shard discovery mismatch");
    let shard_paths: Vec<Path> = parts.into_iter().map(|(p, _)| p).collect();
    let bc = Rc::new(Broadcast::from_schema(schema));

    let mut jobs = Vec::new();
    let mut failures = Vec::new();
    let mut summaries = Vec::new();
    for query in QUERIES {
        let (stats, result) = run_query(env, query, &shard_paths, bc.clone());
        let reference = queries::reference_eval(query, schema);
        if !stats.success {
            failures.push(format!("{query}: job failed"));
        } else if !results_match(&result, &reference) {
            failures.push(format!("{query}: result mismatch vs reference"));
        } else {
            summaries.push(format!(
                "{query}={}g",
                if query == "ss_max" { 1 } else { result.groups.len() }
            ));
        }
        jobs.push(stats);
    }
    let ops_window = env.store.counters().since(&ops_before);
    let validation = if failures.is_empty() {
        Ok(format!(
            "8/8 queries match reference over {} rows [{}]",
            schema.total_rows(),
            summaries.join(" ")
        ))
    } else {
        Err(failures.join("; "))
    };
    WorkloadReport::from_jobs("tpcds", jobs, validation).with_ops(ops_window)
}

fn results_match(a: &QueryResult, b: &QueryResult) -> bool {
    if a.rows_scanned != b.rows_scanned {
        return false;
    }
    match (a.scalar_max, b.scalar_max) {
        (Some((ska, pa)), Some((skb, pb))) => return ska == skb && (pa - pb).abs() < 1e-3,
        (None, None) => {}
        _ => return false,
    }
    if a.groups.len() != b.groups.len() {
        return false;
    }
    a.groups.iter().zip(&b.groups).all(|(x, y)| {
        x.0 == y.0 && x.2 == y.2 && (x.1 - y.1).abs() < (x.1.abs() * 1e-4).max(1.0)
    })
}

/// Total REST ops of a TPC-DS report (used by the harness tables).
pub fn total_ops(report: &WorkloadReport) -> OpCounts {
    report.ops
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::OpKind;
    use crate::workloads::tests_support::make_env;

    #[test]
    fn tpcds_all_queries_match_reference() {
        let mut env = make_env("swift2d", 3, 0);
        let schema = StarSchema::new(env.seed, 3, 2 * CHUNK);
        upload_star_schema(&env, "sales", &schema);
        let report = run(&mut env, "sales", &schema);
        assert!(report.is_valid(), "{:?}", report.validation);
        assert_eq!(report.jobs.len(), 8);
        // Read-only: no writes, no copies.
        assert_eq!(report.ops.get(OpKind::PutObject), 0);
        assert_eq!(report.ops.get(OpKind::CopyObject), 0);
        assert!(report.ops.get(OpKind::GetObject) >= 24, "8 queries x 3 shards");
    }
}
