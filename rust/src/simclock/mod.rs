//! Virtual time for the simulator.
//!
//! All "runtimes" reported by the harness are *virtual-clock* times driven
//! by the latency model in [`crate::objectstore::latency`]; see DESIGN.md §7
//! for the calibration. Virtual time is kept in integer microseconds so the
//! simulation is exactly reproducible (no float drift in the event loop).

use std::fmt;

/// A span of virtual time, in microseconds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimDuration(pub u64);

impl SimDuration {
    pub const ZERO: SimDuration = SimDuration(0);

    pub fn from_micros(us: u64) -> Self {
        SimDuration(us)
    }
    pub fn from_millis(ms: u64) -> Self {
        SimDuration(ms * 1_000)
    }
    pub fn from_secs(s: u64) -> Self {
        SimDuration(s * 1_000_000)
    }
    /// From fractional seconds; saturates at zero for negative input.
    pub fn from_secs_f64(s: f64) -> Self {
        SimDuration((s.max(0.0) * 1e6).round() as u64)
    }
    pub fn as_micros(self) -> u64 {
        self.0
    }
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e6
    }
    pub fn saturating_sub(self, rhs: Self) -> Self {
        SimDuration(self.0.saturating_sub(rhs.0))
    }
}

impl std::ops::Add for SimDuration {
    type Output = SimDuration;
    fn add(self, rhs: Self) -> Self {
        SimDuration(self.0 + rhs.0)
    }
}

impl std::ops::AddAssign for SimDuration {
    fn add_assign(&mut self, rhs: Self) {
        self.0 += rhs.0;
    }
}

impl std::ops::Mul<u64> for SimDuration {
    type Output = SimDuration;
    fn mul(self, rhs: u64) -> Self {
        SimDuration(self.0 * rhs)
    }
}

impl std::iter::Sum for SimDuration {
    fn sum<I: Iterator<Item = Self>>(iter: I) -> Self {
        iter.fold(SimDuration::ZERO, |a, b| a + b)
    }
}

impl fmt::Display for SimDuration {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = self.as_secs_f64();
        if s >= 1.0 {
            write!(f, "{:.2}s", s)
        } else if self.0 >= 1_000 {
            write!(f, "{:.2}ms", self.0 as f64 / 1e3)
        } else {
            write!(f, "{}us", self.0)
        }
    }
}

/// A point on the virtual time axis (microseconds since simulation start).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimInstant(pub u64);

impl SimInstant {
    pub const EPOCH: SimInstant = SimInstant(0);

    pub fn elapsed_since(self, earlier: SimInstant) -> SimDuration {
        SimDuration(self.0.saturating_sub(earlier.0))
    }
}

impl std::ops::Add<SimDuration> for SimInstant {
    type Output = SimInstant;
    fn add(self, rhs: SimDuration) -> SimInstant {
        SimInstant(self.0 + rhs.0)
    }
}

impl fmt::Display for SimInstant {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "t+{}", SimDuration(self.0))
    }
}

/// A monotonically advancing virtual clock. Single-threaded by design: the
/// Spark simulator advances it from the scheduler loop only.
#[derive(Debug, Default)]
pub struct SimClock {
    now: SimInstant,
}

impl SimClock {
    pub fn new() -> Self {
        Self {
            now: SimInstant::EPOCH,
        }
    }

    pub fn now(&self) -> SimInstant {
        self.now
    }

    /// Advance the clock by `d`.
    pub fn advance(&mut self, d: SimDuration) -> SimInstant {
        self.now = self.now + d;
        self.now
    }

    /// Advance the clock *to* `t`, which must not be in the past.
    pub fn advance_to(&mut self, t: SimInstant) {
        assert!(
            t >= self.now,
            "clock cannot move backwards: {} < {}",
            t,
            self.now
        );
        self.now = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duration_conversions() {
        assert_eq!(SimDuration::from_millis(3).as_micros(), 3_000);
        assert_eq!(SimDuration::from_secs(2).as_micros(), 2_000_000);
        assert_eq!(SimDuration::from_secs_f64(0.5).as_micros(), 500_000);
        assert_eq!(SimDuration::from_secs_f64(-1.0).as_micros(), 0);
        assert!((SimDuration::from_micros(1_500_000).as_secs_f64() - 1.5).abs() < 1e-9);
    }

    #[test]
    fn duration_arithmetic() {
        let a = SimDuration::from_millis(10);
        let b = SimDuration::from_millis(5);
        assert_eq!((a + b).as_micros(), 15_000);
        assert_eq!(a.saturating_sub(b).as_micros(), 5_000);
        assert_eq!(b.saturating_sub(a).as_micros(), 0);
        assert_eq!((b * 4).as_micros(), 20_000);
        let total: SimDuration = [a, b, b].into_iter().sum();
        assert_eq!(total.as_micros(), 20_000);
    }

    #[test]
    fn clock_advances() {
        let mut c = SimClock::new();
        assert_eq!(c.now(), SimInstant::EPOCH);
        c.advance(SimDuration::from_secs(1));
        assert_eq!(c.now().0, 1_000_000);
        c.advance_to(SimInstant(2_000_000));
        assert_eq!(c.now().0, 2_000_000);
    }

    #[test]
    #[should_panic(expected = "backwards")]
    fn clock_never_goes_back() {
        let mut c = SimClock::new();
        c.advance(SimDuration::from_secs(1));
        c.advance_to(SimInstant(10));
    }

    #[test]
    fn display_formats() {
        assert_eq!(format!("{}", SimDuration::from_micros(42)), "42us");
        assert_eq!(format!("{}", SimDuration::from_micros(4_200)), "4.20ms");
        assert_eq!(format!("{}", SimDuration::from_secs(90)), "90.00s");
        assert_eq!(format!("{}", SimInstant(1_000_000)), "t+1.00s");
    }

    #[test]
    fn instant_elapsed() {
        let a = SimInstant(100);
        let b = SimInstant(350);
        assert_eq!(b.elapsed_since(a).as_micros(), 250);
        assert_eq!(a.elapsed_since(b).as_micros(), 0);
    }
}
