//! Row-group (de)serialization: one object per row group, columns stored
//! contiguously, footer with offsets and min/max statistics.
//!
//! Layout (little-endian):
//! ```text
//! "PQSH"                     magic
//! u32 ncols, u32 nrows
//! per column:
//!   u16 name_len, name bytes, u8 type code
//!   u64 data offset, u64 data len (bytes)
//!   i32/f32 min, max            (column statistics, for filter pushdown)
//! column data blocks (plain encoding, 4 bytes/value)
//! "HSQP"                     trailing magic
//! ```

use super::schema::{ColType, Schema};

/// A decoded column.
#[derive(Debug, Clone, PartialEq)]
pub enum ColumnData {
    I32(Vec<i32>),
    F32(Vec<f32>),
}

impl ColumnData {
    pub fn len(&self) -> usize {
        match self {
            ColumnData::I32(v) => v.len(),
            ColumnData::F32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn col_type(&self) -> ColType {
        match self {
            ColumnData::I32(_) => ColType::Int32,
            ColumnData::F32(_) => ColType::Float32,
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match self {
            ColumnData::I32(v) => v,
            _ => panic!("column is not i32"),
        }
    }

    pub fn as_f32(&self) -> &[f32] {
        match self {
            ColumnData::F32(v) => v,
            _ => panic!("column is not f32"),
        }
    }
}

/// A row group: schema + columns of equal length.
#[derive(Debug, Clone, PartialEq)]
pub struct RowGroup {
    pub schema: Schema,
    pub columns: Vec<ColumnData>,
    pub rows: usize,
}

const MAGIC: &[u8; 4] = b"PQSH";
const MAGIC_END: &[u8; 4] = b"HSQP";

impl RowGroup {
    pub fn new(schema: Schema, columns: Vec<ColumnData>) -> RowGroup {
        assert_eq!(schema.len(), columns.len(), "schema/column mismatch");
        let rows = columns.first().map(|c| c.len()).unwrap_or(0);
        for (i, c) in columns.iter().enumerate() {
            assert_eq!(c.len(), rows, "ragged column {i}");
            assert_eq!(c.col_type(), schema.fields[i].1, "type mismatch col {i}");
        }
        RowGroup {
            schema,
            columns,
            rows,
        }
    }

    pub fn column(&self, name: &str) -> Option<&ColumnData> {
        self.schema.index_of(name).map(|i| &self.columns[i])
    }

    /// Per-column (min, max) as f64 (statistics).
    fn stats(col: &ColumnData) -> (f64, f64) {
        match col {
            ColumnData::I32(v) => {
                let min = v.iter().copied().min().unwrap_or(0);
                let max = v.iter().copied().max().unwrap_or(0);
                (min as f64, max as f64)
            }
            ColumnData::F32(v) => {
                let min = v.iter().copied().fold(f32::INFINITY, f32::min);
                let max = v.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                if v.is_empty() {
                    (0.0, 0.0)
                } else {
                    (min as f64, max as f64)
                }
            }
        }
    }

    /// Serialize to the parquetish byte layout.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(self.columns.len() as u32).to_le_bytes());
        out.extend_from_slice(&(self.rows as u32).to_le_bytes());
        // Compute the header size first so offsets are absolute.
        let mut header_len = 4 + 4 + 4;
        for (name, _) in &self.schema.fields {
            header_len += 2 + name.len() + 1 + 8 + 8 + 8 + 8;
        }
        let mut offset = header_len as u64;
        for ((name, ty), col) in self.schema.fields.iter().zip(&self.columns) {
            out.extend_from_slice(&(name.len() as u16).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.push(ty.code());
            let len = (col.len() * 4) as u64;
            out.extend_from_slice(&offset.to_le_bytes());
            out.extend_from_slice(&len.to_le_bytes());
            let (min, max) = Self::stats(col);
            out.extend_from_slice(&min.to_le_bytes());
            out.extend_from_slice(&max.to_le_bytes());
            offset += len;
        }
        debug_assert_eq!(out.len(), header_len);
        for col in &self.columns {
            match col {
                ColumnData::I32(v) => {
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
                ColumnData::F32(v) => {
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
            }
        }
        out.extend_from_slice(MAGIC_END);
        out
    }

    /// Parse a parquetish object.
    pub fn decode(bytes: &[u8]) -> Result<RowGroup, String> {
        let take = |range: std::ops::Range<usize>| -> Result<&[u8], String> {
            bytes
                .get(range.clone())
                .ok_or_else(|| format!("truncated row group at {range:?}"))
        };
        if take(0..4)? != MAGIC {
            return Err("bad magic".into());
        }
        if &bytes[bytes.len().saturating_sub(4)..] != MAGIC_END {
            return Err("bad trailing magic (truncated object?)".into());
        }
        let ncols = u32::from_le_bytes(take(4..8)?.try_into().unwrap()) as usize;
        let rows = u32::from_le_bytes(take(8..12)?.try_into().unwrap()) as usize;
        let mut pos = 12;
        let mut fields = Vec::with_capacity(ncols);
        let mut blocks = Vec::with_capacity(ncols);
        for _ in 0..ncols {
            let name_len =
                u16::from_le_bytes(take(pos..pos + 2)?.try_into().unwrap()) as usize;
            pos += 2;
            let name = String::from_utf8(take(pos..pos + name_len)?.to_vec())
                .map_err(|e| e.to_string())?;
            pos += name_len;
            let ty = ColType::from_code(bytes[pos]).ok_or("bad column type")?;
            pos += 1;
            let offset = u64::from_le_bytes(take(pos..pos + 8)?.try_into().unwrap()) as usize;
            pos += 8;
            let len = u64::from_le_bytes(take(pos..pos + 8)?.try_into().unwrap()) as usize;
            pos += 8;
            pos += 16; // min/max stats (not needed for decode)
            fields.push((name, ty));
            blocks.push((ty, offset, len));
        }
        let mut columns = Vec::with_capacity(ncols);
        for (ty, offset, len) in blocks {
            let raw = take(offset..offset + len)?;
            if raw.len() != rows * 4 {
                return Err(format!("column block {} != rows {}", raw.len(), rows * 4));
            }
            let col = match ty {
                ColType::Int32 => ColumnData::I32(
                    raw.chunks_exact(4)
                        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                ),
                ColType::Float32 => ColumnData::F32(
                    raw.chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                ),
            };
            columns.push(col);
        }
        let schema = Schema { fields };
        Ok(RowGroup::new(schema, columns))
    }

    /// Read just the statistics (name, type, min, max) — the footer-probe
    /// equivalent used for filter pushdown.
    pub fn decode_stats(bytes: &[u8]) -> Result<Vec<(String, ColType, f64, f64)>, String> {
        if bytes.get(0..4) != Some(MAGIC.as_slice()) {
            return Err("bad magic".into());
        }
        let ncols = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
        let mut pos = 12;
        let mut out = Vec::with_capacity(ncols);
        for _ in 0..ncols {
            let name_len = u16::from_le_bytes(bytes[pos..pos + 2].try_into().unwrap()) as usize;
            pos += 2;
            let name = String::from_utf8_lossy(&bytes[pos..pos + name_len]).to_string();
            pos += name_len;
            let ty = ColType::from_code(bytes[pos]).ok_or("bad type")?;
            pos += 1 + 16; // type byte + offset/len words
            let min = f64::from_le_bytes(bytes[pos..pos + 8].try_into().unwrap());
            let max = f64::from_le_bytes(bytes[pos + 8..pos + 16].try_into().unwrap());
            pos += 16;
            out.push((name, ty, min, max));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn sample() -> RowGroup {
        RowGroup::new(
            Schema::new(&[("sk", ColType::Int32), ("price", ColType::Float32)]),
            vec![
                ColumnData::I32(vec![1, 5, -3, 900]),
                ColumnData::F32(vec![1.5, 0.0, -2.25, 1e6]),
            ],
        )
    }

    #[test]
    fn encode_decode_roundtrip() {
        let rg = sample();
        let bytes = rg.encode();
        let back = RowGroup::decode(&bytes).unwrap();
        assert_eq!(back, rg);
        assert_eq!(back.rows, 4);
        assert_eq!(back.column("price").unwrap().as_f32()[3], 1e6);
    }

    #[test]
    fn stats_probe() {
        let bytes = sample().encode();
        let stats = RowGroup::decode_stats(&bytes).unwrap();
        assert_eq!(stats[0].0, "sk");
        assert_eq!(stats[0].2, -3.0);
        assert_eq!(stats[0].3, 900.0);
        assert_eq!(stats[1].2, -2.25);
    }

    #[test]
    fn truncated_objects_are_rejected() {
        let bytes = sample().encode();
        // The partial-write fault writes a prefix: decode must fail loudly.
        for cut in [0, 3, 10, bytes.len() - 1] {
            assert!(
                RowGroup::decode(&bytes[..cut]).is_err(),
                "cut at {cut} must fail"
            );
        }
        assert!(RowGroup::decode(b"JUNKJUNKJUNK").is_err());
    }

    #[test]
    fn roundtrip_property() {
        check("rowgroup roundtrip", 40, |g| {
            let n = g.usize(0..200);
            let ints: Vec<i32> = (0..n).map(|_| g.rng().next_u32() as i32).collect();
            let floats: Vec<f32> = (0..n).map(|_| g.rng().next_f64() as f32).collect();
            let rg = RowGroup::new(
                Schema::new(&[("a", ColType::Int32), ("b", ColType::Float32)]),
                vec![ColumnData::I32(ints), ColumnData::F32(floats)],
            );
            let back = RowGroup::decode(&rg.encode()).unwrap();
            assert_eq!(back, rg);
        });
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_columns_rejected() {
        RowGroup::new(
            Schema::new(&[("a", ColType::Int32), ("b", ColType::Int32)]),
            vec![ColumnData::I32(vec![1]), ColumnData::I32(vec![1, 2])],
        );
    }
}
