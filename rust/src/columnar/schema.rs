//! Schemas for the parquetish format.

/// Column types (all the TPC-DS subset needs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ColType {
    Int32,
    Float32,
}

impl ColType {
    pub fn code(self) -> u8 {
        match self {
            ColType::Int32 => 1,
            ColType::Float32 => 2,
        }
    }

    pub fn from_code(c: u8) -> Option<ColType> {
        match c {
            1 => Some(ColType::Int32),
            2 => Some(ColType::Float32),
            _ => None,
        }
    }
}

/// An ordered list of named, typed columns.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Schema {
    pub fields: Vec<(String, ColType)>,
}

impl Schema {
    pub fn new(fields: &[(&str, ColType)]) -> Self {
        Self {
            fields: fields
                .iter()
                .map(|(n, t)| (n.to_string(), *t))
                .collect(),
        }
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.fields.iter().position(|(n, _)| n == name)
    }

    pub fn len(&self) -> usize {
        self.fields.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fields.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn type_codes_roundtrip() {
        for t in [ColType::Int32, ColType::Float32] {
            assert_eq!(ColType::from_code(t.code()), Some(t));
        }
        assert_eq!(ColType::from_code(99), None);
    }

    #[test]
    fn schema_lookup() {
        let s = Schema::new(&[("a", ColType::Int32), ("b", ColType::Float32)]);
        assert_eq!(s.index_of("b"), Some(1));
        assert_eq!(s.index_of("z"), None);
        assert_eq!(s.len(), 2);
    }
}
