//! A miniature Parquet-like columnar file format ("parquetish").
//!
//! The paper's TPC-DS workload reads Parquet files from the object store;
//! the read-path operation pattern depends on the container layout (one
//! object per row group, footer metadata probed before data). This module
//! implements the minimal equivalent: typed column chunks with per-column
//! min/max statistics in a footer, serialized into a single object per row
//! group, readable through any [`crate::fs::FileSystem`] connector.

pub mod schema;
pub mod rowgroup;

pub use rowgroup::{ColumnData, RowGroup};
pub use schema::{ColType, Schema};
