//! The gateway observability registry: lock-free request/sweep metrics
//! and the bounded `/tracez` ring.
//!
//! # The two rules every recorder here obeys
//!
//! **Private-then-merge.** The load plane's [`super::Histogram`] is plain
//! data — each worker thread owns one and the harness merges after join.
//! The gateway cannot do that (scrapes happen *while* traffic flows), so
//! [`AtomicHistogram`] is the same fixed-128-bucket geometric layout
//! (identical [`bucket_index`]/[`bucket_upper_nanos`] math) with relaxed
//! per-bucket atomics: every recording thread writes its own samples
//! independently and a scrape merges them into a plain [`Histogram`]
//! snapshot on demand. The merge happens at scrape time, never on the
//! request path.
//!
//! **Zero hot-path synchronisation.** Nothing in this module takes a
//! lock, spins, or blocks on the serve path: histogram recording is a
//! handful of `Relaxed` `fetch_add`s, sweep stats are recorded once per
//! reactor pass (not per connection), and the trace ring writes through
//! `try_lock` — a contended slot drops the trace rather than stalling
//! the request. Only scrape-side readers (`/metricz`, `/tracez`) may
//! lock, and they are off the hot path by construction. The store
//! front end's debug `front_end_locks` counter staying zero on the idle
//! path, and the goldens A/B (observability on vs off, both cores), pin
//! that this plane observes without perturbing.

use super::histogram::{bucket_index, bucket_upper_nanos, Histogram, BUCKETS};
use super::OpKind;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::Mutex;

/// Scale factor that lets value histograms (conns per pass, bytes per
/// pass) reuse the nanosecond bucket math: a raw unit is recorded as
/// 1000 "nanos", so bucket 0 = {0}, bucket 1 ≈ ≤1.19 units, and the
/// geometric ladder covers ~3.6e15 units in [`BUCKETS`] buckets.
pub const UNIT_SCALE: u64 = 1000;

/// A fixed-bucket histogram recorded through relaxed atomics — the
/// concurrent twin of [`Histogram`], sharing its exact bucket layout.
/// Recording is wait-free; [`AtomicHistogram::snapshot`] merges the
/// buckets into a plain histogram for quantiles and exposition.
pub struct AtomicHistogram {
    counts: [AtomicU64; BUCKETS],
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for AtomicHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl AtomicHistogram {
    pub fn new() -> Self {
        Self {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one nanosecond sample. Wait-free: three relaxed atomic
    /// RMWs, no CAS loop, no lock.
    #[inline]
    pub fn record_nanos(&self, nanos: u64) {
        self.counts[bucket_index(nanos)].fetch_add(1, Relaxed);
        self.sum.fetch_add(nanos, Relaxed);
        self.max.fetch_max(nanos, Relaxed);
    }

    /// Record an elapsed duration.
    #[inline]
    pub fn record(&self, elapsed: std::time::Duration) {
        self.record_nanos(elapsed.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Record a raw unit count (connections, bytes, accepts) through the
    /// same geometric buckets via [`UNIT_SCALE`].
    #[inline]
    pub fn record_units(&self, units: u64) {
        self.record_nanos(units.saturating_mul(UNIT_SCALE));
    }

    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Relaxed)).sum()
    }

    pub fn sum_nanos(&self) -> u64 {
        self.sum.load(Relaxed)
    }

    pub fn max_nanos(&self) -> u64 {
        self.max.load(Relaxed)
    }

    /// Merge the live buckets into a plain [`Histogram`] (the scrape-side
    /// half of private-then-merge). Relaxed loads: a snapshot taken under
    /// concurrent traffic is a consistent-enough view, never torn within
    /// a bucket.
    pub fn snapshot(&self) -> Histogram {
        let counts = std::array::from_fn(|i| self.counts[i].load(Relaxed));
        Histogram::from_bucket_counts(counts, self.sum.load(Relaxed))
    }
}

/// Request phase timings, in nanoseconds, measured by the serving core
/// and the shared router. `queue` is the reactor sweep's dispatch delay
/// (how long the ready request waited behind earlier connections in the
/// same pass; always 0 on the threaded core, which has no sweep).
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseNanos {
    pub queue: u64,
    pub parse: u64,
    pub screen: u64,
    pub route: u64,
    pub serialize: u64,
}

impl PhaseNanos {
    pub fn total(&self) -> u64 {
        self.queue
            .saturating_add(self.parse)
            .saturating_add(self.screen)
            .saturating_add(self.route)
            .saturating_add(self.serialize)
    }
}

/// Phase labels, in [`PhaseNanos`] field order, as exposed on
/// `/metricz` and `/tracez`.
pub const PHASES: [&str; 5] = ["queue", "parse", "screen", "route", "serialize"];

const N_KINDS: usize = OpKind::ALL.len();

/// Per-op-class wall-clock serve metrics for one gateway: end-to-end
/// serve latency, request/response byte sizes, and the per-phase split.
/// All recording is wait-free ([`AtomicHistogram`]).
pub struct RequestMetrics {
    serve: [AtomicHistogram; N_KINDS],
    request_bytes: [AtomicHistogram; N_KINDS],
    response_bytes: [AtomicHistogram; N_KINDS],
    phases: [AtomicHistogram; PHASES.len()],
}

impl Default for RequestMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl RequestMetrics {
    pub fn new() -> Self {
        Self {
            serve: std::array::from_fn(|_| AtomicHistogram::new()),
            request_bytes: std::array::from_fn(|_| AtomicHistogram::new()),
            response_bytes: std::array::from_fn(|_| AtomicHistogram::new()),
            phases: std::array::from_fn(|_| AtomicHistogram::new()),
        }
    }

    /// Record one executed request: serve latency and byte sizes under
    /// its op class, phase splits under the shared phase histograms.
    #[inline]
    pub fn record(&self, kind: OpKind, req_bytes: u64, resp_bytes: u64, phases: &PhaseNanos) {
        let i = kind.index();
        self.serve[i].record_nanos(phases.total());
        self.request_bytes[i].record_units(req_bytes);
        self.response_bytes[i].record_units(resp_bytes);
        let split = [
            phases.queue,
            phases.parse,
            phases.screen,
            phases.route,
            phases.serialize,
        ];
        for (hist, nanos) in self.phases.iter().zip(split) {
            hist.record_nanos(nanos);
        }
    }

    pub fn serve_for(&self, kind: OpKind) -> &AtomicHistogram {
        &self.serve[kind.index()]
    }

    pub fn request_bytes_for(&self, kind: OpKind) -> &AtomicHistogram {
        &self.request_bytes[kind.index()]
    }

    pub fn response_bytes_for(&self, kind: OpKind) -> &AtomicHistogram {
        &self.response_bytes[kind.index()]
    }

    /// Phase histogram by [`PHASES`] index.
    pub fn phase(&self, idx: usize) -> &AtomicHistogram {
        &self.phases[idx]
    }
}

/// Reactor sweep-loop instrumentation, recorded ONCE per pass — the cost
/// is constant per sweep regardless of how many connections it polls.
/// `idle_sleeps / passes` is the idle-sleep ratio (how often a pass made
/// no progress and slept `POLL_IDLE`).
pub struct SweepStats {
    pub passes: AtomicU64,
    pub idle_sleeps: AtomicU64,
    pub accepted: AtomicU64,
    pub bytes_in: AtomicU64,
    pub bytes_out: AtomicU64,
    /// Connections polled per pass (unit-scaled buckets).
    pub conns_polled: AtomicHistogram,
    /// Bytes moved (read + written) per pass (unit-scaled buckets).
    pub bytes_moved: AtomicHistogram,
    /// Accept-burst depth: connections accepted in one pass's burst
    /// (unit-scaled buckets; capped by the reactor's `ACCEPT_BURST`).
    pub accept_burst: AtomicHistogram,
}

impl Default for SweepStats {
    fn default() -> Self {
        Self::new()
    }
}

impl SweepStats {
    pub fn new() -> Self {
        Self {
            passes: AtomicU64::new(0),
            idle_sleeps: AtomicU64::new(0),
            accepted: AtomicU64::new(0),
            bytes_in: AtomicU64::new(0),
            bytes_out: AtomicU64::new(0),
            conns_polled: AtomicHistogram::new(),
            bytes_moved: AtomicHistogram::new(),
            accept_burst: AtomicHistogram::new(),
        }
    }

    /// Record one completed sweep pass. Called once per pass from the
    /// reactor loop; never from per-connection code.
    #[inline]
    pub fn record_pass(&self, conns: u64, accepted: u64, bytes_in: u64, bytes_out: u64, slept: bool) {
        self.passes.fetch_add(1, Relaxed);
        if slept {
            self.idle_sleeps.fetch_add(1, Relaxed);
        }
        self.accepted.fetch_add(accepted, Relaxed);
        self.bytes_in.fetch_add(bytes_in, Relaxed);
        self.bytes_out.fetch_add(bytes_out, Relaxed);
        self.conns_polled.record_units(conns);
        self.bytes_moved.record_units(bytes_in.saturating_add(bytes_out));
        self.accept_burst.record_units(accepted);
    }
}

/// How many requests the `/tracez` ring remembers.
pub const TRACE_RING_SLOTS: usize = 256;

/// One traced request: the identity (`x-request-id` when the client
/// stamped one), what it was, how it was disposed of, and where its
/// nanoseconds went per phase.
#[derive(Debug, Clone)]
pub struct TraceEntry {
    /// Monotone per-gateway sequence number (scrape ordering key).
    pub seq: u64,
    /// The request's `x-request-id`, or `"-"` for unstamped requests.
    pub id: String,
    pub method: String,
    pub path: String,
    pub status: u16,
    /// Op-class name (`OpKind::name`) for classified requests.
    pub op: Option<&'static str>,
    pub phases: PhaseNanos,
    pub total_ns: u64,
    /// `ok`, `replayed`, `rejected-auth`, `rejected-429`, or a
    /// chaos-patched `chaos-*` kind.
    pub disposition: &'static str,
}

/// A bounded ring of the last [`TRACE_RING_SLOTS`] requests. Writers are
/// non-blocking: the cursor is one relaxed `fetch_add` and the slot
/// write is a `try_lock` — if a scraper (or a lapped writer) holds the
/// slot, the trace is dropped, never awaited. Readers (`/tracez`) lock
/// slot-by-slot off the hot path.
pub struct TraceRing {
    cursor: AtomicU64,
    dropped: AtomicU64,
    slots: Vec<Mutex<Option<TraceEntry>>>,
}

impl Default for TraceRing {
    fn default() -> Self {
        Self::new()
    }
}

impl TraceRing {
    pub fn new() -> Self {
        Self::with_slots(TRACE_RING_SLOTS)
    }

    pub fn with_slots(n: usize) -> Self {
        Self {
            cursor: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            slots: (0..n.max(1)).map(|_| Mutex::new(None)).collect(),
        }
    }

    /// Record one trace; returns its (slot, seq) so the connection layer
    /// can patch a chaos disposition in after the wire decision, or
    /// `None` if the slot was contended (trace dropped, caller moves on).
    pub fn push(&self, mut entry: TraceEntry) -> Option<(usize, u64)> {
        let seq = self.cursor.fetch_add(1, Relaxed);
        let idx = (seq % self.slots.len() as u64) as usize;
        match self.slots[idx].try_lock() {
            Ok(mut slot) => {
                entry.seq = seq;
                *slot = Some(entry);
                Some((idx, seq))
            }
            Err(_) => {
                self.dropped.fetch_add(1, Relaxed);
                None
            }
        }
    }

    /// Patch the disposition of a just-pushed entry (chaos annotations
    /// from the connection layer). Non-blocking; a lapped or contended
    /// slot is left alone — the seq check keeps a lapped slot's newer
    /// entry from being mislabelled.
    pub fn patch_disposition(&self, token: (usize, u64), disposition: &'static str) {
        let (idx, seq) = token;
        if let Ok(mut slot) = self.slots[idx].try_lock() {
            if let Some(entry) = slot.as_mut() {
                if entry.seq == seq {
                    entry.disposition = disposition;
                }
            }
        }
    }

    /// Total traces ever pushed (not the ring occupancy).
    pub fn pushed(&self) -> u64 {
        self.cursor.load(Relaxed)
    }

    /// Traces dropped on slot contention.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Relaxed)
    }

    /// Scrape the ring: the retained entries, oldest first. Locks each
    /// slot briefly — scrape path only, never the request path.
    pub fn snapshot(&self) -> Vec<TraceEntry> {
        let mut entries: Vec<TraceEntry> = self
            .slots
            .iter()
            .filter_map(|s| s.lock().ok().and_then(|g| g.clone()))
            .collect();
        entries.sort_by_key(|e| e.seq);
        entries
    }
}

/// The whole observability plane for one gateway: request metrics, sweep
/// stats, and the trace ring, behind one on/off knob
/// (`GatewayConfig::observability`). When disabled, every recording call
/// is a single branch — the A/B goldens pin that on vs off changes no
/// op count, virtual runtime, or fault trace.
pub struct ObsPlane {
    enabled: bool,
    pub requests: RequestMetrics,
    pub sweep: SweepStats,
    pub trace: TraceRing,
}

impl ObsPlane {
    pub fn new(enabled: bool) -> Self {
        Self {
            enabled,
            requests: RequestMetrics::new(),
            sweep: SweepStats::new(),
            trace: TraceRing::new(),
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn atomic_histogram_matches_plain_histogram() {
        let atomic = AtomicHistogram::new();
        let mut plain = Histogram::new();
        for i in 0..1000u64 {
            let v = (i * 7919) % 3_000_000;
            atomic.record_nanos(v);
            plain.record_nanos(v);
        }
        assert_eq!(atomic.count(), plain.count());
        assert_eq!(atomic.sum_nanos(), plain.sum_nanos());
        assert_eq!(atomic.max_nanos(), plain.max_nanos());
        let snap = atomic.snapshot();
        assert_eq!(snap.bucket_counts(), plain.bucket_counts());
        for q in [0.5, 0.95, 0.99] {
            // Same buckets; snapshot min/max are bucket-resolution.
            let (a, b) = (snap.quantile_nanos(q) as f64, plain.quantile_nanos(q) as f64);
            assert!(b >= a * 0.8 && b <= a * 1.2, "q={q}: {a} vs {b}");
        }
    }

    #[test]
    fn atomic_histogram_is_safe_under_concurrent_recording() {
        let hist = std::sync::Arc::new(AtomicHistogram::new());
        let threads: Vec<_> = (0..8)
            .map(|t| {
                let hist = hist.clone();
                std::thread::spawn(move || {
                    for i in 0..10_000u64 {
                        hist.record_nanos(t * 1_000_000 + i);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        // Exact totals: no lost updates.
        assert_eq!(hist.count(), 80_000);
        assert_eq!(hist.snapshot().count(), 80_000);
    }

    #[test]
    fn unit_scale_buckets_resolve_small_counts() {
        let h = AtomicHistogram::new();
        h.record_units(0);
        h.record_units(3);
        h.record_units(200);
        // Three distinct buckets: 0, 3 and 200 must not collapse (the
        // raw nanos scale would put all of them in bucket 0).
        let snap = h.snapshot();
        let populated = snap.bucket_counts().iter().filter(|&&n| n > 0).count();
        assert_eq!(populated, 3, "{:?}", snap.bucket_counts());
        assert_eq!(snap.count(), 3);
        assert_eq!(h.max_nanos() / UNIT_SCALE, 200);
    }

    #[test]
    fn request_metrics_attribute_by_op_class_and_phase() {
        let m = RequestMetrics::new();
        let phases = PhaseNanos {
            queue: 10,
            parse: 20,
            screen: 30,
            route: 1000,
            serialize: 40,
        };
        m.record(OpKind::PutObject, 512, 16, &phases);
        m.record(OpKind::GetObject, 0, 512, &phases);
        assert_eq!(m.serve_for(OpKind::PutObject).count(), 1);
        assert_eq!(m.serve_for(OpKind::GetObject).count(), 1);
        assert_eq!(m.serve_for(OpKind::DeleteObject).count(), 0);
        assert_eq!(m.serve_for(OpKind::PutObject).sum_nanos(), phases.total());
        assert_eq!(m.request_bytes_for(OpKind::PutObject).max_nanos() / UNIT_SCALE, 512);
        assert_eq!(m.response_bytes_for(OpKind::GetObject).max_nanos() / UNIT_SCALE, 512);
        // Each phase histogram saw both requests.
        for i in 0..PHASES.len() {
            assert_eq!(m.phase(i).count(), 2, "phase {}", PHASES[i]);
        }
        assert_eq!(m.phase(3).max_nanos(), 1000, "route phase");
    }

    #[test]
    fn sweep_stats_record_per_pass() {
        let s = SweepStats::new();
        s.record_pass(100, 5, 4096, 8192, false);
        s.record_pass(0, 0, 0, 0, true);
        assert_eq!(s.passes.load(Relaxed), 2);
        assert_eq!(s.idle_sleeps.load(Relaxed), 1);
        assert_eq!(s.accepted.load(Relaxed), 5);
        assert_eq!(s.bytes_in.load(Relaxed), 4096);
        assert_eq!(s.bytes_out.load(Relaxed), 8192);
        assert_eq!(s.conns_polled.count(), 2);
        assert_eq!(s.bytes_moved.max_nanos() / UNIT_SCALE, 12_288);
    }

    fn entry(id: &str) -> TraceEntry {
        TraceEntry {
            seq: 0,
            id: id.to_string(),
            method: "GET".into(),
            path: "/v1/c/k".into(),
            status: 200,
            op: Some("GET Object"),
            phases: PhaseNanos::default(),
            total_ns: 1000,
            disposition: "ok",
        }
    }

    #[test]
    fn trace_ring_keeps_the_last_n_in_order() {
        let ring = TraceRing::with_slots(4);
        for i in 0..10 {
            ring.push(entry(&format!("req-{i}")));
        }
        let snap = ring.snapshot();
        assert_eq!(snap.len(), 4);
        let ids: Vec<&str> = snap.iter().map(|e| e.id.as_str()).collect();
        assert_eq!(ids, ["req-6", "req-7", "req-8", "req-9"]);
        assert!(snap.windows(2).all(|w| w[0].seq < w[1].seq), "oldest first");
        assert_eq!(ring.pushed(), 10);
        assert_eq!(ring.dropped(), 0);
    }

    #[test]
    fn trace_ring_patch_respects_the_seq_guard() {
        let ring = TraceRing::with_slots(2);
        let token = ring.push(entry("a")).unwrap();
        ring.patch_disposition(token, "chaos-kill-response");
        assert_eq!(ring.snapshot()[0].disposition, "chaos-kill-response");
        // Lap the slot: the stale token must no longer patch.
        ring.push(entry("b"));
        ring.push(entry("c")); // same slot as "a"
        ring.patch_disposition(token, "chaos-stall");
        let snap = ring.snapshot();
        let c = snap.iter().find(|e| e.id == "c").unwrap();
        assert_eq!(c.disposition, "ok", "stale token must not relabel a lapped slot");
    }

    #[test]
    fn trace_ring_never_blocks_writers() {
        let ring = std::sync::Arc::new(TraceRing::with_slots(2));
        // Hold one slot's lock; pushes landing there drop, others land.
        let guard = ring.slots[0].lock().unwrap();
        let first = ring.push(entry("blocked")); // slot 0: dropped
        let second = ring.push(entry("landed")); // slot 1: stored
        drop(guard);
        assert!(first.is_none());
        assert!(second.is_some());
        assert_eq!(ring.dropped(), 1);
        let snap = ring.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].id, "landed");
    }

    #[test]
    fn disabled_plane_still_constructs_cleanly() {
        let obs = ObsPlane::new(false);
        assert!(!obs.enabled());
        // Callers gate on enabled(); the plane itself stays inert.
        assert_eq!(obs.requests.serve_for(OpKind::GetObject).count(), 0);
        assert_eq!(obs.trace.snapshot().len(), 0);
        assert!(ObsPlane::new(true).enabled());
    }
}
