//! Fixed-bucket latency histograms for *measured wall-clock* time.
//!
//! The simulator's virtual clock gives exact per-op durations, but the
//! load plane ([`crate::loadgen`]) measures real socket round-trips, and
//! real measurements need a recorder that (a) costs O(1) per sample with
//! no allocation, and (b) merges cheaply so every worker thread can own
//! a private recorder and the harness can combine them after join — the
//! sharded-recorder pattern: workers never share a cache line, let alone
//! a lock.
//!
//! Buckets are geometric: bucket 0 holds everything under 1µs, then each
//! bucket grows by 2^(1/4) (~19%), covering 1µs to ~1 hour in
//! [`BUCKETS`] buckets. Quantiles are therefore upper bounds with ≤19%
//! relative error — ample for p50/p95/p99 reporting — while `min`,
//! `max`, `sum` and `count` are exact.

/// Number of geometric buckets (1µs × 2^((i-1)/4); see module docs).
pub const BUCKETS: usize = 128;

/// Smallest non-underflow bucket boundary, in nanoseconds.
const BASE_NANOS: f64 = 1000.0;

fn bucket_index(nanos: u64) -> usize {
    if nanos < BASE_NANOS as u64 {
        return 0;
    }
    let idx = 1 + ((nanos as f64 / BASE_NANOS).log2() * 4.0).floor() as usize;
    idx.min(BUCKETS - 1)
}

/// Upper bound (nanoseconds) of bucket `idx`: every sample recorded into
/// the bucket is ≤ this (except the final overflow bucket).
fn bucket_upper_nanos(idx: usize) -> u64 {
    (BASE_NANOS * 2f64.powf(idx as f64 / 4.0)) as u64
}

/// A fixed-bucket wall-clock latency histogram. Plain data — no locks,
/// no atomics: one per worker thread, merged after the workers join.
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: [u64; BUCKETS],
    count: u64,
    sum_nanos: u64,
    min_nanos: u64,
    max_nanos: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            counts: [0; BUCKETS],
            count: 0,
            sum_nanos: 0,
            min_nanos: u64::MAX,
            max_nanos: 0,
        }
    }

    /// Record one sample.
    #[inline]
    pub fn record_nanos(&mut self, nanos: u64) {
        self.counts[bucket_index(nanos)] += 1;
        self.count += 1;
        self.sum_nanos = self.sum_nanos.saturating_add(nanos);
        self.min_nanos = self.min_nanos.min(nanos);
        self.max_nanos = self.max_nanos.max(nanos);
    }

    /// Record an elapsed [`std::time::Duration`].
    #[inline]
    pub fn record(&mut self, elapsed: std::time::Duration) {
        self.record_nanos(elapsed.as_nanos().min(u64::MAX as u128) as u64);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn max_nanos(&self) -> u64 {
        self.max_nanos
    }

    /// Fold another histogram into this one (the post-join merge).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum_nanos = self.sum_nanos.saturating_add(other.sum_nanos);
        self.min_nanos = self.min_nanos.min(other.min_nanos);
        self.max_nanos = self.max_nanos.max(other.max_nanos);
    }

    /// The `q`-quantile (`0.0..=1.0`) in nanoseconds: the upper bound of
    /// the bucket where the cumulative count crosses `q·count`, clamped
    /// into the exact observed `[min, max]` range. Zero when empty.
    pub fn quantile_nanos(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (idx, &n) in self.counts.iter().enumerate() {
            cum += n;
            if cum >= target {
                return bucket_upper_nanos(idx)
                    .clamp(self.min_nanos, self.max_nanos);
            }
        }
        self.max_nanos
    }

    /// Summarise into the p50/p95/p99 shape the reports serialize.
    pub fn summary(&self) -> LatencySummary {
        let us = |n: u64| n as f64 / 1000.0;
        LatencySummary {
            count: self.count,
            mean_us: if self.count == 0 {
                0.0
            } else {
                self.sum_nanos as f64 / self.count as f64 / 1000.0
            },
            p50_us: us(self.quantile_nanos(0.50)),
            p95_us: us(self.quantile_nanos(0.95)),
            p99_us: us(self.quantile_nanos(0.99)),
            max_us: us(self.max_nanos),
        }
    }
}

/// Immutable percentile summary of one histogram, in microseconds.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct LatencySummary {
    pub count: u64,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_cover_and_bound() {
        // Every value lands in a bucket whose upper bound is >= it
        // (except the overflow bucket), within 19% relative error.
        for v in [1u64, 999, 1000, 1001, 5_000, 1_000_000, 3_000_000_000] {
            let idx = bucket_index(v);
            assert!(idx < BUCKETS);
            if idx < BUCKETS - 1 {
                let upper = bucket_upper_nanos(idx);
                assert!(upper >= v, "upper {upper} < value {v}");
                assert!((upper as f64) <= v as f64 * 1.20, "upper {upper} too loose for {v}");
            }
        }
        // Monotone index.
        assert!(bucket_index(100) <= bucket_index(2000));
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn quantiles_track_known_distribution() {
        let mut h = Histogram::new();
        // 100 samples: 1..=100 µs.
        for i in 1..=100u64 {
            h.record_nanos(i * 1000);
        }
        assert_eq!(h.count(), 100);
        let p50 = h.quantile_nanos(0.50) as f64;
        let p99 = h.quantile_nanos(0.99) as f64;
        // Bucketed answer within 20% above the exact quantile.
        assert!((50_000.0..=62_000.0).contains(&p50), "p50 {p50}");
        assert!((99_000.0..=120_000.0).contains(&p99), "p99 {p99}");
        assert_eq!(h.quantile_nanos(1.0), 100_000);
        assert_eq!(h.max_nanos(), 100_000);
        let s = h.summary();
        assert_eq!(s.count, 100);
        assert!((s.mean_us - 50.5).abs() < 0.01, "mean {}", s.mean_us);
        assert_eq!(s.max_us, 100.0);
    }

    #[test]
    fn merge_equals_recording_into_one() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut whole = Histogram::new();
        for i in 0..500u64 {
            let v = (i * 7919) % 2_000_000;
            if i % 2 == 0 { a.record_nanos(v) } else { b.record_nanos(v) }
            whole.record_nanos(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.max_nanos(), whole.max_nanos());
        for q in [0.5, 0.9, 0.95, 0.99] {
            assert_eq!(a.quantile_nanos(q), whole.quantile_nanos(q), "q={q}");
        }
    }

    #[test]
    fn empty_histogram_is_all_zero() {
        let h = Histogram::new();
        assert!(h.is_empty());
        assert_eq!(h.quantile_nanos(0.5), 0);
        let s = h.summary();
        assert_eq!(s.count, 0);
        assert_eq!(s.mean_us, 0.0);
        assert_eq!(s.p99_us, 0.0);
    }

    #[test]
    fn duration_recording() {
        let mut h = Histogram::new();
        h.record(std::time::Duration::from_micros(42));
        assert_eq!(h.count(), 1);
        assert_eq!(h.max_nanos(), 42_000);
    }
}
