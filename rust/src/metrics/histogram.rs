//! Fixed-bucket latency histograms for *measured wall-clock* time.
//!
//! The simulator's virtual clock gives exact per-op durations, but the
//! load plane ([`crate::loadgen`]) measures real socket round-trips, and
//! real measurements need a recorder that (a) costs O(1) per sample with
//! no allocation, and (b) merges cheaply so every worker thread can own
//! a private recorder and the harness can combine them after join — the
//! sharded-recorder pattern: workers never share a cache line, let alone
//! a lock.
//!
//! Buckets are geometric: bucket 0 holds everything under 1µs, then each
//! bucket grows by 2^(1/4) (~19%), covering 1µs to ~1 hour in
//! [`BUCKETS`] buckets. Quantiles are therefore upper bounds with ≤19%
//! relative error — ample for p50/p95/p99 reporting — while `min`,
//! `max`, `sum` and `count` are exact.

/// Number of geometric buckets (1µs × 2^((i-1)/4); see module docs).
pub const BUCKETS: usize = 128;

/// Smallest non-underflow bucket boundary, in nanoseconds.
const BASE_NANOS: f64 = 1000.0;

/// Bucket index for a sample of `nanos`. Public so the atomic registry
/// variant ([`super::registry`]) and the `/metricz` exposition share the
/// exact same geometric bucket layout as the worker-private histograms.
pub fn bucket_index(nanos: u64) -> usize {
    if nanos < BASE_NANOS as u64 {
        return 0;
    }
    let idx = 1 + ((nanos as f64 / BASE_NANOS).log2() * 4.0).floor() as usize;
    idx.min(BUCKETS - 1)
}

/// Upper bound (nanoseconds) of bucket `idx`: every sample recorded into
/// the bucket is ≤ this (except the final overflow bucket). Public for
/// the same reason as [`bucket_index`]: cumulative `_bucket{le=...}`
/// exposition series print these bounds.
pub fn bucket_upper_nanos(idx: usize) -> u64 {
    (BASE_NANOS * 2f64.powf(idx as f64 / 4.0)) as u64
}

/// A fixed-bucket wall-clock latency histogram. Plain data — no locks,
/// no atomics: one per worker thread, merged after the workers join.
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: [u64; BUCKETS],
    count: u64,
    sum_nanos: u64,
    min_nanos: u64,
    max_nanos: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            counts: [0; BUCKETS],
            count: 0,
            sum_nanos: 0,
            min_nanos: u64::MAX,
            max_nanos: 0,
        }
    }

    /// Record one sample.
    #[inline]
    pub fn record_nanos(&mut self, nanos: u64) {
        self.counts[bucket_index(nanos)] += 1;
        self.count += 1;
        self.sum_nanos = self.sum_nanos.saturating_add(nanos);
        self.min_nanos = self.min_nanos.min(nanos);
        self.max_nanos = self.max_nanos.max(nanos);
    }

    /// Record an elapsed [`std::time::Duration`].
    #[inline]
    pub fn record(&mut self, elapsed: std::time::Duration) {
        self.record_nanos(elapsed.as_nanos().min(u64::MAX as u128) as u64);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn max_nanos(&self) -> u64 {
        self.max_nanos
    }

    pub fn sum_nanos(&self) -> u64 {
        self.sum_nanos
    }

    /// Per-bucket sample counts, in bucket-index order (see
    /// [`bucket_upper_nanos`] for each bucket's upper bound).
    pub fn bucket_counts(&self) -> &[u64; BUCKETS] {
        &self.counts
    }

    /// Rebuild a histogram from raw per-bucket counts (the scrape-side
    /// inverse of [`Histogram::bucket_counts`]). `min`/`max` are only
    /// known to bucket resolution, so quantiles clamp to bucket bounds.
    pub fn from_bucket_counts(counts: [u64; BUCKETS], sum_nanos: u64) -> Self {
        let count = counts.iter().sum();
        let min_nanos = counts
            .iter()
            .position(|&n| n > 0)
            .map(|i| if i == 0 { 0 } else { bucket_upper_nanos(i - 1) })
            .unwrap_or(u64::MAX);
        let max_nanos = counts
            .iter()
            .rposition(|&n| n > 0)
            .map(bucket_upper_nanos)
            .unwrap_or(0);
        Self {
            counts,
            count,
            sum_nanos,
            min_nanos,
            max_nanos,
        }
    }

    /// Fold another histogram into this one (the post-join merge).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum_nanos = self.sum_nanos.saturating_add(other.sum_nanos);
        self.min_nanos = self.min_nanos.min(other.min_nanos);
        self.max_nanos = self.max_nanos.max(other.max_nanos);
    }

    /// The `q`-quantile (`0.0..=1.0`) in nanoseconds: the upper bound of
    /// the bucket where the cumulative count crosses `q·count`, clamped
    /// into the exact observed `[min, max]` range. Zero when empty.
    pub fn quantile_nanos(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (idx, &n) in self.counts.iter().enumerate() {
            cum += n;
            if cum >= target {
                return bucket_upper_nanos(idx)
                    .clamp(self.min_nanos, self.max_nanos);
            }
        }
        self.max_nanos
    }

    /// Summarise into the p50/p95/p99 shape the reports serialize.
    pub fn summary(&self) -> LatencySummary {
        let us = |n: u64| n as f64 / 1000.0;
        LatencySummary {
            count: self.count,
            mean_us: if self.count == 0 {
                0.0
            } else {
                self.sum_nanos as f64 / self.count as f64 / 1000.0
            },
            p50_us: us(self.quantile_nanos(0.50)),
            p95_us: us(self.quantile_nanos(0.95)),
            p99_us: us(self.quantile_nanos(0.99)),
            max_us: us(self.max_nanos),
        }
    }
}

/// Immutable percentile summary of one histogram, in microseconds.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct LatencySummary {
    pub count: u64,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_cover_and_bound() {
        // Every value lands in a bucket whose upper bound is >= it
        // (except the overflow bucket), within 19% relative error.
        for v in [1u64, 999, 1000, 1001, 5_000, 1_000_000, 3_000_000_000] {
            let idx = bucket_index(v);
            assert!(idx < BUCKETS);
            if idx < BUCKETS - 1 {
                let upper = bucket_upper_nanos(idx);
                assert!(upper >= v, "upper {upper} < value {v}");
                assert!((upper as f64) <= v as f64 * 1.20, "upper {upper} too loose for {v}");
            }
        }
        // Monotone index.
        assert!(bucket_index(100) <= bucket_index(2000));
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn quantiles_track_known_distribution() {
        let mut h = Histogram::new();
        // 100 samples: 1..=100 µs.
        for i in 1..=100u64 {
            h.record_nanos(i * 1000);
        }
        assert_eq!(h.count(), 100);
        let p50 = h.quantile_nanos(0.50) as f64;
        let p99 = h.quantile_nanos(0.99) as f64;
        // Bucketed answer within 20% above the exact quantile.
        assert!((50_000.0..=62_000.0).contains(&p50), "p50 {p50}");
        assert!((99_000.0..=120_000.0).contains(&p99), "p99 {p99}");
        assert_eq!(h.quantile_nanos(1.0), 100_000);
        assert_eq!(h.max_nanos(), 100_000);
        let s = h.summary();
        assert_eq!(s.count, 100);
        assert!((s.mean_us - 50.5).abs() < 0.01, "mean {}", s.mean_us);
        assert_eq!(s.max_us, 100.0);
    }

    #[test]
    fn merge_equals_recording_into_one() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut whole = Histogram::new();
        for i in 0..500u64 {
            let v = (i * 7919) % 2_000_000;
            if i % 2 == 0 { a.record_nanos(v) } else { b.record_nanos(v) }
            whole.record_nanos(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.max_nanos(), whole.max_nanos());
        for q in [0.5, 0.9, 0.95, 0.99] {
            assert_eq!(a.quantile_nanos(q), whole.quantile_nanos(q), "q={q}");
        }
    }

    #[test]
    fn empty_histogram_is_all_zero() {
        let h = Histogram::new();
        assert!(h.is_empty());
        assert_eq!(h.quantile_nanos(0.5), 0);
        let s = h.summary();
        assert_eq!(s.count, 0);
        assert_eq!(s.mean_us, 0.0);
        assert_eq!(s.p99_us, 0.0);
    }

    #[test]
    fn duration_recording() {
        let mut h = Histogram::new();
        h.record(std::time::Duration::from_micros(42));
        assert_eq!(h.count(), 1);
        assert_eq!(h.max_nanos(), 42_000);
    }

    #[test]
    fn empty_histogram_answers_every_quantile_with_zero() {
        let h = Histogram::new();
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(h.quantile_nanos(q), 0, "q={q}");
        }
        assert_eq!(h.max_nanos(), 0);
        assert_eq!(h.sum_nanos(), 0);
        assert!(h.bucket_counts().iter().all(|&n| n == 0));
    }

    #[test]
    fn single_observation_collapses_all_quantiles_to_it() {
        let mut h = Histogram::new();
        h.record_nanos(123_456);
        // One sample: every quantile is clamped into [min, max] = the
        // sample itself — p50 == p99 == max exactly.
        for q in [0.01, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(h.quantile_nanos(q), 123_456, "q={q}");
        }
        assert_eq!(h.quantile_nanos(0.5), h.max_nanos());
        let s = h.summary();
        assert_eq!(s.p50_us, s.p99_us);
        assert_eq!(s.p99_us, s.max_us);
    }

    #[test]
    fn merge_of_disjoint_bucket_ranges_keeps_both_tails() {
        // a: all sub-microsecond (bucket 0); b: all in the seconds range.
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for _ in 0..100 {
            a.record_nanos(500);
            b.record_nanos(2_000_000_000);
        }
        assert_ne!(bucket_index(500), bucket_index(2_000_000_000));
        a.merge(&b);
        assert_eq!(a.count(), 200);
        // Low half from the low range, high tail from the high range.
        assert!(a.quantile_nanos(0.25) <= 1000, "{}", a.quantile_nanos(0.25));
        assert_eq!(a.quantile_nanos(0.99), a.max_nanos());
        assert_eq!(a.max_nanos(), 2_000_000_000);
        // Exactly two buckets populated, 100 each.
        let populated: Vec<_> = a
            .bucket_counts()
            .iter()
            .enumerate()
            .filter(|(_, &n)| n > 0)
            .collect();
        assert_eq!(populated.len(), 2, "{populated:?}");
        assert!(populated.iter().all(|(_, &n)| n == 100));
    }

    #[test]
    fn sums_saturate_instead_of_wrapping() {
        let mut h = Histogram::new();
        h.record_nanos(u64::MAX);
        h.record_nanos(u64::MAX);
        assert_eq!(h.sum_nanos(), u64::MAX, "sum saturates");
        assert_eq!(h.count(), 2, "count stays exact");
        assert_eq!(h.max_nanos(), u64::MAX);
        // Merging two saturated histograms saturates too.
        let other = h.clone();
        h.merge(&other);
        assert_eq!(h.sum_nanos(), u64::MAX);
        assert_eq!(h.count(), 4);
    }

    #[test]
    fn from_bucket_counts_round_trips_quantiles() {
        let mut h = Histogram::new();
        for i in 1..=100u64 {
            h.record_nanos(i * 10_000);
        }
        let rebuilt = Histogram::from_bucket_counts(*h.bucket_counts(), h.sum_nanos());
        assert_eq!(rebuilt.count(), h.count());
        assert_eq!(rebuilt.sum_nanos(), h.sum_nanos());
        // Quantiles agree to bucket resolution (≤19% relative error, and
        // the rebuilt max is the bucket upper bound of the true max).
        for q in [0.5, 0.95, 0.99] {
            let (a, b) = (h.quantile_nanos(q) as f64, rebuilt.quantile_nanos(q) as f64);
            assert!(b >= a * 0.8 && b <= a * 1.2, "q={q}: {a} vs {b}");
        }
        let empty = Histogram::from_bucket_counts([0; BUCKETS], 0);
        assert!(empty.is_empty());
        assert_eq!(empty.quantile_nanos(0.99), 0);
    }
}
