//! REST-operation and byte accounting.
//!
//! The paper's evaluation is largely *counting*: how many REST operations of
//! each type a connector issues (Tables 2 and 7, Figures 5 and 6) and how
//! many bytes are read / written / copied on the object store (Figure 7).
//! This module is the single source of truth for those counters.
//!
//! [`histogram`] adds the *measured-time* counterpart: fixed-bucket
//! wall-clock latency histograms ([`Histogram`]/[`LatencySummary`]) used
//! by the `stress` load plane, shaped so every worker thread records
//! privately and the results merge after join.
//!
//! [`registry`] is the gateway-side observability plane built on the
//! same bucket layout: wait-free atomic histograms (merged at scrape
//! time, not on the request path), reactor sweep stats, and the bounded
//! `/tracez` ring.

pub mod histogram;
pub mod registry;

pub use histogram::{Histogram, LatencySummary};
pub use registry::{AtomicHistogram, ObsPlane, PhaseNanos, SweepStats, TraceEntry, TraceRing};

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};

/// The REST operation types the paper breaks out (Table 2), plus container
/// HEAD which the connectors also issue.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    HeadObject,
    GetObject,
    PutObject,
    CopyObject,
    DeleteObject,
    GetContainer,
    HeadContainer,
}

impl OpKind {
    pub const ALL: [OpKind; 7] = [
        OpKind::HeadObject,
        OpKind::GetObject,
        OpKind::PutObject,
        OpKind::CopyObject,
        OpKind::DeleteObject,
        OpKind::GetContainer,
        OpKind::HeadContainer,
    ];

    pub fn name(self) -> &'static str {
        match self {
            OpKind::HeadObject => "HEAD Object",
            OpKind::GetObject => "GET Object",
            OpKind::PutObject => "PUT Object",
            OpKind::CopyObject => "COPY Object",
            OpKind::DeleteObject => "DELETE Object",
            OpKind::GetContainer => "GET Container",
            OpKind::HeadContainer => "HEAD Container",
        }
    }

    /// Stable array index (`ALL` order) — shared by [`LiveCounters`],
    /// the observability registry, and the client's wire-op counters.
    pub fn index(self) -> usize {
        match self {
            OpKind::HeadObject => 0,
            OpKind::GetObject => 1,
            OpKind::PutObject => 2,
            OpKind::CopyObject => 3,
            OpKind::DeleteObject => 4,
            OpKind::GetContainer => 5,
            OpKind::HeadContainer => 6,
        }
    }
}

impl fmt::Display for OpKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Thread-safe live counters, attached to an [`crate::objectstore::ObjectStore`].
#[derive(Debug, Default)]
pub struct LiveCounters {
    ops: [AtomicU64; 7],
    bytes_read: AtomicU64,
    bytes_written: AtomicU64,
    bytes_copied: AtomicU64,
}

impl LiveCounters {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn record_op(&self, kind: OpKind) {
        self.ops[kind.index()].fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn record_read(&self, bytes: u64) {
        self.bytes_read.fetch_add(bytes, Ordering::Relaxed);
    }

    #[inline]
    pub fn record_write(&self, bytes: u64) {
        self.bytes_written.fetch_add(bytes, Ordering::Relaxed);
    }

    #[inline]
    pub fn record_copy(&self, bytes: u64) {
        self.bytes_copied.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Snapshot the current totals.
    pub fn snapshot(&self) -> OpCounts {
        OpCounts {
            ops: std::array::from_fn(|i| self.ops[i].load(Ordering::Relaxed)),
            bytes_read: self.bytes_read.load(Ordering::Relaxed),
            bytes_written: self.bytes_written.load(Ordering::Relaxed),
            bytes_copied: self.bytes_copied.load(Ordering::Relaxed),
        }
    }
}

/// An immutable snapshot of counters; supports diffing so a harness run can
/// measure exactly the ops a workload issued.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct OpCounts {
    ops: [u64; 7],
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub bytes_copied: u64,
}

impl OpCounts {
    pub fn get(&self, kind: OpKind) -> u64 {
        self.ops[kind.index()]
    }

    pub fn set(&mut self, kind: OpKind, v: u64) {
        self.ops[kind.index()] = v;
    }

    pub fn add(&mut self, kind: OpKind, v: u64) {
        self.ops[kind.index()] += v;
    }

    /// Total REST operations of all types.
    pub fn total(&self) -> u64 {
        self.ops.iter().sum()
    }

    /// Counter-wise difference `self - earlier` (saturating).
    pub fn since(&self, earlier: &OpCounts) -> OpCounts {
        OpCounts {
            ops: std::array::from_fn(|i| self.ops[i].saturating_sub(earlier.ops[i])),
            bytes_read: self.bytes_read.saturating_sub(earlier.bytes_read),
            bytes_written: self.bytes_written.saturating_sub(earlier.bytes_written),
            bytes_copied: self.bytes_copied.saturating_sub(earlier.bytes_copied),
        }
    }

    /// Counter-wise sum.
    pub fn plus(&self, other: &OpCounts) -> OpCounts {
        OpCounts {
            ops: std::array::from_fn(|i| self.ops[i] + other.ops[i]),
            bytes_read: self.bytes_read + other.bytes_read,
            bytes_written: self.bytes_written + other.bytes_written,
            bytes_copied: self.bytes_copied + other.bytes_copied,
        }
    }

    /// Render the Table-2-style one-line breakdown.
    pub fn breakdown(&self) -> String {
        let mut parts: Vec<String> = Vec::new();
        for k in OpKind::ALL {
            let n = self.get(k);
            if n > 0 {
                parts.push(format!("{}={}", k.name(), n));
            }
        }
        if parts.is_empty() {
            "no ops".to_string()
        } else {
            format!("{} (total {})", parts.join(", "), self.total())
        }
    }
}

impl fmt::Display for OpCounts {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.breakdown())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_snapshot() {
        let live = LiveCounters::new();
        live.record_op(OpKind::PutObject);
        live.record_op(OpKind::PutObject);
        live.record_op(OpKind::HeadObject);
        live.record_write(100);
        live.record_read(40);
        live.record_copy(7);
        let s = live.snapshot();
        assert_eq!(s.get(OpKind::PutObject), 2);
        assert_eq!(s.get(OpKind::HeadObject), 1);
        assert_eq!(s.get(OpKind::GetObject), 0);
        assert_eq!(s.total(), 3);
        assert_eq!(s.bytes_written, 100);
        assert_eq!(s.bytes_read, 40);
        assert_eq!(s.bytes_copied, 7);
    }

    #[test]
    fn diffing_isolates_a_window() {
        let live = LiveCounters::new();
        live.record_op(OpKind::GetObject);
        let before = live.snapshot();
        live.record_op(OpKind::GetObject);
        live.record_op(OpKind::DeleteObject);
        live.record_write(50);
        let after = live.snapshot();
        let d = after.since(&before);
        assert_eq!(d.get(OpKind::GetObject), 1);
        assert_eq!(d.get(OpKind::DeleteObject), 1);
        assert_eq!(d.total(), 2);
        assert_eq!(d.bytes_written, 50);
    }

    #[test]
    fn plus_sums_counterwise() {
        let mut a = OpCounts::default();
        a.add(OpKind::PutObject, 3);
        a.bytes_written = 10;
        let mut b = OpCounts::default();
        b.add(OpKind::PutObject, 4);
        b.add(OpKind::HeadObject, 1);
        b.bytes_read = 5;
        let c = a.plus(&b);
        assert_eq!(c.get(OpKind::PutObject), 7);
        assert_eq!(c.get(OpKind::HeadObject), 1);
        assert_eq!(c.bytes_written, 10);
        assert_eq!(c.bytes_read, 5);
    }

    #[test]
    fn breakdown_mentions_nonzero_kinds_only() {
        let mut a = OpCounts::default();
        a.add(OpKind::PutObject, 3);
        a.add(OpKind::GetContainer, 1);
        let s = a.breakdown();
        assert!(s.contains("PUT Object=3"));
        assert!(s.contains("GET Container=1"));
        assert!(!s.contains("COPY"));
        assert!(s.contains("total 4"));
        assert_eq!(OpCounts::default().breakdown(), "no ops");
    }

    #[test]
    fn concurrent_recording() {
        use std::sync::Arc;
        let live = Arc::new(LiveCounters::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let l = live.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        l.record_op(OpKind::HeadObject);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(live.snapshot().get(OpKind::HeadObject), 8000);
    }
}
