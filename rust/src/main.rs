//! `stocator-sim` — CLI for the Stocator reproduction.
//!
//! Subcommands:
//! * `trace table1|table3` — print the paper's operation traces.
//! * `table2` — the one-object REST breakdown vs the paper.
//! * `run --workload W --scenario S [--small] [--runs N]` — one cell.
//! * `sweep [--workloads a,b,...] [--runs N] [--small]` — Tables 5-8 and
//!   Figures 5-7 from one sweep, with the shape check.
//! * `serve` — expose a backend as an HTTP gateway on a real socket;
//!   `--config`/`STOCATOR_GATEWAY_*`/flags select the server core
//!   (reactor event loop vs thread-per-connection), connection cap,
//!   token-bucket rate limit, and bearer auth.
//! * `stress [--clients N] [--seed S] ...` — measured-wall-clock load
//!   plane: N threads hammer a gateway, verify as they go, and write
//!   `BENCH_10.json`. `--chaos` arms the wire chaos plane (killed /
//!   truncated / stalled / reset connections) on the in-process gateway;
//!   the idempotent `x-request-id` replay protocol must keep
//!   `violations: 0`. `--scrape` polls `/metricz` during the hammer and
//!   embeds the server-side latency/op truth next to the client's.

use stocator::harness::tables::{render_table2, Sweep};
use stocator::harness::traces::{table1_trace, table3_trace};
use stocator::harness::{figures, run_cell, Scenario, Sizing, Workload};
use stocator::objectstore::BackendKind;
use stocator::util::cli::Args;

fn parse_scenario(s: &str) -> Option<Scenario> {
    Scenario::ALL
        .iter()
        .copied()
        .find(|sc| sc.label().eq_ignore_ascii_case(s) || short(sc).eq_ignore_ascii_case(s))
}

fn short(s: &Scenario) -> &'static str {
    match s {
        Scenario::HadoopSwiftBase => "hs-base",
        Scenario::S3aBase => "s3a-base",
        Scenario::Stocator => "stocator",
        Scenario::HadoopSwiftCv2 => "hs-cv2",
        Scenario::S3aCv2 => "s3a-cv2",
        Scenario::S3aCv2Fu => "s3a-cv2-fu",
    }
}

fn parse_workload(s: &str) -> Option<Workload> {
    match s.to_ascii_lowercase().as_str() {
        "readonly" | "readonly50" | "ro50" => Some(Workload::ReadOnly50),
        "readonly500" | "ro500" => Some(Workload::ReadOnly500),
        "teragen" => Some(Workload::Teragen),
        "copy" => Some(Workload::Copy),
        "wordcount" => Some(Workload::Wordcount),
        "terasort" => Some(Workload::Terasort),
        "tpcds" | "tpc-ds" => Some(Workload::TpcDs),
        _ => None,
    }
}

const USAGE: &str = "\
stocator-sim — Stocator (Vernik et al. 2017) reproduction

USAGE:
  stocator-sim trace table1
  stocator-sim trace table3 [--attempts N] [--no-cleanup]
  stocator-sim table2
  stocator-sim run --workload W --scenario S [sizing] [--runs N]
  stocator-sim sweep [--workloads w1,w2] [--runs N] [sizing]
  stocator-sim serve [--backend B] [--addr HOST:PORT] [--addr-file PATH]
                     [--config PATH] [--mode reactor|threaded]
                     [--max-conns N] [--rate-limit OPS] [--burst N]
                     [--auth-token TOKEN] [--chaos SPEC] [--chaos-seed S]
  stocator-sim stress [--clients N] [--shards N] [--target HOST:PORT]
                      [--backend mem|sharded[:N]|fs[:DIR]]
                      [--payload BYTES] [--duration D | --ops N]
                      [--seed S] [--no-matrix] [--bench-out PATH]
                      [--open-conns N] [--token TOKEN]
                      [--core reactor|threaded]
                      [--chaos SPEC] [--chaos-seed S] [--scrape]

  stress: real-concurrency load plane — N worker threads (default 8),
          each with its own HttpBackend connection pool, hammer a served
          store with a seeded PUT/GET/ranged-GET/list/delete/multipart/
          abort mix, verifying bytes, ETags, multipart-id uniqueness and
          listing completeness as they go. Serves an in-process gateway
          over sharded:N (default 16) unless --target points at a
          `stocator-sim serve`; --core picks the in-process server core
          (default reactor). --duration (default 2s; accepts 2s/
          500ms/1.5) times the run; --ops N fixes a per-client op budget
          instead (deterministic mix for a given --seed). --open-conns N
          additionally holds N idle keep-alive connections open across
          the whole hammer (the reactor scalability knob); --token sends
          `Authorization: Bearer` on every worker request. Prints per-
          op-class wall-clock p50/p95/p99, (unless --no-matrix) a
          clients × shards × payload throughput matrix plus a reactor-
          vs-threaded core comparison, and the count of real 429/503
          rejections the workers absorbed and recovered from; writes
          everything to --bench-out (default BENCH_10.json). Exits
          non-zero on any correctness violation.
          --scrape starts a background poller that scrapes the
          gateway's /metricz during the hammer (proving the probes stay
          serveable under load) and takes a final scrape after the
          workers join: the run then prints server-client-op-gap (the
          summed per-op-kind |server - client| difference, 0 on a
          chaos-free run because both sides count completed wire ops
          with the same table) and tracez-entries (requests captured in
          the /tracez ring), and embeds the server-side latency
          quantiles next to the client-side ones in the bench JSON.
          Works against --target or the in-process gateway.
          --chaos SPEC arms wire chaos on the in-process gateway for
          the main hammer (comma-separated NAME@p=PROB with NAME one of
          kill-response|truncate|stall|reset; e.g.
          --chaos kill-response@p=0.02,truncate@p=0.01,reset@p=0.01);
          faults are seeded (--chaos-seed, default --seed) so a run is
          reproducible. The client's idempotent retry protocol (every
          mutation carries an x-request-id; the gateway replays its
          cached response on a duplicate id instead of re-executing)
          must keep violations at 0 — the run prints retried-sends and
          replayed-responses so CI can prove chaos actually fired.
          Incompatible with --target (chaos is injected in-process).
          --backend runs the in-process gateway over mem, sharded:N
          (same as --shards N), or a real local-FS store rooted at DIR
          (fs alone picks a fresh temp root; the matrix sweep then
          varies only clients × payload).

  serve: expose a backend as an HTTP object-store gateway (REST routes
         PUT/GET/HEAD/DELETE /v1/{container}/{key}, Range reads, ETags,
         paginated listings, multipart). --addr defaults to 127.0.0.1:0
         (ephemeral port, printed at startup; also written to
         --addr-file when given). Point any run/sweep at it with
         --backend http:HOST:PORT — op counts and virtual runtimes are
         byte-identical to the in-process backends.
         Gateway behavior is configured defaults → --config TOML file →
         STOCATOR_GATEWAY_* env vars → flags: --mode picks the server
         core (default reactor: one-thread non-blocking event loop;
         threaded: legacy thread-per-connection), --max-conns caps
         simultaneous connections (excess sheds an immediate 503 with
         x-error-kind: over-capacity), --rate-limit OPS enables a
         token-bucket limiter (real 429s with fractional Retry-After;
         0 = off) with --burst capacity, and --auth-token requires
         `Authorization: Bearer TOKEN` on every non-/healthz request
         (401 missing / 403 wrong). --chaos SPEC (TOML key `chaos`,
         env STOCATOR_GATEWAY_CHAOS) arms the wire chaos plane on the
         served gateway — kill-response|truncate|stall|reset@p=PROB,
         seeded by --chaos-seed — for soak-testing clients' retry
         protocols against a long-lived process.

  sizing: --small (test sizing) or --paper (paper-faithful object
          counts, the default); mutually exclusive.
          plus --paper-x X (TB-scale: paper object counts, task slots
            and TPC-DS shards multiplied X-fold on the virtual clock;
            100-1000 is the intended band — X=100 is a ~4.65 TB logical
            terasort over 14400 slots. Parts stay 128 MiB logical
            (simulated bytes shrink, data_scale grows), so memory stays
            bounded while the REST-op ledger sees the full TB-scale
            run. Incompatible with --small.)
          plus --backend mem|sharded[:N]|fs[:DIR]|http:HOST:PORT
            mem      in-memory map behind a single lock
            sharded  N-way key-sharded in-memory map (default, N=16)
            fs       persistent local-FS backend rooted at DIR (default:
                     a fresh directory under the system temp dir, printed
                     at startup); each run/cell works in a unique
                     subdirectory of DIR
            http     remote gateway served by `stocator-sim serve`; each
                     run/cell works in a unique container namespace on
                     the served store
          plus --readahead BYTES|off (default: off)
            connector-level prefetch window, simulated bytes: small
            sequential read_range calls coalesce into one ranged GET per
            window fill (S3AInputStream-style; grows on sequential reads,
            collapses for random readers). 'off' (or 0) reproduces the
            paper's one-GET-per-read behaviour exactly.
          plus --faults SPEC (default: none)
            deterministic transient REST faults: comma-separated rules
            OP[:KEY_PREFIX]@TRIGGER[!429] with OP one of put|get|part|
            complete and TRIGGER either NTH[xCOUNT] (the NTH matching
            operation, and the COUNT-1 after it, fail) or p=P (each
            matching operation fails with probability P, deterministic
            under --seed — sustained degraded service). Failures are
            retryable 503s that still burn latency, the op, and (for
            PUT-class ops) the payload bytes; with !429 they are
            throttles instead — an op and base latency, ZERO wire
            bytes, and the flat Retry-After pause on retry.
            Examples: --faults put:teraout/@1 fails the first part PUT;
            --faults put@p=0.05,get@p=0.01!429 models a degraded store.
          plus --retries N (default: 0)
            stream-layer retries per operation, exponential virtual-clock
            backoff (flat Retry-After for 429s). Recovery semantics are
            the connector's: Swift/S3a re-PUT from the local spool, fast
            upload re-sends only the failed part, Stocator restarts its
            whole chunked PUT from offset 0 (the paper's fragility
            footnote). Exhausted budgets fail the task attempt and Spark
            re-attempts it.
          plus --multipart-ttl SECS (default: off)
            age-based lifecycle sweep aborting multipart uploads
            stranded by crashed/exhausted fast-upload writers; the
            Table 8 addendum prices the stranded bytes before/after.

  scenarios: hs-base s3a-base stocator hs-cv2 s3a-cv2 s3a-cv2-fu
  workloads: ro50 ro500 teragen copy wordcount terasort tpcds
";

/// Resolve experiment sizing from `--small` / `--paper` / `--paper-x` /
/// `--backend` / `--readahead`. `--paper` is the explicit spelling of
/// the default; combining it with `--small` is a contradiction and is
/// rejected, as is `--small` with `--paper-x`.
fn select_sizing(args: &Args) -> Result<Sizing, String> {
    args.flag_conflict("small", "paper")?;
    if args.opt("paper-x").is_some() && args.flag("small") {
        return Err("--small and --paper-x are mutually exclusive".to_string());
    }
    let mut sizing = if let Some(spec) = args.opt("paper-x") {
        let x: usize = spec
            .parse()
            .ok()
            .filter(|&x| x >= 1)
            .ok_or_else(|| format!("--paper-x expects a multiplier >= 1, got '{spec}'"))?;
        Sizing::paper_x(x)
    } else if args.flag("small") {
        Sizing::small()
    } else {
        // --paper (or nothing): paper-faithful object counts.
        Sizing::paper()
    };
    if let Some(spec) = args.opt("backend") {
        sizing.backend = BackendKind::parse(spec)?;
    }
    if let Some(spec) = args.opt("readahead") {
        sizing.readahead = match spec {
            "off" => 0,
            s => s.parse().map_err(|_| {
                format!("--readahead expects a byte count or 'off', got '{s}'")
            })?,
        };
    }
    if let Some(spec) = args.opt("faults") {
        sizing.faults = stocator::objectstore::FaultSpec::parse(spec)?;
    }
    sizing.retries = args.opt_u64("retries", 0)? as u32;
    sizing.multipart_ttl_secs = match args.opt("multipart-ttl") {
        Some("off") | None => 0,
        Some(s) => s.parse().map_err(|_| {
            format!("--multipart-ttl expects seconds or 'off', got '{s}'")
        })?,
    };
    // Pin a concrete root for `fs` so the user can find (and reuse) the
    // data; each run then works in a unique subdirectory of it.
    if sizing.backend == BackendKind::LocalFs(None) {
        sizing.backend =
            BackendKind::LocalFs(Some(stocator::objectstore::backend::fresh_temp_root()));
    }
    Ok(sizing)
}

/// Build the stress config from CLI options over [`StressConfig`]'s
/// defaults.
fn stress_config(args: &Args) -> Result<stocator::loadgen::StressConfig, String> {
    let dflt = stocator::loadgen::StressConfig::default();
    let duration = match args.opt("duration") {
        None => dflt.duration,
        Some(s) => Some(
            stocator::util::cli::parse_duration(s).map_err(|e| format!("--duration: {e}"))?,
        ),
    };
    let ops_per_client = match args.opt("ops") {
        None => None,
        Some(_) => Some(args.opt_u64("ops", 0)?),
    };
    let core = match args.opt("core") {
        None => dflt.core,
        Some(s) => stocator::gateway::GatewayMode::parse(s).map_err(|e| format!("--core: {e}"))?,
    };
    let seed = args.opt_u64("seed", dflt.seed)?;
    let mut shards = args.opt_u64("shards", dflt.shards as u64)?.max(1) as usize;
    let mut fs_root = None;
    if let Some(spec) = args.opt("backend") {
        if args.opt("target").is_some() {
            return Err(
                "--backend configures the in-process gateway's store; it conflicts with --target"
                    .to_string(),
            );
        }
        match BackendKind::parse(spec)? {
            BackendKind::Mem => shards = 1,
            BackendKind::Sharded(n) => shards = n,
            BackendKind::LocalFs(root) => {
                // Pin a concrete root so the run can report it.
                fs_root = Some(root.unwrap_or_else(
                    stocator::objectstore::backend::fresh_temp_root,
                ));
            }
            BackendKind::Http { .. } => {
                return Err(
                    "--backend http: use --target HOST:PORT to stress a remote gateway"
                        .to_string(),
                );
            }
        }
    }
    let chaos = match args.opt("chaos") {
        None => dflt.chaos,
        Some(spec) => {
            let mut c = stocator::gateway::ChaosConfig::parse(spec)
                .map_err(|e| format!("--chaos: {e}"))?;
            // Chaos draws are seeded independently of the workload mix
            // but default to the run seed: same command, same faults.
            c.seed = args.opt_u64("chaos-seed", seed)?;
            c
        }
    };
    Ok(stocator::loadgen::StressConfig {
        clients: args.opt_u64("clients", dflt.clients as u64)?.max(1) as usize,
        shards,
        target: args.opt("target").map(str::to_string),
        payload: args.opt_u64("payload", dflt.payload as u64)?.max(1) as usize,
        seed,
        duration,
        ops_per_client,
        matrix: !args.flag("no-matrix"),
        bench_path: Some(std::path::PathBuf::from(
            args.opt_or("bench-out", stocator::loadgen::BENCH_FILE),
        )),
        open_conns: args.opt_u64("open-conns", 0)? as usize,
        token: args.opt("token").map(str::to_string),
        core,
        chaos,
        fs_root,
        scrape: args.flag("scrape"),
    })
}

/// Resolve the `serve` gateway config: defaults → `--config` file →
/// `STOCATOR_GATEWAY_*` env → explicit flags, each later layer winning.
fn serve_gateway_config(args: &Args) -> Result<stocator::gateway::GatewayConfig, String> {
    let mut cfg = stocator::gateway::GatewayConfig::serve_default();
    if let Some(path) = args.opt("config") {
        cfg.apply_file(std::path::Path::new(path))?;
    }
    cfg.apply_env()?;
    for (flag, key) in [
        ("mode", "mode"),
        ("max-conns", "max_conns"),
        ("rate-limit", "rate_limit"),
        ("burst", "burst"),
        ("auth-token", "auth_token"),
        ("chaos", "chaos"),
        ("chaos-seed", "chaos_seed"),
    ] {
        if let Some(value) = args.opt(flag) {
            cfg.set(key, value).map_err(|e| format!("--{flag}: {e}"))?;
        }
    }
    Ok(cfg)
}

fn main() {
    let args = match Args::parse(
        std::env::args().skip(1),
        &["small", "paper", "no-cleanup", "no-matrix", "scrape"],
    ) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    let sizing = match select_sizing(&args) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    match args.subcommand.as_deref() {
        Some("trace") => match args.positionals.first().map(String::as_str) {
            Some("table1") => {
                println!("Table 1 — file operations for a one-task program on HDFS:");
                for (i, line) in table1_trace().iter().enumerate() {
                    println!("  {:>2}. {line}", i + 1);
                }
            }
            Some("table3") => {
                let attempts = args.opt_u64("attempts", 2).unwrap_or(2) as u32;
                let cleanup = !args.flag("no-cleanup");
                let (trace, names) = table3_trace(attempts, cleanup);
                println!(
                    "Table 3 — Stocator REST trace ({attempts} extra attempts of task 2, cleanup={cleanup}):"
                );
                for line in &trace {
                    println!("  {line}");
                }
                println!("final objects:");
                for n in names {
                    println!("  {n}");
                }
            }
            other => {
                eprintln!("unknown trace {other:?}\n{USAGE}");
                std::process::exit(2);
            }
        },
        Some("table2") => print!("{}", render_table2()),
        Some("serve") => {
            use std::sync::Arc;
            let addr = args.opt_or("addr", "127.0.0.1:0");
            let gw_cfg = match serve_gateway_config(&args) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("error: {e}\n{USAGE}");
                    std::process::exit(2);
                }
            };
            let backend: Arc<dyn stocator::objectstore::Backend> =
                Arc::from(stocator::objectstore::backend::make_backend(&sizing.backend));
            let server = match stocator::gateway::GatewayServer::bind_with(addr, backend, gw_cfg.clone()) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("error: binding {addr}: {e}");
                    std::process::exit(1);
                }
            };
            let local = server.local_addr();
            println!("gateway: serving backend {} on http://{local}", sizing.backend.label());
            println!("gateway: {}", gw_cfg.describe());
            println!("gateway: connect with --backend http:{local}");
            if let Some(path) = args.opt("addr-file") {
                if let Err(e) = std::fs::write(path, local.to_string()) {
                    eprintln!("error: writing --addr-file {path}: {e}");
                    std::process::exit(1);
                }
            }
            server.run();
        }
        Some("stress") => {
            use stocator::harness::tables::{
                render_stress_cores, render_stress_latency, render_stress_matrix,
                render_stress_scrape,
            };
            let cfg = match stress_config(&args) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("error: {e}\n{USAGE}");
                    std::process::exit(2);
                }
            };
            println!(
                "stress: {} clients, payload ≤{} B, seed {}, target {}",
                cfg.clients,
                cfg.payload,
                cfg.seed,
                cfg.target.as_deref().unwrap_or("in-process gateway"),
            );
            if cfg.chaos.is_active() {
                println!("chaos: {} (seed {})", cfg.chaos.spec(), cfg.chaos.seed);
            }
            match stocator::loadgen::run_stress(&cfg) {
                Ok(report) => {
                    print!("{}", render_stress_latency(&report.run));
                    if !report.matrix.is_empty() {
                        print!("{}", render_stress_matrix(&report.matrix));
                    }
                    if !report.cores.is_empty() {
                        print!("{}", render_stress_cores(&report.cores));
                    }
                    if report.open_conns > 0 {
                        println!(
                            "open-conns: {} requested, {} held for the full run",
                            report.open_conns, report.open_conns_held
                        );
                    }
                    // Real backpressure the workers absorbed (server-
                    // emitted 429s / over-capacity 503s that were slept
                    // out and re-sent; the recovered ops count normally
                    // above). CI greps these lines.
                    println!("throttled-429s: {}", report.run.throttled_429);
                    println!("shed-503s: {}", report.run.shed_503);
                    // Wire-chaos recovery: send failures survived by
                    // re-sending the same x-request-id, and re-sent
                    // mutations the gateway answered from its replay
                    // cache instead of re-executing. CI gates on these
                    // being nonzero under --chaos.
                    println!("retried-sends: {}", report.run.retried_sends);
                    println!("replayed-responses: {}", report.run.replayed_responses);
                    // Server-side truth from the --scrape poller: CI
                    // gates on the op gap being exactly 0 (chaos-free,
                    // both ends count completed wire ops with the same
                    // table) and on the trace ring being non-empty.
                    if let Some(s) = &report.scrape {
                        print!("{}", render_stress_scrape(s));
                        println!("metricz-polls: {}", s.polls);
                        println!("server-client-op-gap: {}", s.op_gap());
                        println!("tracez-entries: {}", s.tracez_entries);
                    }
                    if let Some(p) = &cfg.bench_path {
                        println!("bench: wrote {}", p.display());
                    }
                    // Matrix and core-comparison cells count too: a
                    // sweep that only goes wrong under some shape must
                    // still fail the run.
                    let total_violations = report.run.violation_count
                        + report.matrix.iter().map(|m| m.violation_count).sum::<u64>()
                        + report.cores.iter().map(|c| c.violation_count).sum::<u64>();
                    println!("violations: {total_violations}");
                    for v in &report.run.violations {
                        println!("  - {v}");
                    }
                    if total_violations > 0 {
                        std::process::exit(1);
                    }
                }
                Err(e) => {
                    eprintln!("error: {e}");
                    std::process::exit(1);
                }
            }
        }
        Some("run") => {
            let Some(w) = args.opt("workload").and_then(parse_workload) else {
                eprintln!("--workload required\n{USAGE}");
                std::process::exit(2);
            };
            let Some(s) = args.opt("scenario").and_then(parse_scenario) else {
                eprintln!("--scenario required\n{USAGE}");
                std::process::exit(2);
            };
            let runs = args.opt_u64("runs", 1).unwrap_or(1) as usize;
            println!("backend: {}", sizing.backend.label());
            let cell = run_cell(s, w, &sizing, runs);
            println!(
                "{} / {}: runtime {:.2}s ± {:.2}s over {} runs",
                s.label(),
                w.label(),
                cell.runtime_mean_s,
                cell.runtime_std_s,
                cell.runs
            );
            println!("ops: {}", cell.ops);
            println!("validation: {}", cell.validation);
            if !cell.valid {
                std::process::exit(1);
            }
        }
        Some("sweep") => {
            println!("backend: {}", sizing.backend.label());
            let runs = args.opt_u64("runs", 3).unwrap_or(3) as usize;
            let workloads: Vec<Workload> = match args.opt("workloads") {
                Some(list) => list
                    .split(',')
                    .map(|w| {
                        parse_workload(w).unwrap_or_else(|| {
                            eprintln!("unknown workload '{w}'");
                            std::process::exit(2);
                        })
                    })
                    .collect(),
                None => Workload::ALL.to_vec(),
            };
            let sweep = Sweep::run(&sizing, runs, &workloads);
            println!("{}", sweep.render_table5());
            println!("{}", sweep.render_table6());
            println!("{}", sweep.render_table7());
            println!("{}", sweep.render_table8());
            let micro: Vec<Workload> = workloads
                .iter()
                .copied()
                .filter(|w| Workload::MICRO.contains(w))
                .collect();
            if !micro.is_empty() {
                println!(
                    "{}",
                    figures::render_rest_figure(
                        &sweep,
                        &micro,
                        "Figure 5 — micro-benchmark REST calls"
                    )
                );
            }
            let macro_w: Vec<Workload> = workloads
                .iter()
                .copied()
                .filter(|w| Workload::MACRO.contains(w))
                .collect();
            if !macro_w.is_empty() {
                println!(
                    "{}",
                    figures::render_rest_figure(
                        &sweep,
                        &macro_w,
                        "Figure 6 — macro-benchmark REST calls"
                    )
                );
            }
            println!("{}", figures::render_fig7(&sweep));
            match sweep.check_shape() {
                Ok(()) => println!("shape check: OK (all DESIGN.md §6 assertions hold)"),
                Err(violations) => {
                    println!("shape check: {} violation(s)", violations.len());
                    for v in violations {
                        println!("  - {v}");
                    }
                    std::process::exit(1);
                }
            }
        }
        _ => print!("{USAGE}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(tokens: &[&str]) -> Args {
        Args::parse(
            tokens.iter().map(|s| s.to_string()),
            &["small", "paper", "no-cleanup", "no-matrix", "scrape"],
        )
        .unwrap()
    }

    #[test]
    fn default_sizing_is_paper() {
        let s = select_sizing(&args(&["run"])).unwrap();
        assert_eq!(s.parts, Sizing::paper().parts);
    }

    #[test]
    fn paper_flag_selects_paper_sizing_explicitly() {
        let s = select_sizing(&args(&["run", "--paper"])).unwrap();
        assert_eq!(s.parts, Sizing::paper().parts);
        let s = select_sizing(&args(&["run", "--small"])).unwrap();
        assert_eq!(s.parts, Sizing::small().parts);
    }

    #[test]
    fn small_and_paper_together_are_rejected() {
        let e = select_sizing(&args(&["run", "--small", "--paper"])).unwrap_err();
        assert!(e.contains("mutually exclusive"), "{e}");
    }

    #[test]
    fn paper_x_selects_tb_scale_sizing() {
        let s = select_sizing(&args(&["run", "--paper-x", "100"])).unwrap();
        assert_eq!(s.parts, Sizing::paper().parts * 100);
        assert_eq!(s.slots, Sizing::paper().slots * 100);
        // Composes with the other sizing knobs.
        let s = select_sizing(&args(&["run", "--paper-x", "10", "--backend", "mem"])).unwrap();
        assert_eq!(s.backend, BackendKind::Mem);
        assert!(select_sizing(&args(&["run", "--paper-x", "0"])).is_err());
        assert!(select_sizing(&args(&["run", "--paper-x", "lots"])).is_err());
        let e = select_sizing(&args(&["run", "--small", "--paper-x", "10"])).unwrap_err();
        assert!(e.contains("mutually exclusive"), "{e}");
    }

    #[test]
    fn backend_option_is_wired_through() {
        let s = select_sizing(&args(&["run", "--small", "--backend", "mem"])).unwrap();
        assert_eq!(s.backend, BackendKind::Mem);
        let s = select_sizing(&args(&["run", "--backend", "sharded:8"])).unwrap();
        assert_eq!(s.backend, BackendKind::Sharded(8));
        // Bare `fs` gets pinned to a concrete (reported) temp root.
        let s = select_sizing(&args(&["run", "--backend=fs"])).unwrap();
        assert!(matches!(s.backend, BackendKind::LocalFs(Some(_))));
        // `http:` parses without connecting (the env connects per cell)
        // and leaves the namespace unset for build_env to specialise.
        let s = select_sizing(&args(&["run", "--backend", "http:127.0.0.1:4321"])).unwrap();
        assert_eq!(
            s.backend,
            BackendKind::Http {
                addr: "127.0.0.1:4321".to_string(),
                ns: None
            }
        );
        assert!(select_sizing(&args(&["run", "--backend", "http:nope"])).is_err());
        assert!(select_sizing(&args(&["run", "--backend", "bogus"])).is_err());
    }

    #[test]
    fn readahead_option_is_wired_through() {
        // Default: off, reproducing the paper's one-GET-per-read reads.
        assert_eq!(select_sizing(&args(&["run"])).unwrap().readahead, 0);
        let s = select_sizing(&args(&["run", "--readahead", "131072"])).unwrap();
        assert_eq!(s.readahead, 131_072);
        let s = select_sizing(&args(&["run", "--readahead=off"])).unwrap();
        assert_eq!(s.readahead, 0);
        assert!(select_sizing(&args(&["run", "--readahead", "lots"])).is_err());
    }

    #[test]
    fn fault_plane_knobs_are_wired_through() {
        use stocator::objectstore::{FaultOp, FaultRule};
        // Defaults: no faults, no retries, no sweep.
        let s = select_sizing(&args(&["run"])).unwrap();
        assert!(s.faults.is_empty());
        assert_eq!(s.retries, 0);
        assert_eq!(s.multipart_ttl_secs, 0);
        // Full spelling.
        let s = select_sizing(&args(&[
            "run",
            "--faults",
            "put:teraout/@1x2,part@3",
            "--retries",
            "2",
            "--multipart-ttl",
            "3600",
        ]))
        .unwrap();
        assert_eq!(s.faults.rules[0], FaultRule::new(FaultOp::Put, "teraout/", 1, 2));
        assert_eq!(s.faults.rules[1], FaultRule::new(FaultOp::UploadPart, "", 3, 1));
        assert_eq!(s.retries, 2);
        assert_eq!(s.multipart_ttl_secs, 3600);
        // Malformed specs are rejected with a parse error.
        assert!(select_sizing(&args(&["run", "--faults", "frob@1"])).is_err());
        assert!(select_sizing(&args(&["run", "--faults", "put@0"])).is_err());
        assert!(select_sizing(&args(&["run", "--retries", "many"])).is_err());
        assert!(select_sizing(&args(&["run", "--multipart-ttl", "soon"])).is_err());
        assert_eq!(
            select_sizing(&args(&["run", "--multipart-ttl", "off"]))
                .unwrap()
                .multipart_ttl_secs,
            0
        );
    }

    #[test]
    fn stress_config_defaults_and_overrides() {
        use std::time::Duration;
        let c = stress_config(&args(&["stress"])).unwrap();
        assert_eq!(c.clients, 8);
        assert_eq!(c.shards, 16);
        assert_eq!(c.target, None);
        assert_eq!(c.duration, Some(Duration::from_secs(2)));
        assert_eq!(c.ops_per_client, None);
        assert!(c.matrix);
        assert_eq!(c.bench_path.as_deref().unwrap().to_str(), Some("BENCH_10.json"));
        assert_eq!(c.open_conns, 0);
        assert_eq!(c.token, None);
        assert_eq!(c.core, stocator::gateway::GatewayMode::Reactor);
        assert!(!c.chaos.is_active(), "chaos is off unless --chaos is given");
        assert_eq!(c.fs_root, None);
        assert!(!c.scrape, "scrape is opt-in");
        let c = stress_config(&args(&[
            "stress",
            "--clients", "32",
            "--shards", "4",
            "--target", "127.0.0.1:9999",
            "--payload", "4096",
            "--duration", "500ms",
            "--seed", "11",
            "--no-matrix",
            "--bench-out", "out.json",
            "--open-conns", "2000",
            "--token", "hunter2",
            "--core", "threaded",
            "--scrape",
        ]))
        .unwrap();
        assert_eq!(c.clients, 32);
        assert_eq!(c.shards, 4);
        assert_eq!(c.target.as_deref(), Some("127.0.0.1:9999"));
        assert_eq!(c.payload, 4096);
        assert_eq!(c.duration, Some(Duration::from_millis(500)));
        assert_eq!(c.seed, 11);
        assert!(!c.matrix);
        assert_eq!(c.bench_path.as_deref().unwrap().to_str(), Some("out.json"));
        assert_eq!(c.open_conns, 2000);
        assert_eq!(c.token.as_deref(), Some("hunter2"));
        assert_eq!(c.core, stocator::gateway::GatewayMode::Threaded);
        assert!(c.scrape);
        // --ops switches to the deterministic fixed-budget mode.
        let c = stress_config(&args(&["stress", "--ops", "40"])).unwrap();
        assert_eq!(c.ops_per_client, Some(40));
        // Bad spellings are parse errors, not panics.
        assert!(stress_config(&args(&["stress", "--duration", "soon"])).is_err());
        assert!(stress_config(&args(&["stress", "--clients", "many"])).is_err());
        assert!(stress_config(&args(&["stress", "--core", "forked"])).is_err());
    }

    #[test]
    fn stress_chaos_and_backend_flags_are_wired_through() {
        // --chaos parses the spec; --chaos-seed defaults to --seed.
        let c = stress_config(&args(&[
            "stress", "--seed", "42", "--chaos", "kill-response@p=0.02,truncate@p=0.01",
        ]))
        .unwrap();
        assert!(c.chaos.is_active());
        assert_eq!(c.chaos.kill_response, 0.02);
        assert_eq!(c.chaos.truncate, 0.01);
        assert_eq!(c.chaos.seed, 42, "chaos seed defaults to the run seed");
        let c = stress_config(&args(&[
            "stress", "--chaos", "reset@p=0.5", "--chaos-seed", "9",
        ]))
        .unwrap();
        assert_eq!(c.chaos.seed, 9);
        // --backend selects the in-process store.
        let c = stress_config(&args(&["stress", "--backend", "mem"])).unwrap();
        assert_eq!(c.shards, 1);
        let c = stress_config(&args(&["stress", "--backend", "sharded:4"])).unwrap();
        assert_eq!(c.shards, 4);
        let c = stress_config(&args(&["stress", "--backend", "fs"])).unwrap();
        assert!(c.fs_root.is_some(), "bare fs pins a concrete temp root");
        let c = stress_config(&args(&["stress", "--backend", "fs:/tmp/stress-store"])).unwrap();
        assert_eq!(c.fs_root.as_deref(), Some(std::path::Path::new("/tmp/stress-store")));
        // Contradictions and bad specs are errors, not silent fallbacks.
        assert!(stress_config(&args(&["stress", "--chaos", "explode@p=0.5"])).is_err());
        assert!(stress_config(&args(&["stress", "--chaos", "reset@p=2"])).is_err());
        assert!(stress_config(&args(&[
            "stress", "--backend", "mem", "--target", "127.0.0.1:1",
        ]))
        .is_err());
        assert!(stress_config(&args(&["stress", "--backend", "http:127.0.0.1:1"])).is_err());
    }

    #[test]
    fn serve_config_layers_file_env_and_flags() {
        use stocator::gateway::GatewayMode;
        // Flag-free default: the reactor core, limiter off, chaos off.
        let cfg = serve_gateway_config(&args(&["serve"])).unwrap();
        assert_eq!(cfg.mode, GatewayMode::Reactor);
        assert_eq!(cfg.rate_limit, 0.0);
        assert!(!cfg.chaos.is_active());
        // --chaos/--chaos-seed flags layer onto the gateway config.
        let cfg = serve_gateway_config(&args(&[
            "serve", "--chaos", "kill-response@p=0.02", "--chaos-seed", "3",
        ]))
        .unwrap();
        assert_eq!(cfg.chaos.kill_response, 0.02);
        assert_eq!(cfg.chaos.seed, 3);
        assert!(serve_gateway_config(&args(&["serve", "--chaos", "frob@p=0.1"])).is_err());
        // Explicit flags win (env vars are absent in this test run for
        // these keys; the layering itself is pinned in gateway::config).
        let cfg = serve_gateway_config(&args(&[
            "serve",
            "--mode", "threaded",
            "--max-conns", "128",
            "--rate-limit", "250.5",
            "--burst", "16",
            "--auth-token", "sesame",
        ]))
        .unwrap();
        assert_eq!(cfg.mode, GatewayMode::Threaded);
        assert_eq!(cfg.max_conns, 128);
        assert_eq!(cfg.rate_limit, 250.5);
        assert_eq!(cfg.burst, 16);
        assert_eq!(cfg.auth_token.as_deref(), Some("sesame"));
        // Bad values are startup errors, not silent defaults.
        assert!(serve_gateway_config(&args(&["serve", "--mode", "forked"])).is_err());
        assert!(serve_gateway_config(&args(&["serve", "--max-conns", "0"])).is_err());
        assert!(serve_gateway_config(&args(&["serve", "--config", "/no/such/file.toml"]))
            .is_err());
        // A config file layers under the flags.
        let dir = std::env::temp_dir().join(format!("stocator-cli-cfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("gw.toml");
        std::fs::write(&path, "mode = \"threaded\"\nmax_conns = 64\n").unwrap();
        let cfg = serve_gateway_config(&args(&[
            "serve",
            "--config", path.to_str().unwrap(),
            "--max-conns", "256",
        ]))
        .unwrap();
        assert_eq!(cfg.mode, GatewayMode::Threaded, "file sets the core");
        assert_eq!(cfg.max_conns, 256, "flag overrides the file");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn scenario_and_workload_parsers_cover_cli_spellings() {
        assert_eq!(parse_scenario("stocator"), Some(Scenario::Stocator));
        assert_eq!(parse_scenario("s3a-cv2-fu"), Some(Scenario::S3aCv2Fu));
        assert_eq!(parse_workload("teragen"), Some(Workload::Teragen));
        assert_eq!(parse_workload("ro500"), Some(Workload::ReadOnly500));
        assert!(parse_workload("nope").is_none());
    }
}
