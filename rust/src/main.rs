//! `stocator-sim` — CLI for the Stocator reproduction.
//!
//! Subcommands:
//! * `trace table1|table3` — print the paper's operation traces.
//! * `table2` — the one-object REST breakdown vs the paper.
//! * `run --workload W --scenario S [--small] [--runs N]` — one cell.
//! * `sweep [--workloads a,b,...] [--runs N] [--small]` — Tables 5-8 and
//!   Figures 5-7 from one sweep, with the shape check.

use stocator::harness::tables::{render_table2, Sweep};
use stocator::harness::traces::{table1_trace, table3_trace};
use stocator::harness::{figures, run_cell, Scenario, Sizing, Workload};
use stocator::util::cli::Args;

fn parse_scenario(s: &str) -> Option<Scenario> {
    Scenario::ALL
        .iter()
        .copied()
        .find(|sc| sc.label().eq_ignore_ascii_case(s) || short(sc).eq_ignore_ascii_case(s))
}

fn short(s: &Scenario) -> &'static str {
    match s {
        Scenario::HadoopSwiftBase => "hs-base",
        Scenario::S3aBase => "s3a-base",
        Scenario::Stocator => "stocator",
        Scenario::HadoopSwiftCv2 => "hs-cv2",
        Scenario::S3aCv2 => "s3a-cv2",
        Scenario::S3aCv2Fu => "s3a-cv2-fu",
    }
}

fn parse_workload(s: &str) -> Option<Workload> {
    match s.to_ascii_lowercase().as_str() {
        "readonly" | "readonly50" | "ro50" => Some(Workload::ReadOnly50),
        "readonly500" | "ro500" => Some(Workload::ReadOnly500),
        "teragen" => Some(Workload::Teragen),
        "copy" => Some(Workload::Copy),
        "wordcount" => Some(Workload::Wordcount),
        "terasort" => Some(Workload::Terasort),
        "tpcds" | "tpc-ds" => Some(Workload::TpcDs),
        _ => None,
    }
}

const USAGE: &str = "\
stocator-sim — Stocator (Vernik et al. 2017) reproduction

USAGE:
  stocator-sim trace table1
  stocator-sim trace table3 [--attempts N] [--no-cleanup]
  stocator-sim table2
  stocator-sim run --workload W --scenario S [--small] [--runs N]
  stocator-sim sweep [--workloads w1,w2] [--runs N] [--small]

  scenarios: hs-base s3a-base stocator hs-cv2 s3a-cv2 s3a-cv2-fu
  workloads: ro50 ro500 teragen copy wordcount terasort tpcds
";

fn main() {
    let args = match Args::parse(std::env::args().skip(1), &["small", "paper", "no-cleanup"]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    let sizing = if args.flag("small") {
        Sizing::small()
    } else {
        Sizing::paper()
    };
    match args.subcommand.as_deref() {
        Some("trace") => match args.positionals.first().map(String::as_str) {
            Some("table1") => {
                println!("Table 1 — file operations for a one-task program on HDFS:");
                for (i, line) in table1_trace().iter().enumerate() {
                    println!("  {:>2}. {line}", i + 1);
                }
            }
            Some("table3") => {
                let attempts = args.opt_u64("attempts", 2).unwrap_or(2) as u32;
                let cleanup = !args.flag("no-cleanup");
                let (trace, names) = table3_trace(attempts, cleanup);
                println!(
                    "Table 3 — Stocator REST trace ({attempts} extra attempts of task 2, cleanup={cleanup}):"
                );
                for line in &trace {
                    println!("  {line}");
                }
                println!("final objects:");
                for n in names {
                    println!("  {n}");
                }
            }
            other => {
                eprintln!("unknown trace {other:?}\n{USAGE}");
                std::process::exit(2);
            }
        },
        Some("table2") => print!("{}", render_table2()),
        Some("run") => {
            let Some(w) = args.opt("workload").and_then(parse_workload) else {
                eprintln!("--workload required\n{USAGE}");
                std::process::exit(2);
            };
            let Some(s) = args.opt("scenario").and_then(parse_scenario) else {
                eprintln!("--scenario required\n{USAGE}");
                std::process::exit(2);
            };
            let runs = args.opt_u64("runs", 1).unwrap_or(1) as usize;
            let cell = run_cell(s, w, &sizing, runs);
            println!(
                "{} / {}: runtime {:.2}s ± {:.2}s over {} runs",
                s.label(),
                w.label(),
                cell.runtime_mean_s,
                cell.runtime_std_s,
                cell.runs
            );
            println!("ops: {}", cell.ops);
            println!("validation: {}", cell.validation);
            if !cell.valid {
                std::process::exit(1);
            }
        }
        Some("sweep") => {
            let runs = args.opt_u64("runs", 3).unwrap_or(3) as usize;
            let workloads: Vec<Workload> = match args.opt("workloads") {
                Some(list) => list
                    .split(',')
                    .map(|w| {
                        parse_workload(w).unwrap_or_else(|| {
                            eprintln!("unknown workload '{w}'");
                            std::process::exit(2);
                        })
                    })
                    .collect(),
                None => Workload::ALL.to_vec(),
            };
            let sweep = Sweep::run(&sizing, runs, &workloads);
            println!("{}", sweep.render_table5());
            println!("{}", sweep.render_table6());
            println!("{}", sweep.render_table7());
            println!("{}", sweep.render_table8());
            let micro: Vec<Workload> = workloads
                .iter()
                .copied()
                .filter(|w| Workload::MICRO.contains(w))
                .collect();
            if !micro.is_empty() {
                println!(
                    "{}",
                    figures::render_rest_figure(
                        &sweep,
                        &micro,
                        "Figure 5 — micro-benchmark REST calls"
                    )
                );
            }
            let macro_w: Vec<Workload> = workloads
                .iter()
                .copied()
                .filter(|w| Workload::MACRO.contains(w))
                .collect();
            if !macro_w.is_empty() {
                println!(
                    "{}",
                    figures::render_rest_figure(
                        &sweep,
                        &macro_w,
                        "Figure 6 — macro-benchmark REST calls"
                    )
                );
            }
            println!("{}", figures::render_fig7(&sweep));
            match sweep.check_shape() {
                Ok(()) => println!("shape check: OK (all DESIGN.md §6 assertions hold)"),
                Err(violations) => {
                    println!("shape check: {} violation(s)", violations.len());
                    for v in violations {
                        println!("  - {v}");
                    }
                    std::process::exit(1);
                }
            }
        }
        _ => print!("{USAGE}"),
    }
}
