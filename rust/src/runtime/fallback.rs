//! Pure-Rust kernel implementations, the exact mirror of
//! `python/compile/kernels/ref.py`. Used when artifacts are absent and as
//! the parity oracle for the XLA path.

use super::{BUCKETS, CHUNK, GROUPS, PARTS};

/// Knuth multiplicative hash constant — must match `hash_count.py`.
pub const HASH_MULT: u32 = 2654435761;

/// Bucket for a token id (the shared hash function).
#[inline]
pub fn bucket_of(token: i32) -> usize {
    ((token as u32).wrapping_mul(HASH_MULT) % BUCKETS as u32) as usize
}

/// The native backend (stateless).
pub struct Fallback;

impl Fallback {
    pub fn wordcount_chunk(&self, tokens: &[i32]) -> (Vec<i32>, i32) {
        assert_eq!(tokens.len(), CHUNK);
        let mut hist = vec![0i32; BUCKETS];
        let mut n = 0i32;
        for &t in tokens {
            hist[bucket_of(t)] += 1;
            if t != 0 {
                n += 1;
            }
        }
        // Padding (token 0) hashes to bucket 0; discount it, as the L2
        // model does.
        let pad = CHUNK as i32 - n;
        hist[bucket_of(0)] -= pad;
        (hist, n)
    }

    pub fn terasort_partition_chunk(&self, keys: &[i32], splitters: &[i32]) -> (Vec<i32>, Vec<i32>) {
        assert_eq!(keys.len(), CHUNK);
        assert_eq!(splitters.len(), PARTS - 1);
        let mut assign = Vec::with_capacity(CHUNK);
        let mut hist = vec![0i32; PARTS];
        for &k in keys {
            // splitters ascending: partition = #{s : k >= s}. The
            // partition_point gives the same value in O(log P).
            let p = splitters.partition_point(|&s| k >= s);
            assign.push(p as i32);
            hist[p] += 1;
        }
        (assign, hist)
    }

    pub fn readonly_chunk(&self, bytes: &[i32]) -> [i32; 2] {
        assert_eq!(bytes.len(), CHUNK);
        let mut newlines = 0;
        let mut nonzero = 0;
        for &b in bytes {
            if b == 10 {
                newlines += 1;
            }
            if b != 0 {
                nonzero += 1;
            }
        }
        [newlines, nonzero]
    }

    pub fn tpcds_agg_chunk(&self, keys: &[i32], vals: &[f32]) -> (Vec<f32>, Vec<i32>) {
        assert_eq!(keys.len(), CHUNK);
        assert_eq!(vals.len(), CHUNK);
        let mut sums = vec![0f32; GROUPS];
        let mut counts = vec![0i32; GROUPS];
        for (&k, &v) in keys.iter().zip(vals) {
            if (0..GROUPS as i32).contains(&k) {
                sums[k as usize] += v;
                counts[k as usize] += 1;
            }
        }
        (sums, counts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::pad_chunk;
    use crate::util::proptest::check;

    #[test]
    fn wordcount_mass_conservation() {
        check("wordcount mass", 50, |g| {
            let n = g.usize(0..CHUNK);
            let toks: Vec<i32> = (0..n).map(|_| g.rng().range(1, 1 << 20) as i32).collect();
            let padded = pad_chunk(&toks, 0);
            let (hist, count) = Fallback.wordcount_chunk(&padded);
            assert_eq!(count as usize, n);
            assert_eq!(hist.iter().sum::<i32>() as usize, n);
            assert!(hist.iter().all(|&h| h >= 0));
        });
    }

    #[test]
    fn partition_assignment_invariants() {
        check("partition invariants", 50, |g| {
            let mut splitters: Vec<i32> =
                (0..PARTS - 1).map(|_| g.rng().range(0, 1 << 20) as i32).collect();
            splitters.sort();
            let keys: Vec<i32> = (0..CHUNK).map(|_| g.rng().range(0, 1 << 20) as i32).collect();
            let (assign, hist) = Fallback.terasort_partition_chunk(&keys, &splitters);
            assert_eq!(hist.iter().sum::<i32>() as usize, CHUNK);
            for (i, (&k, &a)) in keys.iter().zip(&assign).enumerate() {
                assert!((0..PARTS as i32).contains(&a), "row {i}");
                // Keys below the first splitter go to 0; above the last to
                // PARTS-1.
                if k < splitters[0] {
                    assert_eq!(a, 0);
                }
                if k >= splitters[PARTS - 2] {
                    assert_eq!(a, PARTS as i32 - 1);
                }
            }
        });
    }

    #[test]
    fn partition_respects_splitter_boundaries() {
        let mut splitters: Vec<i32> = (1..PARTS as i32).map(|i| i * 100).collect();
        splitters.sort();
        let keys = pad_chunk(&[0, 99, 100, 101, 5000], i32::MAX);
        let (assign, _) = Fallback.terasort_partition_chunk(&keys, &splitters);
        assert_eq!(assign[0], 0);
        assert_eq!(assign[1], 0);
        assert_eq!(assign[2], 1);
        assert_eq!(assign[3], 1);
        assert_eq!(assign[4], 50);
        assert_eq!(assign[5], PARTS as i32 - 1); // padding key = MAX
    }

    #[test]
    fn readonly_counts() {
        let mut data = vec![0i32; CHUNK];
        data[0] = 10;
        data[1] = 65;
        data[2] = 10;
        data[3] = 66;
        let [nl, nz] = Fallback.readonly_chunk(&data);
        assert_eq!(nl, 2);
        assert_eq!(nz, 4);
    }

    #[test]
    fn group_agg_matches_scalar_groupby() {
        check("group agg", 30, |g| {
            let keys: Vec<i32> = (0..CHUNK)
                .map(|_| g.rng().range(0, GROUPS + 10) as i32 - 5)
                .collect();
            let vals: Vec<f32> = (0..CHUNK).map(|_| g.rng().next_f64() as f32).collect();
            let (sums, counts) = Fallback.tpcds_agg_chunk(&keys, &vals);
            let total_in: usize = keys
                .iter()
                .filter(|&&k| (0..GROUPS as i32).contains(&k))
                .count();
            assert_eq!(counts.iter().sum::<i32>() as usize, total_in);
            let sum_all: f32 = sums.iter().sum();
            let expect: f32 = keys
                .iter()
                .zip(&vals)
                .filter(|(&k, _)| (0..GROUPS as i32).contains(&k))
                .map(|(_, &v)| v)
                .sum();
            assert!((sum_all - expect).abs() < 1e-2, "{sum_all} vs {expect}");
        });
    }
}
