//! The PJRT engine: compile `artifacts/*.hlo.txt` once, execute many times.
//!
//! Interchange is HLO *text* (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `PjRtClient::compile`. Each model was
//! lowered with `return_tuple=True`, so results unwrap with `to_tuple`.

use super::{BUCKETS, CHUNK, GROUPS, PARTS};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;

/// Compiled executables for every model in the manifest.
pub struct Engine {
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    pub platform: String,
}

impl Engine {
    /// Load and compile all artifacts from `dir` (produced by
    /// `make artifacts`). Verifies the manifest constants match this
    /// crate's chunk geometry.
    pub fn load(dir: &str) -> Result<Engine> {
        let manifest_path = format!("{dir}/manifest.txt");
        let manifest = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path}"))?;
        let mut model_names = Vec::new();
        for line in manifest.lines() {
            let mut cols = line.split('\t');
            match cols.next() {
                Some("constants") => {
                    for col in cols {
                        let Some((k, v)) = col.split_once('=') else { continue };
                        let v: usize = v.parse().unwrap_or(0);
                        let expect = match k {
                            "CHUNK" => CHUNK,
                            "BUCKETS" => BUCKETS,
                            "PARTS" => PARTS,
                            "GROUPS" => GROUPS,
                            _ => continue,
                        };
                        if v != expect {
                            bail!("manifest {k}={v} but crate expects {expect} — rebuild artifacts");
                        }
                    }
                }
                Some("model") => {
                    if let Some(name) = cols.next() {
                        model_names.push(name.to_string());
                    }
                }
                _ => {}
            }
        }
        if model_names.is_empty() {
            bail!("manifest {manifest_path} lists no models");
        }
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let platform = client.platform_name();
        let mut exes = HashMap::new();
        for name in model_names {
            let path = format!("{dir}/{name}.hlo.txt");
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing {path}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            exes.insert(name, exe);
        }
        Ok(Engine { exes, platform })
    }

    pub fn models(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.exes.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    fn run(&self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .exes
            .get(name)
            .with_context(|| format!("model '{name}' not loaded"))?;
        let result = exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("executing {name}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching {name} result"))?;
        // Lowered with return_tuple=True: the root is always a tuple.
        Ok(lit.to_tuple()?)
    }

    pub fn wordcount_chunk(&self, tokens: &[i32]) -> Result<(Vec<i32>, i32)> {
        assert_eq!(tokens.len(), CHUNK);
        let arg = xla::Literal::vec1(tokens);
        let out = self.run("wordcount_chunk", &[arg])?;
        let hist = out[0].to_vec::<i32>()?;
        let n = out[1].to_vec::<i32>()?[0];
        Ok((hist, n))
    }

    pub fn terasort_partition_chunk(
        &self,
        keys: &[i32],
        splitters: &[i32],
    ) -> Result<(Vec<i32>, Vec<i32>)> {
        assert_eq!(keys.len(), CHUNK);
        assert_eq!(splitters.len(), PARTS - 1);
        let out = self.run(
            "terasort_partition_chunk",
            &[xla::Literal::vec1(keys), xla::Literal::vec1(splitters)],
        )?;
        Ok((out[0].to_vec::<i32>()?, out[1].to_vec::<i32>()?))
    }

    pub fn readonly_chunk(&self, bytes: &[i32]) -> Result<[i32; 2]> {
        assert_eq!(bytes.len(), CHUNK);
        let out = self.run("readonly_chunk", &[xla::Literal::vec1(bytes)])?;
        let v = out[0].to_vec::<i32>()?;
        Ok([v[0], v[1]])
    }

    pub fn tpcds_agg_chunk(&self, keys: &[i32], vals: &[f32]) -> Result<(Vec<f32>, Vec<i32>)> {
        assert_eq!(keys.len(), CHUNK);
        assert_eq!(vals.len(), CHUNK);
        let out = self.run(
            "tpcds_agg_chunk",
            &[xla::Literal::vec1(keys), xla::Literal::vec1(vals)],
        )?;
        Ok((out[0].to_vec::<f32>()?, out[1].to_vec::<i32>()?))
    }
}

// Tests for the XLA path live in `rust/tests/test_runtime_parity.rs` (they
// need `make artifacts` to have run; they skip gracefully otherwise).
