//! The XLA/PJRT runtime: loads the AOT-compiled JAX/Pallas kernels
//! (`artifacts/*.hlo.txt`, produced by `make artifacts`) and executes them
//! from the Spark-simulator task bodies. Python never runs here — the HLO
//! text is compiled once by the PJRT CPU client at startup (see
//! DESIGN.md three-layer architecture and /opt/xla-example/load_hlo).
//!
//! [`fallback`] provides pure-Rust implementations of the same functions
//! (mirroring `python/compile/kernels/ref.py`) so the crate's tests run
//! before artifacts exist; [`Kernels`] dispatches between the two, and the
//! parity tests in `rust/tests/` assert they agree when artifacts are
//! present.

pub mod engine;
pub mod fallback;

pub use engine::Engine;
pub use fallback::Fallback;

/// Chunk geometry — MUST match `python/compile/kernels/__init__.py`; the
/// engine cross-checks against `artifacts/manifest.txt` at load time.
pub const CHUNK: usize = 4096;
pub const BUCKETS: usize = 512;
pub const PARTS: usize = 64;
pub const GROUPS: usize = 64;

/// Kernel backend: AOT-compiled XLA executables, or the native fallback.
pub enum Kernels {
    Xla(Engine),
    Native(Fallback),
}

impl Kernels {
    /// Load the XLA engine from `dir`, or fall back to the native
    /// implementations if artifacts are absent/unloadable.
    pub fn load_or_fallback(dir: &str) -> Kernels {
        match Engine::load(dir) {
            Ok(e) => Kernels::Xla(e),
            Err(err) => {
                eprintln!(
                    "[runtime] artifacts not loadable from '{dir}' ({err}); \
                     using native fallback kernels"
                );
                Kernels::Native(Fallback)
            }
        }
    }

    pub fn backend_name(&self) -> &'static str {
        match self {
            Kernels::Xla(_) => "xla-pjrt",
            Kernels::Native(_) => "native-fallback",
        }
    }

    /// Wordcount: token-id chunk (0 = padding) -> (bucket histogram,
    /// token count).
    pub fn wordcount_chunk(&self, tokens: &[i32]) -> anyhow::Result<(Vec<i32>, i32)> {
        match self {
            Kernels::Xla(e) => e.wordcount_chunk(tokens),
            Kernels::Native(f) => Ok(f.wordcount_chunk(tokens)),
        }
    }

    /// Terasort stage 1: (keys, splitters) -> (partition assignment,
    /// partition histogram).
    pub fn terasort_partition_chunk(
        &self,
        keys: &[i32],
        splitters: &[i32],
    ) -> anyhow::Result<(Vec<i32>, Vec<i32>)> {
        match self {
            Kernels::Xla(e) => e.terasort_partition_chunk(keys, splitters),
            Kernels::Native(f) => Ok(f.terasort_partition_chunk(keys, splitters)),
        }
    }

    /// Read-only: byte chunk -> [newline count, nonzero byte count].
    pub fn readonly_chunk(&self, bytes: &[i32]) -> anyhow::Result<[i32; 2]> {
        match self {
            Kernels::Xla(e) => e.readonly_chunk(bytes),
            Kernels::Native(f) => Ok(f.readonly_chunk(bytes)),
        }
    }

    /// TPC-DS group-by: (group keys with -1 = filtered, values) ->
    /// (sums, counts).
    pub fn tpcds_agg_chunk(
        &self,
        keys: &[i32],
        vals: &[f32],
    ) -> anyhow::Result<(Vec<f32>, Vec<i32>)> {
        match self {
            Kernels::Xla(e) => e.tpcds_agg_chunk(keys, vals),
            Kernels::Native(f) => Ok(f.tpcds_agg_chunk(keys, vals)),
        }
    }
}

/// Pad (or validate) a slice to exactly `CHUNK` elements with `pad`.
pub fn pad_chunk<T: Copy>(xs: &[T], pad: T) -> Vec<T> {
    assert!(xs.len() <= CHUNK, "chunk overflow: {} > {CHUNK}", xs.len());
    let mut v = Vec::with_capacity(CHUNK);
    v.extend_from_slice(xs);
    v.resize(CHUNK, pad);
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_chunk_pads_and_validates() {
        let v = pad_chunk(&[1i32, 2, 3], 0);
        assert_eq!(v.len(), CHUNK);
        assert_eq!(&v[..3], &[1, 2, 3]);
        assert!(v[3..].iter().all(|&x| x == 0));
    }

    #[test]
    #[should_panic(expected = "chunk overflow")]
    fn pad_chunk_rejects_oversize() {
        pad_chunk(&vec![0i32; CHUNK + 1], 0);
    }

    #[test]
    fn fallback_backend_always_available() {
        let k = Kernels::Native(Fallback);
        assert_eq!(k.backend_name(), "native-fallback");
        let toks = pad_chunk(&[1i32, 2, 3], 0);
        let (hist, n) = k.wordcount_chunk(&toks).unwrap();
        assert_eq!(n, 3);
        assert_eq!(hist.iter().sum::<i32>(), 3);
    }
}
