//! Star-schema synthesis: a `store_sales`-like fact table plus small
//! dimension tables, mirroring the slice of TPC-DS the 8 queries touch.

use crate::columnar::{ColType, ColumnData, RowGroup, Schema};
use crate::util::rng::Pcg32;

/// Fact-table columns (a working subset of TPC-DS `store_sales`).
pub const FACT_COLUMNS: [(&str, ColType); 7] = [
    ("ss_sold_date_sk", ColType::Int32),
    ("ss_store_sk", ColType::Int32),
    ("ss_item_sk", ColType::Int32),
    ("ss_hdemo_sk", ColType::Int32),
    ("ss_ticket_number", ColType::Int32),
    ("ss_quantity", ColType::Int32),
    ("ss_net_profit", ColType::Float32),
];

/// Dimension row: date.
#[derive(Debug, Clone, Copy)]
pub struct DateDim {
    pub d_date_sk: i32,
    pub d_year: i32,
    pub d_dow: i32,
    pub d_moy: i32,
}

/// Dimension row: store.
#[derive(Debug, Clone)]
pub struct StoreDim {
    pub s_store_sk: i32,
    pub s_county: u32,
    pub s_city: u32,
}

/// Dimension row: household demographics.
#[derive(Debug, Clone, Copy)]
pub struct HdemoDim {
    pub hd_demo_sk: i32,
    pub hd_dep_count: i32,
    pub hd_vehicle_count: i32,
}

/// The synthesized schema: dimensions in memory, fact rows generated per
/// shard on demand (deterministic in (seed, shard)).
pub struct StarSchema {
    pub seed: u64,
    pub dates: Vec<DateDim>,
    pub stores: Vec<StoreDim>,
    pub hdemos: Vec<HdemoDim>,
    pub rows_per_shard: usize,
    pub shards: usize,
}

pub const N_DATES: usize = 365 * 3;
pub const N_STORES: usize = 24;
pub const N_HDEMO: usize = 72;
pub const N_ITEMS: i32 = 18_000;

impl StarSchema {
    pub fn new(seed: u64, shards: usize, rows_per_shard: usize) -> StarSchema {
        let dates = (0..N_DATES)
            .map(|i| DateDim {
                d_date_sk: 2_450_000 + i as i32,
                d_year: 1998 + (i / 365) as i32,
                d_dow: (i % 7) as i32,
                d_moy: ((i / 30) % 12) as i32 + 1,
            })
            .collect();
        let mut rng = Pcg32::new(seed ^ 0xD1A3);
        let stores = (0..N_STORES)
            .map(|i| StoreDim {
                s_store_sk: i as i32 + 1,
                s_county: rng.next_below(8),
                s_city: rng.next_below(12),
            })
            .collect();
        let hdemos = (0..N_HDEMO)
            .map(|i| HdemoDim {
                hd_demo_sk: i as i32 + 1,
                hd_dep_count: (i % 10) as i32,
                hd_vehicle_count: (i % 5) as i32,
            })
            .collect();
        StarSchema {
            seed,
            dates,
            stores,
            hdemos,
            rows_per_shard,
            shards,
        }
    }

    pub fn fact_schema() -> Schema {
        Schema::new(&FACT_COLUMNS)
    }

    /// Generate one fact shard (deterministic).
    pub fn fact_shard(&self, shard: usize) -> RowGroup {
        assert!(shard < self.shards);
        let mut rng = Pcg32::with_stream(self.seed, shard as u64 + 17);
        let n = self.rows_per_shard;
        let mut date = Vec::with_capacity(n);
        let mut store = Vec::with_capacity(n);
        let mut item = Vec::with_capacity(n);
        let mut hdemo = Vec::with_capacity(n);
        let mut ticket = Vec::with_capacity(n);
        let mut qty = Vec::with_capacity(n);
        let mut profit = Vec::with_capacity(n);
        for i in 0..n {
            date.push(self.dates[rng.range(0, self.dates.len())].d_date_sk);
            store.push(self.stores[rng.range(0, self.stores.len())].s_store_sk);
            item.push(rng.range(1, N_ITEMS as usize) as i32);
            hdemo.push(self.hdemos[rng.range(0, self.hdemos.len())].hd_demo_sk);
            ticket.push((shard * n + i) as i32 / 4); // ~4 line items/ticket
            qty.push(rng.range(1, 100) as i32);
            profit.push((rng.next_f64() * 200.0 - 40.0) as f32);
        }
        RowGroup::new(
            Self::fact_schema(),
            vec![
                ColumnData::I32(date),
                ColumnData::I32(store),
                ColumnData::I32(item),
                ColumnData::I32(hdemo),
                ColumnData::I32(ticket),
                ColumnData::I32(qty),
                ColumnData::F32(profit),
            ],
        )
    }

    pub fn total_rows(&self) -> usize {
        self.shards * self.rows_per_shard
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_shards() {
        let s1 = StarSchema::new(9, 4, 128);
        let s2 = StarSchema::new(9, 4, 128);
        assert_eq!(s1.fact_shard(2), s2.fact_shard(2));
        assert_ne!(s1.fact_shard(0), s1.fact_shard(1));
    }

    #[test]
    fn foreign_keys_resolve() {
        let s = StarSchema::new(3, 2, 256);
        let shard = s.fact_shard(0);
        let dates: std::collections::HashSet<i32> =
            s.dates.iter().map(|d| d.d_date_sk).collect();
        for &sk in shard.column("ss_sold_date_sk").unwrap().as_i32() {
            assert!(dates.contains(&sk));
        }
        for &sk in shard.column("ss_store_sk").unwrap().as_i32() {
            assert!((1..=N_STORES as i32).contains(&sk));
        }
        for &sk in shard.column("ss_hdemo_sk").unwrap().as_i32() {
            assert!((1..=N_HDEMO as i32).contains(&sk));
        }
    }

    #[test]
    fn shard_roundtrips_through_parquetish() {
        let s = StarSchema::new(5, 1, 64);
        let rg = s.fact_shard(0);
        let back = RowGroup::decode(&rg.encode()).unwrap();
        assert_eq!(back, rg);
        assert_eq!(back.rows, 64);
    }
}
