//! The TPC-DS-subset mini query engine (paper §4.3: queries q34, q43,
//! q46, q59, q68, q73, q79 and ss_max over a 50 GB star schema stored as
//! Parquet).
//!
//! We implement the closest synthetic equivalent (DESIGN.md substitution
//! table): [`datagen`] synthesizes a star schema — a `store_sales` fact
//! table sharded into parquetish row groups on the object store, plus
//! small in-memory dimensions — and [`queries`] implements simplified
//! scan→filter→join(dim)→group-by plans for each of the eight queries,
//! with the grouped aggregation running on the `tpcds_agg_chunk` XLA
//! kernel. What the paper's evaluation measures — the *read-path REST op
//! pattern* of scanning a columnar dataset — is preserved exactly.

pub mod datagen;
pub mod queries;

pub use datagen::{StarSchema, FACT_COLUMNS};
pub use queries::{QueryResult, QUERIES};
