//! Simplified implementations of the paper's 8-query TPC-DS subset
//! (q34, q43, q46, q59, q68, q73, q79, ss_max).
//!
//! Each query is expressed as a scan over `store_sales` shards with a
//! dimension-join filter and a grouped aggregate. The per-chunk grouped
//! aggregation `(keys, vals) -> (sums, counts)` runs on the
//! `tpcds_agg_chunk` kernel (L1); this module derives the `(key, val)`
//! pairs per row — the "plan" — and merges per-chunk partials.
//!
//! These are *simplified* plans (single fact table, pre-broadcast
//! dimensions, one aggregate per query); what the paper's evaluation
//! measures — a read-only columnar scan workload against the object store
//! — is preserved (DESIGN.md substitution table).

use super::datagen::StarSchema;
use crate::columnar::RowGroup;
use crate::runtime::GROUPS;
use std::collections::HashMap;

/// The 8 queries from the paper's Impala-subset selection.
pub const QUERIES: [&str; 8] = [
    "q34", "q43", "q46", "q59", "q68", "q73", "q79", "ss_max",
];

/// Result of one query: per-group sums/counts, or a scalar for ss_max.
#[derive(Debug, Clone, PartialEq)]
pub struct QueryResult {
    pub name: String,
    /// group id -> (sum, count); empty for scalar queries.
    pub groups: Vec<(usize, f64, i64)>,
    /// ss_max: the max of each numeric column.
    pub scalar_max: Option<(i32, f32)>,
    pub rows_scanned: u64,
}

impl QueryResult {
    pub fn empty(name: &str) -> QueryResult {
        QueryResult {
            name: name.to_string(),
            groups: Vec::new(),
            scalar_max: None,
            rows_scanned: 0,
        }
    }
}

/// Pre-joined dimension lookup tables, broadcast to all tasks (Spark's
/// broadcast join of small dimensions).
pub struct Broadcast {
    /// date_sk -> (year, dow, moy)
    pub dates: HashMap<i32, (i32, i32, i32)>,
    /// store_sk -> (county, city)
    pub stores: HashMap<i32, (u32, u32)>,
    /// hdemo_sk -> (dep_count, vehicle_count)
    pub hdemos: HashMap<i32, (i32, i32)>,
}

impl Broadcast {
    pub fn from_schema(s: &StarSchema) -> Broadcast {
        Broadcast {
            dates: s
                .dates
                .iter()
                .map(|d| (d.d_date_sk, (d.d_year, d.d_dow, d.d_moy)))
                .collect(),
            stores: s
                .stores
                .iter()
                .map(|st| (st.s_store_sk, (st.s_county, st.s_city)))
                .collect(),
            hdemos: s
                .hdemos
                .iter()
                .map(|h| (h.hd_demo_sk, (h.hd_dep_count, h.hd_vehicle_count)))
                .collect(),
        }
    }
}

/// Derive the per-row (group key, value) pairs for `query` over a decoded
/// shard. Key -1 = row filtered out. Keys are always in [0, GROUPS).
pub fn plan_rows(query: &str, rg: &RowGroup, bc: &Broadcast) -> (Vec<i32>, Vec<f32>) {
    let date_sk = rg.column("ss_sold_date_sk").unwrap().as_i32();
    let store_sk = rg.column("ss_store_sk").unwrap().as_i32();
    let hdemo_sk = rg.column("ss_hdemo_sk").unwrap().as_i32();
    let qty = rg.column("ss_quantity").unwrap().as_i32();
    let profit = rg.column("ss_net_profit").unwrap().as_f32();
    let n = rg.rows;
    let mut keys = Vec::with_capacity(n);
    let mut vals = Vec::with_capacity(n);
    for i in 0..n {
        let (year, dow, moy) = bc.dates[&date_sk[i]];
        let (county, city) = bc.stores[&store_sk[i]];
        let (dep, veh) = bc.hdemos[&hdemo_sk[i]];
        let (key, val): (i32, f32) = match query {
            // q34/q73: ticket counts by household dependent count, for
            // weekend-ish shopping (simplified date predicate).
            "q34" => {
                if dow == 0 || dow == 6 {
                    (dep.clamp(0, GROUPS as i32 - 1), 1.0)
                } else {
                    (-1, 0.0)
                }
            }
            "q73" => {
                if (1..=4).contains(&dep) && year >= 1999 {
                    (dep, 1.0)
                } else {
                    (-1, 0.0)
                }
            }
            // q43: store sales by store and day-of-week, one year.
            "q43" => {
                if year == 1999 {
                    ((dow * 8 + (store_sk[i] - 1) % 8).clamp(0, GROUPS as i32 - 1), profit[i])
                } else {
                    (-1, 0.0)
                }
            }
            // q46/q68: profit by city for weekend tickets.
            "q46" => {
                if dow == 5 || dow == 6 {
                    (city as i32, profit[i])
                } else {
                    (-1, 0.0)
                }
            }
            "q68" => {
                if dep == 4 || veh == 3 {
                    (city as i32, profit[i])
                } else {
                    (-1, 0.0)
                }
            }
            // q59: weekly sales by store/dow across months.
            "q59" => {
                if moy <= 6 {
                    ((dow * 8 + (store_sk[i] - 1) % 8).clamp(0, GROUPS as i32 - 1), profit[i])
                } else {
                    (-1, 0.0)
                }
            }
            // q79: per-store profit for large-vehicle households.
            "q79" => {
                if veh >= 2 {
                    ((store_sk[i] - 1).clamp(0, GROUPS as i32 - 1), profit[i])
                } else {
                    (-1, 0.0)
                }
            }
            // ss_max handled by the scalar path; county silences unused.
            "ss_max" => (-1, county as f32 * 0.0),
            other => panic!("unknown query {other}"),
        };
        keys.push(key);
        vals.push(val + qty[i] as f32 * 0.0);
    }
    (keys, vals)
}

/// The ss_max scalar path: max of the date key and the profit column.
pub fn scalar_max(rg: &RowGroup) -> (i32, f32) {
    let date_sk = rg.column("ss_sold_date_sk").unwrap().as_i32();
    let profit = rg.column("ss_net_profit").unwrap().as_f32();
    let max_sk = date_sk.iter().copied().max().unwrap_or(i32::MIN);
    let max_profit = profit.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    (max_sk, max_profit)
}

/// Merge per-chunk kernel partials `(sums, counts)` into a running result.
pub fn merge_partials(acc: &mut QueryResult, sums: &[f32], counts: &[i32]) {
    if acc.groups.is_empty() {
        acc.groups = (0..GROUPS).map(|g| (g, 0.0, 0)).collect();
    }
    for g in 0..GROUPS {
        acc.groups[g].1 += sums[g] as f64;
        acc.groups[g].2 += counts[g] as i64;
    }
}

/// Merge ss_max partials.
pub fn merge_scalar(acc: &mut QueryResult, part: (i32, f32)) {
    let cur = acc.scalar_max.unwrap_or((i32::MIN, f32::NEG_INFINITY));
    acc.scalar_max = Some((cur.0.max(part.0), cur.1.max(part.1)));
}

/// Drop empty groups at the end (presentation form).
pub fn finalize(mut r: QueryResult) -> QueryResult {
    r.groups.retain(|&(_, _, c)| c > 0);
    r
}

/// Reference evaluation of a query over in-memory shards (no kernels, no
/// storage) — the oracle the workload validates against.
pub fn reference_eval(query: &str, schema: &StarSchema) -> QueryResult {
    let bc = Broadcast::from_schema(schema);
    let mut acc = QueryResult::empty(query);
    for shard in 0..schema.shards {
        let rg = schema.fact_shard(shard);
        acc.rows_scanned += rg.rows as u64;
        if query == "ss_max" {
            merge_scalar(&mut acc, scalar_max(&rg));
            continue;
        }
        let (keys, vals) = plan_rows(query, &rg, &bc);
        if acc.groups.is_empty() {
            acc.groups = (0..GROUPS).map(|g| (g, 0.0, 0)).collect();
        }
        for (k, v) in keys.iter().zip(&vals) {
            if (0..GROUPS as i32).contains(k) {
                acc.groups[*k as usize].1 += *v as f64;
                acc.groups[*k as usize].2 += 1;
            }
        }
    }
    finalize(acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{fallback::Fallback, pad_chunk, CHUNK};

    fn schema() -> StarSchema {
        StarSchema::new(77, 3, 2 * CHUNK)
    }

    #[test]
    fn every_query_produces_output() {
        let s = schema();
        for q in QUERIES {
            let r = reference_eval(q, &s);
            assert_eq!(r.rows_scanned, s.total_rows() as u64, "{q}");
            if q == "ss_max" {
                let (sk, p) = r.scalar_max.unwrap();
                assert!(sk >= 2_450_000);
                assert!(p > 0.0);
            } else {
                assert!(!r.groups.is_empty(), "{q} returned no groups");
                let total: i64 = r.groups.iter().map(|g| g.2).sum();
                assert!(total > 0, "{q} matched no rows");
                assert!(
                    total < s.total_rows() as i64,
                    "{q} filter selected everything"
                );
            }
        }
    }

    #[test]
    fn kernel_path_matches_reference() {
        // Chunked kernel aggregation == direct reference evaluation.
        let s = schema();
        let bc = Broadcast::from_schema(&s);
        for q in ["q34", "q43", "q79"] {
            let mut acc = QueryResult::empty(q);
            for shard in 0..s.shards {
                let rg = s.fact_shard(shard);
                acc.rows_scanned += rg.rows as u64;
                let (keys, vals) = plan_rows(q, &rg, &bc);
                for (kc, vc) in keys.chunks(CHUNK).zip(vals.chunks(CHUNK)) {
                    let kp = pad_chunk(kc, -1);
                    let vp = pad_chunk(vc, 0.0);
                    let (sums, counts) = Fallback.tpcds_agg_chunk(&kp, &vp);
                    merge_partials(&mut acc, &sums, &counts);
                }
            }
            let kernel_r = finalize(acc);
            let ref_r = reference_eval(q, &s);
            assert_eq!(kernel_r.groups.len(), ref_r.groups.len(), "{q}");
            for (a, b) in kernel_r.groups.iter().zip(&ref_r.groups) {
                assert_eq!(a.0, b.0);
                assert_eq!(a.2, b.2, "{q} group {} count", a.0);
                assert!((a.1 - b.1).abs() < 1.0, "{q} group {} sum {} vs {}", a.0, a.1, b.1);
            }
        }
    }

    #[test]
    fn queries_differ_from_each_other() {
        let s = schema();
        let r34 = reference_eval("q34", &s);
        let r73 = reference_eval("q73", &s);
        assert_ne!(r34.groups, r73.groups);
    }
}
