//! File status records, as returned by `FileSystem::get_file_status` and
//! `list_status` — what HMRCC's committers use to decide what to rename.

use super::path::Path;
use crate::simclock::SimInstant;

/// Hadoop `FileStatus`: path + kind + length + mtime.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FileStatus {
    pub path: Path,
    pub is_dir: bool,
    pub len: u64,
    pub modified_at: SimInstant,
}

impl FileStatus {
    pub fn file(path: Path, len: u64, modified_at: SimInstant) -> Self {
        Self {
            path,
            is_dir: false,
            len,
            modified_at,
        }
    }

    pub fn dir(path: Path, modified_at: SimInstant) -> Self {
        Self {
            path,
            is_dir: true,
            len: 0,
            modified_at,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors() {
        let p = Path::parse("h://c/f").unwrap();
        let f = FileStatus::file(p.clone(), 10, SimInstant(3));
        assert!(!f.is_dir);
        assert_eq!(f.len, 10);
        let d = FileStatus::dir(p, SimInstant(3));
        assert!(d.is_dir);
        assert_eq!(d.len, 0);
    }
}
