//! An in-memory HDFS-like hierarchical filesystem.
//!
//! Used for (a) regenerating the paper's Table 1 — the file-system
//! operation sequence Spark executes for a one-task program on HDFS — and
//! (b) the "copy input to HDFS, compute, copy back" alternative the paper's
//! §2.2.2 mentions, which we keep as an ablation baseline.
//!
//! Unlike an object store, HDFS has *real* directories and an atomic,
//! metadata-only rename — which is exactly why the HMRCC commit protocol is
//! cheap on HDFS and ruinous on object stores.

use super::interface::{FileSystem, FsError, FsInputStream, FsOutputStream, OpCtx};
use super::path::Path;
use super::readahead::ReadaheadStream;
use super::status::FileStatus;
use crate::objectstore::faults::{FaultInjector, FaultOp, FaultSpec, RetryPolicy};
use crate::simclock::{SimDuration, SimInstant};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

#[derive(Debug, Clone)]
enum Node {
    Dir,
    File { data: Arc<Vec<u8>>, mtime: SimInstant },
}

/// Virtual-time costs of HDFS operations: metadata ops hit the NameNode
/// (sub-millisecond), data ops stream at disk bandwidth.
#[derive(Debug, Clone)]
pub struct HdfsLatency {
    pub meta_us: u64,
    pub disk_bw: u64,
    pub data_scale: u64,
}

impl Default for HdfsLatency {
    fn default() -> Self {
        Self {
            meta_us: 500,
            disk_bw: 400_000_000, // 3 replicas over 10 Gbps, bottlenecked on SATA
            data_scale: 1,
        }
    }
}

impl HdfsLatency {
    fn data_time(&self, bytes: u64) -> SimDuration {
        if self.disk_bw == u64::MAX {
            return SimDuration::ZERO;
        }
        SimDuration::from_micros(
            bytes.saturating_mul(self.data_scale).saturating_mul(1_000_000) / self.disk_bw,
        )
    }
    fn meta_time(&self) -> SimDuration {
        SimDuration::from_micros(self.meta_us)
    }
}

/// The filesystem. Keys are `container/key` strings; the root of every
/// container always exists.
pub struct Hdfs {
    nodes: Mutex<BTreeMap<String, Node>>,
    latency: HdfsLatency,
    /// Read prefetch window in simulated bytes; 0 = every read streams
    /// its own slice from the DataNodes (the pre-readahead behaviour).
    readahead: u64,
    /// Transient-fault plane: injected pipeline-write failures (the
    /// HDFS analogue of `StoreConfig::faults` — a `put` rule matched
    /// against the file's key fails the write pipeline at close).
    faults: FaultInjector,
    /// How many times a failed pipeline write is re-driven.
    retry: RetryPolicy,
}

impl Hdfs {
    pub fn new() -> Arc<Self> {
        Self::with_latency(HdfsLatency::default())
    }

    pub fn with_latency(latency: HdfsLatency) -> Arc<Self> {
        Self::with_config(latency, 0)
    }

    /// Build with an explicit readahead window (the HDFS analogue of
    /// `StoreConfig::readahead`; the real HDFS client's
    /// `dfs.datanode.readahead.bytes`).
    pub fn with_config(latency: HdfsLatency, readahead: u64) -> Arc<Self> {
        Self::with_faults(latency, readahead, &FaultSpec::none(), RetryPolicy::none())
    }

    /// Build with the full transient-fault plane: `faults` schedules
    /// pipeline-write failures, `retry` bounds the re-drives.
    ///
    /// HDFS has no REST surface, so the spec's *trigger* grammar applies
    /// but the class semantics collapse: every fired rule — including
    /// `!429` — is a pipeline failure (full data-time re-pay, exponential
    /// backoff), and probabilistic rules draw from a fixed seed (HDFS is
    /// the latency baseline; it takes no `--seed`).
    pub fn with_faults(
        latency: HdfsLatency,
        readahead: u64,
        faults: &FaultSpec,
        retry: RetryPolicy,
    ) -> Arc<Self> {
        Arc::new(Self {
            nodes: Mutex::new(BTreeMap::new()),
            latency,
            readahead,
            faults: FaultInjector::new(faults),
            retry,
        })
    }

    fn full_key(path: &Path) -> String {
        if path.key.is_empty() {
            path.container.clone()
        } else {
            format!("{}/{}", path.container, path.key)
        }
    }

    /// Validate a file target and implicitly create parent directories
    /// (Hadoop `create()` semantics), under the caller-held node-table
    /// lock. Shared by `create()` (conflicts surface before any byte is
    /// written) and the stream's `close()` (the tree may have changed
    /// while the stream was open — re-establishing the invariants in the
    /// same lock as the insert keeps file+parents mutations as atomic as
    /// the old whole-buffer create).
    fn validate_and_make_parents(
        nodes: &mut BTreeMap<String, Node>,
        path: &Path,
        overwrite: bool,
    ) -> Result<(), FsError> {
        let key = Self::full_key(path);
        match nodes.get(&key) {
            Some(Node::Dir) => return Err(FsError::IsADirectory(key)),
            Some(Node::File { .. }) if !overwrite => {
                return Err(FsError::AlreadyExists(key));
            }
            _ => {}
        }
        if let Some(parent) = path.parent() {
            let mut cur = path.container.clone();
            nodes.entry(cur.clone()).or_insert(Node::Dir);
            for seg in parent.key.split('/').filter(|s| !s.is_empty()) {
                cur = format!("{cur}/{seg}");
                match nodes.get(&cur) {
                    Some(Node::File { .. }) => return Err(FsError::NotADirectory(cur)),
                    Some(Node::Dir) => {}
                    None => {
                        nodes.insert(cur.clone(), Node::Dir);
                    }
                }
            }
        }
        Ok(())
    }

    /// Children of `key` (direct only).
    fn children(nodes: &BTreeMap<String, Node>, key: &str) -> Vec<String> {
        let prefix = format!("{key}/");
        let mut out = Vec::new();
        for (k, _) in nodes.range(prefix.clone()..) {
            if !k.starts_with(&prefix) {
                break;
            }
            let rest = &k[prefix.len()..];
            if !rest.contains('/') {
                out.push(k.clone());
            }
        }
        out
    }
}

/// HDFS write pipeline: bytes stream to the 3-replica pipeline as they
/// are produced (`write` pays the replication-bottlenecked disk time);
/// the file becomes visible at `close`. A stream dropped without close —
/// a crashed writer — leaves nothing behind: HDFS files materialise on
/// close, so there is no partial object to clean up.
struct HdfsOutputStream<'a> {
    fs: &'a Hdfs,
    path: Path,
    key: String,
    buf: Vec<u8>,
    closed: bool,
}

impl FsOutputStream for HdfsOutputStream<'_> {
    fn write(&mut self, data: &[u8], ctx: &mut OpCtx) -> Result<(), FsError> {
        if self.closed {
            return Err(FsError::Io(format!("write on closed stream {}", self.path)));
        }
        // Pipeline time accrues on the cumulative bytes written, so
        // chunking never changes the total.
        let old = self.buf.len() as u64;
        self.buf.extend_from_slice(data);
        ctx.add_spool_delta(old, self.buf.len() as u64, |b| self.fs.latency.data_time(b));
        Ok(())
    }

    fn write_owned(&mut self, data: Vec<u8>, ctx: &mut OpCtx) -> Result<(), FsError> {
        if self.closed {
            return Err(FsError::Io(format!("write on closed stream {}", self.path)));
        }
        // Zero-copy adoption for whole-file writers; pipeline accounting
        // is identical to `write`.
        let old = self.buf.len() as u64;
        super::interface::adopt_buf(&mut self.buf, data);
        ctx.add_spool_delta(old, self.buf.len() as u64, |b| self.fs.latency.data_time(b));
        Ok(())
    }

    fn close(&mut self, ctx: &mut OpCtx) -> Result<(), FsError> {
        if self.closed {
            return Err(FsError::Io(format!("double close on {}", self.path)));
        }
        self.closed = true;
        let data = std::mem::take(&mut self.buf);
        let len = data.len();
        let path = self.path.clone();
        // Transient pipeline failure (a DataNode in the replica pipeline
        // died): HDFS re-drives the whole write through a rebuilt
        // pipeline, so each retry re-pays the full replication data
        // time — the bytes stream to the DataNodes again — before the
        // file can materialise at close.
        let attempts = self.fs.retry.attempts();
        for attempt in 1..=attempts {
            if self.fs.faults.check(FaultOp::Put, &self.path.key).is_none() {
                break;
            }
            let p = self.path.clone();
            ctx.record("create", || format!("{p} (pipeline failure)"));
            if attempt == attempts {
                return Err(FsError::TransientExhausted(format!(
                    "write pipeline for {} failed {attempts} time(s)",
                    self.path
                )));
            }
            ctx.add(self.fs.retry.backoff(attempt));
            ctx.add(self.fs.latency.data_time(len as u64));
        }
        ctx.record("create", || format!("{path} ({len} bytes)"));
        let mut nodes = self.fs.nodes.lock().unwrap();
        // Revalidate under the lock: neither a directory that appeared at
        // this path since create() nor a file that replaced an ancestor
        // may be corrupted by the insert. (overwrite=false was enforced
        // at create time — the no-clobber guarantee covers the create
        // instant, as documented on `FileSystem::create`.)
        Hdfs::validate_and_make_parents(&mut nodes, &self.path, true)?;
        nodes.insert(
            self.key.clone(),
            Node::File {
                data: Arc::new(data),
                mtime: ctx.now(),
            },
        );
        Ok(())
    }
}

/// HDFS read handle: the NameNode lookup happened at `open`; reads stream
/// from the DataNodes at disk bandwidth.
struct HdfsInputStream<'a> {
    fs: &'a Hdfs,
    path: Path,
    data: Arc<Vec<u8>>,
}

impl FsInputStream for HdfsInputStream<'_> {
    fn size_hint(&self) -> Option<u64> {
        Some(self.data.len() as u64)
    }

    fn read_range(&mut self, offset: u64, len: u64, ctx: &mut OpCtx) -> Result<Vec<u8>, FsError> {
        // Same clamp/416 rule as the object-store backends — one shared
        // implementation of the range contract for the whole stack.
        use crate::objectstore::backend::{clamp_range, BackendError};
        let size = self.data.len() as u64;
        let (start, end) =
            clamp_range(&self.path.container, &self.path.key, offset, len, size).map_err(
                |e| match e {
                    BackendError::InvalidRange(m) => FsError::InvalidRange(m),
                    other => FsError::Io(other.to_string()),
                },
            )?;
        let slice = self.data[start..end].to_vec();
        ctx.add(self.fs.latency.data_time(slice.len() as u64));
        let path = self.path.clone();
        ctx.record("open", || format!("{path} [{offset}+{len})"));
        Ok(slice)
    }

    fn read_to_end(&mut self, ctx: &mut OpCtx) -> Result<Arc<Vec<u8>>, FsError> {
        ctx.add(self.fs.latency.data_time(self.data.len() as u64));
        let path = self.path.clone();
        ctx.record("open", || path.to_string());
        Ok(self.data.clone())
    }
}

impl FileSystem for Hdfs {
    fn scheme(&self) -> &str {
        "hdfs"
    }

    fn mkdirs(&self, path: &Path, ctx: &mut OpCtx) -> Result<(), FsError> {
        let mut nodes = self.nodes.lock().unwrap();
        ctx.add(self.latency.meta_time());
        ctx.record("mkdirs", || path.to_string());
        // Walk down from the container, creating missing dirs; fail if a
        // path component is a file.
        let mut cur = path.container.clone();
        nodes.entry(cur.clone()).or_insert(Node::Dir);
        for seg in path.key.split('/').filter(|s| !s.is_empty()) {
            cur = format!("{cur}/{seg}");
            match nodes.get(&cur) {
                Some(Node::File { .. }) => return Err(FsError::NotADirectory(cur)),
                Some(Node::Dir) => {}
                None => {
                    nodes.insert(cur.clone(), Node::Dir);
                }
            }
        }
        Ok(())
    }

    fn create(
        &self,
        path: &Path,
        overwrite: bool,
        ctx: &mut OpCtx,
    ) -> Result<Box<dyn FsOutputStream + '_>, FsError> {
        // One NameNode round trip opens the write pipeline; conflicts and
        // implicit parent creation happen here, before any byte moves.
        ctx.add(self.latency.meta_time());
        {
            let mut nodes = self.nodes.lock().unwrap();
            Self::validate_and_make_parents(&mut nodes, path, overwrite)?;
        }
        Ok(Box::new(HdfsOutputStream {
            fs: self,
            path: path.clone(),
            key: Self::full_key(path),
            buf: Vec::new(),
            closed: false,
        }))
    }

    fn open(&self, path: &Path, ctx: &mut OpCtx) -> Result<Box<dyn FsInputStream + '_>, FsError> {
        // NameNode lookup; data streams per read call.
        ctx.add(self.latency.meta_time());
        let nodes = self.nodes.lock().unwrap();
        let key = Self::full_key(path);
        match nodes.get(&key) {
            Some(Node::File { data, .. }) => {
                let inner = Box::new(HdfsInputStream {
                    fs: self,
                    path: path.clone(),
                    data: data.clone(),
                });
                Ok(match self.readahead {
                    0 => inner,
                    window => Box::new(ReadaheadStream::new(inner, window)),
                })
            }
            Some(Node::Dir) => Err(FsError::IsADirectory(key)),
            None => Err(FsError::NotFound(key)),
        }
    }

    fn get_file_status(&self, path: &Path, ctx: &mut OpCtx) -> Result<FileStatus, FsError> {
        let nodes = self.nodes.lock().unwrap();
        ctx.add(self.latency.meta_time());
        let key = Self::full_key(path);
        match nodes.get(&key) {
            Some(Node::Dir) => Ok(FileStatus::dir(path.clone(), SimInstant::EPOCH)),
            Some(Node::File { data, mtime }) => {
                Ok(FileStatus::file(path.clone(), data.len() as u64, *mtime))
            }
            None => Err(FsError::NotFound(key)),
        }
    }

    fn list_status(&self, path: &Path, ctx: &mut OpCtx) -> Result<Vec<FileStatus>, FsError> {
        let nodes = self.nodes.lock().unwrap();
        ctx.add(self.latency.meta_time());
        ctx.record("list", || path.to_string());
        let key = Self::full_key(path);
        match nodes.get(&key) {
            Some(Node::File { data, mtime }) => Ok(vec![FileStatus::file(
                path.clone(),
                data.len() as u64,
                *mtime,
            )]),
            Some(Node::Dir) => {
                let mut out = Vec::new();
                for child_key in Self::children(&nodes, &key) {
                    let rel = &child_key[path.container.len() + 1..];
                    let child = Path::new(&path.scheme, &path.container, rel);
                    match nodes.get(&child_key).unwrap() {
                        Node::Dir => out.push(FileStatus::dir(child, SimInstant::EPOCH)),
                        Node::File { data, mtime } => {
                            out.push(FileStatus::file(child, data.len() as u64, *mtime))
                        }
                    }
                }
                Ok(out)
            }
            None => Err(FsError::NotFound(key)),
        }
    }

    fn rename(&self, src: &Path, dst: &Path, ctx: &mut OpCtx) -> Result<bool, FsError> {
        let mut nodes = self.nodes.lock().unwrap();
        // HDFS rename is a metadata-only operation, regardless of size —
        // THE property object stores lack.
        ctx.add(self.latency.meta_time());
        ctx.record("rename", || format!("{src} -> {dst}"));
        let skey = Self::full_key(src);
        let dkey = Self::full_key(dst);
        if !nodes.contains_key(&skey) {
            return Ok(false);
        }
        // Collect the subtree (src itself + descendants).
        let sub_prefix = format!("{skey}/");
        let moved: Vec<String> = std::iter::once(skey.clone())
            .chain(
                nodes
                    .range(sub_prefix.clone()..)
                    .take_while(|(k, _)| k.starts_with(&sub_prefix))
                    .map(|(k, _)| k.clone()),
            )
            .collect();
        for old_key in moved {
            let node = nodes.remove(&old_key).unwrap();
            let new_key = format!("{dkey}{}", &old_key[skey.len()..]);
            nodes.insert(new_key, node);
        }
        Ok(true)
    }

    fn delete(&self, path: &Path, recursive: bool, ctx: &mut OpCtx) -> Result<bool, FsError> {
        let mut nodes = self.nodes.lock().unwrap();
        ctx.add(self.latency.meta_time());
        ctx.record("delete", || path.to_string());
        let key = Self::full_key(path);
        let Some(node) = nodes.get(&key) else {
            return Ok(false);
        };
        if matches!(node, Node::Dir) {
            let sub_prefix = format!("{key}/");
            let children: Vec<String> = nodes
                .range(sub_prefix.clone()..)
                .take_while(|(k, _)| k.starts_with(&sub_prefix))
                .map(|(k, _)| k.clone())
                .collect();
            if !children.is_empty() && !recursive {
                return Err(FsError::Io(format!("directory {key} not empty")));
            }
            for c in children {
                nodes.remove(&c);
            }
        }
        nodes.remove(&key);
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(s: &str) -> Path {
        Path::parse(s).unwrap()
    }

    fn ctx() -> OpCtx {
        OpCtx::new(SimInstant::EPOCH)
    }

    #[test]
    fn create_open_roundtrip() {
        let fs = Hdfs::new();
        let mut c = ctx();
        fs.write_all(&p("hdfs://res/data.txt/part-0"), b"abc".to_vec(), false, &mut c)
            .unwrap();
        let data = fs.read_all(&p("hdfs://res/data.txt/part-0"), &mut c).unwrap();
        assert_eq!(&*data, b"abc");
        // Implicit parent dir exists:
        let st = fs.get_file_status(&p("hdfs://res/data.txt"), &mut c).unwrap();
        assert!(st.is_dir);
    }

    #[test]
    fn mkdirs_is_recursive_and_idempotent() {
        let fs = Hdfs::new();
        let mut c = ctx();
        fs.mkdirs(&p("hdfs://res/a/b/c"), &mut c).unwrap();
        fs.mkdirs(&p("hdfs://res/a/b/c"), &mut c).unwrap();
        assert!(fs.get_file_status(&p("hdfs://res/a/b"), &mut c).unwrap().is_dir);
        // mkdirs through a file fails:
        fs.write_all(&p("hdfs://res/f"), vec![], false, &mut c).unwrap();
        assert!(fs.mkdirs(&p("hdfs://res/f/x"), &mut c).is_err());
    }

    #[test]
    fn overwrite_semantics() {
        let fs = Hdfs::new();
        let mut c = ctx();
        let f = p("hdfs://res/x");
        fs.write_all(&f, b"1".to_vec(), false, &mut c).unwrap();
        assert!(matches!(
            fs.write_all(&f, b"2".to_vec(), false, &mut c),
            Err(FsError::AlreadyExists(_))
        ));
        fs.write_all(&f, b"2".to_vec(), true, &mut c).unwrap();
        assert_eq!(&*fs.read_all(&f, &mut c).unwrap(), b"2");
    }

    #[test]
    fn rename_moves_subtree_atomically() {
        let fs = Hdfs::new();
        let mut c = ctx();
        fs.write_all(&p("hdfs://res/t/_tmp/a/part-0"), b"x".to_vec(), false, &mut c)
            .unwrap();
        fs.write_all(&p("hdfs://res/t/_tmp/a/part-1"), b"y".to_vec(), false, &mut c)
            .unwrap();
        assert!(fs
            .rename(&p("hdfs://res/t/_tmp/a"), &p("hdfs://res/t/final"), &mut c)
            .unwrap());
        assert!(fs.read_all(&p("hdfs://res/t/final/part-0"), &mut c).is_ok());
        assert!(fs.read_all(&p("hdfs://res/t/final/part-1"), &mut c).is_ok());
        assert!(fs.read_all(&p("hdfs://res/t/_tmp/a/part-0"), &mut c).is_err());
        // Renaming a missing source is the benign false case.
        assert!(!fs
            .rename(&p("hdfs://res/none"), &p("hdfs://res/other"), &mut c)
            .unwrap());
    }

    #[test]
    fn rename_is_metadata_only_on_the_clock() {
        let lat = HdfsLatency {
            meta_us: 100,
            disk_bw: 1_000, // very slow disk
            data_scale: 1,
        };
        let fs = Hdfs::with_latency(lat);
        let mut c = ctx();
        fs.write_all(&p("hdfs://res/big"), vec![0u8; 10_000], false, &mut c)
            .unwrap();
        let before = c.elapsed;
        fs.rename(&p("hdfs://res/big"), &p("hdfs://res/big2"), &mut c)
            .unwrap();
        let rename_cost = c.elapsed.saturating_sub(before);
        assert_eq!(rename_cost.as_micros(), 100, "rename must not touch data");
    }

    #[test]
    fn list_status_direct_children_only() {
        let fs = Hdfs::new();
        let mut c = ctx();
        fs.write_all(&p("hdfs://res/d/f1"), vec![1], false, &mut c).unwrap();
        fs.write_all(&p("hdfs://res/d/sub/f2"), vec![2], false, &mut c).unwrap();
        let ls = fs.list_status(&p("hdfs://res/d"), &mut c).unwrap();
        let names: Vec<&str> = ls.iter().map(|s| s.path.name()).collect();
        assert_eq!(names, vec!["f1", "sub"]);
        // Listing a file returns the file itself (Hadoop semantics).
        let lf = fs.list_status(&p("hdfs://res/d/f1"), &mut c).unwrap();
        assert_eq!(lf.len(), 1);
        assert!(!lf[0].is_dir);
    }

    #[test]
    fn delete_recursive_guard() {
        let fs = Hdfs::new();
        let mut c = ctx();
        fs.write_all(&p("hdfs://res/d/f"), vec![], false, &mut c).unwrap();
        assert!(fs.delete(&p("hdfs://res/d"), false, &mut c).is_err());
        assert!(fs.delete(&p("hdfs://res/d"), true, &mut c).unwrap());
        assert!(!fs.exists(&p("hdfs://res/d"), &mut c));
        assert!(!fs.delete(&p("hdfs://res/d"), true, &mut c).unwrap());
    }

    #[test]
    fn dropped_stream_leaves_no_file() {
        // A writer that dies before close: HDFS materialises files at
        // close, so nothing becomes visible.
        let fs = Hdfs::new();
        let mut c = ctx();
        {
            let mut out = fs.create(&p("hdfs://res/doomed"), true, &mut c).unwrap();
            out.write(b"half a part", &mut c).unwrap();
            // dropped without close
        }
        assert!(!fs.exists(&p("hdfs://res/doomed"), &mut c));
    }

    #[test]
    fn transient_pipeline_failure_is_redriven_at_data_cost() {
        use crate::objectstore::faults::{FaultOp, FaultRule, FaultSpec, RetryPolicy};
        let lat = HdfsLatency {
            meta_us: 0,
            disk_bw: 1_000, // 1 KB/s: data time dominates
            data_scale: 1,
        };
        let fs = Hdfs::with_faults(
            lat,
            0,
            &FaultSpec::one(FaultOp::Put, "f", 1),
            RetryPolicy::with_retries(1),
        );
        let mut c = ctx();
        fs.write_all(&p("hdfs://res/f"), vec![0u8; 2_000], false, &mut c)
            .unwrap();
        // First pipeline drive (2s) + backoff (0.1s) + full re-drive (2s).
        assert_eq!(c.elapsed.as_micros(), 2_000_000 + 100_000 + 2_000_000);
        assert_eq!(fs.read_all(&p("hdfs://res/f"), &mut c).unwrap().len(), 2_000);

        // Exhausted retries: no file materialises.
        let fs2 = Hdfs::with_faults(
            HdfsLatency::default(),
            0,
            &FaultSpec::none().with(FaultRule::new(FaultOp::Put, "g", 1, 5)),
            RetryPolicy::with_retries(1),
        );
        let mut c2 = ctx();
        assert!(matches!(
            fs2.write_all(&p("hdfs://res/g"), vec![1u8; 10], false, &mut c2),
            Err(FsError::TransientExhausted(_))
        ));
        assert!(!fs2.exists(&p("hdfs://res/g"), &mut c2));
    }

    #[test]
    fn close_refuses_to_clobber_a_directory() {
        // A dir that appears at the path between create() and close()
        // survives; the stream errors instead of corrupting the tree.
        let fs = Hdfs::new();
        let mut c = ctx();
        let mut out = fs.create(&p("hdfs://res/x"), true, &mut c).unwrap();
        out.write(b"data", &mut c).unwrap();
        fs.mkdirs(&p("hdfs://res/x"), &mut c).unwrap();
        assert!(matches!(out.close(&mut c), Err(FsError::IsADirectory(_))));
        assert!(fs.get_file_status(&p("hdfs://res/x"), &mut c).unwrap().is_dir);
    }

    #[test]
    fn range_reads_and_invalid_ranges() {
        let fs = Hdfs::new();
        let mut c = ctx();
        fs.write_all(&p("hdfs://res/f"), (0u8..100).collect(), false, &mut c)
            .unwrap();
        let mut input = fs.open(&p("hdfs://res/f"), &mut c).unwrap();
        assert_eq!(input.size_hint(), Some(100));
        assert_eq!(input.read_range(10, 5, &mut c).unwrap(), vec![10, 11, 12, 13, 14]);
        assert!(input.read_range(10, 0, &mut c).unwrap().is_empty());
        assert_eq!(input.read_range(90, 1000, &mut c).unwrap().len(), 10, "clamped to EOF");
        assert!(input.read_range(100, 5, &mut c).unwrap().is_empty(), "offset == EOF");
        assert!(matches!(
            input.read_range(101, 1, &mut c),
            Err(FsError::InvalidRange(_))
        ));
    }

    #[test]
    fn readahead_preserves_bytes_and_sequential_scan_time() {
        // HDFS reads have no per-op base latency, only linear DataNode
        // streaming time — so coalescing a sequential scan into window
        // fills must return the same bytes in the same virtual time.
        let lat = HdfsLatency {
            meta_us: 0,
            disk_bw: 1_000_000,
            data_scale: 1,
        };
        let run = |readahead: u64| -> (Vec<u8>, u64) {
            let fs = Hdfs::with_config(lat.clone(), readahead);
            let mut c = ctx();
            let data: Vec<u8> = (0..400u16).map(|i| (i % 251) as u8).collect();
            fs.write_all(&p("hdfs://res/f"), data, false, &mut c).unwrap();
            let mut c = ctx();
            let mut input = fs.open(&p("hdfs://res/f"), &mut c).unwrap();
            let mut got = Vec::new();
            for off in (0..400).step_by(8) {
                got.extend(input.read_range(off, 8, &mut c).unwrap());
            }
            (got, c.elapsed.as_micros())
        };
        let (naive, t_naive) = run(0);
        let (ra, t_ra) = run(64);
        assert_eq!(naive, ra, "readahead must not change the bytes");
        assert_eq!(t_naive, t_ra, "same bytes stream off the DataNodes");
        // And the window layer clamps at EOF like everything else.
        let fs = Hdfs::with_config(lat.clone(), 64);
        let mut c = ctx();
        fs.write_all(&p("hdfs://res/g"), (0u8..100).collect(), false, &mut c)
            .unwrap();
        let mut input = fs.open(&p("hdfs://res/g"), &mut c).unwrap();
        assert_eq!(input.read_range(90, 50, &mut c).unwrap().len(), 10);
        assert!(input.read_range(100, 1, &mut c).unwrap().is_empty());
        assert!(matches!(
            input.read_range(101, 1, &mut c),
            Err(FsError::InvalidRange(_))
        ));
    }

    #[test]
    fn streamed_write_equals_whole_buffer_write() {
        let fs = Hdfs::new();
        let mut c = ctx();
        let mut out = fs.create(&p("hdfs://res/streamed"), true, &mut c).unwrap();
        out.write(b"abc", &mut c).unwrap();
        out.write(b"def", &mut c).unwrap();
        out.close(&mut c).unwrap();
        assert_eq!(&*fs.read_all(&p("hdfs://res/streamed"), &mut c).unwrap(), b"abcdef");
    }

    #[test]
    fn trace_records_op_sequence() {
        let fs = Hdfs::new();
        let mut c = OpCtx::traced(SimInstant::EPOCH);
        fs.mkdirs(&p("hdfs://res/data.txt/_temporary/0"), &mut c).unwrap();
        fs.write_all(&p("hdfs://res/data.txt/_temporary/0/part-0"), vec![0], false, &mut c)
            .unwrap();
        fs.rename(
            &p("hdfs://res/data.txt/_temporary/0/part-0"),
            &p("hdfs://res/data.txt/part-0"),
            &mut c,
        )
        .unwrap();
        let t = c.take_trace();
        assert_eq!(t.len(), 3);
        assert!(t[0].starts_with("mkdirs:"));
        assert!(t[2].contains("->"));
    }
}
