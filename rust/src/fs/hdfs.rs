//! An in-memory HDFS-like hierarchical filesystem.
//!
//! Used for (a) regenerating the paper's Table 1 — the file-system
//! operation sequence Spark executes for a one-task program on HDFS — and
//! (b) the "copy input to HDFS, compute, copy back" alternative the paper's
//! §2.2.2 mentions, which we keep as an ablation baseline.
//!
//! Unlike an object store, HDFS has *real* directories and an atomic,
//! metadata-only rename — which is exactly why the HMRCC commit protocol is
//! cheap on HDFS and ruinous on object stores.

use super::interface::{FileSystem, FsError, OpCtx};
use super::path::Path;
use super::status::FileStatus;
use crate::simclock::{SimDuration, SimInstant};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

#[derive(Debug, Clone)]
enum Node {
    Dir,
    File { data: Arc<Vec<u8>>, mtime: SimInstant },
}

/// Virtual-time costs of HDFS operations: metadata ops hit the NameNode
/// (sub-millisecond), data ops stream at disk bandwidth.
#[derive(Debug, Clone)]
pub struct HdfsLatency {
    pub meta_us: u64,
    pub disk_bw: u64,
    pub data_scale: u64,
}

impl Default for HdfsLatency {
    fn default() -> Self {
        Self {
            meta_us: 500,
            disk_bw: 400_000_000, // 3 replicas over 10 Gbps, bottlenecked on SATA
            data_scale: 1,
        }
    }
}

impl HdfsLatency {
    fn data_time(&self, bytes: u64) -> SimDuration {
        if self.disk_bw == u64::MAX {
            return SimDuration::ZERO;
        }
        SimDuration::from_micros(
            bytes.saturating_mul(self.data_scale).saturating_mul(1_000_000) / self.disk_bw,
        )
    }
    fn meta_time(&self) -> SimDuration {
        SimDuration::from_micros(self.meta_us)
    }
}

/// The filesystem. Keys are `container/key` strings; the root of every
/// container always exists.
pub struct Hdfs {
    nodes: Mutex<BTreeMap<String, Node>>,
    latency: HdfsLatency,
}

impl Hdfs {
    pub fn new() -> Arc<Self> {
        Self::with_latency(HdfsLatency::default())
    }

    pub fn with_latency(latency: HdfsLatency) -> Arc<Self> {
        Arc::new(Self {
            nodes: Mutex::new(BTreeMap::new()),
            latency,
        })
    }

    fn full_key(path: &Path) -> String {
        if path.key.is_empty() {
            path.container.clone()
        } else {
            format!("{}/{}", path.container, path.key)
        }
    }

    /// Children of `key` (direct only).
    fn children(nodes: &BTreeMap<String, Node>, key: &str) -> Vec<String> {
        let prefix = format!("{key}/");
        let mut out = Vec::new();
        for (k, _) in nodes.range(prefix.clone()..) {
            if !k.starts_with(&prefix) {
                break;
            }
            let rest = &k[prefix.len()..];
            if !rest.contains('/') {
                out.push(k.clone());
            }
        }
        out
    }
}

impl FileSystem for Hdfs {
    fn scheme(&self) -> &str {
        "hdfs"
    }

    fn mkdirs(&self, path: &Path, ctx: &mut OpCtx) -> Result<(), FsError> {
        let mut nodes = self.nodes.lock().unwrap();
        ctx.add(self.latency.meta_time());
        ctx.record("mkdirs", || path.to_string());
        // Walk down from the container, creating missing dirs; fail if a
        // path component is a file.
        let mut cur = path.container.clone();
        nodes.entry(cur.clone()).or_insert(Node::Dir);
        for seg in path.key.split('/').filter(|s| !s.is_empty()) {
            cur = format!("{cur}/{seg}");
            match nodes.get(&cur) {
                Some(Node::File { .. }) => return Err(FsError::NotADirectory(cur)),
                Some(Node::Dir) => {}
                None => {
                    nodes.insert(cur.clone(), Node::Dir);
                }
            }
        }
        Ok(())
    }

    fn create(
        &self,
        path: &Path,
        data: Vec<u8>,
        overwrite: bool,
        ctx: &mut OpCtx,
    ) -> Result<(), FsError> {
        let mut nodes = self.nodes.lock().unwrap();
        ctx.add(self.latency.meta_time() + self.latency.data_time(data.len() as u64));
        ctx.record("create", || format!("{path} ({} bytes)", data.len()));
        let key = Self::full_key(path);
        match nodes.get(&key) {
            Some(Node::Dir) => return Err(FsError::IsADirectory(key)),
            Some(Node::File { .. }) if !overwrite => {
                return Err(FsError::AlreadyExists(key));
            }
            _ => {}
        }
        // Implicitly create parent dirs (Hadoop create() does).
        if let Some(parent) = path.parent() {
            let mut cur = path.container.clone();
            nodes.entry(cur.clone()).or_insert(Node::Dir);
            for seg in parent.key.split('/').filter(|s| !s.is_empty()) {
                cur = format!("{cur}/{seg}");
                match nodes.get(&cur) {
                    Some(Node::File { .. }) => return Err(FsError::NotADirectory(cur)),
                    Some(Node::Dir) => {}
                    None => {
                        nodes.insert(cur.clone(), Node::Dir);
                    }
                }
            }
        }
        nodes.insert(
            key,
            Node::File {
                data: Arc::new(data),
                mtime: ctx.now(),
            },
        );
        Ok(())
    }

    fn open(&self, path: &Path, ctx: &mut OpCtx) -> Result<Arc<Vec<u8>>, FsError> {
        let nodes = self.nodes.lock().unwrap();
        let key = Self::full_key(path);
        match nodes.get(&key) {
            Some(Node::File { data, .. }) => {
                ctx.add(self.latency.meta_time() + self.latency.data_time(data.len() as u64));
                ctx.record("open", || path.to_string());
                Ok(data.clone())
            }
            Some(Node::Dir) => Err(FsError::IsADirectory(key)),
            None => {
                ctx.add(self.latency.meta_time());
                Err(FsError::NotFound(key))
            }
        }
    }

    fn get_file_status(&self, path: &Path, ctx: &mut OpCtx) -> Result<FileStatus, FsError> {
        let nodes = self.nodes.lock().unwrap();
        ctx.add(self.latency.meta_time());
        let key = Self::full_key(path);
        match nodes.get(&key) {
            Some(Node::Dir) => Ok(FileStatus::dir(path.clone(), SimInstant::EPOCH)),
            Some(Node::File { data, mtime }) => {
                Ok(FileStatus::file(path.clone(), data.len() as u64, *mtime))
            }
            None => Err(FsError::NotFound(key)),
        }
    }

    fn list_status(&self, path: &Path, ctx: &mut OpCtx) -> Result<Vec<FileStatus>, FsError> {
        let nodes = self.nodes.lock().unwrap();
        ctx.add(self.latency.meta_time());
        ctx.record("list", || path.to_string());
        let key = Self::full_key(path);
        match nodes.get(&key) {
            Some(Node::File { data, mtime }) => Ok(vec![FileStatus::file(
                path.clone(),
                data.len() as u64,
                *mtime,
            )]),
            Some(Node::Dir) => {
                let mut out = Vec::new();
                for child_key in Self::children(&nodes, &key) {
                    let rel = &child_key[path.container.len() + 1..];
                    let child = Path::new(&path.scheme, &path.container, rel);
                    match nodes.get(&child_key).unwrap() {
                        Node::Dir => out.push(FileStatus::dir(child, SimInstant::EPOCH)),
                        Node::File { data, mtime } => {
                            out.push(FileStatus::file(child, data.len() as u64, *mtime))
                        }
                    }
                }
                Ok(out)
            }
            None => Err(FsError::NotFound(key)),
        }
    }

    fn rename(&self, src: &Path, dst: &Path, ctx: &mut OpCtx) -> Result<bool, FsError> {
        let mut nodes = self.nodes.lock().unwrap();
        // HDFS rename is a metadata-only operation, regardless of size —
        // THE property object stores lack.
        ctx.add(self.latency.meta_time());
        ctx.record("rename", || format!("{src} -> {dst}"));
        let skey = Self::full_key(src);
        let dkey = Self::full_key(dst);
        if !nodes.contains_key(&skey) {
            return Ok(false);
        }
        // Collect the subtree (src itself + descendants).
        let sub_prefix = format!("{skey}/");
        let moved: Vec<String> = std::iter::once(skey.clone())
            .chain(
                nodes
                    .range(sub_prefix.clone()..)
                    .take_while(|(k, _)| k.starts_with(&sub_prefix))
                    .map(|(k, _)| k.clone()),
            )
            .collect();
        for old_key in moved {
            let node = nodes.remove(&old_key).unwrap();
            let new_key = format!("{dkey}{}", &old_key[skey.len()..]);
            nodes.insert(new_key, node);
        }
        Ok(true)
    }

    fn delete(&self, path: &Path, recursive: bool, ctx: &mut OpCtx) -> Result<bool, FsError> {
        let mut nodes = self.nodes.lock().unwrap();
        ctx.add(self.latency.meta_time());
        ctx.record("delete", || path.to_string());
        let key = Self::full_key(path);
        let Some(node) = nodes.get(&key) else {
            return Ok(false);
        };
        if matches!(node, Node::Dir) {
            let sub_prefix = format!("{key}/");
            let children: Vec<String> = nodes
                .range(sub_prefix.clone()..)
                .take_while(|(k, _)| k.starts_with(&sub_prefix))
                .map(|(k, _)| k.clone())
                .collect();
            if !children.is_empty() && !recursive {
                return Err(FsError::Io(format!("directory {key} not empty")));
            }
            for c in children {
                nodes.remove(&c);
            }
        }
        nodes.remove(&key);
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(s: &str) -> Path {
        Path::parse(s).unwrap()
    }

    fn ctx() -> OpCtx {
        OpCtx::new(SimInstant::EPOCH)
    }

    #[test]
    fn create_open_roundtrip() {
        let fs = Hdfs::new();
        let mut c = ctx();
        fs.create(&p("hdfs://res/data.txt/part-0"), b"abc".to_vec(), false, &mut c)
            .unwrap();
        let data = fs.open(&p("hdfs://res/data.txt/part-0"), &mut c).unwrap();
        assert_eq!(&*data, b"abc");
        // Implicit parent dir exists:
        let st = fs.get_file_status(&p("hdfs://res/data.txt"), &mut c).unwrap();
        assert!(st.is_dir);
    }

    #[test]
    fn mkdirs_is_recursive_and_idempotent() {
        let fs = Hdfs::new();
        let mut c = ctx();
        fs.mkdirs(&p("hdfs://res/a/b/c"), &mut c).unwrap();
        fs.mkdirs(&p("hdfs://res/a/b/c"), &mut c).unwrap();
        assert!(fs.get_file_status(&p("hdfs://res/a/b"), &mut c).unwrap().is_dir);
        // mkdirs through a file fails:
        fs.create(&p("hdfs://res/f"), vec![], false, &mut c).unwrap();
        assert!(fs.mkdirs(&p("hdfs://res/f/x"), &mut c).is_err());
    }

    #[test]
    fn overwrite_semantics() {
        let fs = Hdfs::new();
        let mut c = ctx();
        let f = p("hdfs://res/x");
        fs.create(&f, b"1".to_vec(), false, &mut c).unwrap();
        assert!(matches!(
            fs.create(&f, b"2".to_vec(), false, &mut c),
            Err(FsError::AlreadyExists(_))
        ));
        fs.create(&f, b"2".to_vec(), true, &mut c).unwrap();
        assert_eq!(&*fs.open(&f, &mut c).unwrap(), b"2");
    }

    #[test]
    fn rename_moves_subtree_atomically() {
        let fs = Hdfs::new();
        let mut c = ctx();
        fs.create(&p("hdfs://res/t/_tmp/a/part-0"), b"x".to_vec(), false, &mut c)
            .unwrap();
        fs.create(&p("hdfs://res/t/_tmp/a/part-1"), b"y".to_vec(), false, &mut c)
            .unwrap();
        assert!(fs
            .rename(&p("hdfs://res/t/_tmp/a"), &p("hdfs://res/t/final"), &mut c)
            .unwrap());
        assert!(fs.open(&p("hdfs://res/t/final/part-0"), &mut c).is_ok());
        assert!(fs.open(&p("hdfs://res/t/final/part-1"), &mut c).is_ok());
        assert!(fs.open(&p("hdfs://res/t/_tmp/a/part-0"), &mut c).is_err());
        // Renaming a missing source is the benign false case.
        assert!(!fs
            .rename(&p("hdfs://res/none"), &p("hdfs://res/other"), &mut c)
            .unwrap());
    }

    #[test]
    fn rename_is_metadata_only_on_the_clock() {
        let lat = HdfsLatency {
            meta_us: 100,
            disk_bw: 1_000, // very slow disk
            data_scale: 1,
        };
        let fs = Hdfs::with_latency(lat);
        let mut c = ctx();
        fs.create(&p("hdfs://res/big"), vec![0u8; 10_000], false, &mut c)
            .unwrap();
        let before = c.elapsed;
        fs.rename(&p("hdfs://res/big"), &p("hdfs://res/big2"), &mut c)
            .unwrap();
        let rename_cost = c.elapsed.saturating_sub(before);
        assert_eq!(rename_cost.as_micros(), 100, "rename must not touch data");
    }

    #[test]
    fn list_status_direct_children_only() {
        let fs = Hdfs::new();
        let mut c = ctx();
        fs.create(&p("hdfs://res/d/f1"), vec![1], false, &mut c).unwrap();
        fs.create(&p("hdfs://res/d/sub/f2"), vec![2], false, &mut c).unwrap();
        let ls = fs.list_status(&p("hdfs://res/d"), &mut c).unwrap();
        let names: Vec<&str> = ls.iter().map(|s| s.path.name()).collect();
        assert_eq!(names, vec!["f1", "sub"]);
        // Listing a file returns the file itself (Hadoop semantics).
        let lf = fs.list_status(&p("hdfs://res/d/f1"), &mut c).unwrap();
        assert_eq!(lf.len(), 1);
        assert!(!lf[0].is_dir);
    }

    #[test]
    fn delete_recursive_guard() {
        let fs = Hdfs::new();
        let mut c = ctx();
        fs.create(&p("hdfs://res/d/f"), vec![], false, &mut c).unwrap();
        assert!(fs.delete(&p("hdfs://res/d"), false, &mut c).is_err());
        assert!(fs.delete(&p("hdfs://res/d"), true, &mut c).unwrap());
        assert!(!fs.exists(&p("hdfs://res/d"), &mut c));
        assert!(!fs.delete(&p("hdfs://res/d"), true, &mut c).unwrap());
    }

    #[test]
    fn trace_records_op_sequence() {
        let fs = Hdfs::new();
        let mut c = OpCtx::traced(SimInstant::EPOCH);
        fs.mkdirs(&p("hdfs://res/data.txt/_temporary/0"), &mut c).unwrap();
        fs.create(&p("hdfs://res/data.txt/_temporary/0/part-0"), vec![0], false, &mut c)
            .unwrap();
        fs.rename(
            &p("hdfs://res/data.txt/_temporary/0/part-0"),
            &p("hdfs://res/data.txt/part-0"),
            &mut c,
        )
        .unwrap();
        let t = c.take_trace();
        assert_eq!(t.len(), 3);
        assert!(t[0].starts_with("mkdirs:"));
        assert!(t[2].contains("->"));
    }
}
