//! Hadoop-style paths: `scheme://container/key/segments`.
//!
//! Object stores have no real directories, but Hadoop paths are
//! hierarchical; connectors map the path's key part onto hierarchical
//! object *names* (paper §2.1). `Path` keeps the parsed form and offers the
//! ancestry operations HMRCC and the committers need.

use std::fmt;

/// A parsed Hadoop path. `key` is empty for the container root.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Path {
    pub scheme: String,
    pub container: String,
    pub key: String,
}

impl Path {
    /// Parse `scheme://container/key...`. Normalizes duplicate and trailing
    /// slashes in the key.
    pub fn parse(s: &str) -> Result<Path, String> {
        let (scheme, rest) = s
            .split_once("://")
            .ok_or_else(|| format!("path '{s}' has no scheme://"))?;
        if scheme.is_empty() {
            return Err(format!("path '{s}' has empty scheme"));
        }
        let (container, key) = match rest.split_once('/') {
            Some((c, k)) => (c, k),
            None => (rest, ""),
        };
        if container.is_empty() {
            return Err(format!("path '{s}' has empty container"));
        }
        let key: String = key
            .split('/')
            .filter(|seg| !seg.is_empty())
            .collect::<Vec<_>>()
            .join("/");
        Ok(Path {
            scheme: scheme.to_string(),
            container: container.to_string(),
            key,
        })
    }

    /// Build from parts (already normalized).
    pub fn new(scheme: &str, container: &str, key: &str) -> Path {
        Path::parse(&format!("{scheme}://{container}/{key}")).expect("valid parts")
    }

    pub fn is_root(&self) -> bool {
        self.key.is_empty()
    }

    /// Last key segment (file/dir name); container for the root.
    pub fn name(&self) -> &str {
        if self.key.is_empty() {
            &self.container
        } else {
            self.key.rsplit('/').next().unwrap()
        }
    }

    /// Parent path; `None` at the container root.
    pub fn parent(&self) -> Option<Path> {
        if self.key.is_empty() {
            return None;
        }
        let parent_key = match self.key.rsplit_once('/') {
            Some((head, _)) => head,
            None => "",
        };
        Some(Path {
            scheme: self.scheme.clone(),
            container: self.container.clone(),
            key: parent_key.to_string(),
        })
    }

    /// All ancestors from the container root (exclusive) down to the parent.
    pub fn ancestors(&self) -> Vec<Path> {
        let mut out = Vec::new();
        let mut cur = self.parent();
        while let Some(p) = cur {
            if p.is_root() {
                break;
            }
            cur = p.parent();
            out.push(p);
        }
        out.reverse();
        out
    }

    /// Append a child segment (or multi-segment suffix).
    pub fn child(&self, name: &str) -> Path {
        let key = if self.key.is_empty() {
            name.to_string()
        } else {
            format!("{}/{}", self.key, name)
        };
        Path::new(&self.scheme, &self.container, &key)
    }

    /// Is `self` equal to or under `other`?
    pub fn starts_with(&self, other: &Path) -> bool {
        self.container == other.container
            && (self.key == other.key
                || other.key.is_empty()
                || self.key.starts_with(&format!("{}/", other.key)))
    }

    /// The key suffix of `self` relative to ancestor `base`.
    pub fn relative_to(&self, base: &Path) -> Option<String> {
        if !self.starts_with(base) {
            return None;
        }
        if base.key.is_empty() {
            Some(self.key.clone())
        } else if self.key == base.key {
            Some(String::new())
        } else {
            Some(self.key[base.key.len() + 1..].to_string())
        }
    }
}

impl fmt::Display for Path {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.key.is_empty() {
            write!(f, "{}://{}", self.scheme, self.container)
        } else {
            write!(f, "{}://{}/{}", self.scheme, self.container, self.key)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_display() {
        let p = Path::parse("swift2d://res/data.txt/part-0").unwrap();
        assert_eq!(p.scheme, "swift2d");
        assert_eq!(p.container, "res");
        assert_eq!(p.key, "data.txt/part-0");
        assert_eq!(p.to_string(), "swift2d://res/data.txt/part-0");
    }

    #[test]
    fn parse_normalizes_slashes() {
        let p = Path::parse("s3a://b//x///y/").unwrap();
        assert_eq!(p.key, "x/y");
        let root = Path::parse("s3a://b").unwrap();
        assert!(root.is_root());
        assert_eq!(root.to_string(), "s3a://b");
        assert_eq!(Path::parse("s3a://b/").unwrap(), root);
    }

    #[test]
    fn parse_errors() {
        assert!(Path::parse("no-scheme/x").is_err());
        assert!(Path::parse("://c/x").is_err());
        assert!(Path::parse("s3a:///x").is_err());
    }

    #[test]
    fn parent_chain() {
        let p = Path::parse("h://c/a/b/c").unwrap();
        assert_eq!(p.name(), "c");
        let par = p.parent().unwrap();
        assert_eq!(par.key, "a/b");
        assert_eq!(par.parent().unwrap().key, "a");
        let root = par.parent().unwrap().parent().unwrap();
        assert!(root.is_root());
        assert!(root.parent().is_none());
        assert_eq!(root.name(), "c"); // container name
    }

    #[test]
    fn ancestors_ordered_top_down() {
        let p = Path::parse("h://c/a/b/c/d").unwrap();
        let anc: Vec<String> = p.ancestors().iter().map(|a| a.key.clone()).collect();
        assert_eq!(anc, vec!["a", "a/b", "a/b/c"]);
        assert!(Path::parse("h://c/top").unwrap().ancestors().is_empty());
    }

    #[test]
    fn child_and_relative() {
        let d = Path::parse("h://c/data.txt").unwrap();
        let t = d.child("_temporary/0");
        assert_eq!(t.key, "data.txt/_temporary/0");
        assert!(t.starts_with(&d));
        assert!(!d.starts_with(&t));
        assert_eq!(t.relative_to(&d).unwrap(), "_temporary/0");
        assert_eq!(d.relative_to(&d).unwrap(), "");
        let other = Path::parse("h://c/other").unwrap();
        assert!(t.relative_to(&other).is_none());
    }

    #[test]
    fn starts_with_is_segment_aware() {
        let a = Path::parse("h://c/data").unwrap();
        let b = Path::parse("h://c/data.txt").unwrap();
        assert!(!b.starts_with(&a), "prefix must match whole segments");
        let root = Path::parse("h://c").unwrap();
        assert!(b.starts_with(&root));
    }
}
