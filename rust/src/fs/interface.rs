//! The Hadoop `FileSystem` trait, its streaming I/O handles, and the
//! per-task operation context.
//!
//! Every filesystem call threads an [`OpCtx`], which (a) advances the
//! caller's position on the virtual clock as storage operations complete,
//! and (b) optionally records a human-readable trace — this is how the
//! harness regenerates the paper's Tables 1 and 3 (operation sequences).
//!
//! I/O is stream-shaped, mirroring Hadoop's `FSDataOutputStream` /
//! `FSDataInputStream`: [`FileSystem::create`] hands back an
//! [`FsOutputStream`] and [`FileSystem::open`] an [`FsInputStream`]. *How*
//! bytes move is the connectors' differentiator (paper §3.3): Hadoop-Swift
//! and base S3a spool every [`FsOutputStream::write`] to simulated local
//! disk and upload at [`FsOutputStream::close`]; S3a fast-upload flushes
//! full multipart parts *during* `write`; Stocator streams a single
//! chunked-transfer PUT from the first byte. Whole-buffer call shapes
//! survive as the default-method wrappers [`FileSystem::write_all`] /
//! [`FileSystem::read_all`], which are exactly `create`+`write`+`close`
//! and `open`+`read_to_end`, so accounting is identical either way.

use super::path::Path;
use super::status::FileStatus;
use crate::simclock::{SimDuration, SimInstant};
use std::fmt;
use std::sync::Arc;

/// Filesystem-level errors (connector faults map store errors into these).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FsError {
    NotFound(String),
    AlreadyExists(String),
    NotADirectory(String),
    IsADirectory(String),
    /// A ranged read whose offset lies beyond end-of-file (HTTP 416).
    InvalidRange(String),
    /// A transient (5xx/timeout) storage failure that survived every
    /// retry the connector's [`crate::objectstore::RetryPolicy`] allowed
    /// (a policy of zero retries exhausts on the first failure). The
    /// committer/driver escalate this into a failed task attempt and the
    /// scheduler's re-attempt machinery takes over.
    TransientExhausted(String),
    Io(String),
}

impl fmt::Display for FsError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FsError::NotFound(p) => write!(f, "not found: {p}"),
            FsError::AlreadyExists(p) => write!(f, "already exists: {p}"),
            FsError::NotADirectory(p) => write!(f, "not a directory: {p}"),
            FsError::IsADirectory(p) => write!(f, "is a directory: {p}"),
            FsError::InvalidRange(m) => write!(f, "invalid range: {m}"),
            FsError::TransientExhausted(m) => write!(f, "transient failure, retries exhausted: {m}"),
            FsError::Io(m) => write!(f, "io error: {m}"),
        }
    }
}

impl std::error::Error for FsError {}

/// Per-caller context: where this caller sits on the virtual clock, plus an
/// optional operation trace.
#[derive(Debug)]
pub struct OpCtx {
    /// Virtual time at which the caller started.
    pub start: SimInstant,
    /// Virtual time consumed by the caller so far (storage ops + compute).
    pub elapsed: SimDuration,
    /// When `Some`, every storage operation appends a line.
    pub trace: Option<Vec<String>>,
}

impl OpCtx {
    pub fn new(start: SimInstant) -> Self {
        Self {
            start,
            elapsed: SimDuration::ZERO,
            trace: None,
        }
    }

    pub fn traced(start: SimInstant) -> Self {
        Self {
            start,
            elapsed: SimDuration::ZERO,
            trace: Some(Vec::new()),
        }
    }

    /// Current position on the virtual clock.
    #[inline]
    pub fn now(&self) -> SimInstant {
        self.start + self.elapsed
    }

    /// Account a completed operation of duration `d`.
    #[inline]
    pub fn add(&mut self, d: SimDuration) {
        self.elapsed += d;
    }

    /// Charge the cost of growing a spool/pipeline from `old` to `new`
    /// cumulative bytes under a cumulative cost function. Telescoping: the
    /// sum over any sequence of writes equals `cost(total)`, so virtual
    /// time never depends on how callers chunk their writes — THE
    /// invariant the buffer-to-disk output streams rely on.
    #[inline]
    pub fn add_spool_delta(&mut self, old: u64, new: u64, cost: impl Fn(u64) -> SimDuration) {
        self.add(cost(new).saturating_sub(cost(old)));
    }

    /// Record a trace line (no-op unless tracing).
    pub fn record(&mut self, actor: &str, line: impl FnOnce() -> String) {
        if let Some(t) = &mut self.trace {
            t.push(format!("{actor}: {}", line()));
        }
    }

    /// Take the accumulated trace.
    pub fn take_trace(&mut self) -> Vec<String> {
        self.trace.take().unwrap_or_default()
    }
}

/// Adopt `data` into an empty stream buffer (zero-copy) or append it.
/// Shared by every connector's [`FsOutputStream::write_owned`] override
/// so the owned-write byte handling stays identical everywhere — each
/// impl then differs only in its connector-specific accounting, which
/// must mirror its borrowing `write` exactly (the chunking-invariance
/// golden tests rely on that lockstep).
pub(crate) fn adopt_buf(buf: &mut Vec<u8>, data: Vec<u8>) {
    if buf.is_empty() {
        *buf = data;
    } else {
        buf.extend_from_slice(&data);
    }
}

/// A writable file handle, mirroring Hadoop's `FSDataOutputStream`.
///
/// Contract:
///
/// * [`write`](FsOutputStream::write) appends bytes; each connector pays
///   its write-path cost here, on the caller's virtual clock (local-disk
///   spooling, multipart part flushes, …).
/// * [`close`](FsOutputStream::close) finishes the write — the object
///   becomes durable/visible per the connector's semantics. Call it
///   exactly once; `write` or `close` after `close` is an error.
/// * **Dropping a stream without `close` models an executor crash
///   mid-write** — the real abort path. What (if anything) remains
///   visible is connector-defined: buffer-to-disk connectors lose the
///   local spool (nothing reaches the store), S3a fast-upload strands an
///   orphaned multipart upload, and Stocator's chunked-transfer PUT
///   leaves a truncated object at the target name (the §3.2 fail-stop
///   case its read-side dedup/manifest tolerates).
/// * **Transient REST failures are retried under the shared
///   [`crate::objectstore::RetryPolicy`]**, with per-connector resume
///   semantics: buffer-to-disk connectors re-PUT from the local spool
///   (cheap — the spool survives), fast upload re-sends only the failed
///   part, Stocator restarts the whole chunked-transfer PUT from offset
///   0 (the paper's fragility footnote — chunked transfer cannot be
///   resumed), and HDFS re-drives the replication pipeline. Exhausted
///   retries surface as [`FsError::TransientExhausted`].
pub trait FsOutputStream {
    /// Append `data` to the stream.
    fn write(&mut self, data: &[u8], ctx: &mut OpCtx) -> Result<(), FsError>;

    /// Append `data`, taking ownership of the buffer. Identical semantics
    /// and accounting to [`write`](FsOutputStream::write); connectors
    /// whose streams buffer bytes override this to adopt the vector when
    /// the stream is empty — the zero-copy fast path for whole-part
    /// writers, who hand the stream their entire output in one call (hot
    /// on the 500 GB cells, where each part is megabytes of simulated
    /// bytes). The default falls back to a borrowing `write`.
    fn write_owned(&mut self, data: Vec<u8>, ctx: &mut OpCtx) -> Result<(), FsError> {
        self.write(&data, ctx)
    }

    /// Finish the write and install the object.
    fn close(&mut self, ctx: &mut OpCtx) -> Result<(), FsError>;
}

/// A readable file handle, mirroring Hadoop's `FSDataInputStream`.
///
/// Handles are cheap: connectors that HEAD-on-open do so in
/// [`FileSystem::open`]; Stocator's handle is fully lazy (§3.4 — no HEAD
/// before GET) and issues its first request on the first read. A bare
/// handle issues one GET (full or ranged) per read call — readers keep no
/// cursor. With readahead enabled (`StoreConfig::readahead` /
/// `--readahead`), connectors wrap the handle in
/// [`crate::fs::readahead::ReadaheadStream`], which prefetches a window
/// on each miss and serves in-window reads from memory, coalescing many
/// small `read_range` calls into few ranged GETs.
pub trait FsInputStream {
    /// The object's size, when the connector already knows it (learned at
    /// `open` or from a previous read). `None` until the lazy connectors
    /// issue their first request.
    fn size_hint(&self) -> Option<u64>;

    /// Read bytes `[offset, offset + len)`, clamped to end-of-file. An
    /// offset strictly past EOF is [`FsError::InvalidRange`]; a
    /// zero-length range is valid and returns no bytes.
    fn read_range(&mut self, offset: u64, len: u64, ctx: &mut OpCtx)
        -> Result<Vec<u8>, FsError>;

    /// Read the whole object.
    fn read_to_end(&mut self, ctx: &mut OpCtx) -> Result<Arc<Vec<u8>>, FsError>;
}

/// The Hadoop FileSystem interface (paper Fig. 1) — the contract all three
/// connectors and the HDFS baseline implement. `create`/`open` hand back
/// streaming handles; the whole-buffer wrappers [`FileSystem::write_all`]
/// and [`FileSystem::read_all`] are thin default methods over them.
pub trait FileSystem: Send + Sync {
    /// URI scheme this filesystem serves (e.g. `swift2d`).
    fn scheme(&self) -> &str;

    /// Create all missing directories down to `path`.
    fn mkdirs(&self, path: &Path, ctx: &mut OpCtx) -> Result<(), FsError>;

    /// Open a file for writing. `overwrite=false` fails on an existing
    /// file (checked here, before any byte is written — not re-checked at
    /// `close`, so the no-clobber guarantee covers the create instant,
    /// as with Hadoop's lease-at-create; the simulator drives each path
    /// from one writer at a time). The write-path semantics live in the
    /// returned stream.
    fn create(
        &self,
        path: &Path,
        overwrite: bool,
        ctx: &mut OpCtx,
    ) -> Result<Box<dyn FsOutputStream + '_>, FsError>;

    /// Open a file for reading.
    fn open(&self, path: &Path, ctx: &mut OpCtx) -> Result<Box<dyn FsInputStream + '_>, FsError>;

    /// Whole-buffer write convenience: `create` + one `write` + `close`.
    /// Issues exactly the REST ops of the streaming path.
    fn write_all(
        &self,
        path: &Path,
        data: Vec<u8>,
        overwrite: bool,
        ctx: &mut OpCtx,
    ) -> Result<(), FsError> {
        let mut out = self.create(path, overwrite, ctx)?;
        out.write_owned(data, ctx)?;
        out.close(ctx)
    }

    /// Whole-buffer read convenience: `open` + `read_to_end`.
    fn read_all(&self, path: &Path, ctx: &mut OpCtx) -> Result<Arc<Vec<u8>>, FsError> {
        self.open(path, ctx)?.read_to_end(ctx)
    }

    /// Status of a file or directory.
    fn get_file_status(&self, path: &Path, ctx: &mut OpCtx) -> Result<FileStatus, FsError>;

    /// List the children of a directory (or the status of a plain file).
    fn list_status(&self, path: &Path, ctx: &mut OpCtx) -> Result<Vec<FileStatus>, FsError>;

    /// Rename a file or directory tree. Returns Ok(true) on success,
    /// Ok(false) for the benign "source missing" case Hadoop tolerates.
    fn rename(&self, src: &Path, dst: &Path, ctx: &mut OpCtx) -> Result<bool, FsError>;

    /// Delete a file or directory (recursively if asked). Returns Ok(true)
    /// if something was deleted.
    fn delete(&self, path: &Path, recursive: bool, ctx: &mut OpCtx) -> Result<bool, FsError>;

    /// Existence check (default: via `get_file_status`).
    fn exists(&self, path: &Path, ctx: &mut OpCtx) -> bool {
        self.get_file_status(path, ctx).is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctx_tracks_virtual_time() {
        let mut ctx = OpCtx::new(SimInstant(1_000));
        assert_eq!(ctx.now(), SimInstant(1_000));
        ctx.add(SimDuration::from_micros(500));
        assert_eq!(ctx.now(), SimInstant(1_500));
        assert_eq!(ctx.elapsed.as_micros(), 500);
    }

    #[test]
    fn tracing_is_optional_and_lazy() {
        let mut quiet = OpCtx::new(SimInstant::EPOCH);
        let mut called = false;
        quiet.record("Driver", || {
            called = true;
            "x".into()
        });
        assert!(!called, "trace closure must not run when not tracing");
        assert!(quiet.take_trace().is_empty());

        let mut traced = OpCtx::traced(SimInstant::EPOCH);
        traced.record("Driver", || "make directories".into());
        let t = traced.take_trace();
        assert_eq!(t, vec!["Driver: make directories"]);
        // Trace is consumed.
        assert!(traced.take_trace().is_empty());
    }
}
