//! The Hadoop `FileSystem` trait and the per-task operation context.
//!
//! Every filesystem call threads an [`OpCtx`], which (a) advances the
//! caller's position on the virtual clock as storage operations complete,
//! and (b) optionally records a human-readable trace — this is how the
//! harness regenerates the paper's Tables 1 and 3 (operation sequences).

use super::path::Path;
use super::status::FileStatus;
use crate::simclock::{SimDuration, SimInstant};
use std::fmt;

/// Filesystem-level errors (connector faults map store errors into these).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FsError {
    NotFound(String),
    AlreadyExists(String),
    NotADirectory(String),
    IsADirectory(String),
    Io(String),
}

impl fmt::Display for FsError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FsError::NotFound(p) => write!(f, "not found: {p}"),
            FsError::AlreadyExists(p) => write!(f, "already exists: {p}"),
            FsError::NotADirectory(p) => write!(f, "not a directory: {p}"),
            FsError::IsADirectory(p) => write!(f, "is a directory: {p}"),
            FsError::Io(m) => write!(f, "io error: {m}"),
        }
    }
}

impl std::error::Error for FsError {}

/// Per-caller context: where this caller sits on the virtual clock, plus an
/// optional operation trace.
#[derive(Debug)]
pub struct OpCtx {
    /// Virtual time at which the caller started.
    pub start: SimInstant,
    /// Virtual time consumed by the caller so far (storage ops + compute).
    pub elapsed: SimDuration,
    /// When `Some`, every storage operation appends a line.
    pub trace: Option<Vec<String>>,
}

impl OpCtx {
    pub fn new(start: SimInstant) -> Self {
        Self {
            start,
            elapsed: SimDuration::ZERO,
            trace: None,
        }
    }

    pub fn traced(start: SimInstant) -> Self {
        Self {
            start,
            elapsed: SimDuration::ZERO,
            trace: Some(Vec::new()),
        }
    }

    /// Current position on the virtual clock.
    #[inline]
    pub fn now(&self) -> SimInstant {
        self.start + self.elapsed
    }

    /// Account a completed operation of duration `d`.
    #[inline]
    pub fn add(&mut self, d: SimDuration) {
        self.elapsed += d;
    }

    /// Record a trace line (no-op unless tracing).
    pub fn record(&mut self, actor: &str, line: impl FnOnce() -> String) {
        if let Some(t) = &mut self.trace {
            t.push(format!("{actor}: {}", line()));
        }
    }

    /// Take the accumulated trace.
    pub fn take_trace(&mut self) -> Vec<String> {
        self.trace.take().unwrap_or_default()
    }
}

/// The Hadoop FileSystem interface (paper Fig. 1) — the contract all three
/// connectors and the HDFS baseline implement. File writes are modelled as
/// whole-file `create` (Spark's output streams are closed exactly once per
/// part; buffering behaviour is a connector-internal timing matter).
pub trait FileSystem: Send + Sync {
    /// URI scheme this filesystem serves (e.g. `swift2d`).
    fn scheme(&self) -> &str;

    /// Create all missing directories down to `path`.
    fn mkdirs(&self, path: &Path, ctx: &mut OpCtx) -> Result<(), FsError>;

    /// Create a file with the given content. `overwrite=false` fails on an
    /// existing file.
    fn create(
        &self,
        path: &Path,
        data: Vec<u8>,
        overwrite: bool,
        ctx: &mut OpCtx,
    ) -> Result<(), FsError>;

    /// Read a whole file.
    fn open(&self, path: &Path, ctx: &mut OpCtx) -> Result<std::sync::Arc<Vec<u8>>, FsError>;

    /// Status of a file or directory.
    fn get_file_status(&self, path: &Path, ctx: &mut OpCtx) -> Result<FileStatus, FsError>;

    /// List the children of a directory (or the status of a plain file).
    fn list_status(&self, path: &Path, ctx: &mut OpCtx) -> Result<Vec<FileStatus>, FsError>;

    /// Rename a file or directory tree. Returns Ok(true) on success,
    /// Ok(false) for the benign "source missing" case Hadoop tolerates.
    fn rename(&self, src: &Path, dst: &Path, ctx: &mut OpCtx) -> Result<bool, FsError>;

    /// Delete a file or directory (recursively if asked). Returns Ok(true)
    /// if something was deleted.
    fn delete(&self, path: &Path, recursive: bool, ctx: &mut OpCtx) -> Result<bool, FsError>;

    /// Existence check (default: via `get_file_status`).
    fn exists(&self, path: &Path, ctx: &mut OpCtx) -> bool {
        self.get_file_status(path, ctx).is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctx_tracks_virtual_time() {
        let mut ctx = OpCtx::new(SimInstant(1_000));
        assert_eq!(ctx.now(), SimInstant(1_000));
        ctx.add(SimDuration::from_micros(500));
        assert_eq!(ctx.now(), SimInstant(1_500));
        assert_eq!(ctx.elapsed.as_micros(), 500);
    }

    #[test]
    fn tracing_is_optional_and_lazy() {
        let mut quiet = OpCtx::new(SimInstant::EPOCH);
        let mut called = false;
        quiet.record("Driver", || {
            called = true;
            "x".into()
        });
        assert!(!called, "trace closure must not run when not tracing");
        assert!(quiet.take_trace().is_empty());

        let mut traced = OpCtx::traced(SimInstant::EPOCH);
        traced.record("Driver", || "make directories".into());
        let t = traced.take_trace();
        assert_eq!(t, vec!["Driver: make directories"]);
        // Trace is consumed.
        assert!(traced.take_trace().is_empty());
    }
}
