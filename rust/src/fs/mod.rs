//! The Hadoop FileSystem abstraction (paper Fig. 1).
//!
//! Spark talks to storage through the Hadoop Map Reduce Client Core
//! (HMRCC), which talks to a *connector* implementing the Hadoop
//! `FileSystem` interface. This module defines that interface
//! ([`interface::FileSystem`]), Hadoop-style paths ([`path::Path`]) and
//! file statuses, plus an in-memory HDFS-like filesystem used for the
//! paper's Table 1 trace and the copy-via-HDFS ablation.

pub mod path;
pub mod status;
pub mod interface;
pub mod hdfs;

pub use interface::{FileSystem, FsError, OpCtx};
pub use path::Path;
pub use status::FileStatus;
