//! The Hadoop FileSystem abstraction (paper Fig. 1).
//!
//! Spark talks to storage through the Hadoop Map Reduce Client Core
//! (HMRCC), which talks to a *connector* implementing the Hadoop
//! `FileSystem` interface. This module defines that interface
//! ([`interface::FileSystem`]) and its streaming I/O handles
//! ([`interface::FsOutputStream`] / [`interface::FsInputStream`] —
//! Hadoop's `FSDataOutputStream`/`FSDataInputStream` analogues),
//! Hadoop-style paths ([`path::Path`]) and file statuses, plus an
//! in-memory HDFS-like filesystem used for the paper's Table 1 trace and
//! the copy-via-HDFS ablation.
//!
//! The stream shape is what lets each connector express its paper-§3.3
//! write path honestly — spool-then-PUT, multipart-during-write, or
//! single chunked-transfer PUT — and what makes *dropping a stream
//! without close* (an executor crash) a first-class, connector-defined
//! event instead of a fraction-of-a-buffer hack. On the read side,
//! [`readahead::ReadaheadStream`] gives every connector an
//! S3AInputStream-style prefetch window so many small `read_range` calls
//! coalesce into few ranged GETs (`--readahead BYTES` on the CLI).

pub mod path;
pub mod status;
pub mod interface;
pub mod hdfs;
pub mod readahead;

pub use interface::{FileSystem, FsError, FsInputStream, FsOutputStream, OpCtx};
pub use path::Path;
pub use readahead::ReadaheadStream;
pub use status::FileStatus;
