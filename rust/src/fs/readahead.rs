//! Connector-level readahead: an S3AInputStream-style prefetch buffer
//! under [`FsInputStream`].
//!
//! PR 2's streaming read API made partial reads expressible, but every
//! `read_range` call still issued its own GET — so small-record readers
//! (terasort splitter sampling, wordcount line scans, TPC-DS column
//! probes) pay one REST round trip per sliver, exactly the request
//! amplification the paper's Table 2/7 op-count reductions attack.
//! [`ReadaheadStream`] coalesces them: it tracks the caller's position,
//! serves reads that fall inside a prefetched window from memory (zero
//! REST ops, zero virtual time — the bytes are already on the Spark
//! server), and on a miss issues **one** ranged GET of
//! `max(requested, window)` bytes.
//!
//! Policy (modelled on Hadoop's `S3AInputStream` sequential/random modes):
//!
//! * the window starts at the configured `readahead` size;
//! * a *sequential* miss (the read starts exactly where the previous read
//!   ended) doubles the window, up to [`MAX_WINDOW_MULTIPLE`] × the
//!   configured size — streaming readers amortise ever more round trips;
//! * a *non-contiguous* miss resets the window to the configured size,
//!   and after [`RANDOM_MISS_THRESHOLD`] consecutive non-contiguous
//!   misses the window collapses to zero — a random reader (columnar
//!   footer probes, index lookups) stops paying for bytes it will never
//!   use. A later sequential miss re-opens the window.
//!
//! Fills inherit the range contract of the layer below ([the shared
//! `clamp_range`](crate::objectstore::backend::clamp_range)): a fill that
//! starts before EOF but extends past it is **clamped** (HTTP 206 partial
//! content), never an error; only a read starting strictly past EOF
//! surfaces [`FsError::InvalidRange`] (HTTP 416). Pricing is the layer
//! below's too: each fill is one GET whose duration and byte accounting
//! cover the fetched slice, paper-scaled by the full object size
//! ([`LatencyModel::range_get_duration`](crate::objectstore::LatencyModel::range_get_duration)),
//! so coalescing N small reads into one fill replaces N first-byte
//! latencies with one without changing the bytes billed.
//!
//! The wrapper is connector-agnostic: Swift/S3a wrap their HEAD-on-open
//! streams, Stocator its lazy no-HEAD stream (the first fill's GET still
//! warms the HEAD cache, §3.4), and HDFS its DataNode reader — enabled by
//! `StoreConfig::readahead` / `--readahead BYTES` (0/`off` disables it,
//! leaving every read a bare GET exactly as before).

use super::interface::{FsError, FsInputStream, OpCtx};
use std::sync::Arc;

/// Window growth cap: the window may grow to this multiple of the
/// configured readahead size under sustained sequential reads.
pub const MAX_WINDOW_MULTIPLE: u64 = 4;

/// Consecutive non-contiguous misses after which the stream falls back to
/// random-read mode (fills fetch exactly the requested bytes).
pub const RANDOM_MISS_THRESHOLD: u32 = 4;

/// A prefetching wrapper over any [`FsInputStream`]. See the module docs
/// for the policy.
pub struct ReadaheadStream<'a> {
    inner: Box<dyn FsInputStream + 'a>,
    /// Configured window size (bytes); invariant: > 0.
    readahead: u64,
    /// Current fill size (0 = random-read fallback: no over-fetch).
    window_target: u64,
    /// Buffered bytes `[window_start, window_start + window.len())`.
    window: Vec<u8>,
    window_start: u64,
    /// Offset one past the last byte served (sequential-read detection).
    expected_next: Option<u64>,
    /// Consecutive non-contiguous misses.
    noncontig_misses: u32,
    /// Fill count (ranged GETs issued), for tests and benches.
    fills: u64,
    /// Window-served read count, for tests and benches.
    hits: u64,
}

impl<'a> ReadaheadStream<'a> {
    /// Wrap `inner` with a `readahead_bytes`-sized prefetch window.
    /// `readahead_bytes` must be positive — callers gate on the config
    /// knob and skip the wrapper entirely when readahead is off.
    pub fn new(inner: Box<dyn FsInputStream + 'a>, readahead_bytes: u64) -> Self {
        assert!(readahead_bytes > 0, "readahead window must be positive");
        Self {
            inner,
            readahead: readahead_bytes,
            window_target: readahead_bytes,
            window: Vec::new(),
            window_start: 0,
            expected_next: None,
            noncontig_misses: 0,
            fills: 0,
            hits: 0,
        }
    }

    /// Ranged GETs issued so far (fills; misses of the window).
    pub fn fills(&self) -> u64 {
        self.fills
    }

    /// Reads served from the prefetch window without a REST op.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// End offset (exclusive) of the buffered window.
    fn window_end(&self) -> u64 {
        self.window_start + self.window.len() as u64
    }

    /// Serve `[offset, offset + len)` from the buffered window. Caller
    /// guarantees the range lies within it.
    fn serve(&mut self, offset: u64, len: usize) -> Vec<u8> {
        let s = (offset - self.window_start) as usize;
        let out = self.window[s..s + len].to_vec();
        self.hits += 1;
        self.expected_next = Some(offset + len as u64);
        out
    }

    /// Adapt the window to this miss and return the fill length.
    fn plan_fill(&mut self, offset: u64, len: u64) -> u64 {
        let sequential = self.expected_next == Some(offset);
        if sequential {
            self.noncontig_misses = 0;
            self.window_target = if self.window_target == 0 {
                // Random fallback ended: the reader went sequential again.
                self.readahead
            } else {
                self.window_target
                    .saturating_mul(2)
                    .min(self.readahead.saturating_mul(MAX_WINDOW_MULTIPLE))
            };
        } else if self.expected_next.is_some() {
            // A true seek (the very first read of a stream is not one).
            self.noncontig_misses += 1;
            self.window_target = if self.noncontig_misses >= RANDOM_MISS_THRESHOLD {
                0 // random-read fallback: fetch exactly what was asked
            } else {
                self.readahead
            };
        }
        len.max(self.window_target)
    }
}

impl FsInputStream for ReadaheadStream<'_> {
    fn size_hint(&self) -> Option<u64> {
        self.inner.size_hint()
    }

    fn read_range(&mut self, offset: u64, len: u64, ctx: &mut OpCtx) -> Result<Vec<u8>, FsError> {
        let wend = self.window_end();
        let in_window_start = !self.window.is_empty() && offset >= self.window_start;
        // Fully buffered: serve from memory, zero REST ops.
        if in_window_start && offset.saturating_add(len) <= wend {
            return Ok(self.serve(offset, len as usize));
        }
        // The read starts inside a window that already reaches EOF: the
        // clamped (partial-content) answer is fully buffered too — a
        // refill would re-fetch bytes we hold and return nothing new.
        // (`offset <= wend <= size` here, so past-EOF reads never take
        // this path and still surface 416 from the fill below.)
        if in_window_start && offset <= wend {
            if let Some(size) = self.inner.size_hint() {
                if wend >= size {
                    let clamped = (wend - offset) as usize;
                    return Ok(self.serve(offset, clamped));
                }
            }
        }
        // Miss: one ranged GET of max(requested, window), clamped at EOF
        // by the layer below; an offset strictly past EOF is its 416.
        let fetch = self.plan_fill(offset, len);
        let data = self.inner.read_range(offset, fetch, ctx)?;
        self.fills += 1;
        let served = (len as usize).min(data.len());
        let out = data[..served].to_vec();
        self.window = data;
        self.window_start = offset;
        self.expected_next = Some(offset + served as u64);
        Ok(out)
    }

    fn read_to_end(&mut self, ctx: &mut OpCtx) -> Result<Arc<Vec<u8>>, FsError> {
        // Whole-object reads bypass the window (one full GET, exactly as
        // without readahead) — unless the window already holds the entire
        // object, in which case the bytes never cross the wire again.
        if !self.window.is_empty() && self.window_start == 0 {
            if let Some(size) = self.inner.size_hint() {
                if self.window_end() >= size {
                    self.hits += 1;
                    self.expected_next = Some(size);
                    return Ok(Arc::new(self.window.clone()));
                }
            }
        }
        self.inner.read_to_end(ctx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objectstore::backend::{clamp_range, BackendError};
    use crate::simclock::SimInstant;

    /// An in-memory stream honouring the store's range contract.
    struct MemStream {
        data: Vec<u8>,
    }

    impl FsInputStream for MemStream {
        fn size_hint(&self) -> Option<u64> {
            Some(self.data.len() as u64)
        }

        fn read_range(
            &mut self,
            offset: u64,
            len: u64,
            _ctx: &mut OpCtx,
        ) -> Result<Vec<u8>, FsError> {
            let (s, e) = clamp_range("c", "k", offset, len, self.data.len() as u64)
                .map_err(|e| match e {
                    BackendError::InvalidRange(m) => FsError::InvalidRange(m),
                    other => FsError::Io(other.to_string()),
                })?;
            Ok(self.data[s..e].to_vec())
        }

        fn read_to_end(&mut self, _ctx: &mut OpCtx) -> Result<Arc<Vec<u8>>, FsError> {
            Ok(Arc::new(self.data.clone()))
        }
    }

    fn stream(size: usize, readahead: u64) -> ReadaheadStream<'static> {
        let data: Vec<u8> = (0..size).map(|i| (i % 251) as u8).collect();
        ReadaheadStream::new(Box::new(MemStream { data }), readahead)
    }

    fn ctx() -> OpCtx {
        OpCtx::new(SimInstant::EPOCH)
    }

    fn expect(size: usize, offset: usize, len: usize) -> Vec<u8> {
        (offset..(offset + len).min(size)).map(|i| (i % 251) as u8).collect()
    }

    #[test]
    fn sequential_small_reads_coalesce_with_window_growth() {
        let mut s = stream(400, 64);
        let mut c = ctx();
        let mut got = Vec::new();
        for off in (0..400).step_by(8) {
            got.extend(s.read_range(off as u64, 8, &mut c).unwrap());
        }
        assert_eq!(got, expect(400, 0, 400), "bytes must be identical");
        // Fills at 0 (64), 64 (128: doubled), 192 (256: doubled, clamped
        // to 400): 3 GETs for 50 reads.
        assert_eq!(s.fills(), 3);
        assert_eq!(s.hits(), 47);
    }

    #[test]
    fn fill_count_is_chunking_invariant() {
        // 8-byte and 16-byte steps hit the same window boundaries.
        let fills = |step: usize| {
            let mut s = stream(400, 64);
            let mut c = ctx();
            for off in (0..400).step_by(step) {
                s.read_range(off as u64, step as u64, &mut c).unwrap();
            }
            s.fills()
        };
        assert_eq!(fills(8), fills(16));
    }

    #[test]
    fn fill_spanning_eof_clamps_instead_of_416() {
        // The regression the readahead layer must never introduce: the
        // over-fetch `max(requested, window)` extends past EOF — partial
        // content, not InvalidRange.
        let mut s = stream(100, 64);
        let mut c = ctx();
        let tail = s.read_range(90, 8, &mut c).unwrap();
        assert_eq!(tail, expect(100, 90, 8));
        assert_eq!(s.fills(), 1, "one clamped fill");
        // The next read extends past EOF but starts before it: clamped,
        // and served from the EOF-touching window without another GET.
        let rest = s.read_range(98, 10, &mut c).unwrap();
        assert_eq!(rest, expect(100, 98, 2));
        assert_eq!(s.fills(), 1);
        // Reading exactly at EOF is valid and empty; strictly past is 416.
        assert!(s.read_range(100, 5, &mut c).unwrap().is_empty());
        assert!(matches!(
            s.read_range(101, 1, &mut c),
            Err(FsError::InvalidRange(_))
        ));
    }

    #[test]
    fn random_reads_fall_back_to_exact_fetches() {
        let mut s = stream(100_000, 64);
        let mut c = ctx();
        // A scatter of seeks, far enough apart that nothing hits.
        for off in [10_000u64, 70_000, 30_000, 90_000, 50_000, 20_000] {
            let got = s.read_range(off, 8, &mut c).unwrap();
            assert_eq!(got, expect(100_000, off as usize, 8));
        }
        // After RANDOM_MISS_THRESHOLD non-contiguous misses the window
        // collapsed: later fills fetch exactly the requested 8 bytes.
        assert_eq!(s.window.len(), 8, "random fallback fetches no extra");
        // Going sequential again re-opens the window.
        let next = 20_008u64;
        s.read_range(next, 8, &mut c).unwrap();
        assert_eq!(s.window.len() as u64, 64, "sequential read re-arms readahead");
    }

    #[test]
    fn window_growth_is_capped() {
        let mut s = stream(100_000, 64);
        let mut c = ctx();
        let mut off = 0u64;
        // Long sequential scan: window must stop at 4x the configured size.
        for _ in 0..200 {
            let got = s.read_range(off, 64, &mut c).unwrap();
            off += got.len() as u64;
        }
        assert!(s.window.len() as u64 <= 64 * MAX_WINDOW_MULTIPLE);
        assert!(s.fills() < 200 / 2, "most reads must be window hits");
    }

    #[test]
    fn read_to_end_delegates_unless_fully_buffered() {
        let mut s = stream(100, 64);
        let mut c = ctx();
        let all = s.read_to_end(&mut c).unwrap();
        assert_eq!(&*all, &expect(100, 0, 100));
        assert_eq!(s.fills(), 0, "read_to_end is a plain full GET, not a fill");
        // Now buffer the whole object via a ranged read, then read_to_end
        // again: served from the window.
        let mut s = stream(100, 256);
        let first = s.read_range(0, 10, &mut c).unwrap();
        assert_eq!(first, expect(100, 0, 10));
        assert_eq!(s.fills(), 1);
        let all = s.read_to_end(&mut c).unwrap();
        assert_eq!(&*all, &expect(100, 0, 100));
        assert_eq!(s.fills(), 1, "whole object was already buffered");
        assert_eq!(s.hits(), 1);
    }

    #[test]
    fn zero_length_reads_are_valid() {
        let mut s = stream(100, 64);
        let mut c = ctx();
        assert!(s.read_range(0, 0, &mut c).unwrap().is_empty());
        s.read_range(10, 20, &mut c).unwrap();
        assert!(s.read_range(15, 0, &mut c).unwrap().is_empty());
    }

    #[test]
    fn size_hint_passes_through() {
        let mut s = stream(1234, 64);
        assert_eq!(s.size_hint(), Some(1234));
        let mut c = ctx();
        s.read_range(0, 8, &mut c).unwrap();
        assert_eq!(s.size_hint(), Some(1234));
    }
}
