//! The virtual-time cost model for REST operations (DESIGN.md §7).
//!
//! Calibrated to reflect the paper's testbed (§4.1): three Spark servers
//! with 10 Gbps NICs, HAProxy round-robin over two COS Accessers (20 Gbps
//! each), twelve Slicestors behind a (12,8,10) erasure code. We model:
//!
//! * a fixed per-op request latency (HTTP round trip + store work),
//! * payload transfer time at a per-stream bandwidth (the aggregate NIC
//!   bandwidth divided by the cluster's task parallelism),
//! * server-side COPY at its own bandwidth (COPY moves the bytes inside the
//!   store, twice over the erasure-coded backend),
//! * listing time growing with the number of names returned.
//!
//! Because the simulated datasets are scaled down byte-wise but keep the
//! paper's *object counts* (DESIGN.md §2), `data_scale` inflates payload
//! sizes back to paper scale for *timing and byte-accounting* purposes:
//! a 128 KiB simulated part with `data_scale = 1024` behaves, on the
//! virtual clock and in Figure 7, like the paper's 128 MiB part.

use crate::metrics::OpKind;
use crate::simclock::SimDuration;

/// Per-operation latency/bandwidth parameters. All latencies in
/// microseconds of virtual time.
#[derive(Debug, Clone)]
pub struct LatencyModel {
    /// Request latency for HEAD Object / HEAD Container.
    pub head_us: u64,
    /// Request latency for GET Object (first byte).
    pub get_us: u64,
    /// Request latency for PUT Object (first byte).
    pub put_us: u64,
    /// Request latency for DELETE Object.
    pub delete_us: u64,
    /// Base latency for GET Container.
    pub list_base_us: u64,
    /// Additional latency per name returned by GET Container.
    pub list_per_entry_us: u64,
    /// Base latency for COPY Object.
    pub copy_base_us: u64,
    /// Per-stream transfer bandwidth, bytes/second of virtual time.
    pub stream_bw: u64,
    /// Server-side COPY bandwidth, bytes/second.
    pub copy_bw: u64,
    /// Local-disk bandwidth on a Spark server (used by connectors that
    /// buffer output to local disk before uploading), bytes/second.
    pub local_disk_bw: u64,
    /// Multiplier from simulated bytes to "paper-scale" bytes.
    pub data_scale: u64,
    /// Payloads smaller than this are NOT scaled: they model metadata
    /// objects (`_SUCCESS` manifests, directory markers, small result
    /// files) whose real size does not grow with the dataset. Dataset
    /// parts must be sized >= this threshold.
    pub scale_threshold: u64,
    /// Multiplicative jitter amplitude (0.0 = deterministic). The store
    /// draws jitter from its seeded RNG, so runs remain reproducible.
    pub jitter: f64,
}

impl LatencyModel {
    /// Defaults per DESIGN.md §7. `stream_bw` reflects 30 Gbps aggregate
    /// split across 144 concurrent task slots ≈ 26 MB/s per stream; COPY
    /// runs server-side at 10 Gbps shared ≈ we charge 120 MB/s per stream.
    pub fn paper_testbed() -> Self {
        Self {
            head_us: 15_000,
            get_us: 25_000,
            put_us: 30_000,
            delete_us: 25_000,
            list_base_us: 50_000,
            list_per_entry_us: 10,
            copy_base_us: 40_000,
            stream_bw: 26_000_000,
            copy_bw: 120_000_000,
            // One 1 TB SATA disk per server (§4.1) shared by 48 concurrent
            // tasks: ~3 MB/s effective per buffering stream. This is what
            // makes the non-fast-upload connectors pay so dearly for
            // buffer-to-disk (Table 5: S3a Cv2 169.7s vs Cv2+FU 56.8s).
            local_disk_bw: 3_000_000,
            data_scale: 1,
            scale_threshold: 0,
            jitter: 0.0,
        }
    }

    /// Paper testbed with payload scaling, for the scaled-down datasets.
    /// Objects under 24 KiB (metadata: manifests, markers, small outputs)
    /// are not scaled.
    pub fn paper_testbed_scaled(data_scale: u64) -> Self {
        Self {
            data_scale,
            scale_threshold: 24 * 1024,
            ..Self::paper_testbed()
        }
    }

    /// A fast, zero-latency model for pure correctness tests where virtual
    /// time is irrelevant.
    pub fn instant() -> Self {
        Self {
            head_us: 0,
            get_us: 0,
            put_us: 0,
            delete_us: 0,
            list_base_us: 0,
            list_per_entry_us: 0,
            copy_base_us: 0,
            stream_bw: u64::MAX,
            copy_bw: u64::MAX,
            local_disk_bw: u64::MAX,
            data_scale: 1,
            scale_threshold: 0,
            jitter: 0.0,
        }
    }

    /// Scale simulated bytes up to paper-scale bytes. Sub-threshold
    /// payloads (metadata objects) keep their real size.
    #[inline]
    pub fn scaled_bytes(&self, bytes: u64) -> u64 {
        if bytes < self.scale_threshold {
            return bytes;
        }
        bytes.saturating_mul(self.data_scale)
    }

    /// Transfer time for `logical` (already-scaled) bytes over the
    /// per-stream link.
    #[inline]
    fn transfer_of_logical(&self, logical: u64) -> SimDuration {
        if self.stream_bw == u64::MAX {
            return SimDuration::ZERO;
        }
        SimDuration::from_micros(logical.saturating_mul(1_000_000) / self.stream_bw)
    }

    /// Transfer time for `bytes` *simulated* bytes over the per-stream link.
    #[inline]
    pub fn transfer_time(&self, bytes: u64) -> SimDuration {
        self.transfer_of_logical(self.scaled_bytes(bytes))
    }

    /// Scale a ranged-read `slice` of an object whose full size is
    /// `full_size`. Whether paper-scaling applies is a property of the
    /// *object* (a slice of a dataset part is dataset bytes, however small
    /// the slice), so the threshold test uses the full size and the
    /// multiplier then applies to the slice.
    #[inline]
    pub fn scaled_range_bytes(&self, slice: u64, full_size: u64) -> u64 {
        if full_size < self.scale_threshold {
            return slice;
        }
        slice.saturating_mul(self.data_scale)
    }

    /// Duration of a ranged GET returning `slice` simulated bytes of a
    /// `full_size`-byte object. This is also what prices a readahead
    /// *fill* ([`crate::fs::readahead::ReadaheadStream`]): one GET base
    /// latency plus transfer of the whole fetched window — so coalescing
    /// N small reads into one fill pays `get_us` once instead of N times
    /// while the bytes billed stay those that cross the wire.
    #[inline]
    pub fn range_get_duration(&self, slice: u64, full_size: u64) -> SimDuration {
        SimDuration::from_micros(self.get_us)
            + self.transfer_of_logical(self.scaled_range_bytes(slice, full_size))
    }

    /// Local-disk write/read time (buffer-to-disk connectors).
    #[inline]
    pub fn local_disk_time(&self, bytes: u64) -> SimDuration {
        if self.local_disk_bw == u64::MAX {
            return SimDuration::ZERO;
        }
        let logical = self.scaled_bytes(bytes);
        SimDuration::from_micros(logical.saturating_mul(1_000_000) / self.local_disk_bw)
    }

    /// Duration of one REST op. `bytes` is the payload size (simulated
    /// bytes); `entries` is the number of names for GET Container.
    pub fn op_duration(&self, kind: OpKind, bytes: u64, entries: usize) -> SimDuration {
        let base = match kind {
            OpKind::HeadObject | OpKind::HeadContainer => SimDuration::from_micros(self.head_us),
            OpKind::GetObject => {
                SimDuration::from_micros(self.get_us) + self.transfer_time(bytes)
            }
            OpKind::PutObject => {
                SimDuration::from_micros(self.put_us) + self.transfer_time(bytes)
            }
            OpKind::DeleteObject => SimDuration::from_micros(self.delete_us),
            OpKind::CopyObject => {
                let copy = if self.copy_bw == u64::MAX {
                    SimDuration::ZERO
                } else {
                    SimDuration::from_micros(
                        self.scaled_bytes(bytes).saturating_mul(1_000_000) / self.copy_bw,
                    )
                };
                SimDuration::from_micros(self.copy_base_us) + copy
            }
            OpKind::GetContainer => SimDuration::from_micros(
                self.list_base_us + self.list_per_entry_us * entries as u64,
            ),
        };
        base
    }

    /// Apply jitter drawn as a uniform in [-1,1] to a duration.
    pub fn jittered(&self, d: SimDuration, unit_draw: f64) -> SimDuration {
        if self.jitter == 0.0 {
            return d;
        }
        let factor = 1.0 + self.jitter * (2.0 * unit_draw - 1.0);
        SimDuration::from_secs_f64(d.as_secs_f64() * factor.max(0.0))
    }
}

impl Default for LatencyModel {
    fn default() -> Self {
        Self::paper_testbed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_is_cheapest() {
        let m = LatencyModel::paper_testbed();
        let head = m.op_duration(OpKind::HeadObject, 0, 0);
        let get = m.op_duration(OpKind::GetObject, 0, 0);
        let put = m.op_duration(OpKind::PutObject, 0, 0);
        assert!(head < get && head < put);
    }

    #[test]
    fn transfer_scales_with_bytes_and_data_scale() {
        let m = LatencyModel::paper_testbed();
        let t1 = m.op_duration(OpKind::GetObject, 26_000_000, 0);
        // 26 MB at 26 MB/s = 1s + 25ms base.
        assert_eq!(t1.as_micros(), 1_000_000 + 25_000);

        let ms = LatencyModel::paper_testbed_scaled(1000);
        let t2 = ms.op_duration(OpKind::GetObject, 26_000, 0);
        // 26 KB scaled 1000x = same as above.
        assert_eq!(t2, t1);
    }

    #[test]
    fn range_scaling_follows_the_full_object_size() {
        let m = LatencyModel::paper_testbed_scaled(4096);
        // A small slice of a big (scaled) data object IS dataset bytes:
        // the multiplier applies even though the slice is sub-threshold.
        assert_eq!(m.scaled_range_bytes(1_000, 32 * 1024), 1_000 * 4096);
        // A slice of a small metadata object keeps its real size.
        assert_eq!(m.scaled_range_bytes(10, 100), 10);
        // Whole-object ranges agree with the plain GET scaling.
        assert_eq!(m.scaled_range_bytes(32 * 1024, 32 * 1024), m.scaled_bytes(32 * 1024));
        assert_eq!(
            m.range_get_duration(32 * 1024, 32 * 1024),
            m.op_duration(OpKind::GetObject, 32 * 1024, 0)
        );
    }

    #[test]
    fn one_fill_undercuts_equivalent_sliver_gets() {
        // The readahead economics: fetching a 64 KiB window in one ranged
        // GET costs one first-byte latency; the same bytes as 64 separate
        // 1 KiB GETs cost sixty-four. Transfer time is identical.
        let m = LatencyModel::paper_testbed();
        // Sliver size divisible by stream_bw/1e6 = 26 so integer-µs
        // transfer times add exactly.
        let full = 2_000_000;
        let fill = m.range_get_duration(64 * 26_000, full);
        let slivers: u64 = (0..64)
            .map(|_| m.range_get_duration(26_000, full).as_micros())
            .sum();
        assert_eq!(
            slivers - fill.as_micros(),
            63 * m.get_us,
            "coalescing saves exactly the per-request latencies"
        );
    }

    #[test]
    fn copy_charges_server_side_bandwidth() {
        let m = LatencyModel::paper_testbed();
        let c = m.op_duration(OpKind::CopyObject, 120_000_000, 0);
        assert_eq!(c.as_micros(), 40_000 + 1_000_000);
    }

    #[test]
    fn listing_grows_with_entries() {
        let m = LatencyModel::paper_testbed();
        let small = m.op_duration(OpKind::GetContainer, 0, 10);
        let big = m.op_duration(OpKind::GetContainer, 0, 10_000);
        assert!(big > small);
        assert_eq!(big.as_micros(), 50_000 + 10 * 10_000);
    }

    #[test]
    fn instant_model_is_zero() {
        let m = LatencyModel::instant();
        for k in OpKind::ALL {
            assert_eq!(m.op_duration(k, 1 << 30, 100_000), SimDuration::ZERO);
        }
        assert_eq!(m.local_disk_time(1 << 40), SimDuration::ZERO);
    }

    #[test]
    fn jitter_bounds() {
        let mut m = LatencyModel::paper_testbed();
        m.jitter = 0.1;
        let d = SimDuration::from_secs(10);
        let lo = m.jittered(d, 0.0);
        let hi = m.jittered(d, 1.0);
        assert_eq!(lo.as_micros(), 9_000_000);
        assert_eq!(hi.as_micros(), 11_000_000);
        m.jitter = 0.0;
        assert_eq!(m.jittered(d, 0.9), d);
    }
}
