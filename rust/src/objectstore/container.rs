//! A container (bucket): a flat, sorted map of object names with
//! eventually-consistent listing views (see [`super::consistency`]).

use super::consistency::ConsistencyModel;
use super::object::Object;
use crate::simclock::SimInstant;
use std::collections::BTreeMap;

/// One name slot in a container. Tracks both the authoritative object state
/// (for GET/HEAD, read-after-write consistent) and the *listing* view (for
/// GET Container, eventually consistent).
#[derive(Debug, Clone)]
struct Entry {
    /// Authoritative state: `Some` = exists, `None` = deleted.
    obj: Option<Object>,
    /// When this name starts appearing in listings (after create).
    list_visible_at: SimInstant,
    /// After a delete: the stale object that listings may still show, and
    /// the time at which it finally disappears.
    stale: Option<(Object, SimInstant)>,
}

/// Summary of one object in a listing (name + size + etag, like an S3
/// `ListObjects` entry).
#[derive(Debug, Clone, PartialEq)]
pub struct ObjectSummary {
    pub name: String,
    pub size: u64,
    pub etag: u64,
}

/// Result of a GET Container: objects plus collapsed common prefixes when a
/// delimiter was supplied (S3/Swift "directory" emulation).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Listing {
    pub objects: Vec<ObjectSummary>,
    pub common_prefixes: Vec<String>,
}

impl Listing {
    pub fn is_empty(&self) -> bool {
        self.objects.is_empty() && self.common_prefixes.is_empty()
    }

    pub fn len(&self) -> usize {
        self.objects.len() + self.common_prefixes.len()
    }
}

/// A container of objects.
#[derive(Debug, Default)]
pub struct Container {
    entries: BTreeMap<String, Entry>,
    pub created_at: SimInstant,
}

impl Container {
    pub fn new(created_at: SimInstant) -> Self {
        Self {
            entries: BTreeMap::new(),
            created_at,
        }
    }

    /// Atomic PUT (create or replace).
    pub fn put(&mut self, name: &str, obj: Object, now: SimInstant, cm: &ConsistencyModel) {
        let visible_at = now + cm.create_lag;
        match self.entries.get_mut(name) {
            Some(e) => {
                // Replacing: if the name was already visible in listings it
                // stays visible; a fresh create after delete gets a new lag.
                let already_visible = e.obj.is_some() && e.list_visible_at <= now;
                e.obj = Some(obj);
                if !already_visible {
                    e.list_visible_at = visible_at;
                }
                e.stale = None;
            }
            None => {
                self.entries.insert(
                    name.to_string(),
                    Entry {
                        obj: Some(obj),
                        list_visible_at: visible_at,
                        stale: None,
                    },
                );
            }
        }
    }

    /// Authoritative lookup (GET/HEAD path) — read-after-write consistent.
    pub fn get(&self, name: &str) -> Option<&Object> {
        self.entries.get(name).and_then(|e| e.obj.as_ref())
    }

    /// DELETE. Returns true if the object existed. The name may keep
    /// appearing in listings for `delete_lag`.
    pub fn delete(&mut self, name: &str, now: SimInstant, cm: &ConsistencyModel) -> bool {
        match self.entries.get_mut(name) {
            Some(e) if e.obj.is_some() => {
                let was_listed = e.list_visible_at <= now;
                let old = e.obj.take().unwrap();
                e.stale = if was_listed && cm.delete_lag.as_micros() > 0 {
                    Some((old, now + cm.delete_lag))
                } else {
                    None
                };
                true
            }
            _ => false,
        }
    }

    /// Number of live objects (authoritative view).
    pub fn live_count(&self) -> usize {
        self.entries.values().filter(|e| e.obj.is_some()).count()
    }

    /// Total live bytes (authoritative view).
    pub fn live_bytes(&self) -> u64 {
        self.entries
            .values()
            .filter_map(|e| e.obj.as_ref())
            .map(|o| o.size())
            .sum()
    }

    /// Iterate authoritative live objects (name, object) — used by tests and
    /// the harness, NOT by connectors (they must go through listings).
    pub fn iter_live(&self) -> impl Iterator<Item = (&str, &Object)> {
        self.entries
            .iter()
            .filter_map(|(k, e)| e.obj.as_ref().map(|o| (k.as_str(), o)))
    }

    /// GET Container — the *eventually consistent* listing at time `now`,
    /// filtered by `prefix`, optionally collapsing at `delimiter`.
    pub fn list(&self, now: SimInstant, prefix: &str, delimiter: Option<char>) -> Listing {
        let mut listing = Listing::default();
        let range = self.entries.range(prefix.to_string()..);
        for (name, e) in range {
            if !name.starts_with(prefix) {
                break; // BTreeMap is sorted; past the prefix block.
            }
            // Visibility per the consistency model:
            let visible: Option<&Object> = if let Some(obj) = &e.obj {
                if e.list_visible_at <= now {
                    Some(obj)
                } else {
                    None // created, but not yet listed
                }
            } else if let Some((stale, until)) = &e.stale {
                if *until > now {
                    Some(stale) // deleted, but still listed
                } else {
                    None
                }
            } else {
                None
            };
            let Some(obj) = visible else { continue };
            let rest = &name[prefix.len()..];
            if let Some(d) = delimiter {
                if let Some(i) = rest.find(d) {
                    let cp = format!("{}{}", prefix, &rest[..=i]);
                    if listing.common_prefixes.last() != Some(&cp) {
                        listing.common_prefixes.push(cp);
                    }
                    continue;
                }
            }
            listing.objects.push(ObjectSummary {
                name: name.clone(),
                size: obj.size(),
                etag: obj.etag,
            });
        }
        listing
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objectstore::object::Metadata;
    use crate::simclock::SimDuration;

    fn obj(data: &[u8], t: u64) -> Object {
        Object::new(data.to_vec(), Metadata::new(), SimInstant(t))
    }

    fn strong() -> ConsistencyModel {
        ConsistencyModel::strong()
    }

    #[test]
    fn put_get_delete_authoritative() {
        let cm = strong();
        let mut c = Container::new(SimInstant::EPOCH);
        c.put("a/b", obj(b"xy", 0), SimInstant(0), &cm);
        assert_eq!(c.get("a/b").unwrap().size(), 2);
        assert!(c.get("a/c").is_none());
        assert!(c.delete("a/b", SimInstant(1), &cm));
        assert!(c.get("a/b").is_none());
        assert!(!c.delete("a/b", SimInstant(2), &cm));
    }

    #[test]
    fn strong_listing_with_prefix() {
        let cm = strong();
        let mut c = Container::new(SimInstant::EPOCH);
        for name in ["d/x", "d/y", "e/z", "d2"] {
            c.put(name, obj(b"1", 0), SimInstant(0), &cm);
        }
        let l = c.list(SimInstant(0), "d/", None);
        assert_eq!(
            l.objects.iter().map(|o| o.name.as_str()).collect::<Vec<_>>(),
            vec!["d/x", "d/y"]
        );
    }

    #[test]
    fn delimiter_collapses_prefixes() {
        let cm = strong();
        let mut c = Container::new(SimInstant::EPOCH);
        for name in ["ds/part-0", "ds/_temporary/0/t1", "ds/_temporary/0/t2", "ds/sub/deep/x"] {
            c.put(name, obj(b"1", 0), SimInstant(0), &cm);
        }
        let l = c.list(SimInstant(0), "ds/", Some('/'));
        assert_eq!(
            l.objects.iter().map(|o| o.name.as_str()).collect::<Vec<_>>(),
            vec!["ds/part-0"]
        );
        assert_eq!(l.common_prefixes, vec!["ds/_temporary/", "ds/sub/"]);
    }

    #[test]
    fn eventual_create_lag_hides_new_objects_from_listing() {
        let cm = ConsistencyModel {
            create_lag: SimDuration::from_secs(5),
            delete_lag: SimDuration::ZERO,
        };
        let mut c = Container::new(SimInstant::EPOCH);
        c.put("k", obj(b"v", 0), SimInstant(0), &cm);
        // GET sees it immediately (read-after-write)...
        assert!(c.get("k").is_some());
        // ...but the listing doesn't until t=5s.
        assert!(c.list(SimInstant(0), "", None).is_empty());
        assert!(c.list(SimInstant(4_999_999), "", None).is_empty());
        assert_eq!(c.list(SimInstant(5_000_000), "", None).objects.len(), 1);
    }

    #[test]
    fn eventual_delete_lag_keeps_ghost_in_listing() {
        let cm = ConsistencyModel {
            create_lag: SimDuration::ZERO,
            delete_lag: SimDuration::from_secs(3),
        };
        let mut c = Container::new(SimInstant::EPOCH);
        c.put("k", obj(b"vv", 0), SimInstant(0), &cm);
        c.delete("k", SimInstant(1_000_000), &cm);
        // GET is strongly consistent: gone.
        assert!(c.get("k").is_none());
        // Listing still shows the ghost until t=4s.
        let l = c.list(SimInstant(2_000_000), "", None);
        assert_eq!(l.objects.len(), 1);
        assert_eq!(l.objects[0].size, 2);
        assert!(c.list(SimInstant(4_000_000), "", None).is_empty());
    }

    #[test]
    fn delete_before_listed_leaves_no_ghost() {
        // Created and deleted within the create-lag window: never listed.
        let cm = ConsistencyModel {
            create_lag: SimDuration::from_secs(10),
            delete_lag: SimDuration::from_secs(10),
        };
        let mut c = Container::new(SimInstant::EPOCH);
        c.put("k", obj(b"v", 0), SimInstant(0), &cm);
        c.delete("k", SimInstant(1), &cm);
        for t in [0u64, 1, 5_000_000, 20_000_000] {
            assert!(c.list(SimInstant(t), "", None).is_empty(), "t={t}");
        }
    }

    #[test]
    fn replace_keeps_visibility() {
        let cm = ConsistencyModel {
            create_lag: SimDuration::from_secs(5),
            delete_lag: SimDuration::ZERO,
        };
        let mut c = Container::new(SimInstant::EPOCH);
        c.put("k", obj(b"1", 0), SimInstant(0), &cm);
        // Visible at t=5s; replace at t=6s must stay visible immediately.
        c.put("k", obj(b"22", 0), SimInstant(6_000_000), &cm);
        let l = c.list(SimInstant(6_000_000), "", None);
        assert_eq!(l.objects.len(), 1);
        assert_eq!(l.objects[0].size, 2);
    }

    #[test]
    fn live_accounting() {
        let cm = strong();
        let mut c = Container::new(SimInstant::EPOCH);
        c.put("a", obj(b"123", 0), SimInstant(0), &cm);
        c.put("b", obj(b"4567", 0), SimInstant(0), &cm);
        assert_eq!(c.live_count(), 2);
        assert_eq!(c.live_bytes(), 7);
        c.delete("a", SimInstant(1), &cm);
        assert_eq!(c.live_count(), 1);
        assert_eq!(c.live_bytes(), 4);
        assert_eq!(c.iter_live().count(), 1);
    }
}
