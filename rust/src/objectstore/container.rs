//! Listing types for the flat-namespace-with-hierarchical-naming model:
//! object summaries, the GET Container result, and the delimiter collapse
//! that emulates directories (S3/Swift `prefix` + `delimiter` semantics).
//!
//! Storage itself lives behind [`super::backend::Backend`]; the
//! eventually-consistent *visibility* of names in listings is applied by
//! the front end's [`super::visibility`] overlay before the collapse here.

/// Summary of one object in a listing (name + size + etag, like an S3
/// `ListObjects` entry).
#[derive(Debug, Clone, PartialEq)]
pub struct ObjectSummary {
    pub name: String,
    pub size: u64,
    pub etag: u64,
}

/// Result of a GET Container: objects plus collapsed common prefixes when a
/// delimiter was supplied (S3/Swift "directory" emulation).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Listing {
    pub objects: Vec<ObjectSummary>,
    pub common_prefixes: Vec<String>,
}

impl Listing {
    pub fn is_empty(&self) -> bool {
        self.objects.is_empty() && self.common_prefixes.is_empty()
    }

    pub fn len(&self) -> usize {
        self.objects.len() + self.common_prefixes.len()
    }

    /// Build a listing from visible entries (sorted ascending, all names
    /// starting with `prefix`), collapsing names that contain `delimiter`
    /// after the prefix into deduplicated common prefixes.
    pub fn collapse(prefix: &str, delimiter: Option<char>, entries: Vec<ObjectSummary>) -> Listing {
        let mut listing = Listing::default();
        for entry in entries {
            debug_assert!(entry.name.starts_with(prefix));
            let rest = &entry.name[prefix.len()..];
            if let Some(d) = delimiter {
                if let Some(i) = rest.find(d) {
                    let cp = format!("{}{}", prefix, &rest[..=i]);
                    if listing.common_prefixes.last() != Some(&cp) {
                        listing.common_prefixes.push(cp);
                    }
                    continue;
                }
            }
            listing.objects.push(entry);
        }
        listing
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn summary(name: &str) -> ObjectSummary {
        ObjectSummary {
            name: name.to_string(),
            size: 1,
            etag: 0,
        }
    }

    #[test]
    fn no_delimiter_keeps_all_objects() {
        let l = Listing::collapse("d/", None, vec![summary("d/x"), summary("d/y")]);
        assert_eq!(
            l.objects.iter().map(|o| o.name.as_str()).collect::<Vec<_>>(),
            vec!["d/x", "d/y"]
        );
        assert!(l.common_prefixes.is_empty());
        assert_eq!(l.len(), 2);
        assert!(!l.is_empty());
    }

    #[test]
    fn delimiter_collapses_prefixes() {
        let l = Listing::collapse(
            "ds/",
            Some('/'),
            vec![
                summary("ds/_temporary/0/t1"),
                summary("ds/_temporary/0/t2"),
                summary("ds/part-0"),
                summary("ds/sub/deep/x"),
            ],
        );
        assert_eq!(
            l.objects.iter().map(|o| o.name.as_str()).collect::<Vec<_>>(),
            vec!["ds/part-0"]
        );
        assert_eq!(l.common_prefixes, vec!["ds/_temporary/", "ds/sub/"]);
        assert_eq!(l.len(), 3);
    }

    #[test]
    fn empty_listing() {
        let l = Listing::collapse("", Some('/'), vec![]);
        assert!(l.is_empty());
        assert_eq!(l.len(), 0);
    }
}
