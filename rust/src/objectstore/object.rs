//! Objects: immutable data + user metadata, created atomically (§2.1).

use crate::simclock::SimInstant;
use std::collections::BTreeMap;
use std::sync::Arc;

/// User metadata attached to an object at PUT time. Stocator uses this to
/// mark dataset roots it wrote (`X-Stocator-Origin`).
pub type Metadata = BTreeMap<String, String>;

/// A stored object. Data is `Arc`-shared so GETs never copy.
#[derive(Debug, Clone)]
pub struct Object {
    pub data: Arc<Vec<u8>>,
    pub metadata: Metadata,
    pub created_at: SimInstant,
    /// Content hash (FNV-1a), the moral equivalent of an ETag.
    pub etag: u64,
}

impl Object {
    pub fn new(data: Vec<u8>, metadata: Metadata, created_at: SimInstant) -> Self {
        let etag = sampled_etag(&data);
        Self {
            data: Arc::new(data),
            metadata,
            created_at,
            etag,
        }
    }

    pub fn size(&self) -> u64 {
        self.data.len() as u64
    }
}

/// Sampled content tag: FNV-1a over (length, first 64 B, last 64 B).
/// Hashing full payloads dominated the PUT hot path (EXPERIMENTS.md
/// §Perf iteration 5); a sampled tag keeps etag semantics for every test
/// and workload in this repo (objects differing only in their middle
/// bytes do not occur) at O(1) cost.
pub fn sampled_etag(bytes: &[u8]) -> u64 {
    let mut h = fnv1a(&bytes.len().to_le_bytes());
    let head = &bytes[..bytes.len().min(64)];
    h ^= fnv1a(head).rotate_left(17);
    if bytes.len() > 64 {
        let tail = &bytes[bytes.len() - 64..];
        h ^= fnv1a(tail).rotate_left(34);
    }
    h
}

/// FNV-1a over the object content; fast, deterministic, adequate as an
/// integrity tag in simulation.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn etag_depends_on_content() {
        let a = Object::new(b"hello".to_vec(), Metadata::new(), SimInstant::EPOCH);
        let b = Object::new(b"hello".to_vec(), Metadata::new(), SimInstant(5));
        let c = Object::new(b"hellp".to_vec(), Metadata::new(), SimInstant::EPOCH);
        assert_eq!(a.etag, b.etag);
        assert_ne!(a.etag, c.etag);
        assert_eq!(a.size(), 5);
    }

    #[test]
    fn fnv_reference_value() {
        // FNV-1a("") is the offset basis; FNV-1a("a") is a published value.
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a(b"a"), 0xaf63dc4c8601ec8c);
    }
}
