//! The object store front end: REST-shaped API, operation accounting,
//! virtual-time costing, consistency enforcement.
//!
//! Every public operation returns `(Result<T, StoreError>, SimDuration)`:
//! failed operations (e.g. a HEAD on a missing object — the bread and
//! butter of the legacy connectors' existence checks) still cost wire time,
//! and the paper's op counts include them.
//!
//! Storage itself is delegated to a pluggable [`Backend`] (selected via
//! [`StoreConfig::backend`]): everything the paper measures — which REST
//! ops a connector issues, what they cost on the virtual clock, how
//! eventually-consistent listings lag mutations — happens in this front
//! end, so op counts and simulated runtimes are backend-invariant by
//! construction.
//!
//! # Front-end scaling rules
//!
//! The front end is built to scale with real writer threads above a
//! sharded backend, under three rules:
//!
//! - **Op accounting is lock-free.** Counts and wire bytes live in a
//!   fixed per-[`OpKind`] array of relaxed `AtomicU64`s
//!   ([`LiveCounters`]); reads take a [`LiveCounters::snapshot`]. No
//!   operation ever takes a lock to be counted, and the idle fault path
//!   ([`ObjectStore::faults_idle`]) is one relaxed load.
//! - **Mutable front-end state is striped.** The visibility overlay and
//!   the multipart trackers are split across [`StoreConfig::stripes`]
//!   `Mutex` stripes (default [`DEFAULT_SHARDS`]). Keys stripe by the
//!   SAME FNV hash as `ShardedMemBackend`'s shard function; multipart
//!   trackers stripe by upload id. `stripes: 1` is exactly the legacy
//!   single-mutex layout, and striping never changes per-key
//!   create-lag/delete-lag semantics — listings chain the overlay across
//!   stripes (each key's pending/ghost state lives in exactly one
//!   stripe, so the passes compose to the single-map result).
//! - **Jitter is per-thread.** Each thread draws from its own PCG32
//!   stream instead of a global `Mutex<Pcg32>` (see
//!   [`ObjectStore::jitter_draw`]); the first-drawing thread gets the
//!   legacy stream, so single-threaded runs are byte-identical.
//!
//! Net effect: the strong-consistency, zero-jitter, idle-fault PUT/GET
//! hot path takes **zero** front-end locks (debug builds count stripe
//! locks — see [`ObjectStore::debug_front_end_locks`]).

use super::backend::{make_backend, Backend, BackendError, DEFAULT_PAGE_SIZE, DEFAULT_SHARDS};
use super::backend::{BackendKind, ObjectStat};
use super::consistency::ConsistencyModel;
use super::container::Listing;
use super::faults::{FaultClass, FaultInjector, FaultOp, FaultSpec, InjectedFault, RetryPolicy};
use super::latency::LatencyModel;
use super::multipart::DEFAULT_MIN_PART_SIZE;
use super::object::{fnv1a, Metadata, Object};
use super::visibility::VisibilityMap;
use crate::metrics::{LiveCounters, OpCounts, OpKind};
use crate::simclock::{SimDuration, SimInstant};
use crate::util::rng::Pcg32;
use std::cell::RefCell;
use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// Errors mirroring the REST error space the connectors care about.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StoreError {
    NoSuchContainer(String),
    NoSuchKey(String),
    ContainerAlreadyExists(String),
    NoSuchUpload(u64),
    InvalidRequest(String),
    /// Ranged GET with an offset strictly past end-of-file (HTTP 416).
    InvalidRange(String),
    /// A retryable 5xx/timeout injected by the [`FaultInjector`]. The
    /// request reached the store (latency burned, op counted, payload
    /// bytes on the wire) but had no effect; connectors may retry it
    /// under their [`RetryPolicy`].
    TransientFailure(String),
    /// A 429 Too Many Requests injected by the [`FaultInjector`]: the
    /// store shed the request before reading its body, so the op and
    /// base latency are burned but **zero** payload bytes crossed the
    /// wire. Retryable like a 503, but connectors pause for the flat
    /// Retry-After ([`RetryPolicy::retry_after_us`]) instead of the
    /// exponential backoff.
    Throttled(String),
    /// Real-IO failure in a persistent backend (no REST analogue).
    Backend(String),
}

impl fmt::Display for StoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StoreError::NoSuchContainer(c) => write!(f, "404 NoSuchContainer: {c}"),
            StoreError::NoSuchKey(k) => write!(f, "404 NoSuchKey: {k}"),
            StoreError::ContainerAlreadyExists(c) => write!(f, "409 ContainerExists: {c}"),
            StoreError::NoSuchUpload(id) => write!(f, "404 NoSuchUpload: {id}"),
            StoreError::InvalidRequest(m) => write!(f, "400 InvalidRequest: {m}"),
            StoreError::InvalidRange(m) => write!(f, "416 InvalidRange: {m}"),
            StoreError::TransientFailure(m) => write!(f, "503 Transient: {m}"),
            StoreError::Throttled(m) => write!(f, "429 Throttled: {m}"),
            StoreError::Backend(m) => write!(f, "500 BackendIo: {m}"),
        }
    }
}

impl std::error::Error for StoreError {}

impl StoreError {
    /// The retryable failure classes the stream-layer retry contract
    /// covers: injected 503 transients and 429 throttles.
    pub fn is_transient(&self) -> bool {
        matches!(
            self,
            StoreError::TransientFailure(_) | StoreError::Throttled(_)
        )
    }

    /// Trace-line tag for a failed transient request (`"503 transient"`
    /// / `"429 throttle"`).
    pub fn transient_tag(&self) -> &'static str {
        match self {
            StoreError::TransientFailure(_) => "503 transient",
            StoreError::Throttled(_) => "429 throttle",
            _ => "error",
        }
    }

    /// Extract the failure description (for exhaustion reporting).
    pub fn into_msg(self) -> String {
        match self {
            StoreError::TransientFailure(m) | StoreError::Throttled(m) => m,
            other => other.to_string(),
        }
    }
}

impl From<BackendError> for StoreError {
    fn from(e: BackendError) -> Self {
        match e {
            BackendError::NoSuchContainer(c) => StoreError::NoSuchContainer(c),
            BackendError::NoSuchKey(k) => StoreError::NoSuchKey(k),
            BackendError::ContainerAlreadyExists(c) => StoreError::ContainerAlreadyExists(c),
            BackendError::NoSuchUpload(id) => StoreError::NoSuchUpload(id),
            BackendError::InvalidRequest(m) => StoreError::InvalidRequest(m),
            BackendError::InvalidRange(m) => StoreError::InvalidRange(m),
            BackendError::Io(m) => StoreError::Backend(m),
        }
    }
}

/// Head-object response: metadata + size, no data (HTTP HEAD).
#[derive(Debug, Clone)]
pub struct HeadResult {
    pub size: u64,
    pub etag: u64,
    pub metadata: Metadata,
    pub created_at: SimInstant,
}

impl From<ObjectStat> for HeadResult {
    fn from(s: ObjectStat) -> Self {
        HeadResult {
            size: s.size,
            etag: s.etag,
            metadata: s.metadata,
            created_at: s.created_at,
        }
    }
}

/// Get-object response: data + everything HEAD returns (the read-path
/// optimization in paper §3.4 relies on GET carrying the metadata).
#[derive(Debug, Clone)]
pub struct GetResult {
    pub data: Arc<Vec<u8>>,
    pub head: HeadResult,
}

/// Store construction parameters.
#[derive(Debug, Clone)]
pub struct StoreConfig {
    pub latency: LatencyModel,
    pub consistency: ConsistencyModel,
    /// Minimum multipart part size (S3 semantics).
    pub min_part_size: u64,
    /// Seed for the jitter stream.
    pub seed: u64,
    /// Which storage backend holds the bytes.
    pub backend: BackendKind,
    /// Connector-side readahead window in *simulated* bytes; 0 disables
    /// it. When set, every connector wraps the streams it hands out in a
    /// [`crate::fs::readahead::ReadaheadStream`], so small sequential
    /// `read_range` calls coalesce into few ranged GETs. Off by default:
    /// with 0, every read issues its own GET and all op counts and
    /// virtual runtimes are byte-identical to the pre-readahead stack.
    pub readahead: u64,
    /// Deterministic transient-fault schedule (`--faults` on the CLI).
    /// Empty by default: nothing fires and every golden REST sequence
    /// and virtual runtime reproduces the fault-free stack exactly.
    pub faults: FaultSpec,
    /// The stream-layer retry contract the connectors follow
    /// (`--retries` on the CLI). Zero retries by default.
    pub retry: RetryPolicy,
    /// Mutex stripes for the front end's own mutable state — the
    /// visibility overlay and the multipart trackers (clamped to ≥ 1).
    /// `1` reproduces the legacy global-lock layout exactly; the default
    /// ([`DEFAULT_SHARDS`]) matches the sharded backend so front-end
    /// striping and backend sharding agree about which keys collide.
    /// Striping is invisible to every single-threaded result: op counts,
    /// fault traces, visible listings and virtual runtimes are
    /// stripe-count-invariant (pinned by goldens + conformance).
    pub stripes: usize,
}

impl Default for StoreConfig {
    fn default() -> Self {
        Self {
            latency: LatencyModel::paper_testbed(),
            consistency: ConsistencyModel::eventual(),
            min_part_size: DEFAULT_MIN_PART_SIZE,
            seed: 0,
            backend: BackendKind::default(),
            readahead: 0,
            faults: FaultSpec::none(),
            retry: RetryPolicy::none(),
            stripes: DEFAULT_SHARDS,
        }
    }
}

impl StoreConfig {
    /// Strong consistency + zero latency: pure protocol-correctness tests.
    pub fn instant_strong() -> Self {
        Self {
            latency: LatencyModel::instant(),
            consistency: ConsistencyModel::strong(),
            min_part_size: 0,
            ..Self::default()
        }
    }

    /// Zero latency but eventually-consistent listings.
    pub fn instant_eventual() -> Self {
        Self {
            latency: LatencyModel::instant(),
            consistency: ConsistencyModel::eventual(),
            min_part_size: 0,
            ..Self::default()
        }
    }
}

/// Front-end record of one in-flight multipart upload: who it targets,
/// when it started, and the (scaled) bytes parked in it. This is what
/// the age-based GC sweep and the stranded-bytes accounting read — the
/// backend only stores the part buffers.
#[derive(Debug, Clone)]
struct MultipartTracker {
    /// Target object key (what fault rules match against).
    key: String,
    started: SimInstant,
    /// part number -> paper-scaled bytes uploaded for that part.
    part_bytes: HashMap<u32, u64>,
}

/// Result of one [`ObjectStore::sweep_stale_multiparts`] pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct MultipartSweep {
    /// Uploads aborted by this sweep.
    pub aborted: usize,
    /// Paper-scaled bytes those uploads had parked (freed by the sweep).
    pub freed_bytes: u64,
}

/// Allocates [`ObjectStore::jitter_key`] slots. Monotonic, never reused:
/// a dead store's stale thread-local RNG entries can never be adopted by
/// a new store.
static STORE_IDS: AtomicU64 = AtomicU64::new(0);

thread_local! {
    /// This thread's private jitter RNGs, one per store it has drawn
    /// from (keyed by [`ObjectStore::jitter_key`]). Entries outlive
    /// their store (a few dozen bytes each) but are never shared, so
    /// the jitter path takes no lock. See [`ObjectStore::jitter_draw`].
    static JITTER_RNGS: RefCell<HashMap<u64, Pcg32>> = RefCell::new(HashMap::new());
}

/// The shared object store. Safe to use from the executor threads of the
/// Spark simulator: the hot path contends only on the backend's shard
/// locks (and, under eventual consistency, the front end's own
/// visibility stripes — see the module docs for the striping rules).
pub struct ObjectStore {
    backend: Box<dyn Backend>,
    /// Visibility overlay, striped by the backend's shard hash over
    /// (container, key). [`StoreConfig::stripes`] entries; 1 = the
    /// legacy single-mutex layout.
    visibility: Vec<Mutex<VisibilityMap>>,
    counters: LiveCounters,
    injector: FaultInjector,
    /// In-flight multipart uploads (see [`MultipartTracker`]), striped
    /// by the FNV hash of the upload id (parts and completes only know
    /// the id, not the target key).
    multipart: Vec<Mutex<HashMap<u64, MultipartTracker>>>,
    /// This store's slot in each thread's [`JITTER_RNGS`] map.
    jitter_key: u64,
    /// Next PCG32 stream to hand out to a first-drawing thread.
    next_stream: AtomicU64,
    /// Debug builds count every front-end stripe lock taken, so tests
    /// can assert the idle hot path takes none.
    #[cfg(debug_assertions)]
    front_end_locks: AtomicU64,
    pub config: StoreConfig,
}

impl ObjectStore {
    pub fn new(config: StoreConfig) -> Arc<Self> {
        let backend = make_backend(&config.backend);
        Self::with_backend(config, backend)
    }

    /// Run on an explicit backend instance (tests, pre-opened roots).
    pub fn with_backend(config: StoreConfig, backend: Box<dyn Backend>) -> Arc<Self> {
        let stripes = config.stripes.max(1);
        Arc::new(Self {
            backend,
            visibility: (0..stripes)
                .map(|_| Mutex::new(VisibilityMap::default()))
                .collect(),
            counters: LiveCounters::new(),
            injector: FaultInjector::with_seed(&config.faults, config.seed),
            multipart: (0..stripes).map(|_| Mutex::new(HashMap::new())).collect(),
            jitter_key: STORE_IDS.fetch_add(1, Ordering::Relaxed),
            next_stream: AtomicU64::new(0),
            #[cfg(debug_assertions)]
            front_end_locks: AtomicU64::new(0),
            config,
        })
    }

    /// Count one front-end stripe lock (debug builds only — compiles to
    /// nothing in release).
    #[inline]
    fn note_front_end_lock(&self) {
        #[cfg(debug_assertions)]
        self.front_end_locks.fetch_add(1, Ordering::Relaxed);
    }

    /// How many front-end stripe locks this store has taken (always 0 in
    /// release builds, where counting is compiled out). The zero-lock
    /// invariant: under strong consistency with zero jitter and no armed
    /// faults, PUT/GET/HEAD/DELETE/LIST leave this at 0 — only multipart
    /// ops (whose trackers are front-end state) and the
    /// eventual-consistency overlay take stripes.
    pub fn debug_front_end_locks(&self) -> u64 {
        #[cfg(debug_assertions)]
        {
            self.front_end_locks.load(Ordering::Relaxed)
        }
        #[cfg(not(debug_assertions))]
        {
            0
        }
    }

    /// Lock the visibility stripe that owns `(container, key)` — the
    /// SAME shard hash as `ShardedMemBackend`, so front-end striping and
    /// backend sharding agree about which keys collide.
    fn visibility_stripe(&self, container: &str, key: &str) -> MutexGuard<'_, VisibilityMap> {
        self.note_front_end_lock();
        let h = fnv1a(container.as_bytes()) ^ fnv1a(key.as_bytes()).rotate_left(13);
        self.visibility[(h % self.visibility.len() as u64) as usize]
            .lock()
            .unwrap()
    }

    /// Lock the multipart stripe that owns `upload_id`.
    fn multipart_stripe(&self, upload_id: u64) -> MutexGuard<'_, HashMap<u64, MultipartTracker>> {
        self.note_front_end_lock();
        let h = fnv1a(&upload_id.to_le_bytes());
        self.multipart[(h % self.multipart.len() as u64) as usize]
            .lock()
            .unwrap()
    }

    /// One jitter draw from the calling thread's private PCG32 stream —
    /// no lock, ever. The FIRST thread to draw from this store gets
    /// stream slot 0: exactly the legacy global stream
    /// `Pcg32::new(seed ^ 0x5106_a70c)`, so every single-threaded run is
    /// byte-identical to the pre-striping front end (pinned by the
    /// goldens). Later threads get `Pcg32::with_stream(seed, slot)`
    /// variants: each thread's draw sequence is internally
    /// deterministic, but WHICH slot a thread gets is first-draw
    /// allocation order — multi-threaded jitter is decorrelated and
    /// per-thread-deterministic, not reproducible across racy runs.
    fn jitter_draw(&self) -> f64 {
        JITTER_RNGS.with(|cell| {
            let mut map = cell.borrow_mut();
            let rng = map.entry(self.jitter_key).or_insert_with(|| {
                let stream = self.next_stream.fetch_add(1, Ordering::Relaxed);
                let seed = self.config.seed ^ 0x5106_a70c;
                if stream == 0 {
                    Pcg32::new(seed)
                } else {
                    Pcg32::with_stream(seed, stream)
                }
            });
            rng.next_f64()
        })
    }

    /// Arm additional fault rules mid-run (fresh match counters, counted
    /// from now). This is how the Spark driver turns a
    /// [`crate::spark::FaultKind::TransientOps`] schedule into live REST
    /// faults for one task attempt.
    pub fn arm_faults(&self, spec: &FaultSpec) {
        self.injector.arm(spec);
    }

    /// Whether the fault injector has no rules armed (see
    /// [`FaultInjector::is_idle`]): connectors use this to skip the
    /// defensive payload clones their retry loops would otherwise make.
    pub fn faults_idle(&self) -> bool {
        self.injector.is_idle()
    }

    /// The backend's human-readable name (`mem`, `sharded-mem`, `local-fs`).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Live op/byte counters (for harness snapshots).
    pub fn counters(&self) -> OpCounts {
        self.counters.snapshot()
    }

    /// Record the op and price it on the virtual clock. The jitter stream
    /// is only consulted when jitter is enabled, so the hot path takes no
    /// lock here.
    fn charge(&self, kind: OpKind, bytes: u64, entries: usize) -> SimDuration {
        self.charge_duration(kind, self.config.latency.op_duration(kind, bytes, entries))
    }

    /// Record the op and jitter an explicitly computed duration (ranged
    /// GETs price themselves, since scaling depends on the full object).
    fn charge_duration(&self, kind: OpKind, d: SimDuration) -> SimDuration {
        self.counters.record_op(kind);
        if self.config.latency.jitter == 0.0 {
            d
        } else {
            let draw = self.jitter_draw();
            self.config.latency.jittered(d, draw)
        }
    }

    /// Price one injected failure and surface it as the matching error.
    /// A 503 transient behaves like a real request that died late: full
    /// latency, the op, and (for PUT-class ops) the payload bytes on the
    /// wire. A 429 throttle was shed before the body was read: the op
    /// and base latency only — zero wire bytes.
    fn charge_injected(
        &self,
        kind: OpKind,
        fault: InjectedFault,
        payload_bytes: u64,
    ) -> (StoreError, SimDuration) {
        match fault.class {
            FaultClass::Transient => {
                let d = self.charge(kind, payload_bytes, 0);
                if payload_bytes > 0 {
                    self.counters
                        .record_write(self.config.latency.scaled_bytes(payload_bytes));
                }
                (StoreError::TransientFailure(fault.msg), d)
            }
            FaultClass::Throttle => {
                let d = self.charge(kind, 0, 0);
                (StoreError::Throttled(fault.msg), d)
            }
        }
    }

    /// Install an object through the backend and keep the visibility
    /// overlay in sync (shared by PUT, COPY and multipart-complete).
    fn apply_put(
        &self,
        container: &str,
        key: &str,
        data: Vec<u8>,
        metadata: Metadata,
        now: SimInstant,
    ) -> Result<(), StoreError> {
        let obj = Object::new(data, metadata, now);
        let replaced = self.backend.put(container, key, obj)?;
        if !self.config.consistency.is_strong() {
            self.visibility_stripe(container, key).on_put(
                container,
                key,
                replaced,
                now,
                self.config.consistency.create_lag,
            );
        }
        Ok(())
    }

    // ---- container operations -------------------------------------------

    /// PUT Container (create). Counted as a PUT.
    pub fn create_container(
        &self,
        name: &str,
        _now: SimInstant,
    ) -> (Result<(), StoreError>, SimDuration) {
        let d = self.charge(OpKind::PutObject, 0, 0);
        (
            self.backend.create_container(name).map_err(StoreError::from),
            d,
        )
    }

    /// HEAD Container.
    pub fn head_container(&self, name: &str) -> (Result<(), StoreError>, SimDuration) {
        let d = self.charge(OpKind::HeadContainer, 0, 0);
        if self.backend.container_exists(name) {
            (Ok(()), d)
        } else {
            (Err(StoreError::NoSuchContainer(name.into())), d)
        }
    }

    // ---- object operations ----------------------------------------------

    /// PUT Object — atomic create/replace (§2.1). With chunked transfer
    /// encoding this is still one PUT; the streaming *timing* benefit is
    /// modelled by the connector (overlap with production), not here.
    pub fn put_object(
        &self,
        container: &str,
        key: &str,
        data: Vec<u8>,
        metadata: Metadata,
        now: SimInstant,
    ) -> (Result<(), StoreError>, SimDuration) {
        let size = data.len() as u64;
        // Injected failure: a 503 means the whole body went onto the
        // wire before the error came back (real stores bill failed
        // PUTs); a 429 was shed before the body. Either way the backend
        // never sees the object.
        if let Some(fault) = self.injector.check(FaultOp::Put, key) {
            let (e, d) = self.charge_injected(OpKind::PutObject, fault, size);
            return (Err(e), d);
        }
        let d = self.charge(OpKind::PutObject, size, 0);
        match self.apply_put(container, key, data, metadata, now) {
            Ok(()) => {
                self.counters
                    .record_write(self.config.latency.scaled_bytes(size));
                (Ok(()), d)
            }
            Err(e) => (Err(e), d),
        }
    }

    /// GET Object — returns data *and* metadata (basis of Stocator's
    /// skip-the-HEAD read optimization, §3.4).
    pub fn get_object(
        &self,
        container: &str,
        key: &str,
    ) -> (Result<GetResult, StoreError>, SimDuration) {
        // Injected failure: the error arrives before the body, so only
        // the request latency and the op are burned, whatever the class.
        if let Some(fault) = self.injector.check(FaultOp::Get, key) {
            let (e, d) = self.charge_injected(OpKind::GetObject, fault, 0);
            return (Err(e), d);
        }
        match self.backend.get(container, key) {
            Ok(obj) => {
                let size = obj.size();
                let d = self.charge(OpKind::GetObject, size, 0);
                self.counters
                    .record_read(self.config.latency.scaled_bytes(size));
                (
                    Ok(GetResult {
                        data: obj.data.clone(),
                        head: HeadResult {
                            size,
                            etag: obj.etag,
                            metadata: obj.metadata,
                            created_at: obj.created_at,
                        },
                    }),
                    d,
                )
            }
            Err(e) => {
                let d = self.charge(OpKind::GetObject, 0, 0);
                (Err(e.into()), d)
            }
        }
    }

    /// GET Object with an HTTP `Range` header: bytes `[offset, offset+len)`
    /// clamped to EOF (an offset strictly past EOF is a 416). Still one
    /// GET REST op, but transfer time and byte accounting cover only the
    /// returned slice — this is what makes partial reads (e.g. sampling a
    /// part's prefix) cheaper than whole-object GETs on the virtual clock.
    /// Whether paper-scaling applies is decided by the FULL object size
    /// (see [`LatencyModel::scaled_range_bytes`]), so a small slice of a
    /// scaled dataset part is still charged as dataset bytes. The result's
    /// `head` describes the FULL object (`Content-Range` total), so a
    /// ranged GET still carries the metadata (§3.4 applies to ranged
    /// reads too).
    pub fn get_object_range(
        &self,
        container: &str,
        key: &str,
        offset: u64,
        len: u64,
    ) -> (Result<GetResult, StoreError>, SimDuration) {
        if let Some(fault) = self.injector.check(FaultOp::Get, key) {
            let (e, d) = self.charge_injected(OpKind::GetObject, fault, 0);
            return (Err(e), d);
        }
        match self.backend.get_range(container, key, offset, len) {
            Ok((data, stat)) => {
                let n = data.len() as u64;
                let d = self.charge_duration(
                    OpKind::GetObject,
                    self.config.latency.range_get_duration(n, stat.size),
                );
                self.counters
                    .record_read(self.config.latency.scaled_range_bytes(n, stat.size));
                (
                    Ok(GetResult {
                        data: Arc::new(data),
                        head: stat.into(),
                    }),
                    d,
                )
            }
            Err(e) => {
                let d = self.charge(OpKind::GetObject, 0, 0);
                (Err(e.into()), d)
            }
        }
    }

    /// HEAD Object.
    pub fn head_object(
        &self,
        container: &str,
        key: &str,
    ) -> (Result<HeadResult, StoreError>, SimDuration) {
        let d = self.charge(OpKind::HeadObject, 0, 0);
        let found = self
            .backend
            .head(container, key)
            .map(HeadResult::from)
            .map_err(StoreError::from);
        (found, d)
    }

    /// COPY Object — the expensive server-side copy that rename is built
    /// from. Charged by source size on the copy bandwidth.
    pub fn copy_object(
        &self,
        src_container: &str,
        src_key: &str,
        dst_container: &str,
        dst_key: &str,
        now: SimInstant,
    ) -> (Result<(), StoreError>, SimDuration) {
        match self.backend.get(src_container, src_key) {
            Ok(obj) => {
                let size = obj.size();
                let d = self.charge(OpKind::CopyObject, size, 0);
                if !self.backend.container_exists(dst_container) {
                    return (Err(StoreError::NoSuchContainer(dst_container.into())), d);
                }
                self.counters
                    .record_copy(self.config.latency.scaled_bytes(size));
                let r = self.apply_put(
                    dst_container,
                    dst_key,
                    obj.data.as_ref().clone(),
                    obj.metadata.clone(),
                    now,
                );
                (r, d)
            }
            Err(e) => {
                let d = self.charge(OpKind::CopyObject, 0, 0);
                (Err(e.into()), d)
            }
        }
    }

    /// DELETE Object. Deleting a missing key is a 404 but still an op.
    pub fn delete_object(
        &self,
        container: &str,
        key: &str,
        now: SimInstant,
    ) -> (Result<(), StoreError>, SimDuration) {
        let d = self.charge(OpKind::DeleteObject, 0, 0);
        match self.backend.delete(container, key) {
            Ok(stat) => {
                if !self.config.consistency.is_strong() {
                    self.visibility_stripe(container, key).on_delete(
                        container,
                        key,
                        stat.size,
                        stat.etag,
                        now,
                        self.config.consistency.delete_lag,
                    );
                }
                (Ok(()), d)
            }
            Err(e) => (Err(e.into()), d),
        }
    }

    /// GET Container — the eventually consistent listing (§2.1).
    pub fn list(
        &self,
        container: &str,
        prefix: &str,
        delimiter: Option<char>,
        now: SimInstant,
    ) -> (Result<Listing, StoreError>, SimDuration) {
        let result = self.list_visible(container, prefix, delimiter, now);
        let entries = result.as_ref().map(|l| l.len()).unwrap_or(0);
        let d = self.charge(OpKind::GetContainer, 0, entries);
        (result, d)
    }

    /// Walk every page of the backend's authoritative listing.
    fn walk_all_pages(
        &self,
        container: &str,
        prefix: &str,
    ) -> Result<Vec<super::container::ObjectSummary>, StoreError> {
        let mut all = Vec::new();
        let mut start_after: Option<String> = None;
        loop {
            let page = self.backend.list_page(
                container,
                prefix,
                start_after.as_deref(),
                DEFAULT_PAGE_SIZE,
            )?;
            let empty = page.entries.is_empty();
            all.extend(page.entries);
            match page.next {
                Some(n) if !empty => start_after = Some(n),
                _ => return Ok(all),
            }
        }
    }

    /// Walk the backend's paginated listing, apply the visibility overlay,
    /// collapse at the delimiter.
    fn list_visible(
        &self,
        container: &str,
        prefix: &str,
        delimiter: Option<char>,
        now: SimInstant,
    ) -> Result<Listing, StoreError> {
        if !self.backend.container_exists(container) {
            return Err(StoreError::NoSuchContainer(container.into()));
        }
        let raw = self.walk_all_pages(container, prefix)?;
        let visible = if self.config.consistency.is_strong() {
            raw
        } else {
            // Each key's pending/ghost state lives in exactly one stripe
            // (disjoint key sets) and `overlay` preserves sortedness, so
            // chaining the stripes over the raw listing is exact — same
            // result as the legacy single-map overlay, in any order.
            let mut out = raw;
            for stripe in &self.visibility {
                self.note_front_end_lock();
                out = stripe.lock().unwrap().overlay(container, prefix, now, out);
            }
            out
        };
        Ok(Listing::collapse(prefix, delimiter, visible))
    }

    // ---- multipart upload (S3a fast-upload path) --------------------------

    /// The target key of an in-flight upload (for fault matching).
    fn multipart_target(&self, upload_id: u64) -> Option<String> {
        self.multipart_stripe(upload_id)
            .get(&upload_id)
            .map(|t| t.key.clone())
    }

    /// Initiate a multipart upload. Charged as a PUT request. `now` is
    /// recorded as the upload's start time — the age the
    /// [`ObjectStore::sweep_stale_multiparts`] lifecycle sweep measures.
    pub fn initiate_multipart(
        &self,
        container: &str,
        key: &str,
        metadata: Metadata,
        now: SimInstant,
    ) -> (Result<u64, StoreError>, SimDuration) {
        let d = self.charge(OpKind::PutObject, 0, 0);
        let r = self
            .backend
            .initiate_multipart(container, key, metadata)
            .map_err(StoreError::from);
        if let Ok(id) = &r {
            self.multipart_stripe(*id).insert(
                *id,
                MultipartTracker {
                    key: key.to_string(),
                    started: now,
                    part_bytes: HashMap::new(),
                },
            );
        }
        (r, d)
    }

    /// Upload one part. Charged as a PUT of the part's size.
    pub fn upload_part(
        &self,
        upload_id: u64,
        part_number: u32,
        data: Vec<u8>,
    ) -> (Result<(), StoreError>, SimDuration) {
        let size = data.len() as u64;
        // Injected failure: like a failed whole-object PUT — a 503
        // burns latency, op and payload bytes; a 429 costs the op and
        // base latency only. Either way the part is not stored. The
        // target key only matters for fault matching, so an idle
        // injector skips the stripe lookup entirely (idle path stays
        // lock-free; an idle check returns None for any key).
        if !self.faults_idle() {
            let target = self.multipart_target(upload_id);
            if let Some(fault) = self
                .injector
                .check(FaultOp::UploadPart, target.as_deref().unwrap_or(""))
            {
                let (e, d) = self.charge_injected(OpKind::PutObject, fault, size);
                return (Err(e), d);
            }
        }
        let d = self.charge(OpKind::PutObject, size, 0);
        match self.backend.upload_part(upload_id, part_number, data) {
            Ok(()) => {
                let scaled = self.config.latency.scaled_bytes(size);
                self.counters.record_write(scaled);
                if let Some(t) = self.multipart_stripe(upload_id).get_mut(&upload_id) {
                    t.part_bytes.insert(part_number, scaled);
                }
                (Ok(()), d)
            }
            Err(e) => (Err(e.into()), d),
        }
    }

    /// Complete a multipart upload: assembles parts into the final object.
    pub fn complete_multipart(
        &self,
        upload_id: u64,
        now: SimInstant,
    ) -> (Result<(), StoreError>, SimDuration) {
        // An injected failure on the completion POST leaves the upload
        // alive (the request never took effect), so a retry can
        // complete it without re-sending any part. As in
        // [`ObjectStore::upload_part`], an idle injector skips the
        // target-key stripe lookup (it would return None for any key).
        if !self.faults_idle() {
            let target = self.multipart_target(upload_id);
            if let Some(fault) = self
                .injector
                .check(FaultOp::CompleteMultipart, target.as_deref().unwrap_or(""))
            {
                let (e, d) = self.charge_injected(OpKind::PutObject, fault, 0);
                return (Err(e), d);
            }
        }
        let d = self.charge(OpKind::PutObject, 0, 0);
        // The backend consumes the upload whether or not assembly
        // succeeds (S3 semantics) — drop the tracker either way.
        self.multipart_stripe(upload_id).remove(&upload_id);
        let assembled = match self
            .backend
            .complete_multipart(upload_id, self.config.min_part_size)
        {
            Ok(a) => a,
            Err(e) => return (Err(e.into()), d),
        };
        // Bytes were already accounted at upload_part time.
        let r = self.apply_put(
            &assembled.container,
            &assembled.key,
            assembled.data,
            assembled.metadata,
            now,
        );
        (r, d)
    }

    /// Abort a multipart upload (task abort path). Charged as a DELETE.
    pub fn abort_multipart(&self, upload_id: u64) -> (Result<(), StoreError>, SimDuration) {
        let d = self.charge(OpKind::DeleteObject, 0, 0);
        self.multipart_stripe(upload_id).remove(&upload_id);
        (
            self.backend
                .abort_multipart(upload_id)
                .map_err(StoreError::from),
            d,
        )
    }

    /// Age-based multipart GC — the lifecycle rule real stores offer
    /// (`AbortIncompleteMultipartUpload`): abort every in-flight upload
    /// initiated at or before `now - max_age`, freeing the parked part
    /// bytes. Crashed or transiently-exhausted fast-upload writers
    /// strand uploads (nobody aborts for a dead executor), and stranded
    /// parts are *billed storage* until reaped. Each abort is charged as
    /// a DELETE via [`ObjectStore::abort_multipart`]; the summed
    /// durations are returned for callers that account the sweep on a
    /// clock (the harness treats it as server-side housekeeping).
    pub fn sweep_stale_multiparts(
        &self,
        now: SimInstant,
        max_age: SimDuration,
    ) -> (MultipartSweep, SimDuration) {
        let mut stale: Vec<(u64, u64)> = Vec::new();
        for stripe in &self.multipart {
            self.note_front_end_lock();
            let mp = stripe.lock().unwrap();
            stale.extend(
                mp.iter()
                    .filter(|(_, t)| now.elapsed_since(t.started) >= max_age)
                    .map(|(id, t)| (*id, t.part_bytes.values().sum::<u64>())),
            );
        }
        let mut sweep = MultipartSweep::default();
        let mut elapsed = SimDuration::ZERO;
        for (id, bytes) in stale {
            let (r, d) = self.abort_multipart(id);
            elapsed += d;
            if r.is_ok() {
                sweep.aborted += 1;
                sweep.freed_bytes += bytes;
            }
        }
        (sweep, elapsed)
    }

    // ---- inspection (harness/tests only; not REST, not counted) -----------

    /// Authoritative object count in a container.
    pub fn debug_live_count(&self, container: &str) -> usize {
        self.backend.live_count(container)
    }

    /// Authoritative byte count in a container.
    pub fn debug_live_bytes(&self, container: &str) -> u64 {
        self.backend.live_bytes(container)
    }

    /// Authoritative name list (sorted) — bypasses eventual consistency.
    pub fn debug_names(&self, container: &str, prefix: &str) -> Vec<String> {
        self.walk_all_pages(container, prefix)
            .map(|entries| entries.into_iter().map(|e| e.name).collect())
            .unwrap_or_default()
    }

    /// In-flight multipart uploads (leak detection in tests).
    pub fn debug_multipart_in_flight(&self) -> usize {
        self.backend.multipart_in_flight()
    }

    /// Paper-scaled bytes parked in in-flight multipart uploads — the
    /// stranded fast-upload debris the Table 8 addendum prices and the
    /// [`ObjectStore::sweep_stale_multiparts`] lifecycle sweep frees.
    pub fn debug_stranded_multipart_bytes(&self) -> u64 {
        self.multipart
            .iter()
            .map(|stripe| {
                stripe
                    .lock()
                    .unwrap()
                    .values()
                    .map(|t| t.part_bytes.values().sum::<u64>())
                    .sum::<u64>()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn store() -> Arc<ObjectStore> {
        let s = ObjectStore::new(StoreConfig::instant_strong());
        s.create_container("res", SimInstant::EPOCH).0.unwrap();
        s
    }

    /// A store plus the on-disk root to reap when the test ends (fs
    /// backend only) — keeps `cargo test` from littering the temp dir.
    struct TestStore {
        store: Arc<ObjectStore>,
        root: Option<PathBuf>,
    }

    impl std::ops::Deref for TestStore {
        type Target = ObjectStore;
        fn deref(&self) -> &ObjectStore {
            &self.store
        }
    }

    impl Drop for TestStore {
        fn drop(&mut self) {
            if let Some(root) = &self.root {
                let _ = std::fs::remove_dir_all(root);
            }
        }
    }

    fn test_store(backend: BackendKind, base: StoreConfig) -> TestStore {
        let (backend, root) = match backend {
            BackendKind::LocalFs(None) => {
                let root = super::super::backend::fresh_temp_root();
                (BackendKind::LocalFs(Some(root.clone())), Some(root))
            }
            other => (other, None),
        };
        let s = ObjectStore::new(StoreConfig { backend, ..base });
        s.create_container("res", SimInstant::EPOCH).0.unwrap();
        TestStore { store: s, root }
    }

    /// Same protocol state, on every backend kind.
    fn all_backend_stores() -> Vec<TestStore> {
        [
            BackendKind::Mem,
            BackendKind::Sharded(4),
            BackendKind::LocalFs(None),
        ]
        .into_iter()
        .map(|backend| test_store(backend, StoreConfig::instant_strong()))
        .collect()
    }

    #[test]
    fn put_get_roundtrip_with_metadata() {
        for s in all_backend_stores() {
            let mut md = Metadata::new();
            md.insert("X-Stocator-Origin".into(), "stocator-1.0".into());
            s.put_object("res", "d/part-0", b"abc".to_vec(), md, SimInstant(0))
                .0
                .unwrap();
            let (r, _) = s.get_object("res", "d/part-0");
            let r = r.unwrap();
            assert_eq!(&*r.data, b"abc", "backend {}", s.backend_name());
            assert_eq!(r.head.size, 3);
            assert_eq!(
                r.head.metadata.get("X-Stocator-Origin").map(String::as_str),
                Some("stocator-1.0")
            );
        }
    }

    #[test]
    fn ranged_get_on_every_backend() {
        for s in all_backend_stores() {
            s.put_object("res", "k", (0u8..200).collect(), Metadata::new(), SimInstant(0))
                .0
                .unwrap();
            let (r, _) = s.get_object_range("res", "k", 50, 10);
            let r = r.unwrap();
            assert_eq!(
                &*r.data,
                &(50u8..60).collect::<Vec<u8>>()[..],
                "backend {}",
                s.backend_name()
            );
            assert_eq!(r.head.size, 200, "head carries the FULL object size");
            // Past-EOF offset is a 416; a missing key stays a 404.
            assert!(matches!(
                s.get_object_range("res", "k", 201, 1).0,
                Err(StoreError::InvalidRange(_))
            ));
            assert!(matches!(
                s.get_object_range("res", "nope", 0, 1).0,
                Err(StoreError::NoSuchKey(_))
            ));
            // Every ranged read (failed ones included) is one GET op;
            // bytes_read covers only the returned slice.
            let c = s.counters();
            assert_eq!(c.get(OpKind::GetObject), 3);
            assert_eq!(c.bytes_read, 10);
        }
    }

    #[test]
    fn ranged_get_charges_slice_transfer_time() {
        let cfg = StoreConfig {
            latency: LatencyModel::paper_testbed(),
            ..StoreConfig::instant_strong()
        };
        let s = ObjectStore::new(cfg);
        s.create_container("res", SimInstant::EPOCH).0.unwrap();
        s.put_object("res", "k", vec![0u8; 52_000_000], Metadata::new(), SimInstant(0))
            .0
            .unwrap();
        let (_, d_full) = s.get_object("res", "k");
        let (r, d_half) = s.get_object_range("res", "k", 0, 26_000_000);
        assert!(r.is_ok());
        // 26 MB at 26 MB/s = 1s + 25ms first-byte latency.
        assert_eq!(d_half.as_micros(), 25_000 + 1_000_000);
        assert!(d_full > d_half, "partial read must cost less than a full GET");
    }

    #[test]
    fn ranged_get_scales_by_the_full_object_size() {
        // A sub-threshold slice of a scaled dataset part is still dataset
        // bytes: the data_scale multiplier must apply.
        let cfg = StoreConfig {
            latency: LatencyModel {
                data_scale: 1000,
                scale_threshold: 64,
                ..LatencyModel::instant()
            },
            ..StoreConfig::instant_strong()
        };
        let s = ObjectStore::new(cfg);
        s.create_container("res", SimInstant::EPOCH).0.unwrap();
        s.put_object("res", "part", vec![0u8; 100], Metadata::new(), SimInstant(0))
            .0
            .unwrap();
        s.put_object("res", "meta", vec![0u8; 10], Metadata::new(), SimInstant(0))
            .0
            .unwrap();
        let before = s.counters();
        s.get_object_range("res", "part", 0, 5).0.unwrap();
        assert_eq!(
            s.counters().since(&before).bytes_read,
            5 * 1000,
            "slice of a scaled part reads paper-scale bytes"
        );
        let before = s.counters();
        s.get_object_range("res", "meta", 0, 5).0.unwrap();
        assert_eq!(
            s.counters().since(&before).bytes_read,
            5,
            "slice of a metadata object keeps its real size"
        );
    }

    #[test]
    fn missing_key_is_404_but_counted() {
        let s = store();
        let before = s.counters();
        let (r, _) = s.head_object("res", "nope");
        assert!(matches!(r, Err(StoreError::NoSuchKey(_))));
        let d = s.counters().since(&before);
        assert_eq!(d.get(OpKind::HeadObject), 1);
    }

    #[test]
    fn copy_then_delete_is_rename() {
        for s in all_backend_stores() {
            s.put_object("res", "tmp/x", b"data".to_vec(), Metadata::new(), SimInstant(0))
                .0
                .unwrap();
            s.copy_object("res", "tmp/x", "res", "final/x", SimInstant(1))
                .0
                .unwrap();
            s.delete_object("res", "tmp/x", SimInstant(2)).0.unwrap();
            assert!(s.get_object("res", "final/x").0.is_ok());
            assert!(s.get_object("res", "tmp/x").0.is_err());
            let c = s.counters();
            assert_eq!(c.get(OpKind::CopyObject), 1);
            assert_eq!(c.get(OpKind::DeleteObject), 1);
            // COPY moved the bytes server-side:
            assert_eq!(c.bytes_copied, 4);
            assert_eq!(c.bytes_written, 4);
        }
    }

    #[test]
    fn atomic_put_replaces_whole_value() {
        for s in all_backend_stores() {
            s.put_object("res", "k", b"first".to_vec(), Metadata::new(), SimInstant(0))
                .0
                .unwrap();
            s.put_object("res", "k", b"2nd".to_vec(), Metadata::new(), SimInstant(1))
                .0
                .unwrap();
            let (r, _) = s.get_object("res", "k");
            assert_eq!(&*r.unwrap().data, b"2nd");
            assert_eq!(s.debug_live_count("res"), 1);
        }
    }

    #[test]
    fn listing_is_eventually_consistent() {
        let s = ObjectStore::new(StoreConfig::instant_eventual());
        s.create_container("res", SimInstant::EPOCH).0.unwrap();
        s.put_object("res", "a", b"1".to_vec(), Metadata::new(), SimInstant(0))
            .0
            .unwrap();
        // Immediately after the PUT the listing is empty...
        let (l, _) = s.list("res", "", None, SimInstant(0));
        assert!(l.unwrap().is_empty());
        // ...but after the lag (2s default) the object appears.
        let (l, _) = s.list("res", "", None, SimInstant(2_000_000));
        assert_eq!(l.unwrap().objects.len(), 1);
        // GET was always consistent:
        assert!(s.get_object("res", "a").0.is_ok());
    }

    #[test]
    fn delete_ghost_lingers_in_listing_on_every_backend() {
        for backend in [BackendKind::Mem, BackendKind::LocalFs(None)] {
            let s = test_store(backend, StoreConfig::instant_eventual());
            s.put_object("res", "k", b"vv".to_vec(), Metadata::new(), SimInstant(0))
                .0
                .unwrap();
            s.delete_object("res", "k", SimInstant(2_500_000)).0.unwrap();
            // GET is strongly consistent: gone.
            assert!(s.get_object("res", "k").0.is_err());
            // Listing still shows the ghost (2s delete lag), with the old size.
            let (l, _) = s.list("res", "", None, SimInstant(3_000_000));
            let l = l.unwrap();
            assert_eq!(l.objects.len(), 1, "backend {}", s.backend_name());
            assert_eq!(l.objects[0].size, 2);
            let (l, _) = s.list("res", "", None, SimInstant(5_000_000));
            assert!(l.unwrap().is_empty());
        }
    }

    #[test]
    fn ops_on_missing_container_fail() {
        let s = ObjectStore::new(StoreConfig::instant_strong());
        assert!(matches!(
            s.put_object("c", "k", vec![], Metadata::new(), SimInstant(0)).0,
            Err(StoreError::NoSuchContainer(_))
        ));
        assert!(matches!(
            s.list("c", "", None, SimInstant(0)).0,
            Err(StoreError::NoSuchContainer(_))
        ));
        assert!(s.head_container("c").0.is_err());
        s.create_container("c", SimInstant(0)).0.unwrap();
        assert!(s.head_container("c").0.is_ok());
        assert!(matches!(
            s.create_container("c", SimInstant(0)).0,
            Err(StoreError::ContainerAlreadyExists(_))
        ));
    }

    #[test]
    fn multipart_assembles_and_counts_puts() {
        for s in all_backend_stores() {
            let before = s.counters();
            let (id, _) = s.initiate_multipart("res", "big", Metadata::new(), SimInstant(0));
            let id = id.unwrap();
            s.upload_part(id, 1, b"hello ".to_vec()).0.unwrap();
            s.upload_part(id, 2, b"world".to_vec()).0.unwrap();
            s.complete_multipart(id, SimInstant(5)).0.unwrap();
            let (r, _) = s.get_object("res", "big");
            assert_eq!(&*r.unwrap().data, b"hello world");
            let d = s.counters().since(&before);
            // initiate + 2 parts + complete = 4 PUT-class requests, 1 GET.
            assert_eq!(d.get(OpKind::PutObject), 4);
            assert_eq!(s.debug_multipart_in_flight(), 0);
        }
    }

    #[test]
    fn multipart_abort_cleans_up() {
        for s in all_backend_stores() {
            let (id, _) = s.initiate_multipart("res", "x", Metadata::new(), SimInstant(0));
            let id = id.unwrap();
            s.upload_part(id, 1, b"junk".to_vec()).0.unwrap();
            s.abort_multipart(id).0.unwrap();
            assert_eq!(s.debug_multipart_in_flight(), 0);
            assert!(s.get_object("res", "x").0.is_err());
            assert!(s.complete_multipart(id, SimInstant(0)).0.is_err());
        }
    }

    #[test]
    fn durations_follow_latency_model() {
        let cfg = StoreConfig {
            latency: LatencyModel::paper_testbed(),
            ..StoreConfig::instant_strong()
        };
        let s = ObjectStore::new(cfg);
        let (_, d) = s.create_container("res", SimInstant::EPOCH);
        assert_eq!(d.as_micros(), 30_000); // PUT base
        let (_, d) = s.head_container("res");
        assert_eq!(d.as_micros(), 15_000); // HEAD base
        let (_, d) = s.put_object(
            "res",
            "k",
            vec![0u8; 26_000_000],
            Metadata::new(),
            SimInstant(0),
        );
        assert_eq!(d.as_micros(), 30_000 + 1_000_000); // base + 1s transfer
    }

    #[test]
    fn jitter_is_deterministic_per_seed() {
        let mk = |seed| {
            let mut lat = LatencyModel::paper_testbed();
            lat.jitter = 0.2;
            let cfg = StoreConfig {
                latency: lat,
                seed,
                ..StoreConfig::instant_strong()
            };
            let s = ObjectStore::new(cfg);
            let (_, d) = s.create_container("res", SimInstant::EPOCH);
            d
        };
        assert_eq!(mk(1), mk(1));
        assert_ne!(mk(1), mk(2));
    }

    #[test]
    fn injected_put_fault_burns_latency_op_and_bytes() {
        use super::super::faults::{FaultOp, FaultSpec};
        let cfg = StoreConfig {
            latency: LatencyModel::paper_testbed(),
            faults: FaultSpec::one(FaultOp::Put, "d/", 1),
            ..StoreConfig::instant_strong()
        };
        let s = ObjectStore::new(cfg);
        s.create_container("res", SimInstant::EPOCH).0.unwrap();
        let body = vec![0u8; 26_000_000];
        let (r, d) = s.put_object("res", "d/part-0", body.clone(), Metadata::new(), SimInstant(0));
        assert!(matches!(r, Err(StoreError::TransientFailure(_))));
        // Full PUT pricing: base latency + transfer of the whole body.
        assert_eq!(d.as_micros(), 30_000 + 1_000_000);
        let c = s.counters();
        assert_eq!(c.get(OpKind::PutObject), 1 + 1 /* container */);
        assert_eq!(c.bytes_written, 26_000_000, "failed PUT bytes hit the wire");
        // Nothing landed in the backend.
        assert!(s.get_object("res", "d/part-0").0.is_err());
        // The second matching PUT (the retry) succeeds.
        let (r, _) = s.put_object("res", "d/part-0", body, Metadata::new(), SimInstant(1));
        assert!(r.is_ok());
        assert_eq!(s.counters().bytes_written, 52_000_000, "re-send doubles wire bytes");
    }

    #[test]
    fn injected_get_fault_burns_op_but_no_bytes() {
        use super::super::faults::{FaultOp, FaultSpec};
        let cfg = StoreConfig {
            faults: FaultSpec::none()
                .with(super::super::faults::FaultRule::new(FaultOp::Get, "", 1, 1))
                .with(super::super::faults::FaultRule::new(FaultOp::Get, "", 3, 1)),
            ..StoreConfig::instant_strong()
        };
        let s = ObjectStore::new(cfg);
        s.create_container("res", SimInstant::EPOCH).0.unwrap();
        s.put_object("res", "k", (0u8..100).collect(), Metadata::new(), SimInstant(0))
            .0
            .unwrap();
        // GET match 1: injected.
        assert!(matches!(
            s.get_object("res", "k").0,
            Err(StoreError::TransientFailure(_))
        ));
        // GET match 2: fine. Match 3 (ranged): injected again.
        assert!(s.get_object("res", "k").0.is_ok());
        assert!(matches!(
            s.get_object_range("res", "k", 0, 10).0,
            Err(StoreError::TransientFailure(_))
        ));
        let c = s.counters();
        assert_eq!(c.get(OpKind::GetObject), 3, "failed GETs are still ops");
        assert_eq!(c.bytes_read, 100, "only the successful GET moved bytes");
    }

    #[test]
    fn injected_throttle_burns_op_and_latency_but_zero_bytes() {
        use super::super::faults::FaultSpec;
        let cfg = StoreConfig {
            latency: LatencyModel::paper_testbed(),
            faults: FaultSpec::parse("put:d/@1!429").unwrap(),
            ..StoreConfig::instant_strong()
        };
        let s = ObjectStore::new(cfg);
        s.create_container("res", SimInstant::EPOCH).0.unwrap();
        let body = vec![0u8; 26_000_000];
        let (r, d) = s.put_object("res", "d/part-0", body.clone(), Metadata::new(), SimInstant(0));
        assert!(matches!(r, Err(StoreError::Throttled(_))));
        // The 429 was shed before the body: base PUT latency only, no
        // transfer time, and NO payload bytes on the wire.
        assert_eq!(d.as_micros(), 30_000);
        let c = s.counters();
        assert_eq!(c.get(OpKind::PutObject), 1 + 1 /* container */);
        assert_eq!(c.bytes_written, 0, "a throttled PUT puts nothing on the wire");
        assert!(s.get_object("res", "d/part-0").0.is_err());
        // The retry (match 2, outside the rule window) succeeds and pays
        // the full freight once.
        let (r, _) = s.put_object("res", "d/part-0", body, Metadata::new(), SimInstant(1));
        assert!(r.is_ok());
        assert_eq!(s.counters().bytes_written, 26_000_000);
    }

    #[test]
    fn probabilistic_faults_follow_the_store_seed() {
        use super::super::faults::FaultSpec;
        let run = |seed: u64| -> Vec<bool> {
            let cfg = StoreConfig {
                faults: FaultSpec::parse("put@p=0.4").unwrap(),
                seed,
                ..StoreConfig::instant_strong()
            };
            let s = ObjectStore::new(cfg);
            s.create_container("res", SimInstant::EPOCH).0.unwrap();
            (0..32)
                .map(|i| {
                    s.put_object("res", &format!("k{i}"), vec![1], Metadata::new(), SimInstant(i))
                        .0
                        .is_err()
                })
                .collect()
        };
        assert_eq!(run(11), run(11), "same --seed, same fault schedule");
        assert_ne!(run(11), run(12), "different --seed, different schedule");
        assert!(run(11).iter().any(|b| *b), "p=0.4 over 32 PUTs fires");
        assert!(!run(11).iter().all(|b| *b), "p=0.4 is not p=1");
    }

    #[test]
    fn transient_complete_leaves_upload_retryable() {
        use super::super::faults::{FaultOp, FaultSpec};
        let cfg = StoreConfig {
            faults: FaultSpec::one(FaultOp::CompleteMultipart, "big", 1),
            ..StoreConfig::instant_strong()
        };
        let s = ObjectStore::new(cfg);
        s.create_container("res", SimInstant::EPOCH).0.unwrap();
        let (id, _) = s.initiate_multipart("res", "big", Metadata::new(), SimInstant(0));
        let id = id.unwrap();
        s.upload_part(id, 1, b"hello".to_vec()).0.unwrap();
        // First complete: injected 503. The upload must stay alive.
        assert!(matches!(
            s.complete_multipart(id, SimInstant(1)).0,
            Err(StoreError::TransientFailure(_))
        ));
        assert_eq!(s.debug_multipart_in_flight(), 1);
        // Retry completes without re-sending any part.
        s.complete_multipart(id, SimInstant(2)).0.unwrap();
        assert_eq!(&*s.get_object("res", "big").0.unwrap().data, b"hello");
        assert_eq!(s.debug_multipart_in_flight(), 0);
        assert_eq!(s.debug_stranded_multipart_bytes(), 0);
    }

    #[test]
    fn multipart_gc_sweeps_only_stale_uploads() {
        let s = store();
        let (old_id, _) = s.initiate_multipart("res", "old", Metadata::new(), SimInstant(0));
        let old_id = old_id.unwrap();
        s.upload_part(old_id, 1, vec![1u8; 100]).0.unwrap();
        s.upload_part(old_id, 2, vec![2u8; 50]).0.unwrap();
        let (new_id, _) =
            s.initiate_multipart("res", "new", Metadata::new(), SimInstant(5_000_000));
        let new_id = new_id.unwrap();
        s.upload_part(new_id, 1, vec![3u8; 10]).0.unwrap();
        assert_eq!(s.debug_stranded_multipart_bytes(), 160);

        // Sweep at t=6s with a 2s TTL: only the t=0 upload is stale.
        let before = s.counters();
        let (sweep, _) =
            s.sweep_stale_multiparts(SimInstant(6_000_000), SimDuration::from_secs(2));
        assert_eq!(sweep.aborted, 1);
        assert_eq!(sweep.freed_bytes, 150);
        assert_eq!(s.debug_multipart_in_flight(), 1);
        assert_eq!(s.debug_stranded_multipart_bytes(), 10);
        assert_eq!(
            s.counters().since(&before).get(OpKind::DeleteObject),
            1,
            "each abort is a DELETE-class request"
        );
        // The reaped upload is gone for good; the young one still works.
        assert!(s.complete_multipart(old_id, SimInstant(7_000_000)).0.is_err());
        assert!(s.complete_multipart(new_id, SimInstant(7_000_000)).0.is_ok());
    }

    #[test]
    fn default_config_injects_nothing() {
        let s = store();
        for i in 0..50u64 {
            s.put_object("res", &format!("k{i}"), vec![0u8; 8], Metadata::new(), SimInstant(i))
                .0
                .unwrap();
            s.get_object("res", &format!("k{i}")).0.unwrap();
        }
        assert_eq!(s.counters().get(OpKind::PutObject), 51);
    }

    #[test]
    fn idle_hot_path_takes_zero_front_end_locks() {
        // Strong consistency, zero jitter, no armed faults: the entire
        // whole-object data path must never touch a front-end stripe.
        let s = store();
        s.put_object("res", "d/k", vec![0u8; 64], Metadata::new(), SimInstant(0))
            .0
            .unwrap();
        s.get_object("res", "d/k").0.unwrap();
        s.get_object_range("res", "d/k", 8, 8).0.unwrap();
        s.head_object("res", "d/k").0.unwrap();
        s.list("res", "", None, SimInstant(1)).0.unwrap();
        s.copy_object("res", "d/k", "res", "d/k2", SimInstant(2))
            .0
            .unwrap();
        s.delete_object("res", "d/k", SimInstant(3)).0.unwrap();
        assert_eq!(
            s.debug_front_end_locks(),
            0,
            "idle strong-consistency hot path must be lock-free"
        );
        // Sanity for the counter itself (only counted in debug builds):
        // the eventual-consistency overlay DOES take stripes.
        #[cfg(debug_assertions)]
        {
            let e = ObjectStore::new(StoreConfig::instant_eventual());
            e.create_container("res", SimInstant::EPOCH).0.unwrap();
            e.put_object("res", "k", vec![1], Metadata::new(), SimInstant(0))
                .0
                .unwrap();
            assert!(e.debug_front_end_locks() > 0, "overlay writes are counted");
        }
    }

    #[test]
    fn striping_preserves_visibility_semantics_exactly() {
        // The same timed put/delete/list protocol must produce identical
        // visible listings and op counters whether the overlay lives in
        // one mutex or sixteen stripes: per-key lag state is disjoint
        // across stripes and the chained overlay preserves sortedness.
        let run = |stripes: usize| {
            let s = ObjectStore::new(StoreConfig {
                stripes,
                ..StoreConfig::instant_eventual()
            });
            s.create_container("res", SimInstant::EPOCH).0.unwrap();
            for i in 0..40u64 {
                s.put_object(
                    "res",
                    &format!("d/part-{i:02}"),
                    vec![0u8; (i as usize + 1) * 3],
                    Metadata::new(),
                    SimInstant(i * 250_000),
                )
                .0
                .unwrap();
            }
            for i in (0..40u64).step_by(3) {
                s.delete_object("res", &format!("d/part-{i:02}"), SimInstant(10_000_000 + i))
                    .0
                    .unwrap();
            }
            let mut listings = Vec::new();
            for t in [0, 1_500_000, 5_000_000, 9_999_999, 11_000_000, 13_000_000] {
                let (l, _) = s.list("res", "d/", None, SimInstant(t));
                listings.push(
                    l.unwrap()
                        .objects
                        .into_iter()
                        .map(|o| (o.name, o.size))
                        .collect::<Vec<_>>(),
                );
            }
            (listings, s.counters())
        };
        let (legacy_listings, legacy_counts) = run(1);
        let (striped_listings, striped_counts) = run(16);
        assert_eq!(legacy_listings, striped_listings);
        assert_eq!(legacy_counts, striped_counts);
    }

    #[test]
    fn jitter_streams_decorrelate_across_threads() {
        // Two real threads drawing jitter from one store get distinct
        // PCG32 streams: each thread's sequence is deterministic for it,
        // but the sequences differ (no shared mutex, no shared stream).
        let mut lat = LatencyModel::paper_testbed();
        lat.jitter = 0.2;
        let s = ObjectStore::new(StoreConfig {
            latency: lat,
            seed: 7,
            ..StoreConfig::instant_strong()
        });
        s.create_container("res", SimInstant::EPOCH).0.unwrap();
        let draws = |n: usize| -> Vec<u64> {
            (0..n).map(|_| s.head_container("res").1.as_micros()).collect()
        };
        let (a, b) = std::thread::scope(|scope| {
            let ta = scope.spawn(|| draws(16));
            let tb = scope.spawn(|| draws(16));
            (ta.join().unwrap(), tb.join().unwrap())
        });
        assert_ne!(a, b, "per-thread jitter streams must decorrelate");
    }

    #[test]
    fn byte_accounting_scales_with_data_scale() {
        let cfg = StoreConfig {
            latency: LatencyModel {
                data_scale: 1000,
                scale_threshold: 0,
                ..LatencyModel::instant()
            },
            ..StoreConfig::instant_strong()
        };
        let s = ObjectStore::new(cfg);
        s.create_container("res", SimInstant::EPOCH).0.unwrap();
        s.put_object("res", "k", vec![0u8; 100], Metadata::new(), SimInstant(0))
            .0
            .unwrap();
        assert_eq!(s.counters().bytes_written, 100_000);
    }
}
