//! The object store front end: REST-shaped API, operation accounting,
//! virtual-time costing, consistency enforcement.
//!
//! Every public operation returns `(Result<T, StoreError>, SimDuration)`:
//! failed operations (e.g. a HEAD on a missing object — the bread and
//! butter of the legacy connectors' existence checks) still cost wire time,
//! and the paper's op counts include them.

use super::consistency::ConsistencyModel;
use super::container::{Container, Listing};
use super::latency::LatencyModel;
use super::multipart::{MultipartTable, DEFAULT_MIN_PART_SIZE};
use super::object::{Metadata, Object};
use crate::metrics::{LiveCounters, OpCounts, OpKind};
use crate::simclock::{SimDuration, SimInstant};
use crate::util::rng::Pcg32;
use std::collections::BTreeMap;
use std::fmt;
use std::sync::{Arc, Mutex};

/// Errors mirroring the REST error space the connectors care about.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StoreError {
    NoSuchContainer(String),
    NoSuchKey(String),
    ContainerAlreadyExists(String),
    NoSuchUpload(u64),
    InvalidRequest(String),
}

impl fmt::Display for StoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StoreError::NoSuchContainer(c) => write!(f, "404 NoSuchContainer: {c}"),
            StoreError::NoSuchKey(k) => write!(f, "404 NoSuchKey: {k}"),
            StoreError::ContainerAlreadyExists(c) => write!(f, "409 ContainerExists: {c}"),
            StoreError::NoSuchUpload(id) => write!(f, "404 NoSuchUpload: {id}"),
            StoreError::InvalidRequest(m) => write!(f, "400 InvalidRequest: {m}"),
        }
    }
}

impl std::error::Error for StoreError {}

/// Head-object response: metadata + size, no data (HTTP HEAD).
#[derive(Debug, Clone)]
pub struct HeadResult {
    pub size: u64,
    pub etag: u64,
    pub metadata: Metadata,
    pub created_at: SimInstant,
}

/// Get-object response: data + everything HEAD returns (the read-path
/// optimization in paper §3.4 relies on GET carrying the metadata).
#[derive(Debug, Clone)]
pub struct GetResult {
    pub data: Arc<Vec<u8>>,
    pub head: HeadResult,
}

/// Store construction parameters.
#[derive(Debug, Clone)]
pub struct StoreConfig {
    pub latency: LatencyModel,
    pub consistency: ConsistencyModel,
    /// Minimum multipart part size (S3 semantics).
    pub min_part_size: u64,
    /// Seed for the jitter stream.
    pub seed: u64,
}

impl Default for StoreConfig {
    fn default() -> Self {
        Self {
            latency: LatencyModel::paper_testbed(),
            consistency: ConsistencyModel::eventual(),
            min_part_size: DEFAULT_MIN_PART_SIZE,
            seed: 0,
        }
    }
}

impl StoreConfig {
    /// Strong consistency + zero latency: pure protocol-correctness tests.
    pub fn instant_strong() -> Self {
        Self {
            latency: LatencyModel::instant(),
            consistency: ConsistencyModel::strong(),
            min_part_size: 0,
            seed: 0,
        }
    }

    /// Zero latency but eventually-consistent listings.
    pub fn instant_eventual() -> Self {
        Self {
            latency: LatencyModel::instant(),
            consistency: ConsistencyModel::eventual(),
            min_part_size: 0,
            seed: 0,
        }
    }
}

struct Inner {
    containers: BTreeMap<String, Container>,
    multipart: MultipartTable,
    rng: Pcg32,
}

/// The shared object store. Cloneable handle (`Arc` inside); safe to use
/// from the executor threads of the Spark simulator.
pub struct ObjectStore {
    inner: Mutex<Inner>,
    counters: LiveCounters,
    pub config: StoreConfig,
}

impl ObjectStore {
    pub fn new(config: StoreConfig) -> Arc<Self> {
        Arc::new(Self {
            inner: Mutex::new(Inner {
                containers: BTreeMap::new(),
                multipart: MultipartTable::default(),
                rng: Pcg32::new(config.seed ^ 0x5106_a70c),
            }),
            counters: LiveCounters::new(),
            config,
        })
    }

    /// Live op/byte counters (for harness snapshots).
    pub fn counters(&self) -> OpCounts {
        self.counters.snapshot()
    }

    fn charge(&self, inner: &mut Inner, kind: OpKind, bytes: u64, entries: usize) -> SimDuration {
        self.counters.record_op(kind);
        let d = self.config.latency.op_duration(kind, bytes, entries);
        self.config.latency.jittered(d, inner.rng.next_f64())
    }

    // ---- container operations -------------------------------------------

    /// PUT Container (create). Counted as a PUT.
    pub fn create_container(&self, name: &str, now: SimInstant) -> (Result<(), StoreError>, SimDuration) {
        let mut inner = self.inner.lock().unwrap();
        let d = self.charge(&mut inner, OpKind::PutObject, 0, 0);
        if inner.containers.contains_key(name) {
            return (Err(StoreError::ContainerAlreadyExists(name.into())), d);
        }
        inner.containers.insert(name.to_string(), Container::new(now));
        (Ok(()), d)
    }

    /// HEAD Container.
    pub fn head_container(&self, name: &str) -> (Result<(), StoreError>, SimDuration) {
        let mut inner = self.inner.lock().unwrap();
        let d = self.charge(&mut inner, OpKind::HeadContainer, 0, 0);
        if inner.containers.contains_key(name) {
            (Ok(()), d)
        } else {
            (Err(StoreError::NoSuchContainer(name.into())), d)
        }
    }

    // ---- object operations ----------------------------------------------

    /// PUT Object — atomic create/replace (§2.1). With chunked transfer
    /// encoding this is still one PUT; the streaming *timing* benefit is
    /// modelled by the connector (overlap with production), not here.
    pub fn put_object(
        &self,
        container: &str,
        key: &str,
        data: Vec<u8>,
        metadata: Metadata,
        now: SimInstant,
    ) -> (Result<(), StoreError>, SimDuration) {
        let mut inner = self.inner.lock().unwrap();
        let size = data.len() as u64;
        let d = self.charge(&mut inner, OpKind::PutObject, size, 0);
        let Some(c) = inner.containers.get_mut(container) else {
            return (Err(StoreError::NoSuchContainer(container.into())), d);
        };
        self.counters
            .record_write(self.config.latency.scaled_bytes(size));
        c.put(key, Object::new(data, metadata, now), now, &self.config.consistency);
        (Ok(()), d)
    }

    /// GET Object — returns data *and* metadata (basis of Stocator's
    /// skip-the-HEAD read optimization, §3.4).
    pub fn get_object(
        &self,
        container: &str,
        key: &str,
    ) -> (Result<GetResult, StoreError>, SimDuration) {
        let mut inner = self.inner.lock().unwrap();
        let found = inner
            .containers
            .get(container)
            .ok_or_else(|| StoreError::NoSuchContainer(container.into()))
            .and_then(|c| {
                c.get(key)
                    .cloned()
                    .ok_or_else(|| StoreError::NoSuchKey(format!("{container}/{key}")))
            });
        match found {
            Ok(obj) => {
                let size = obj.size();
                let d = self.charge(&mut inner, OpKind::GetObject, size, 0);
                self.counters
                    .record_read(self.config.latency.scaled_bytes(size));
                (
                    Ok(GetResult {
                        data: obj.data.clone(),
                        head: HeadResult {
                            size,
                            etag: obj.etag,
                            metadata: obj.metadata.clone(),
                            created_at: obj.created_at,
                        },
                    }),
                    d,
                )
            }
            Err(e) => {
                let d = self.charge(&mut inner, OpKind::GetObject, 0, 0);
                (Err(e), d)
            }
        }
    }

    /// HEAD Object.
    pub fn head_object(
        &self,
        container: &str,
        key: &str,
    ) -> (Result<HeadResult, StoreError>, SimDuration) {
        let mut inner = self.inner.lock().unwrap();
        let d = self.charge(&mut inner, OpKind::HeadObject, 0, 0);
        let found = inner
            .containers
            .get(container)
            .ok_or_else(|| StoreError::NoSuchContainer(container.into()))
            .and_then(|c| {
                c.get(key)
                    .ok_or_else(|| StoreError::NoSuchKey(format!("{container}/{key}")))
                    .map(|obj| HeadResult {
                        size: obj.size(),
                        etag: obj.etag,
                        metadata: obj.metadata.clone(),
                        created_at: obj.created_at,
                    })
            });
        (found, d)
    }

    /// COPY Object — the expensive server-side copy that rename is built
    /// from. Charged by source size on the copy bandwidth.
    pub fn copy_object(
        &self,
        src_container: &str,
        src_key: &str,
        dst_container: &str,
        dst_key: &str,
        now: SimInstant,
    ) -> (Result<(), StoreError>, SimDuration) {
        let mut inner = self.inner.lock().unwrap();
        let src = inner
            .containers
            .get(src_container)
            .ok_or_else(|| StoreError::NoSuchContainer(src_container.into()))
            .and_then(|c| {
                c.get(src_key)
                    .cloned()
                    .ok_or_else(|| StoreError::NoSuchKey(format!("{src_container}/{src_key}")))
            });
        match src {
            Ok(obj) => {
                let size = obj.size();
                let d = self.charge(&mut inner, OpKind::CopyObject, size, 0);
                if !inner.containers.contains_key(dst_container) {
                    return (Err(StoreError::NoSuchContainer(dst_container.into())), d);
                }
                self.counters
                    .record_copy(self.config.latency.scaled_bytes(size));
                let copied = Object::new(
                    obj.data.as_ref().clone(),
                    obj.metadata.clone(),
                    now,
                );
                inner
                    .containers
                    .get_mut(dst_container)
                    .unwrap()
                    .put(dst_key, copied, now, &self.config.consistency);
                (Ok(()), d)
            }
            Err(e) => {
                let d = self.charge(&mut inner, OpKind::CopyObject, 0, 0);
                (Err(e), d)
            }
        }
    }

    /// DELETE Object. Deleting a missing key is a 404 but still an op.
    pub fn delete_object(
        &self,
        container: &str,
        key: &str,
        now: SimInstant,
    ) -> (Result<(), StoreError>, SimDuration) {
        let mut inner = self.inner.lock().unwrap();
        let d = self.charge(&mut inner, OpKind::DeleteObject, 0, 0);
        let cm = self.config.consistency;
        let Some(c) = inner.containers.get_mut(container) else {
            return (Err(StoreError::NoSuchContainer(container.into())), d);
        };
        if c.delete(key, now, &cm) {
            (Ok(()), d)
        } else {
            (Err(StoreError::NoSuchKey(format!("{container}/{key}"))), d)
        }
    }

    /// GET Container — the eventually consistent listing (§2.1).
    pub fn list(
        &self,
        container: &str,
        prefix: &str,
        delimiter: Option<char>,
        now: SimInstant,
    ) -> (Result<Listing, StoreError>, SimDuration) {
        let mut inner = self.inner.lock().unwrap();
        let result = inner
            .containers
            .get(container)
            .ok_or_else(|| StoreError::NoSuchContainer(container.into()))
            .map(|c| c.list(now, prefix, delimiter));
        let entries = result.as_ref().map(|l| l.len()).unwrap_or(0);
        let d = self.charge(&mut inner, OpKind::GetContainer, 0, entries);
        (result, d)
    }

    // ---- multipart upload (S3a fast-upload path) --------------------------

    /// Initiate a multipart upload. Charged as a PUT request.
    pub fn initiate_multipart(
        &self,
        container: &str,
        key: &str,
        metadata: Metadata,
    ) -> (Result<u64, StoreError>, SimDuration) {
        let mut inner = self.inner.lock().unwrap();
        let d = self.charge(&mut inner, OpKind::PutObject, 0, 0);
        if !inner.containers.contains_key(container) {
            return (Err(StoreError::NoSuchContainer(container.into())), d);
        }
        let id = inner.multipart.initiate(container, key, metadata);
        (Ok(id), d)
    }

    /// Upload one part. Charged as a PUT of the part's size.
    pub fn upload_part(
        &self,
        upload_id: u64,
        part_number: u32,
        data: Vec<u8>,
    ) -> (Result<(), StoreError>, SimDuration) {
        let mut inner = self.inner.lock().unwrap();
        let size = data.len() as u64;
        let d = self.charge(&mut inner, OpKind::PutObject, size, 0);
        match inner.multipart.get_mut(upload_id) {
            Some(up) => {
                self.counters
                    .record_write(self.config.latency.scaled_bytes(size));
                up.put_part(part_number, data);
                (Ok(()), d)
            }
            None => (Err(StoreError::NoSuchUpload(upload_id)), d),
        }
    }

    /// Complete a multipart upload: assembles parts into the final object.
    pub fn complete_multipart(
        &self,
        upload_id: u64,
        now: SimInstant,
    ) -> (Result<(), StoreError>, SimDuration) {
        let mut inner = self.inner.lock().unwrap();
        let d = self.charge(&mut inner, OpKind::PutObject, 0, 0);
        let Some(up) = inner.multipart.take(upload_id) else {
            return (Err(StoreError::NoSuchUpload(upload_id)), d);
        };
        let container = up.container.clone();
        let key = up.key.clone();
        match up.assemble(self.config.min_part_size) {
            Ok((data, metadata)) => {
                let cm = self.config.consistency;
                let Some(c) = inner.containers.get_mut(&container) else {
                    return (Err(StoreError::NoSuchContainer(container)), d);
                };
                // Bytes were already accounted at upload_part time.
                c.put(&key, Object::new(data, metadata, now), now, &cm);
                (Ok(()), d)
            }
            Err(msg) => (Err(StoreError::InvalidRequest(msg)), d),
        }
    }

    /// Abort a multipart upload (task abort path). Charged as a DELETE.
    pub fn abort_multipart(&self, upload_id: u64) -> (Result<(), StoreError>, SimDuration) {
        let mut inner = self.inner.lock().unwrap();
        let d = self.charge(&mut inner, OpKind::DeleteObject, 0, 0);
        match inner.multipart.take(upload_id) {
            Some(_) => (Ok(()), d),
            None => (Err(StoreError::NoSuchUpload(upload_id)), d),
        }
    }

    // ---- inspection (harness/tests only; not REST, not counted) -----------

    /// Authoritative object count in a container.
    pub fn debug_live_count(&self, container: &str) -> usize {
        let inner = self.inner.lock().unwrap();
        inner
            .containers
            .get(container)
            .map(|c| c.live_count())
            .unwrap_or(0)
    }

    /// Authoritative byte count in a container.
    pub fn debug_live_bytes(&self, container: &str) -> u64 {
        let inner = self.inner.lock().unwrap();
        inner
            .containers
            .get(container)
            .map(|c| c.live_bytes())
            .unwrap_or(0)
    }

    /// Authoritative name list (sorted) — bypasses eventual consistency.
    pub fn debug_names(&self, container: &str, prefix: &str) -> Vec<String> {
        let inner = self.inner.lock().unwrap();
        inner
            .containers
            .get(container)
            .map(|c| {
                c.iter_live()
                    .filter(|(k, _)| k.starts_with(prefix))
                    .map(|(k, _)| k.to_string())
                    .collect()
            })
            .unwrap_or_default()
    }

    /// In-flight multipart uploads (leak detection in tests).
    pub fn debug_multipart_in_flight(&self) -> usize {
        self.inner.lock().unwrap().multipart.in_flight()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> Arc<ObjectStore> {
        let s = ObjectStore::new(StoreConfig::instant_strong());
        s.create_container("res", SimInstant::EPOCH).0.unwrap();
        s
    }

    #[test]
    fn put_get_roundtrip_with_metadata() {
        let s = store();
        let mut md = Metadata::new();
        md.insert("X-Stocator-Origin".into(), "stocator-1.0".into());
        s.put_object("res", "d/part-0", b"abc".to_vec(), md, SimInstant(0))
            .0
            .unwrap();
        let (r, _) = s.get_object("res", "d/part-0");
        let r = r.unwrap();
        assert_eq!(&*r.data, b"abc");
        assert_eq!(r.head.size, 3);
        assert_eq!(
            r.head.metadata.get("X-Stocator-Origin").map(String::as_str),
            Some("stocator-1.0")
        );
    }

    #[test]
    fn missing_key_is_404_but_counted() {
        let s = store();
        let before = s.counters();
        let (r, _) = s.head_object("res", "nope");
        assert!(matches!(r, Err(StoreError::NoSuchKey(_))));
        let d = s.counters().since(&before);
        assert_eq!(d.get(OpKind::HeadObject), 1);
    }

    #[test]
    fn copy_then_delete_is_rename() {
        let s = store();
        s.put_object("res", "tmp/x", b"data".to_vec(), Metadata::new(), SimInstant(0))
            .0
            .unwrap();
        s.copy_object("res", "tmp/x", "res", "final/x", SimInstant(1))
            .0
            .unwrap();
        s.delete_object("res", "tmp/x", SimInstant(2)).0.unwrap();
        assert!(s.get_object("res", "final/x").0.is_ok());
        assert!(s.get_object("res", "tmp/x").0.is_err());
        let c = s.counters();
        assert_eq!(c.get(OpKind::CopyObject), 1);
        assert_eq!(c.get(OpKind::DeleteObject), 1);
        // COPY moved the bytes server-side:
        assert_eq!(c.bytes_copied, 4);
        assert_eq!(c.bytes_written, 4);
    }

    #[test]
    fn atomic_put_replaces_whole_value() {
        let s = store();
        s.put_object("res", "k", b"first".to_vec(), Metadata::new(), SimInstant(0))
            .0
            .unwrap();
        s.put_object("res", "k", b"2nd".to_vec(), Metadata::new(), SimInstant(1))
            .0
            .unwrap();
        let (r, _) = s.get_object("res", "k");
        assert_eq!(&*r.unwrap().data, b"2nd");
        assert_eq!(s.debug_live_count("res"), 1);
    }

    #[test]
    fn listing_is_eventually_consistent() {
        let s = ObjectStore::new(StoreConfig::instant_eventual());
        s.create_container("res", SimInstant::EPOCH).0.unwrap();
        s.put_object("res", "a", b"1".to_vec(), Metadata::new(), SimInstant(0))
            .0
            .unwrap();
        // Immediately after the PUT the listing is empty...
        let (l, _) = s.list("res", "", None, SimInstant(0));
        assert!(l.unwrap().is_empty());
        // ...but after the lag (2s default) the object appears.
        let (l, _) = s.list("res", "", None, SimInstant(2_000_000));
        assert_eq!(l.unwrap().objects.len(), 1);
        // GET was always consistent:
        assert!(s.get_object("res", "a").0.is_ok());
    }

    #[test]
    fn ops_on_missing_container_fail() {
        let s = ObjectStore::new(StoreConfig::instant_strong());
        assert!(matches!(
            s.put_object("c", "k", vec![], Metadata::new(), SimInstant(0)).0,
            Err(StoreError::NoSuchContainer(_))
        ));
        assert!(matches!(
            s.list("c", "", None, SimInstant(0)).0,
            Err(StoreError::NoSuchContainer(_))
        ));
        assert!(s.head_container("c").0.is_err());
        s.create_container("c", SimInstant(0)).0.unwrap();
        assert!(s.head_container("c").0.is_ok());
        assert!(matches!(
            s.create_container("c", SimInstant(0)).0,
            Err(StoreError::ContainerAlreadyExists(_))
        ));
    }

    #[test]
    fn multipart_assembles_and_counts_puts() {
        let s = store();
        let before = s.counters();
        let (id, _) = s.initiate_multipart("res", "big", Metadata::new());
        let id = id.unwrap();
        s.upload_part(id, 1, b"hello ".to_vec()).0.unwrap();
        s.upload_part(id, 2, b"world".to_vec()).0.unwrap();
        s.complete_multipart(id, SimInstant(5)).0.unwrap();
        let (r, _) = s.get_object("res", "big");
        assert_eq!(&*r.unwrap().data, b"hello world");
        let d = s.counters().since(&before);
        // initiate + 2 parts + complete = 4 PUT-class requests, 1 GET.
        assert_eq!(d.get(OpKind::PutObject), 4);
        assert_eq!(s.debug_multipart_in_flight(), 0);
    }

    #[test]
    fn multipart_abort_cleans_up() {
        let s = store();
        let (id, _) = s.initiate_multipart("res", "x", Metadata::new());
        let id = id.unwrap();
        s.upload_part(id, 1, b"junk".to_vec()).0.unwrap();
        s.abort_multipart(id).0.unwrap();
        assert_eq!(s.debug_multipart_in_flight(), 0);
        assert!(s.get_object("res", "x").0.is_err());
        assert!(s.complete_multipart(id, SimInstant(0)).0.is_err());
    }

    #[test]
    fn durations_follow_latency_model() {
        let cfg = StoreConfig {
            latency: LatencyModel::paper_testbed(),
            consistency: ConsistencyModel::strong(),
            min_part_size: 0,
            seed: 0,
        };
        let s = ObjectStore::new(cfg);
        let (_, d) = s.create_container("res", SimInstant::EPOCH);
        assert_eq!(d.as_micros(), 30_000); // PUT base
        let (_, d) = s.head_container("res");
        assert_eq!(d.as_micros(), 15_000); // HEAD base
        let (_, d) = s.put_object(
            "res",
            "k",
            vec![0u8; 26_000_000],
            Metadata::new(),
            SimInstant(0),
        );
        assert_eq!(d.as_micros(), 30_000 + 1_000_000); // base + 1s transfer
    }

    #[test]
    fn jitter_is_deterministic_per_seed() {
        let mk = |seed| {
            let mut lat = LatencyModel::paper_testbed();
            lat.jitter = 0.2;
            let cfg = StoreConfig {
                latency: lat,
                consistency: ConsistencyModel::strong(),
                min_part_size: 0,
                seed,
            };
            let s = ObjectStore::new(cfg);
            let (_, d) = s.create_container("res", SimInstant::EPOCH);
            d
        };
        assert_eq!(mk(1), mk(1));
        assert_ne!(mk(1), mk(2));
    }

    #[test]
    fn byte_accounting_scales_with_data_scale() {
        let cfg = StoreConfig {
            latency: LatencyModel {
                data_scale: 1000,
                scale_threshold: 0,
                ..LatencyModel::instant()
            },
            consistency: ConsistencyModel::strong(),
            min_part_size: 0,
            seed: 0,
        };
        let s = ObjectStore::new(cfg);
        s.create_container("res", SimInstant::EPOCH).0.unwrap();
        s.put_object("res", "k", vec![0u8; 100], Metadata::new(), SimInstant(0))
            .0
            .unwrap();
        assert_eq!(s.counters().bytes_written, 100_000);
    }
}
