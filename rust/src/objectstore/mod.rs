//! A cloud object store with the semantics the paper depends on (§2.1):
//! atomic whole-object PUT, GET/HEAD/COPY/DELETE, flat namespace with
//! hierarchical *naming* (prefix + delimiter listings), and **eventually
//! consistent container listings** — a listing may omit a recently created
//! object and may still include a recently deleted one.
//!
//! The stack is split into a front end and a data plane:
//!
//! * [`store::ObjectStore`] — the front end: REST op accounting in
//!   [`crate::metrics::LiveCounters`], virtual-clock costing via
//!   [`latency::LatencyModel`], pricing via [`pricing`], listing
//!   consistency via the [`visibility`] overlay driven by
//!   [`consistency::ConsistencyModel`], and deterministic transient REST
//!   faults via [`faults::FaultInjector`] (a failed request still burns
//!   latency, an op and wire bytes — stores bill failures too). This is the substitute for the
//!   paper's IBM COS cluster (DESIGN.md §2): connector behaviour depends
//!   only on the REST API semantics and the consistency model.
//! * [`backend`] — pluggable storage backends behind the
//!   [`backend::Backend`] trait: a sharded in-memory map and a persistent
//!   local-filesystem layout. Op counts and simulated runtimes are
//!   backend-invariant; backends trade wall-clock speed, concurrency and
//!   durability.

pub mod backend;
pub mod consistency;
pub mod container;
pub mod faults;
pub mod latency;
pub mod multipart;
pub mod object;
pub mod pricing;
pub mod store;
mod visibility;

pub use backend::{Backend, BackendError, BackendKind, LocalFsBackend, ShardedMemBackend};
pub use consistency::ConsistencyModel;
pub use container::{Listing, ObjectSummary};
pub use faults::{FaultClass, FaultInjector, FaultOp, FaultRule, FaultSpec, InjectedFault, RetryPolicy};
pub use latency::LatencyModel;
pub use object::{Metadata, Object};
pub use pricing::{cost_usd, storage_cost_usd_month, Provider, PROVIDERS};
pub use store::{MultipartSweep, ObjectStore, StoreConfig, StoreError};
