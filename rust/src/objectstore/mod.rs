//! An in-memory cloud object store with the semantics the paper depends on
//! (§2.1): atomic whole-object PUT, GET/HEAD/COPY/DELETE, flat namespace
//! with hierarchical *naming* (prefix + delimiter listings), and
//! **eventually consistent container listings** — a listing may omit a
//! recently created object and may still include a recently deleted one.
//!
//! Every operation is accounted in [`crate::metrics::LiveCounters`] and
//! costed on the virtual clock by [`latency::LatencyModel`]; REST-op prices
//! come from [`pricing`]. This is the substitute for the paper's IBM COS
//! cluster (DESIGN.md §2): connector behaviour depends only on the REST API
//! semantics and the consistency model, both implemented here.

pub mod object;
pub mod consistency;
pub mod container;
pub mod latency;
pub mod pricing;
pub mod multipart;
pub mod store;

pub use consistency::ConsistencyModel;
pub use container::{Listing, ObjectSummary};
pub use latency::LatencyModel;
pub use object::{Metadata, Object};
pub use pricing::{cost_usd, Provider, PROVIDERS};
pub use store::{ObjectStore, StoreConfig, StoreError};
