//! S3-style multipart upload (used by S3a's "fast upload" /
//! `S3AFastOutputStream`, paper §3.3). Each part upload is a separate PUT
//! request; `complete` assembles parts in part-number order into the final
//! object. The Swift analogue — chunked transfer encoding, which Stocator
//! uses — is a *single* PUT and is modelled directly in the store.
//!
//! [`MultipartUpload`] is the shared part-buffer + assembly/validation
//! logic for [`super::backend`] implementations: the in-memory backend
//! keeps a [`MultipartTable`] of these, and the local-FS backend rebuilds
//! one from its on-disk part files at complete time, so both enforce the
//! same min-part-size rules.

use super::object::Metadata;
use std::collections::BTreeMap;

/// Minimum part size for all but the last part (S3 enforces 5 MiB; we keep
/// the constant configurable because our datasets are byte-scaled).
pub const DEFAULT_MIN_PART_SIZE: u64 = 5 * 1024 * 1024;

/// An in-flight multipart upload session.
#[derive(Debug)]
pub struct MultipartUpload {
    pub container: String,
    pub key: String,
    pub metadata: Metadata,
    /// part number -> data. BTreeMap gives assembly order for free.
    parts: BTreeMap<u32, Vec<u8>>,
}

impl MultipartUpload {
    pub fn new(container: &str, key: &str, metadata: Metadata) -> Self {
        Self {
            container: container.to_string(),
            key: key.to_string(),
            metadata,
            parts: BTreeMap::new(),
        }
    }

    /// Upload (or replace) one part.
    pub fn put_part(&mut self, part_number: u32, data: Vec<u8>) {
        self.parts.insert(part_number, data);
    }

    pub fn part_count(&self) -> usize {
        self.parts.len()
    }

    pub fn bytes_buffered(&self) -> u64 {
        self.parts.values().map(|p| p.len() as u64).sum()
    }

    /// Assemble the final object content (parts in part-number order).
    /// Returns an error if any non-final part is under `min_part_size`.
    pub fn assemble(self, min_part_size: u64) -> Result<(Vec<u8>, Metadata), String> {
        if self.parts.is_empty() {
            return Err("multipart upload completed with no parts".into());
        }
        let last = *self.parts.keys().last().unwrap();
        for (&num, data) in &self.parts {
            if num != last && (data.len() as u64) < min_part_size {
                return Err(format!(
                    "part {} is {} bytes, below the {}-byte minimum",
                    num,
                    data.len(),
                    min_part_size
                ));
            }
        }
        let total: usize = self.parts.values().map(|p| p.len()).sum();
        let mut out = Vec::with_capacity(total);
        for (_, data) in self.parts {
            out.extend_from_slice(&data);
        }
        Ok((out, self.metadata))
    }
}

/// The store's table of in-flight uploads, keyed by upload id.
#[derive(Debug, Default)]
pub struct MultipartTable {
    next_id: u64,
    uploads: BTreeMap<u64, MultipartUpload>,
}

impl MultipartTable {
    pub fn initiate(&mut self, container: &str, key: &str, metadata: Metadata) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.uploads
            .insert(id, MultipartUpload::new(container, key, metadata));
        id
    }

    pub fn get_mut(&mut self, id: u64) -> Option<&mut MultipartUpload> {
        self.uploads.get_mut(&id)
    }

    pub fn take(&mut self, id: u64) -> Option<MultipartUpload> {
        self.uploads.remove(&id)
    }

    pub fn in_flight(&self) -> usize {
        self.uploads.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assemble_in_part_order() {
        let mut up = MultipartUpload::new("c", "k", Metadata::new());
        up.put_part(2, b"world".to_vec());
        up.put_part(1, b"hello ".to_vec());
        let (data, _) = up.assemble(0).unwrap();
        assert_eq!(data, b"hello world");
    }

    #[test]
    fn min_part_size_enforced_except_last() {
        let mut up = MultipartUpload::new("c", "k", Metadata::new());
        up.put_part(1, vec![0u8; 10]);
        up.put_part(2, vec![0u8; 3]); // last part may be small
        assert!(up.assemble(10).is_ok());

        let mut up2 = MultipartUpload::new("c", "k", Metadata::new());
        up2.put_part(1, vec![0u8; 3]); // non-final part too small
        up2.put_part(2, vec![0u8; 10]);
        let err = up2.assemble(10).unwrap_err();
        assert!(err.contains("below"), "{err}");
    }

    #[test]
    fn empty_completion_rejected() {
        let up = MultipartUpload::new("c", "k", Metadata::new());
        assert!(up.assemble(0).is_err());
    }

    #[test]
    fn replace_part() {
        let mut up = MultipartUpload::new("c", "k", Metadata::new());
        up.put_part(1, b"aaa".to_vec());
        up.put_part(1, b"bb".to_vec());
        assert_eq!(up.part_count(), 1);
        assert_eq!(up.bytes_buffered(), 2);
    }

    #[test]
    fn table_lifecycle() {
        let mut t = MultipartTable::default();
        let id1 = t.initiate("c", "a", Metadata::new());
        let id2 = t.initiate("c", "b", Metadata::new());
        assert_ne!(id1, id2);
        assert_eq!(t.in_flight(), 2);
        t.get_mut(id1).unwrap().put_part(1, b"x".to_vec());
        let up = t.take(id1).unwrap();
        assert_eq!(up.part_count(), 1);
        assert_eq!(t.in_flight(), 1);
        assert!(t.take(id1).is_none());
    }
}
