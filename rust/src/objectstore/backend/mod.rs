//! Pluggable storage backends: the data plane behind [`crate::objectstore::ObjectStore`].
//!
//! The front end owns everything the paper's evaluation measures — REST op
//! accounting, the virtual-time latency model, eventual-consistency
//! enforcement, pricing — while a [`Backend`] owns the bytes. This module
//! defines the seam every backend plugs into, plus two implementations:
//!
//! * [`ShardedMemBackend`] — an N-way key-sharded in-memory map
//!   (shard-per-lock). One shard reproduces the legacy single-global-mutex
//!   layout; the default 16 shards let Spark executor threads stop
//!   serialising on the store hot path.
//! * [`LocalFsBackend`] — objects laid out under a root directory with
//!   sidecar metadata/ETag files. Survives process restart and supports
//!   real-IO benchmarking.
//!
//! A third implementation lives in [`crate::gateway::HttpBackend`]: the
//! same contract spoken over real sockets to a gateway started with
//! `stocator-sim serve` (selected via `--backend http:HOST:PORT`); it
//! passes this module's conformance suite through an in-process server.
//!
//! # Trait contract
//!
//! Every backend MUST provide these semantics; the conformance suite in
//! `rust/tests/test_backend_conformance.rs` enforces them against each
//! implementation:
//!
//! * **Atomic create/replace.** [`Backend::put`] installs the whole object
//!   or nothing; a concurrent [`Backend::get`] sees either the old or the
//!   new object, never a torn mixture. `put` reports whether it replaced
//!   an existing object (the front end needs that bit for listing
//!   visibility).
//! * **Last writer wins.** There is no versioning: the most recent `put`
//!   for a key defines the object, including its metadata and ETag.
//! * **Authoritative, sorted, paginated listings.** [`Backend::list_page`]
//!   returns keys in ascending lexicographic order, filtered by prefix,
//!   resuming strictly after `start_after`. Listings are authoritative
//!   (read-after-write): the *eventually consistent* listings the paper
//!   depends on (§2.1) are synthesised above this layer by the front
//!   end's visibility overlay, which delays newly created names and
//!   retains ghosts of deleted ones. Backends therefore never model lag.
//! * **Ranged reads follow HTTP semantics.** [`Backend::get_range`]
//!   returns `[offset, offset+len)` clamped to EOF together with the full
//!   object's stat; an offset strictly past EOF is
//!   [`BackendError::InvalidRange`] (see [`clamp_range`], the shared
//!   implementation of the rule).
//! * **ETags are content hashes.** Backends must tag objects with
//!   [`crate::objectstore::object::sampled_etag`] over the payload so the
//!   same bytes produce the same ETag on every backend (the conformance
//!   suite round-trips this).
//! * **Errors carry full names.** `NoSuchKey` messages are formatted
//!   `"container/key"` to match the front end's REST error space.
//! * **Multipart uploads are consumed on completion.** A
//!   [`Backend::complete_multipart`] call removes the upload whether or
//!   not assembly succeeds (S3 semantics: a failed complete still
//!   invalidates the upload id). Assembly concatenates parts in
//!   ascending part-number order and enforces `min_part_size` on every
//!   part but the last.

pub mod fs;
pub mod mem;

pub use fs::LocalFsBackend;
pub use mem::ShardedMemBackend;

use super::container::ObjectSummary;
use super::object::{Metadata, Object};
use crate::simclock::SimInstant;
use std::fmt;
use std::path::PathBuf;

/// Default shard count for [`ShardedMemBackend`] (`BackendKind::Sharded`).
pub const DEFAULT_SHARDS: usize = 16;

/// Page size the front end uses when walking a full listing.
pub const DEFAULT_PAGE_SIZE: usize = 1000;

/// Errors a backend can raise. The front end maps these onto
/// [`crate::objectstore::StoreError`] without losing information.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BackendError {
    NoSuchContainer(String),
    /// Formatted `"container/key"`.
    NoSuchKey(String),
    ContainerAlreadyExists(String),
    NoSuchUpload(u64),
    InvalidRequest(String),
    /// A ranged read whose offset lies strictly past end-of-file (the
    /// HTTP 416 case; see [`clamp_range`] for the exact contract).
    InvalidRange(String),
    /// Real-IO failure (LocalFsBackend); the simulated REST space has no
    /// equivalent, so the front end surfaces it as a 500.
    Io(String),
}

impl fmt::Display for BackendError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BackendError::NoSuchContainer(c) => write!(f, "no such container: {c}"),
            BackendError::NoSuchKey(k) => write!(f, "no such key: {k}"),
            BackendError::ContainerAlreadyExists(c) => write!(f, "container exists: {c}"),
            BackendError::NoSuchUpload(id) => write!(f, "no such upload: {id}"),
            BackendError::InvalidRequest(m) => write!(f, "invalid request: {m}"),
            BackendError::InvalidRange(m) => write!(f, "invalid range: {m}"),
            BackendError::Io(m) => write!(f, "backend io error: {m}"),
        }
    }
}

impl std::error::Error for BackendError {}

impl BackendError {
    /// The canonical `NoSuchKey` error (`"container/key"` formatting —
    /// shared by every backend so the front end's REST error space stays
    /// uniform).
    pub fn no_such_key(container: &str, key: &str) -> Self {
        BackendError::NoSuchKey(format!("{container}/{key}"))
    }
}

/// The shared ranged-read contract (HTTP Range semantics), used by every
/// backend so [`Backend::get_range`] behaves identically across them:
///
/// * ranges are **clamped to EOF** — `offset + len` may exceed the object
///   size and simply returns fewer bytes;
/// * `offset == size` is valid and yields an empty slice;
/// * `offset > size` is [`BackendError::InvalidRange`] (HTTP 416);
/// * a zero-length range is valid and returns no bytes.
///
/// Returns the half-open byte bounds `[start, end)` to read.
pub fn clamp_range(
    container: &str,
    key: &str,
    offset: u64,
    len: u64,
    size: u64,
) -> Result<(usize, usize), BackendError> {
    if offset > size {
        return Err(BackendError::InvalidRange(format!(
            "{container}/{key}: offset {offset} past EOF (size {size})"
        )));
    }
    let end = offset.saturating_add(len).min(size);
    Ok((offset as usize, end as usize))
}

/// HEAD-shaped view of a stored object: everything but the data.
#[derive(Debug, Clone)]
pub struct ObjectStat {
    pub size: u64,
    pub etag: u64,
    pub metadata: Metadata,
    pub created_at: SimInstant,
}

impl ObjectStat {
    pub fn of(obj: &Object) -> Self {
        Self {
            size: obj.size(),
            etag: obj.etag,
            metadata: obj.metadata.clone(),
            created_at: obj.created_at,
        }
    }
}

/// One page of an authoritative listing.
#[derive(Debug, Clone, Default)]
pub struct ListPage {
    /// Ascending by name; every name starts with the requested prefix.
    pub entries: Vec<ObjectSummary>,
    /// `Some(last_returned_key)` when more entries may follow; pass it
    /// back as `start_after` to continue. `None` when exhausted.
    pub next: Option<String>,
}

/// A completed multipart upload, assembled but not yet installed. The
/// front end runs it through the normal put path so consistency overlay
/// bookkeeping and byte accounting stay backend-agnostic.
#[derive(Debug)]
pub struct AssembledUpload {
    pub container: String,
    pub key: String,
    pub data: Vec<u8>,
    pub metadata: Metadata,
}

/// The storage data plane. See the module docs for the full contract.
pub trait Backend: Send + Sync {
    /// Human-readable backend name (for logs and benches).
    fn name(&self) -> &'static str;

    // ---- containers ------------------------------------------------------

    fn create_container(&self, name: &str) -> Result<(), BackendError>;

    fn container_exists(&self, name: &str) -> bool;

    // ---- objects ---------------------------------------------------------

    /// Atomic create/replace. Returns `true` if an existing object was
    /// replaced.
    fn put(&self, container: &str, key: &str, obj: Object) -> Result<bool, BackendError>;

    fn get(&self, container: &str, key: &str) -> Result<Object, BackendError>;

    /// Ranged read: bytes `[offset, offset + len)` of an object plus its
    /// **full** stat (HTTP `Content-Range` semantics: the stat's `size` is
    /// the whole object's, not the slice's). Range handling must follow
    /// [`clamp_range`]; the conformance suite checks mid-object,
    /// zero-length, exact-EOF and past-EOF cases against every backend.
    fn get_range(
        &self,
        container: &str,
        key: &str,
        offset: u64,
        len: u64,
    ) -> Result<(Vec<u8>, ObjectStat), BackendError>;

    fn head(&self, container: &str, key: &str) -> Result<ObjectStat, BackendError>;

    /// Remove an object, returning its final stat (the front end needs
    /// size + etag to keep a listing ghost under eventual consistency).
    fn delete(&self, container: &str, key: &str) -> Result<ObjectStat, BackendError>;

    /// One page of the authoritative listing: keys starting with `prefix`,
    /// strictly greater than `start_after` (when given), ascending, at
    /// most `max_keys` entries.
    fn list_page(
        &self,
        container: &str,
        prefix: &str,
        start_after: Option<&str>,
        max_keys: usize,
    ) -> Result<ListPage, BackendError>;

    // ---- multipart uploads ----------------------------------------------

    fn initiate_multipart(
        &self,
        container: &str,
        key: &str,
        metadata: Metadata,
    ) -> Result<u64, BackendError>;

    fn upload_part(
        &self,
        upload_id: u64,
        part_number: u32,
        data: Vec<u8>,
    ) -> Result<(), BackendError>;

    /// Assemble and consume the upload (consumed even on failure).
    fn complete_multipart(
        &self,
        upload_id: u64,
        min_part_size: u64,
    ) -> Result<AssembledUpload, BackendError>;

    fn abort_multipart(&self, upload_id: u64) -> Result<(), BackendError>;

    fn multipart_in_flight(&self) -> usize;

    // ---- stats (harness/tests; not REST, not counted) --------------------

    fn live_count(&self, container: &str) -> usize;

    fn live_bytes(&self, container: &str) -> u64;
}

/// Which backend an [`crate::objectstore::ObjectStore`] should run on.
/// Carried by `StoreConfig` (and `harness::Sizing`) and selectable on the
/// CLI via `--backend mem|sharded[:N]|fs[:DIR]|http:HOST:PORT`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BackendKind {
    /// Single-shard in-memory map — the legacy single-global-lock layout.
    Mem,
    /// N-way key-sharded in-memory map (shard-per-lock).
    Sharded(usize),
    /// Persistent local-filesystem backend rooted at the given directory;
    /// `None` picks a fresh unique directory under the system temp dir.
    LocalFs(Option<PathBuf>),
    /// Remote gateway ([`crate::gateway`]) reached over real sockets.
    /// `ns`, when set, prefixes container names on the wire so each
    /// client gets a disjoint world on a shared served store (the
    /// harness sets a unique one per workload environment, mirroring
    /// the `fs` backend's per-env subdirectory).
    Http { addr: String, ns: Option<String> },
}

impl Default for BackendKind {
    fn default() -> Self {
        BackendKind::Sharded(DEFAULT_SHARDS)
    }
}

impl BackendKind {
    /// Parse a CLI spelling: `mem`, `sharded`, `sharded:N`, `fs`,
    /// `fs:DIR`, `http:HOST:PORT` (`http://HOST:PORT` also accepted).
    pub fn parse(s: &str) -> Result<BackendKind, String> {
        let (kind, arg) = match s.split_once(':') {
            Some((k, a)) => (k, Some(a)),
            None => (s, None),
        };
        match (kind, arg) {
            ("mem", None) => Ok(BackendKind::Mem),
            ("sharded", None) => Ok(BackendKind::Sharded(DEFAULT_SHARDS)),
            ("sharded", Some(n)) => match n.parse::<usize>() {
                Ok(n) if n >= 1 => Ok(BackendKind::Sharded(n)),
                _ => Err(format!("sharded:{n} — shard count must be a positive integer")),
            },
            ("fs", None) => Ok(BackendKind::LocalFs(None)),
            ("fs", Some(dir)) if !dir.is_empty() => {
                Ok(BackendKind::LocalFs(Some(PathBuf::from(dir))))
            }
            ("http", Some(addr)) => {
                let addr = addr.trim_start_matches("//").trim_end_matches('/');
                if addr.split_once(':').map_or(false, |(host, port)| {
                    !host.is_empty() && port.parse::<u16>().is_ok()
                }) {
                    Ok(BackendKind::Http {
                        addr: addr.to_string(),
                        ns: None,
                    })
                } else {
                    Err(format!("http:{addr} — expected http:HOST:PORT"))
                }
            }
            _ => Err(format!(
                "unknown backend '{s}' (expected mem, sharded[:N], fs[:DIR], or http:HOST:PORT)"
            )),
        }
    }

    /// The CLI spelling (for usage/help text).
    pub fn label(&self) -> String {
        match self {
            BackendKind::Mem => "mem".to_string(),
            BackendKind::Sharded(n) => format!("sharded:{n}"),
            BackendKind::LocalFs(None) => "fs".to_string(),
            BackendKind::LocalFs(Some(p)) => format!("fs:{}", p.display()),
            BackendKind::Http { addr, .. } => format!("http:{addr}"),
        }
    }
}

/// Build a backend from its kind. Panics if a LocalFs root cannot be
/// created (the store constructor is infallible by API contract; callers
/// that need to validate a root first use [`LocalFsBackend::open`]).
pub fn make_backend(kind: &BackendKind) -> Box<dyn Backend> {
    match kind {
        BackendKind::Mem => Box::new(ShardedMemBackend::new(1)),
        BackendKind::Sharded(n) => Box::new(ShardedMemBackend::new(*n)),
        BackendKind::LocalFs(Some(root)) => Box::new(
            LocalFsBackend::open(root)
                .unwrap_or_else(|e| panic!("opening fs backend at {}: {e}", root.display())),
        ),
        BackendKind::LocalFs(None) => {
            let root = fresh_temp_root();
            Box::new(
                LocalFsBackend::open(&root)
                    .unwrap_or_else(|e| panic!("opening fs backend at {}: {e}", root.display())),
            )
        }
        BackendKind::Http { addr, ns } => Box::new(
            crate::gateway::HttpBackend::connect(addr, ns.clone())
                .unwrap_or_else(|e| panic!("connecting http backend at {addr}: {e}")),
        ),
    }
}

/// A process-unique directory under the system temp dir.
pub fn fresh_temp_root() -> PathBuf {
    unique_subroot(&std::env::temp_dir())
}

/// A process-unique subdirectory of `root`. The harness derives one per
/// workload environment so repeated runs against the same `fs:DIR` never
/// collide on container creation (each run's store is a fresh world, as
/// with the in-memory backends, while all data stays under `DIR` for
/// inspection).
pub fn unique_subroot(root: &std::path::Path) -> PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.subsec_nanos())
        .unwrap_or(0);
    root.join(format!(
        "stocator-fs-{}-{}-{}",
        std::process::id(),
        COUNTER.fetch_add(1, Ordering::Relaxed),
        nanos
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_backend_kinds() {
        assert_eq!(BackendKind::parse("mem").unwrap(), BackendKind::Mem);
        assert_eq!(
            BackendKind::parse("sharded").unwrap(),
            BackendKind::Sharded(DEFAULT_SHARDS)
        );
        assert_eq!(
            BackendKind::parse("sharded:4").unwrap(),
            BackendKind::Sharded(4)
        );
        assert_eq!(BackendKind::parse("fs").unwrap(), BackendKind::LocalFs(None));
        assert_eq!(
            BackendKind::parse("fs:/tmp/x").unwrap(),
            BackendKind::LocalFs(Some(PathBuf::from("/tmp/x")))
        );
        assert!(BackendKind::parse("sharded:0").is_err());
        assert!(BackendKind::parse("sharded:no").is_err());
        assert!(BackendKind::parse("redis").is_err());
        assert!(BackendKind::parse("fs:").is_err());
        assert_eq!(
            BackendKind::parse("http:127.0.0.1:8080").unwrap(),
            BackendKind::Http {
                addr: "127.0.0.1:8080".to_string(),
                ns: None
            }
        );
        // The scheme-prefixed spelling normalises to HOST:PORT.
        assert_eq!(
            BackendKind::parse("http://127.0.0.1:8080").unwrap(),
            BackendKind::parse("http:127.0.0.1:8080").unwrap()
        );
        assert_eq!(
            BackendKind::parse("http:localhost:9000").unwrap().label(),
            "http:localhost:9000"
        );
        assert!(BackendKind::parse("http").is_err());
        assert!(BackendKind::parse("http:").is_err());
        assert!(BackendKind::parse("http:noport").is_err());
        assert!(BackendKind::parse("http:host:notaport").is_err());
    }

    #[test]
    fn default_is_sharded() {
        assert_eq!(BackendKind::default(), BackendKind::Sharded(DEFAULT_SHARDS));
        assert_eq!(BackendKind::default().label(), "sharded:16");
    }

    #[test]
    fn temp_roots_are_unique() {
        assert_ne!(fresh_temp_root(), fresh_temp_root());
        let base = std::path::Path::new("/x");
        assert_ne!(unique_subroot(base), unique_subroot(base));
        assert!(unique_subroot(base).starts_with(base));
    }
}
