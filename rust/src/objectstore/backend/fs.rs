//! Persistent local-filesystem backend.
//!
//! Objects live under a root directory, one file per object plus a sidecar
//! carrying what the filesystem cannot: the ETag, the virtual-clock
//! creation instant, and the user metadata. Layout:
//!
//! ```text
//! <root>/
//!   .tmp/                      staging area for atomic renames
//!   .multipart/<id>/           one dir per in-flight multipart upload
//!     upload.meta              container, key, user metadata
//!     part-<n>                 raw part payloads
//!   <container>/
//!     objects/<encoded-key>    object data
//!     meta/<encoded-key>       sidecar: etag, created_at, metadata
//! ```
//!
//! Keys are percent-encoded into single path components (object-store keys
//! are flat names that may contain `/`, which the filesystem would
//! interpret); listings decode and sort, so pagination order matches the
//! in-memory backends exactly. Writes go through `.tmp` + `rename`, so an
//! individual file is installed atomically; a reopened root (process
//! restart) sees every completed put, and multipart upload ids resume past
//! the highest id on disk. Concurrent readers of a key being replaced may
//! transiently pair new data with the old sidecar — the simulator drives
//! each key from one task at a time, so this is out of contract (noted
//! here rather than locked around, to keep real-IO benchmarking honest).

use super::{AssembledUpload, Backend, BackendError, ListPage, ObjectStat};
use crate::objectstore::container::ObjectSummary;
use crate::objectstore::multipart::MultipartUpload;
use crate::objectstore::object::{sampled_etag, Metadata, Object};
use crate::simclock::SimInstant;
use std::io::ErrorKind;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Percent-encode a store name into one safe path component. A leading
/// `.` is always encoded, so stored files never collide with the
/// backend's own dot-directories and dotfiles can be skipped in listings.
/// The empty name encodes as a bare `%` (unambiguous: `%` is otherwise
/// always followed by two hex digits).
fn encode(name: &str) -> String {
    if name.is_empty() {
        return "%".to_string();
    }
    let mut out = String::with_capacity(name.len());
    for (i, b) in name.bytes().enumerate() {
        let plain = b.is_ascii_alphanumeric()
            || b == b'_'
            || b == b'-'
            || (b == b'.' && i > 0);
        if plain {
            out.push(b as char);
        } else {
            out.push('%');
            out.push_str(&format!("{b:02X}"));
        }
    }
    out
}

/// Inverse of [`encode`]; `None` for names this backend did not write.
fn decode(enc: &str) -> Option<String> {
    if enc == "%" {
        return Some(String::new());
    }
    let bytes = enc.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'%' {
            let hex = bytes.get(i + 1..i + 3)?;
            let s = std::str::from_utf8(hex).ok()?;
            out.push(u8::from_str_radix(s, 16).ok()?);
            i += 3;
        } else {
            out.push(bytes[i]);
            i += 1;
        }
    }
    String::from_utf8(out).ok()
}

fn io_err(ctx: &str, e: std::io::Error) -> BackendError {
    BackendError::Io(format!("{ctx}: {e}"))
}

/// Parsed sidecar contents.
struct Sidecar {
    etag: u64,
    created_at: SimInstant,
    metadata: Metadata,
}

impl Sidecar {
    fn render(etag: u64, created_at: SimInstant, metadata: &Metadata) -> String {
        let mut out = format!("etag {etag:016x}\ncreated_at {}\n", created_at.0);
        for (k, v) in metadata {
            out.push_str(&format!("meta {} {}\n", encode(k), encode(v)));
        }
        out
    }

    fn parse(text: &str) -> Sidecar {
        let mut etag = 0;
        let mut created_at = SimInstant::EPOCH;
        let mut metadata = Metadata::new();
        for line in text.lines() {
            let mut cols = line.splitn(3, ' ');
            match (cols.next(), cols.next(), cols.next()) {
                (Some("etag"), Some(v), None) => {
                    etag = u64::from_str_radix(v, 16).unwrap_or(0);
                }
                (Some("created_at"), Some(v), None) => {
                    created_at = SimInstant(v.parse().unwrap_or(0));
                }
                (Some("meta"), Some(k), Some(v)) => {
                    if let (Some(k), Some(v)) = (decode(k), decode(v)) {
                        metadata.insert(k, v);
                    }
                }
                _ => {}
            }
        }
        Sidecar {
            etag,
            created_at,
            metadata,
        }
    }
}

/// Objects under a root directory with sidecar metadata; see module docs.
pub struct LocalFsBackend {
    root: PathBuf,
    next_upload: AtomicU64,
    tmp_seq: AtomicU64,
}

impl LocalFsBackend {
    /// Open (creating if needed) a backend rooted at `root`. Reopening an
    /// existing root resumes its containers, objects and multipart ids.
    pub fn open(root: &Path) -> Result<Self, BackendError> {
        std::fs::create_dir_all(root.join(".tmp"))
            .map_err(|e| io_err("creating staging dir", e))?;
        std::fs::create_dir_all(root.join(".multipart"))
            .map_err(|e| io_err("creating multipart dir", e))?;
        let mut max_id = 0;
        let entries = std::fs::read_dir(root.join(".multipart"))
            .map_err(|e| io_err("scanning multipart dir", e))?;
        for entry in entries.flatten() {
            if let Some(id) = entry.file_name().to_str().and_then(|n| n.parse::<u64>().ok()) {
                max_id = max_id.max(id + 1);
            }
        }
        Ok(Self {
            root: root.to_path_buf(),
            next_upload: AtomicU64::new(max_id),
            tmp_seq: AtomicU64::new(0),
        })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn objects_dir(&self, container: &str) -> PathBuf {
        self.root.join(encode(container)).join("objects")
    }

    fn meta_dir(&self, container: &str) -> PathBuf {
        self.root.join(encode(container)).join("meta")
    }

    fn data_path(&self, container: &str, key: &str) -> PathBuf {
        self.objects_dir(container).join(encode(key))
    }

    fn meta_path(&self, container: &str, key: &str) -> PathBuf {
        self.meta_dir(container).join(encode(key))
    }

    fn upload_dir(&self, id: u64) -> PathBuf {
        self.root.join(".multipart").join(id.to_string())
    }

    fn check_container(&self, name: &str) -> Result<(), BackendError> {
        if self.container_exists(name) {
            Ok(())
        } else {
            Err(BackendError::NoSuchContainer(name.to_string()))
        }
    }

    /// Write `bytes` to `dest` atomically (stage in `.tmp`, then rename).
    fn write_atomic(&self, dest: &Path, bytes: &[u8]) -> Result<(), BackendError> {
        let tmp = self.root.join(".tmp").join(format!(
            "t{}-{}",
            std::process::id(),
            self.tmp_seq.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::write(&tmp, bytes).map_err(|e| io_err("staging write", e))?;
        std::fs::rename(&tmp, dest).map_err(|e| io_err("installing write", e))
    }

    /// Read a key's sidecar; when absent (foreign file dropped into the
    /// root), synthesise one from the data so reads still work.
    fn read_sidecar(&self, container: &str, key: &str) -> Result<Sidecar, BackendError> {
        match std::fs::read_to_string(self.meta_path(container, key)) {
            Ok(text) => Ok(Sidecar::parse(&text)),
            Err(e) if e.kind() == ErrorKind::NotFound => {
                let data = std::fs::read(self.data_path(container, key))
                    .map_err(|e| io_err("reading data for missing sidecar", e))?;
                Ok(Sidecar {
                    etag: sampled_etag(&data),
                    created_at: SimInstant::EPOCH,
                    metadata: Metadata::new(),
                })
            }
            Err(e) => Err(io_err("reading sidecar", e)),
        }
    }

    /// All decoded key names in a container, unsorted.
    fn key_names(&self, container: &str) -> Result<Vec<String>, BackendError> {
        let entries = std::fs::read_dir(self.objects_dir(container))
            .map_err(|e| io_err("listing objects dir", e))?;
        let mut names = Vec::new();
        for entry in entries.flatten() {
            let Some(fname) = entry.file_name().to_str().map(String::from) else {
                continue;
            };
            if fname.starts_with('.') {
                continue;
            }
            if let Some(decoded) = decode(&fname) {
                names.push(decoded);
            }
        }
        Ok(names)
    }
}

impl Backend for LocalFsBackend {
    fn name(&self) -> &'static str {
        "local-fs"
    }

    fn create_container(&self, name: &str) -> Result<(), BackendError> {
        let objects = self.objects_dir(name);
        if objects.is_dir() {
            return Err(BackendError::ContainerAlreadyExists(name.to_string()));
        }
        std::fs::create_dir_all(&objects).map_err(|e| io_err("creating container", e))?;
        std::fs::create_dir_all(self.meta_dir(name))
            .map_err(|e| io_err("creating container meta dir", e))
    }

    fn container_exists(&self, name: &str) -> bool {
        self.objects_dir(name).is_dir()
    }

    fn put(&self, container: &str, key: &str, obj: Object) -> Result<bool, BackendError> {
        self.check_container(container)?;
        let data_path = self.data_path(container, key);
        let replaced = data_path.exists();
        let sidecar = Sidecar::render(obj.etag, obj.created_at, &obj.metadata);
        self.write_atomic(&self.meta_path(container, key), sidecar.as_bytes())?;
        self.write_atomic(&data_path, &obj.data)?;
        Ok(replaced)
    }

    fn get(&self, container: &str, key: &str) -> Result<Object, BackendError> {
        self.check_container(container)?;
        let data = match std::fs::read(self.data_path(container, key)) {
            Ok(d) => d,
            Err(e) if e.kind() == ErrorKind::NotFound => {
                return Err(BackendError::no_such_key(container, key))
            }
            Err(e) => return Err(io_err("reading object", e)),
        };
        let sidecar = self.read_sidecar(container, key)?;
        Ok(Object {
            data: Arc::new(data),
            metadata: sidecar.metadata,
            created_at: sidecar.created_at,
            etag: sidecar.etag,
        })
    }

    fn get_range(
        &self,
        container: &str,
        key: &str,
        offset: u64,
        len: u64,
    ) -> Result<(Vec<u8>, ObjectStat), BackendError> {
        use std::io::{Read, Seek, SeekFrom};
        let stat = self.head(container, key)?;
        let (start, end) = super::clamp_range(container, key, offset, len, stat.size)?;
        let take = end - start;
        if take == 0 {
            return Ok((Vec::new(), stat));
        }
        // Real ranged IO: seek + bounded read, never the whole file.
        let mut f = std::fs::File::open(self.data_path(container, key))
            .map_err(|e| io_err("opening object for ranged read", e))?;
        f.seek(SeekFrom::Start(start as u64))
            .map_err(|e| io_err("seeking object", e))?;
        let mut out = vec![0u8; take];
        f.read_exact(&mut out)
            .map_err(|e| io_err("ranged read", e))?;
        Ok((out, stat))
    }

    fn head(&self, container: &str, key: &str) -> Result<ObjectStat, BackendError> {
        self.check_container(container)?;
        let size = match std::fs::metadata(self.data_path(container, key)) {
            Ok(m) => m.len(),
            Err(e) if e.kind() == ErrorKind::NotFound => {
                return Err(BackendError::no_such_key(container, key))
            }
            Err(e) => return Err(io_err("stat object", e)),
        };
        let sidecar = self.read_sidecar(container, key)?;
        Ok(ObjectStat {
            size,
            etag: sidecar.etag,
            metadata: sidecar.metadata,
            created_at: sidecar.created_at,
        })
    }

    fn delete(&self, container: &str, key: &str) -> Result<ObjectStat, BackendError> {
        let stat = self.head(container, key)?;
        match std::fs::remove_file(self.data_path(container, key)) {
            Ok(()) => {}
            Err(e) if e.kind() == ErrorKind::NotFound => {
                return Err(BackendError::no_such_key(container, key))
            }
            Err(e) => return Err(io_err("removing object", e)),
        }
        let _ = std::fs::remove_file(self.meta_path(container, key));
        Ok(stat)
    }

    fn list_page(
        &self,
        container: &str,
        prefix: &str,
        start_after: Option<&str>,
        max_keys: usize,
    ) -> Result<ListPage, BackendError> {
        self.check_container(container)?;
        let mut names: Vec<String> = self
            .key_names(container)?
            .into_iter()
            .filter(|n| n.starts_with(prefix))
            .filter(|n| start_after.map_or(true, |s| n.as_str() > s))
            .collect();
        names.sort_unstable();
        let has_more = names.len() > max_keys;
        names.truncate(max_keys);
        let mut entries = Vec::with_capacity(names.len());
        for name in names {
            // One stat + one sidecar read per returned entry (container
            // existence was checked once above). Objects deleted between
            // the directory scan and this stat are simply omitted
            // (sequential use never hits this).
            let size = match std::fs::metadata(self.data_path(container, &name)) {
                Ok(m) => m.len(),
                Err(e) if e.kind() == ErrorKind::NotFound => continue,
                Err(e) => return Err(io_err("stat object", e)),
            };
            let sidecar = self.read_sidecar(container, &name)?;
            entries.push(ObjectSummary {
                name,
                size,
                etag: sidecar.etag,
            });
        }
        let next = if has_more {
            entries.last().map(|s| s.name.clone())
        } else {
            None
        };
        Ok(ListPage { entries, next })
    }

    fn initiate_multipart(
        &self,
        container: &str,
        key: &str,
        metadata: Metadata,
    ) -> Result<u64, BackendError> {
        self.check_container(container)?;
        let id = self.next_upload.fetch_add(1, Ordering::Relaxed);
        let dir = self.upload_dir(id);
        std::fs::create_dir_all(&dir).map_err(|e| io_err("creating upload dir", e))?;
        let mut meta_text = format!("container {}\nkey {}\n", encode(container), encode(key));
        for (k, v) in &metadata {
            meta_text.push_str(&format!("meta {} {}\n", encode(k), encode(v)));
        }
        self.write_atomic(&dir.join("upload.meta"), meta_text.as_bytes())?;
        Ok(id)
    }

    fn upload_part(
        &self,
        upload_id: u64,
        part_number: u32,
        data: Vec<u8>,
    ) -> Result<(), BackendError> {
        let dir = self.upload_dir(upload_id);
        if !dir.is_dir() {
            return Err(BackendError::NoSuchUpload(upload_id));
        }
        self.write_atomic(&dir.join(format!("part-{part_number}")), &data)
    }

    fn complete_multipart(
        &self,
        upload_id: u64,
        min_part_size: u64,
    ) -> Result<AssembledUpload, BackendError> {
        let dir = self.upload_dir(upload_id);
        let meta_text = match std::fs::read_to_string(dir.join("upload.meta")) {
            Ok(t) => t,
            Err(e) if e.kind() == ErrorKind::NotFound => {
                return Err(BackendError::NoSuchUpload(upload_id))
            }
            Err(e) => return Err(io_err("reading upload.meta", e)),
        };
        let mut container = String::new();
        let mut key = String::new();
        let mut metadata = Metadata::new();
        for line in meta_text.lines() {
            let mut cols = line.splitn(3, ' ');
            match (cols.next(), cols.next(), cols.next()) {
                (Some("container"), Some(v), None) => {
                    container = decode(v).unwrap_or_default();
                }
                (Some("key"), Some(v), None) => key = decode(v).unwrap_or_default(),
                (Some("meta"), Some(k), Some(v)) => {
                    if let (Some(k), Some(v)) = (decode(k), decode(v)) {
                        metadata.insert(k, v);
                    }
                }
                _ => {}
            }
        }
        let mut upload = MultipartUpload::new(&container, &key, metadata);
        let entries = std::fs::read_dir(&dir).map_err(|e| io_err("listing upload dir", e))?;
        for entry in entries.flatten() {
            let Some(fname) = entry.file_name().to_str().map(String::from) else {
                continue;
            };
            let Some(num) = fname.strip_prefix("part-").and_then(|n| n.parse::<u32>().ok())
            else {
                continue;
            };
            let data = std::fs::read(entry.path()).map_err(|e| io_err("reading part", e))?;
            upload.put_part(num, data);
        }
        // Consume the upload before assembling: a failed complete still
        // invalidates the id (trait contract).
        std::fs::remove_dir_all(&dir).map_err(|e| io_err("removing upload dir", e))?;
        let (data, metadata) = upload
            .assemble(min_part_size)
            .map_err(BackendError::InvalidRequest)?;
        Ok(AssembledUpload {
            container,
            key,
            data,
            metadata,
        })
    }

    fn abort_multipart(&self, upload_id: u64) -> Result<(), BackendError> {
        let dir = self.upload_dir(upload_id);
        if !dir.is_dir() {
            return Err(BackendError::NoSuchUpload(upload_id));
        }
        std::fs::remove_dir_all(&dir).map_err(|e| io_err("removing upload dir", e))
    }

    fn multipart_in_flight(&self) -> usize {
        std::fs::read_dir(self.root.join(".multipart"))
            .map(|entries| {
                entries
                    .flatten()
                    .filter(|e| e.path().is_dir())
                    .count()
            })
            .unwrap_or(0)
    }

    fn live_count(&self, container: &str) -> usize {
        self.key_names(container).map(|n| n.len()).unwrap_or(0)
    }

    fn live_bytes(&self, container: &str) -> u64 {
        let Ok(entries) = std::fs::read_dir(self.objects_dir(container)) else {
            return 0;
        };
        entries
            .flatten()
            .filter(|e| {
                e.file_name()
                    .to_str()
                    .map(|n| !n.starts_with('.'))
                    .unwrap_or(false)
            })
            .filter_map(|e| e.metadata().ok())
            .map(|m| m.len())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip() {
        for name in ["", "plain", "a/b/part-0001", "_temporary/0/t1", ".hidden", "x%y z", "näme"] {
            let enc = encode(name);
            assert!(!enc.is_empty());
            assert!(!enc.starts_with('.'), "{name} -> {enc}");
            assert!(!enc.contains('/'), "{name} -> {enc}");
            assert_eq!(decode(&enc).as_deref(), Some(name), "{name} -> {enc}");
        }
    }

    #[test]
    fn decode_rejects_garbage() {
        assert_eq!(decode("%zz"), None);
        assert_eq!(decode("a%2"), None);
        assert_eq!(decode("a%2Fb").as_deref(), Some("a/b"));
    }

    #[test]
    fn sidecar_roundtrip() {
        let mut md = Metadata::new();
        md.insert("X-Stocator-Origin".into(), "stocator 1.0".into());
        let text = Sidecar::render(0xdead_beef, SimInstant(42), &md);
        let s = Sidecar::parse(&text);
        assert_eq!(s.etag, 0xdead_beef);
        assert_eq!(s.created_at, SimInstant(42));
        assert_eq!(
            s.metadata.get("X-Stocator-Origin").map(String::as_str),
            Some("stocator 1.0")
        );
    }
}
