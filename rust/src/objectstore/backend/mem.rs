//! In-memory backend, N-way sharded by key hash.
//!
//! The legacy store kept every container behind one global `Mutex`, which
//! serialised Spark executor threads on the put/get hot path. Here each
//! object lives in the shard selected by an FNV-1a hash of
//! `(container, key)`, and each shard has its own lock, so writers with
//! disjoint keys proceed in parallel (see the contention benchmark in
//! `rust/benches/store_hotpath.rs`). `ShardedMemBackend::new(1)` is
//! exactly the legacy single-lock layout and backs `BackendKind::Mem`.
//!
//! The container registry is a read-mostly `RwLock` set: hot-path ops only
//! take its read lock. Multipart uploads sit behind their own lock —
//! they are orders of magnitude rarer than object ops.

use super::{AssembledUpload, Backend, BackendError, ListPage, ObjectStat};
use crate::objectstore::container::ObjectSummary;
use crate::objectstore::multipart::MultipartTable;
use crate::objectstore::object::{fnv1a, Metadata, Object};
use std::collections::{BTreeMap, BTreeSet};
use std::ops::Bound;
use std::sync::{Mutex, RwLock};

/// `container -> key -> object`, restricted to the keys this shard owns.
type ShardMap = BTreeMap<String, BTreeMap<String, Object>>;

/// N-way key-sharded in-memory storage.
pub struct ShardedMemBackend {
    shards: Vec<Mutex<ShardMap>>,
    containers: RwLock<BTreeSet<String>>,
    multipart: Mutex<MultipartTable>,
}

impl ShardedMemBackend {
    /// `shards >= 1`; one shard reproduces the legacy global-lock layout.
    pub fn new(shards: usize) -> Self {
        assert!(shards >= 1, "shard count must be at least 1");
        Self {
            shards: (0..shards).map(|_| Mutex::new(ShardMap::new())).collect(),
            containers: RwLock::new(BTreeSet::new()),
            multipart: Mutex::new(MultipartTable::default()),
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    #[inline]
    fn shard_idx(&self, container: &str, key: &str) -> usize {
        let h = fnv1a(container.as_bytes()) ^ fnv1a(key.as_bytes()).rotate_left(13);
        (h % self.shards.len() as u64) as usize
    }

    fn check_container(&self, name: &str) -> Result<(), BackendError> {
        if self.containers.read().unwrap().contains(name) {
            Ok(())
        } else {
            Err(BackendError::NoSuchContainer(name.to_string()))
        }
    }
}

impl Backend for ShardedMemBackend {
    fn name(&self) -> &'static str {
        if self.shards.len() == 1 {
            "mem"
        } else {
            "sharded-mem"
        }
    }

    fn create_container(&self, name: &str) -> Result<(), BackendError> {
        let mut reg = self.containers.write().unwrap();
        if !reg.insert(name.to_string()) {
            return Err(BackendError::ContainerAlreadyExists(name.to_string()));
        }
        Ok(())
    }

    fn container_exists(&self, name: &str) -> bool {
        self.containers.read().unwrap().contains(name)
    }

    fn put(&self, container: &str, key: &str, obj: Object) -> Result<bool, BackendError> {
        self.check_container(container)?;
        let mut shard = self.shards[self.shard_idx(container, key)].lock().unwrap();
        let prev = shard
            .entry(container.to_string())
            .or_default()
            .insert(key.to_string(), obj);
        Ok(prev.is_some())
    }

    fn get(&self, container: &str, key: &str) -> Result<Object, BackendError> {
        self.check_container(container)?;
        let shard = self.shards[self.shard_idx(container, key)].lock().unwrap();
        shard
            .get(container)
            .and_then(|m| m.get(key))
            .cloned()
            .ok_or_else(|| BackendError::no_such_key(container, key))
    }

    fn get_range(
        &self,
        container: &str,
        key: &str,
        offset: u64,
        len: u64,
    ) -> Result<(Vec<u8>, ObjectStat), BackendError> {
        self.check_container(container)?;
        let shard = self.shards[self.shard_idx(container, key)].lock().unwrap();
        let obj = shard
            .get(container)
            .and_then(|m| m.get(key))
            .ok_or_else(|| BackendError::no_such_key(container, key))?;
        let (start, end) = super::clamp_range(container, key, offset, len, obj.size())?;
        Ok((obj.data[start..end].to_vec(), ObjectStat::of(obj)))
    }

    fn head(&self, container: &str, key: &str) -> Result<ObjectStat, BackendError> {
        self.check_container(container)?;
        let shard = self.shards[self.shard_idx(container, key)].lock().unwrap();
        shard
            .get(container)
            .and_then(|m| m.get(key))
            .map(ObjectStat::of)
            .ok_or_else(|| BackendError::no_such_key(container, key))
    }

    fn delete(&self, container: &str, key: &str) -> Result<ObjectStat, BackendError> {
        self.check_container(container)?;
        let mut shard = self.shards[self.shard_idx(container, key)].lock().unwrap();
        shard
            .get_mut(container)
            .and_then(|m| m.remove(key))
            .map(|obj| ObjectStat::of(&obj))
            .ok_or_else(|| BackendError::no_such_key(container, key))
    }

    fn list_page(
        &self,
        container: &str,
        prefix: &str,
        start_after: Option<&str>,
        max_keys: usize,
    ) -> Result<ListPage, BackendError> {
        self.check_container(container)?;
        // Gather up to max_keys+1 candidates from each shard (each shard's
        // candidates are its smallest matching keys, so the global smallest
        // max_keys+1 are always among them), then merge.
        let lower: Bound<String> = match start_after {
            Some(s) if s.as_bytes() >= prefix.as_bytes() => Bound::Excluded(s.to_string()),
            _ => Bound::Included(prefix.to_string()),
        };
        let mut merged: Vec<ObjectSummary> = Vec::new();
        for shard in &self.shards {
            let shard = shard.lock().unwrap();
            let Some(m) = shard.get(container) else { continue };
            let mut taken = 0;
            for (k, obj) in m.range((lower.clone(), Bound::Unbounded)) {
                if !k.starts_with(prefix) {
                    break;
                }
                merged.push(ObjectSummary {
                    name: k.clone(),
                    size: obj.size(),
                    etag: obj.etag,
                });
                taken += 1;
                if taken > max_keys {
                    break;
                }
            }
        }
        merged.sort_unstable_by(|a, b| a.name.cmp(&b.name));
        let next = if merged.len() > max_keys {
            merged.truncate(max_keys);
            merged.last().map(|s| s.name.clone())
        } else {
            None
        };
        Ok(ListPage {
            entries: merged,
            next,
        })
    }

    fn initiate_multipart(
        &self,
        container: &str,
        key: &str,
        metadata: Metadata,
    ) -> Result<u64, BackendError> {
        self.check_container(container)?;
        Ok(self
            .multipart
            .lock()
            .unwrap()
            .initiate(container, key, metadata))
    }

    fn upload_part(
        &self,
        upload_id: u64,
        part_number: u32,
        data: Vec<u8>,
    ) -> Result<(), BackendError> {
        let mut table = self.multipart.lock().unwrap();
        match table.get_mut(upload_id) {
            Some(up) => {
                up.put_part(part_number, data);
                Ok(())
            }
            None => Err(BackendError::NoSuchUpload(upload_id)),
        }
    }

    fn complete_multipart(
        &self,
        upload_id: u64,
        min_part_size: u64,
    ) -> Result<AssembledUpload, BackendError> {
        // take() consumes the upload up front: a failed assembly still
        // invalidates the id (see the trait contract).
        let up = self
            .multipart
            .lock()
            .unwrap()
            .take(upload_id)
            .ok_or(BackendError::NoSuchUpload(upload_id))?;
        let container = up.container.clone();
        let key = up.key.clone();
        let (data, metadata) = up
            .assemble(min_part_size)
            .map_err(BackendError::InvalidRequest)?;
        Ok(AssembledUpload {
            container,
            key,
            data,
            metadata,
        })
    }

    fn abort_multipart(&self, upload_id: u64) -> Result<(), BackendError> {
        match self.multipart.lock().unwrap().take(upload_id) {
            Some(_) => Ok(()),
            None => Err(BackendError::NoSuchUpload(upload_id)),
        }
    }

    fn multipart_in_flight(&self) -> usize {
        self.multipart.lock().unwrap().in_flight()
    }

    fn live_count(&self, container: &str) -> usize {
        self.shards
            .iter()
            .map(|s| {
                s.lock()
                    .unwrap()
                    .get(container)
                    .map(|m| m.len())
                    .unwrap_or(0)
            })
            .sum()
    }

    fn live_bytes(&self, container: &str) -> u64 {
        self.shards
            .iter()
            .map(|s| {
                s.lock()
                    .unwrap()
                    .get(container)
                    .map(|m| m.values().map(|o| o.size()).sum::<u64>())
                    .unwrap_or(0)
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simclock::SimInstant;

    fn obj(data: &[u8]) -> Object {
        Object::new(data.to_vec(), Metadata::new(), SimInstant::EPOCH)
    }

    #[test]
    fn keys_spread_across_shards() {
        let b = ShardedMemBackend::new(8);
        b.create_container("c").unwrap();
        for i in 0..64 {
            b.put("c", &format!("k{i}"), obj(b"x")).unwrap();
        }
        let populated = b
            .shards
            .iter()
            .filter(|s| {
                s.lock()
                    .unwrap()
                    .get("c")
                    .map(|m| !m.is_empty())
                    .unwrap_or(false)
            })
            .count();
        assert!(populated >= 4, "only {populated}/8 shards used");
        assert_eq!(b.live_count("c"), 64);
    }

    #[test]
    fn listing_merges_shards_in_order() {
        let b = ShardedMemBackend::new(4);
        b.create_container("c").unwrap();
        let mut names: Vec<String> = (0..40).map(|i| format!("p/{i:03}")).collect();
        for n in &names {
            b.put("c", n, obj(b"d")).unwrap();
        }
        names.sort();
        let page = b.list_page("c", "p/", None, 100).unwrap();
        let got: Vec<&str> = page.entries.iter().map(|e| e.name.as_str()).collect();
        assert_eq!(got, names.iter().map(String::as_str).collect::<Vec<_>>());
        assert!(page.next.is_none());
    }

    #[test]
    fn single_shard_is_legacy_layout() {
        let b = ShardedMemBackend::new(1);
        assert_eq!(b.name(), "mem");
        assert_eq!(b.shard_count(), 1);
        let b16 = ShardedMemBackend::new(super::super::DEFAULT_SHARDS);
        assert_eq!(b16.name(), "sharded-mem");
    }
}
