//! REST-operation pricing (paper Table 8).
//!
//! The paper computes the relative cost of each scenario's REST calls using
//! the 2017 price sheets of IBM, AWS, Google and Azure, noting "the models
//! are very similar [so] we report the average price". All four providers
//! share the same *structure*: write-class operations (PUT, COPY, POST,
//! LIST) cost roughly an order of magnitude more than read-class operations
//! (GET, HEAD), and DELETE is free. We encode that structure with each
//! provider's (approximate) 2017 rates, in USD per 1,000 operations.

use crate::metrics::{OpCounts, OpKind};

/// One provider's price sheet: USD per 1,000 operations per class.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Provider {
    pub name: &'static str,
    /// PUT / COPY / LIST (GET Container) — "Class A" ops.
    pub write_class_per_1k: f64,
    /// GET / HEAD — "Class B" ops.
    pub read_class_per_1k: f64,
    /// DELETE — free on all four providers.
    pub delete_per_1k: f64,
}

/// Approximate 2017 rates (USD per 1k requests).
pub const PROVIDERS: [Provider; 4] = [
    Provider {
        name: "IBM",
        write_class_per_1k: 0.005,
        read_class_per_1k: 0.0004,
        delete_per_1k: 0.0,
    },
    Provider {
        name: "AWS",
        write_class_per_1k: 0.005,
        read_class_per_1k: 0.0004,
        delete_per_1k: 0.0,
    },
    Provider {
        name: "Google",
        write_class_per_1k: 0.005,
        read_class_per_1k: 0.0004,
        delete_per_1k: 0.0,
    },
    Provider {
        name: "Azure",
        write_class_per_1k: 0.0036,
        read_class_per_1k: 0.0004,
        delete_per_1k: 0.0,
    },
];

impl Provider {
    /// Price of a single op of `kind`, in USD.
    pub fn op_price(&self, kind: OpKind) -> f64 {
        let per_1k = match kind {
            OpKind::PutObject | OpKind::CopyObject | OpKind::GetContainer => {
                self.write_class_per_1k
            }
            OpKind::GetObject | OpKind::HeadObject | OpKind::HeadContainer => {
                self.read_class_per_1k
            }
            OpKind::DeleteObject => self.delete_per_1k,
        };
        per_1k / 1000.0
    }

    /// Total cost of an op-count snapshot on this provider, in USD.
    pub fn cost(&self, counts: &OpCounts) -> f64 {
        OpKind::ALL
            .iter()
            .map(|&k| counts.get(k) as f64 * self.op_price(k))
            .sum()
    }
}

/// Average cost across the four providers (what Table 8 reports).
pub fn cost_usd(counts: &OpCounts) -> f64 {
    PROVIDERS.iter().map(|p| p.cost(counts)).sum::<f64>() / PROVIDERS.len() as f64
}

/// Flat 2017-era object-storage price used for the Table 8 stranded-bytes
/// addendum (the four providers' standard tiers cluster around
/// $0.021–0.025 per GB-month). Parts parked in orphaned multipart
/// uploads are billed at exactly this rate until a lifecycle sweep
/// aborts them — the cost the `--multipart-ttl` GC knob eliminates.
pub const STORAGE_USD_PER_GB_MONTH: f64 = 0.023;

/// Monthly storage cost of `bytes` stranded bytes, in USD.
pub fn storage_cost_usd_month(bytes: u64) -> f64 {
    bytes as f64 / (1024.0 * 1024.0 * 1024.0) * STORAGE_USD_PER_GB_MONTH
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_class_dominates() {
        for p in PROVIDERS {
            assert!(p.write_class_per_1k > p.read_class_per_1k * 5.0, "{}", p.name);
            assert_eq!(p.delete_per_1k, 0.0);
        }
    }

    #[test]
    fn cost_of_known_mix() {
        // 1000 PUTs + 1000 GETs on AWS = $0.005 + $0.0004.
        let mut c = OpCounts::default();
        c.add(OpKind::PutObject, 1000);
        c.add(OpKind::GetObject, 1000);
        let aws = PROVIDERS.iter().find(|p| p.name == "AWS").unwrap();
        assert!((aws.cost(&c) - 0.0054).abs() < 1e-12);
    }

    #[test]
    fn deletes_are_free() {
        let mut c = OpCounts::default();
        c.add(OpKind::DeleteObject, 1_000_000);
        assert_eq!(cost_usd(&c), 0.0);
    }

    #[test]
    fn copy_and_list_priced_as_writes() {
        let mut copies = OpCounts::default();
        copies.add(OpKind::CopyObject, 100);
        let mut puts = OpCounts::default();
        puts.add(OpKind::PutObject, 100);
        let mut lists = OpCounts::default();
        lists.add(OpKind::GetContainer, 100);
        for p in PROVIDERS {
            assert_eq!(p.cost(&copies), p.cost(&puts));
            assert_eq!(p.cost(&lists), p.cost(&puts));
        }
    }

    #[test]
    fn stranded_storage_is_priced_per_gb_month() {
        assert_eq!(storage_cost_usd_month(0), 0.0);
        let one_gb = 1024 * 1024 * 1024;
        assert!((storage_cost_usd_month(one_gb) - STORAGE_USD_PER_GB_MONTH).abs() < 1e-12);
        assert!(storage_cost_usd_month(10 * one_gb) > storage_cost_usd_month(one_gb));
    }

    #[test]
    fn average_is_between_min_and_max() {
        let mut c = OpCounts::default();
        c.add(OpKind::PutObject, 10_000);
        let costs: Vec<f64> = PROVIDERS.iter().map(|p| p.cost(&c)).collect();
        let avg = cost_usd(&c);
        let min = costs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = costs.iter().cloned().fold(0.0, f64::max);
        assert!(avg >= min && avg <= max);
    }
}
