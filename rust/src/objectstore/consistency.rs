//! The consistency model (§2.1 of the paper).
//!
//! GET/HEAD on an object name are read-after-write consistent (as AWS
//! guaranteed for new objects), but **container listings are eventually
//! consistent**: a newly created object may not appear in a listing until
//! `create_lag` has elapsed, and a deleted object may keep appearing until
//! `delete_lag` has elapsed. These two lags are exactly the window in which
//! the rename-based committers mis-commit (paper §2.2.2); Stocator's
//! correctness argument is that it never lists during commit.

use crate::simclock::SimDuration;

/// How container listings lag behind object mutations.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ConsistencyModel {
    /// Time until a newly created object becomes visible in listings.
    pub create_lag: SimDuration,
    /// Time until a deleted object stops appearing in listings.
    pub delete_lag: SimDuration,
}

impl ConsistencyModel {
    /// Strongly consistent listings (an idealised store; useful as an
    /// ablation baseline).
    pub fn strong() -> Self {
        Self {
            create_lag: SimDuration::ZERO,
            delete_lag: SimDuration::ZERO,
        }
    }

    /// Typical public-cloud eventual consistency: listings lag mutations by
    /// a few seconds.
    pub fn eventual() -> Self {
        Self {
            create_lag: SimDuration::from_secs(2),
            delete_lag: SimDuration::from_secs(2),
        }
    }

    /// An adversarial model with long lag windows — used by the
    /// eventual-consistency failure-injection tests to make the
    /// rename-committer race all but certain.
    pub fn adversarial(lag: SimDuration) -> Self {
        Self {
            create_lag: lag,
            delete_lag: lag,
        }
    }

    pub fn is_strong(&self) -> bool {
        self.create_lag == SimDuration::ZERO && self.delete_lag == SimDuration::ZERO
    }
}

impl Default for ConsistencyModel {
    fn default() -> Self {
        Self::eventual()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets() {
        assert!(ConsistencyModel::strong().is_strong());
        assert!(!ConsistencyModel::eventual().is_strong());
        let a = ConsistencyModel::adversarial(SimDuration::from_secs(60));
        assert_eq!(a.create_lag, SimDuration::from_secs(60));
        assert_eq!(a.delete_lag, SimDuration::from_secs(60));
    }
}
