//! The transient-fault plane: deterministic REST fault injection and the
//! shared stream retry policy.
//!
//! The paper's fault-tolerance argument (§2.2.1, §3.3) is about what
//! survives when operations *fail* — including the footnote that
//! Stocator's chunked-transfer PUT cannot be resumed after a transient
//! failure, so the whole object must be re-sent, where S3a's fast upload
//! re-sends only the failed part and the buffer-to-disk connectors
//! re-PUT cheaply from their local spool. The Spark layer already models
//! fail-stop executor crashes ([`crate::spark::FaultKind`]); this module
//! adds the *REST-level* half: a 5xx/timeout on one specific PUT or GET,
//! visible to the connector that issued it, priced like a real request
//! (latency burned, op counted, payload bytes on the wire — real stores
//! bill failed requests too).
//!
//! * [`FaultRule`] / [`FaultSpec`] — a deterministic schedule: fail the
//!   Nth operation matching an (op-kind, key-prefix) pattern, optionally
//!   for several consecutive matches — or, for sustained degraded
//!   service, fail each matching operation with a seeded probability
//!   (`op[:prefix]@p=0.05`). Every rule also carries a [`FaultClass`]:
//!   the default 503 transient, or (with a `!429` suffix) a throttle —
//!   the store shed the request before reading the body, so it costs an
//!   op and base latency but puts **zero** payload bytes on the wire,
//!   and connectors pause for the Retry-After-shaped
//!   [`RetryPolicy::retry_after_us`] instead of the exponential backoff.
//!   Parsed from the CLI `--faults` spec; carried by
//!   [`crate::objectstore::StoreConfig::faults`].
//! * [`FaultInjector`] — the armed rule set threaded through
//!   `put_object` / `get_object` / `get_object_range` / `upload_part` /
//!   `complete_multipart` on the store front end. Rules can also be
//!   armed mid-run ([`crate::objectstore::ObjectStore::arm_faults`]) —
//!   that is how [`crate::spark::FaultKind::TransientOps`] schedules
//!   flaky ops for one specific task attempt.
//! * [`RetryPolicy`] — the stream-layer retry contract every connector
//!   follows: up to `retries` re-attempts per operation with
//!   exponential virtual-clock backoff. The *semantics* of a retry are
//!   per-connector (re-PUT from spool, re-send one part, restart the
//!   whole chunked PUT, re-drive the HDFS pipeline); the budget and the
//!   backoff schedule are shared so `--retries N` means the same thing
//!   everywhere.
//!
//! Determinism: with an empty spec nothing ever fires and every golden
//! REST sequence and virtual runtime is byte-identical to the
//! fault-free stack; with a spec, which ops fail is a pure function of
//! the operation sequence — exact-Nth rules count matches, and
//! probabilistic rules draw from a PCG32 stream seeded by the store's
//! `--seed` — so fault schedules replay exactly and are
//! backend-invariant.

use crate::simclock::SimDuration;
use crate::util::rng::Pcg32;
use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Which store operation class a fault rule matches. Only the operations
/// the connectors' data paths issue are injectable; control-plane ops
/// (HEAD, LIST, DELETE, COPY) stay reliable — the paper's fragility
/// story is about the *write/read* paths.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultOp {
    /// `put_object` — whole-object PUTs (spool uploads, chunked-transfer
    /// PUTs, markers, `_SUCCESS`).
    Put,
    /// `get_object` / `get_object_range` — full and ranged GETs.
    Get,
    /// `upload_part` — one multipart part PUT (S3a fast upload).
    UploadPart,
    /// `complete_multipart` — the multipart completion POST.
    CompleteMultipart,
}

impl FaultOp {
    /// CLI spelling (`--faults put:...`, `get`, `part`, `complete`).
    pub fn parse(s: &str) -> Option<FaultOp> {
        match s {
            "put" => Some(FaultOp::Put),
            "get" => Some(FaultOp::Get),
            "part" => Some(FaultOp::UploadPart),
            "complete" => Some(FaultOp::CompleteMultipart),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            FaultOp::Put => "put",
            FaultOp::Get => "get",
            FaultOp::UploadPart => "part",
            FaultOp::CompleteMultipart => "complete",
        }
    }
}

impl fmt::Display for FaultOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Which failure a rule injects when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FaultClass {
    /// Retryable 5xx/timeout (a 503): the request crossed the wire
    /// before failing, so PUT-class payload bytes are burned.
    #[default]
    Transient,
    /// 429 Too Many Requests: the store shed the request before reading
    /// the body — an op and base latency, **zero** wire bytes, and the
    /// retry pause is the flat Retry-After
    /// ([`RetryPolicy::retry_after_us`]), not the exponential backoff.
    Throttle,
}

/// One deterministic fault rule over the (op, key-prefix) pattern. Two
/// trigger modes:
///
/// * **exact-Nth** (`prob_ppm == 0`): fail matches `nth .. nth + count`
///   (1-based) — point faults for golden retry traces;
/// * **probabilistic** (`prob_ppm > 0`): fail each match independently
///   with probability `prob_ppm / 1e6`, drawn from the injector's seeded
///   PCG32 stream — sustained degraded service, deterministic per
///   `--seed`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultRule {
    pub op: FaultOp,
    /// Object-key prefix the operation's target must start with
    /// (empty = every key). Multipart ops match on the upload's target
    /// key.
    pub key_prefix: String,
    /// Fail starting at the Nth matching operation (1-based).
    pub nth: u64,
    /// How many consecutive matching operations fail (≥ 1). `count`
    /// larger than the retry budget forces [`exhaustion`](crate::fs::FsError::TransientExhausted).
    pub count: u64,
    /// Per-match failure probability in parts per million; 0 selects the
    /// exact-Nth mode. (Stored integrally so rules stay `Eq` and the CLI
    /// grammar round-trips exactly.)
    pub prob_ppm: u32,
    /// What firing injects: a 503 transient (default) or a 429 throttle.
    pub class: FaultClass,
}

impl FaultRule {
    pub fn new(op: FaultOp, key_prefix: &str, nth: u64, count: u64) -> Self {
        Self {
            op,
            key_prefix: key_prefix.to_string(),
            nth: nth.max(1),
            count: count.max(1),
            prob_ppm: 0,
            class: FaultClass::Transient,
        }
    }

    /// A probabilistic rule: each matching op fails with probability `p`
    /// (clamped to `(0, 1]`, ppm resolution).
    pub fn probabilistic(op: FaultOp, key_prefix: &str, p: f64) -> Self {
        let ppm = (p * 1e6).round().clamp(1.0, 1e6) as u32;
        Self {
            prob_ppm: ppm,
            ..Self::new(op, key_prefix, 1, 1)
        }
    }

    /// Builder: select the failure class (`!429` in the CLI grammar).
    pub fn with_class(mut self, class: FaultClass) -> Self {
        self.class = class;
        self
    }

    pub fn is_probabilistic(&self) -> bool {
        self.prob_ppm > 0
    }
}

impl fmt::Display for FaultRule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}@", self.op, self.key_prefix)?;
        if self.is_probabilistic() {
            write!(f, "p={}", self.prob_ppm as f64 / 1e6)?;
        } else {
            write!(f, "{}x{}", self.nth, self.count)?;
        }
        if self.class == FaultClass::Throttle {
            write!(f, "!429")?;
        }
        Ok(())
    }
}

/// A deterministic fault schedule: zero or more [`FaultRule`]s. The
/// default (empty) spec injects nothing and reproduces the fault-free
/// stack byte-identically.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct FaultSpec {
    pub rules: Vec<FaultRule>,
}

impl FaultSpec {
    pub fn none() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }

    /// Builder: add one rule.
    pub fn with(mut self, rule: FaultRule) -> Self {
        self.rules.push(rule);
        self
    }

    /// Convenience: a single one-shot fault on the Nth matching op.
    pub fn one(op: FaultOp, key_prefix: &str, nth: u64) -> Self {
        Self::none().with(FaultRule::new(op, key_prefix, nth, 1))
    }

    /// Parse the CLI grammar:
    ///
    /// ```text
    /// SPEC    := RULE ( ',' RULE )*
    /// RULE    := OP [ ':' KEY_PREFIX ] '@' TRIGGER [ '!429' ]
    /// TRIGGER := NTH [ 'x' COUNT ] | 'p=' P
    /// OP      := put | get | part | complete
    /// ```
    ///
    /// Examples: `put@1` (the very first PUT fails once),
    /// `put:out/@3x2` (the 3rd and 4th PUTs under `out/` fail),
    /// `part:out/@2,complete@1` (two rules),
    /// `put@p=0.05` (each PUT fails with probability 5%, seeded),
    /// `get@p=0.01!429` (1% of GETs are 429-throttled instead of 503s).
    pub fn parse(s: &str) -> Result<FaultSpec, String> {
        let mut spec = FaultSpec::none();
        for raw in s.split(',') {
            let raw = raw.trim();
            if raw.is_empty() {
                continue;
            }
            let (head, tail) = raw
                .split_once('@')
                .ok_or_else(|| format!("fault rule '{raw}' is missing '@NTH' or '@p=P'"))?;
            let (op_s, prefix) = match head.split_once(':') {
                Some((o, p)) => (o, p),
                None => (head, ""),
            };
            let op = FaultOp::parse(op_s)
                .ok_or_else(|| format!("unknown fault op '{op_s}' (put|get|part|complete)"))?;
            let (tail, class) = match tail.strip_suffix("!429") {
                Some(t) => (t, FaultClass::Throttle),
                None => (tail, FaultClass::Transient),
            };
            let rule = if let Some(p_s) = tail.strip_prefix("p=") {
                // Lower bound is the grammar's ppm resolution: silently
                // rounding p=1e-7 up to 1 ppm would inflate the
                // requested rate tenfold.
                let p: f64 = p_s
                    .parse()
                    .ok()
                    .filter(|p| *p >= 1e-6 && *p <= 1.0)
                    .ok_or_else(|| {
                        format!(
                            "fault rule '{raw}': P must be a probability in [0.000001, 1]"
                        )
                    })?;
                FaultRule::probabilistic(op, prefix, p)
            } else {
                let (nth_s, count_s) = match tail.split_once('x') {
                    Some((n, c)) => (n, c),
                    None => (tail, "1"),
                };
                let nth: u64 = nth_s
                    .parse()
                    .ok()
                    .filter(|&n| n >= 1)
                    .ok_or_else(|| format!("fault rule '{raw}': NTH must be a positive integer"))?;
                let count: u64 = count_s
                    .parse()
                    .ok()
                    .filter(|&c| c >= 1)
                    .ok_or_else(|| format!("fault rule '{raw}': COUNT must be a positive integer"))?;
                FaultRule::new(op, prefix, nth, count)
            };
            spec.rules.push(rule.with_class(class));
        }
        if spec.is_empty() {
            return Err("empty --faults spec".to_string());
        }
        Ok(spec)
    }
}

impl fmt::Display for FaultSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let rules: Vec<String> = self.rules.iter().map(|r| r.to_string()).collect();
        f.write_str(&rules.join(","))
    }
}

/// A rule plus its live match counter.
#[derive(Debug)]
struct ArmedRule {
    rule: FaultRule,
    /// Matching operations seen so far (armed rules count from the
    /// moment they are armed, so a [`crate::spark::FaultKind::TransientOps`]
    /// schedule counts ops from its attempt's start).
    seen: u64,
}

/// A fired fault as the store front end sees it: which class to surface
/// (and price) plus the human-readable description.
#[derive(Debug, Clone)]
pub struct InjectedFault {
    pub class: FaultClass,
    pub msg: String,
}

/// The armed fault rules a store consults on every injectable operation.
/// Thread-safe; the zero-rule fast path is one relaxed atomic load, so
/// the fault-free hot path stays wall-clock-neutral.
#[derive(Debug)]
pub struct FaultInjector {
    n_rules: AtomicUsize,
    armed: Mutex<Vec<ArmedRule>>,
    /// The seeded stream probabilistic rules draw from (one draw per
    /// matching op per probabilistic rule, fired or not, so the stream
    /// stays aligned with the op sequence).
    rng: Mutex<Pcg32>,
}

impl Default for FaultInjector {
    fn default() -> Self {
        Self {
            n_rules: AtomicUsize::new(0),
            armed: Mutex::new(Vec::new()),
            rng: Mutex::new(Pcg32::new(0x7412_0f4a)),
        }
    }
}

impl FaultInjector {
    pub fn new(spec: &FaultSpec) -> Self {
        Self::with_seed(spec, 0)
    }

    /// Build with the seed probabilistic rules draw under (the store
    /// passes its `--seed`, so `p=` schedules replay per seed).
    pub fn with_seed(spec: &FaultSpec, seed: u64) -> Self {
        let inj = Self {
            rng: Mutex::new(Pcg32::new(seed ^ 0x7412_0f4a)),
            ..Self::default()
        };
        inj.arm(spec);
        inj
    }

    /// Append `spec`'s rules with fresh match counters. Rules are never
    /// removed: a fired rule simply stops matching once its
    /// `nth + count` window passes.
    pub fn arm(&self, spec: &FaultSpec) {
        if spec.is_empty() {
            return;
        }
        let mut armed = self.armed.lock().unwrap();
        for rule in &spec.rules {
            armed.push(ArmedRule {
                rule: rule.clone(),
                seen: 0,
            });
        }
        self.n_rules.store(armed.len(), Ordering::Relaxed);
    }

    /// No rules armed at all — the hot-path hint retry loops use to skip
    /// defensive payload clones (an idle injector can never produce a
    /// `TransientFailure`, so a single attempt needs no re-send copy).
    /// One relaxed atomic load, no lock: this is the check the store
    /// front end's zero-lock idle path rests on (multipart ops also gate
    /// their target-key stripe lookup behind it — an idle
    /// [`FaultInjector::check`] returns `None` for any key, so skipping
    /// the lookup changes nothing).
    pub fn is_idle(&self) -> bool {
        self.n_rules.load(Ordering::Relaxed) == 0
    }

    /// Record one (op, key) operation against every armed rule; returns
    /// the injected failure if any rule covers this match (exact-Nth
    /// window, or a probabilistic draw under the seeded stream).
    /// Exact-Nth rules whose windows have fully passed are dropped, so
    /// the idle fast path (and the connectors' clone-free retry loops)
    /// come back once every scheduled point fault has fired;
    /// probabilistic rules stay armed for the store's lifetime.
    pub fn check(&self, op: FaultOp, key: &str) -> Option<InjectedFault> {
        if self.n_rules.load(Ordering::Relaxed) == 0 {
            return None;
        }
        let mut armed = self.armed.lock().unwrap();
        let mut fired: Option<InjectedFault> = None;
        for a in armed.iter_mut() {
            if a.rule.op != op || !key.starts_with(a.rule.key_prefix.as_str()) {
                continue;
            }
            a.seen += 1;
            let hit = if a.rule.is_probabilistic() {
                // Draw even when another rule already fired: the stream
                // position must be a pure function of the op sequence.
                let draw = self.rng.lock().unwrap().next_f64();
                draw < a.rule.prob_ppm as f64 / 1e6
            } else {
                a.seen >= a.rule.nth && a.seen < a.rule.nth + a.rule.count
            };
            if hit && fired.is_none() {
                fired = Some(InjectedFault {
                    class: a.rule.class,
                    msg: format!(
                        "injected fault on {op} {key} (match {} of rule {})",
                        a.seen, a.rule
                    ),
                });
            }
        }
        armed.retain(|a| a.rule.is_probabilistic() || a.seen + 1 < a.rule.nth + a.rule.count);
        self.n_rules.store(armed.len(), Ordering::Relaxed);
        fired
    }
}

/// The shared stream-layer retry contract (`--retries N`): how many times
/// a connector re-attempts a transiently failed operation, and the
/// virtual-clock backoff charged before each re-attempt. What a
/// re-attempt *does* is the connector's write-path semantics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Re-attempts after the first try (0 = fail fast; the default, so
    /// the fault-free stack is reproduced byte-identically).
    pub retries: u32,
    /// Backoff before the first re-attempt, in virtual microseconds;
    /// doubles on each further re-attempt (exponential, no jitter — the
    /// schedule must replay deterministically).
    pub backoff_us: u64,
    /// The flat Retry-After pause honoured before retrying a 429
    /// [`crate::objectstore::StoreError::Throttled`] request: the server
    /// names the pause, so it does not grow per attempt the way the
    /// exponential 503 backoff does.
    pub retry_after_us: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            retries: 0,
            backoff_us: 100_000,
            retry_after_us: 1_000_000,
        }
    }
}

impl RetryPolicy {
    pub fn none() -> Self {
        Self::default()
    }

    pub fn with_retries(retries: u32) -> Self {
        Self {
            retries,
            ..Self::default()
        }
    }

    /// Total tries per operation (first attempt + retries).
    pub fn attempts(&self) -> u32 {
        self.retries + 1
    }

    /// Virtual-clock backoff before re-attempt `retry_index` (1-based):
    /// `backoff_us << (retry_index - 1)`.
    pub fn backoff(&self, retry_index: u32) -> SimDuration {
        let shift = retry_index.saturating_sub(1).min(20);
        SimDuration::from_micros(self.backoff_us << shift)
    }

    /// The pause before re-attempt `retry_index` for a given transient
    /// failure: 429 throttles wait the flat Retry-After, everything else
    /// takes the exponential backoff.
    pub fn retry_delay(
        &self,
        retry_index: u32,
        err: &crate::objectstore::StoreError,
    ) -> SimDuration {
        match err {
            crate::objectstore::StoreError::Throttled(_) => {
                SimDuration::from_micros(self.retry_after_us)
            }
            _ => self.backoff(retry_index),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_grammar_roundtrip() {
        let spec = FaultSpec::parse("put:out/@3x2,part@1,complete:d/@2").unwrap();
        assert_eq!(spec.rules.len(), 3);
        assert_eq!(spec.rules[0], FaultRule::new(FaultOp::Put, "out/", 3, 2));
        assert_eq!(spec.rules[1], FaultRule::new(FaultOp::UploadPart, "", 1, 1));
        assert_eq!(
            spec.rules[2],
            FaultRule::new(FaultOp::CompleteMultipart, "d/", 2, 1)
        );
        // Display re-parses to the same spec.
        assert_eq!(FaultSpec::parse(&spec.to_string()).unwrap(), spec);
    }

    #[test]
    fn spec_rejects_malformed_rules() {
        assert!(FaultSpec::parse("").is_err());
        assert!(FaultSpec::parse("put").is_err(), "missing @NTH");
        assert!(FaultSpec::parse("frob@1").is_err(), "unknown op");
        assert!(FaultSpec::parse("put@0").is_err(), "NTH is 1-based");
        assert!(FaultSpec::parse("put@2x0").is_err(), "COUNT must be >= 1");
        assert!(FaultSpec::parse("put@abc").is_err());
    }

    #[test]
    fn injector_fires_exactly_the_nth_window() {
        let inj = FaultInjector::new(&FaultSpec::parse("put:d/@2x2").unwrap());
        assert!(inj.check(FaultOp::Put, "d/a").is_none(), "match 1");
        assert!(inj.check(FaultOp::Put, "elsewhere").is_none(), "prefix miss");
        assert!(inj.check(FaultOp::Get, "d/a").is_none(), "op miss");
        assert!(inj.check(FaultOp::Put, "d/b").is_some(), "match 2 fires");
        assert!(inj.check(FaultOp::Put, "d/c").is_some(), "match 3 fires");
        assert!(inj.check(FaultOp::Put, "d/d").is_none(), "window passed");
    }

    #[test]
    fn arming_mid_run_counts_from_arming() {
        let inj = FaultInjector::default();
        assert!(inj.check(FaultOp::Put, "k").is_none());
        inj.arm(&FaultSpec::one(FaultOp::Put, "", 1));
        assert!(inj.check(FaultOp::Put, "k").is_some(), "fresh counter");
        assert!(inj.check(FaultOp::Put, "k").is_none());
    }

    #[test]
    fn retry_policy_backoff_doubles() {
        let p = RetryPolicy::with_retries(3);
        assert_eq!(p.attempts(), 4);
        assert_eq!(p.backoff(1).as_micros(), 100_000);
        assert_eq!(p.backoff(2).as_micros(), 200_000);
        assert_eq!(p.backoff(3).as_micros(), 400_000);
        assert_eq!(RetryPolicy::none().attempts(), 1);
    }

    #[test]
    fn probabilistic_and_throttle_grammar_roundtrip() {
        let spec = FaultSpec::parse("put@p=0.05,get:d/@p=0.5!429,put:out/@2x3!429").unwrap();
        assert_eq!(spec.rules.len(), 3);
        assert_eq!(spec.rules[0], FaultRule::probabilistic(FaultOp::Put, "", 0.05));
        assert_eq!(spec.rules[0].prob_ppm, 50_000);
        assert_eq!(
            spec.rules[1],
            FaultRule::probabilistic(FaultOp::Get, "d/", 0.5).with_class(FaultClass::Throttle)
        );
        assert_eq!(
            spec.rules[2],
            FaultRule::new(FaultOp::Put, "out/", 2, 3).with_class(FaultClass::Throttle)
        );
        // Display re-parses to the same spec (including class and p).
        assert_eq!(FaultSpec::parse(&spec.to_string()).unwrap(), spec);
        // Probability bounds are enforced, including the ppm floor
        // (sub-ppm rates would silently round up tenfold or more).
        assert!(FaultSpec::parse("put@p=0").is_err());
        assert!(FaultSpec::parse("put@p=0.0000001").is_err());
        assert!(FaultSpec::parse("put@p=1.5").is_err());
        assert!(FaultSpec::parse("put@p=lots").is_err());
        assert!(FaultSpec::parse("put@p=0.000001").is_ok(), "exactly 1 ppm is the floor");
    }

    #[test]
    fn probabilistic_rules_are_deterministic_per_seed() {
        let fired = |seed: u64| -> Vec<bool> {
            let inj = FaultInjector::with_seed(
                &FaultSpec::parse("put@p=0.3").unwrap(),
                seed,
            );
            (0..64).map(|i| inj.check(FaultOp::Put, &format!("k{i}")).is_some()).collect()
        };
        assert_eq!(fired(7), fired(7), "same seed, same schedule");
        assert_ne!(fired(7), fired(8), "different seed, different schedule");
        let hits = fired(7).iter().filter(|b| **b).count();
        assert!((5..=30).contains(&hits), "p=0.3 over 64 ops fired {hits} times");
        // p=1 fires on every match; the rule never expires, so the
        // injector never goes idle (retry loops must keep their clones).
        let always = FaultInjector::with_seed(&FaultSpec::parse("put@p=1").unwrap(), 1);
        for i in 0..8 {
            assert!(always.check(FaultOp::Put, &format!("k{i}")).is_some());
        }
        assert!(!always.is_idle());
    }

    #[test]
    fn throttle_rules_carry_their_class() {
        let inj = FaultInjector::new(&FaultSpec::parse("put@1!429,get@1").unwrap());
        let put = inj.check(FaultOp::Put, "k").expect("put fires");
        assert_eq!(put.class, FaultClass::Throttle);
        let get = inj.check(FaultOp::Get, "k").expect("get fires");
        assert_eq!(get.class, FaultClass::Transient);
    }

    #[test]
    fn retry_delay_is_flat_for_throttles() {
        use crate::objectstore::StoreError;
        let p = RetryPolicy::with_retries(3);
        let throttled = StoreError::Throttled("429".into());
        let transient = StoreError::TransientFailure("503".into());
        assert_eq!(p.retry_delay(1, &throttled).as_micros(), 1_000_000);
        assert_eq!(p.retry_delay(3, &throttled).as_micros(), 1_000_000, "flat, not exponential");
        assert_eq!(p.retry_delay(3, &transient), p.backoff(3));
    }
}
