//! Listing-visibility overlay: the front end's eventual-consistency state.
//!
//! Backends keep authoritative, read-after-write state (see
//! [`super::backend`]); the *eventually consistent* container listings of
//! paper §2.1 are synthesised here. For each container the overlay tracks:
//!
//! * **pending** names — created but not yet visible in listings (until
//!   `create_lag` elapses), and
//! * **ghosts** — deleted names that listings must keep showing (with the
//!   deleted object's size and ETag) until `delete_lag` elapses.
//!
//! The rules mirror the legacy per-entry bookkeeping exactly:
//! replacing an already-visible object keeps it visible immediately, a
//! fresh create after delete (or a replace inside the create-lag window)
//! restarts the lag, and an object created and deleted entirely within its
//! create-lag window never appears at all. Entries are pure functions of
//! the timestamps recorded at mutation time, so queries may arrive with
//! non-monotonic `now` values (independent task clocks) and still agree
//! with the legacy semantics.
//!
//! # Striping
//!
//! The store holds [`StoreConfig::stripes`](super::StoreConfig::stripes)
//! independent `Mutex<VisibilityMap>` instances and routes each
//! `(container, key)` mutation to one by the *same* FNV shard hash as
//! `ShardedMemBackend`, so 16 real writer threads contend on 16 stripes
//! instead of one map. Nothing in this module knows about that: every
//! entry is keyed by its exact (container, key), the key sets held by
//! different stripes are disjoint, and [`VisibilityMap::overlay`] is an
//! identity on entries it holds no state for — so a listing can chain
//! the stripes' overlays in any order over the raw backend listing and
//! get the byte-identical result of the legacy single-map layout
//! (pinned by `striping_preserves_visibility_semantics_exactly`).

use super::container::ObjectSummary;
use crate::simclock::{SimDuration, SimInstant};
use std::collections::BTreeMap;

/// A deleted object that listings may still show.
#[derive(Debug, Clone)]
struct Ghost {
    size: u64,
    etag: u64,
    until: SimInstant,
}

#[derive(Debug, Default)]
struct ContainerVisibility {
    /// Name -> instant it becomes visible in listings.
    pending: BTreeMap<String, SimInstant>,
    /// Name -> stale view shown until the recorded instant.
    ghosts: BTreeMap<String, Ghost>,
}

/// Per-container visibility state; owned by the store, consulted only when
/// the consistency model is not strong.
#[derive(Debug, Default)]
pub struct VisibilityMap {
    containers: BTreeMap<String, ContainerVisibility>,
}

impl VisibilityMap {
    /// Record a PUT. `replaced` is whether the backend overwrote an
    /// existing object.
    pub fn on_put(
        &mut self,
        container: &str,
        key: &str,
        replaced: bool,
        now: SimInstant,
        create_lag: SimDuration,
    ) {
        let cv = self.containers.entry(container.to_string()).or_default();
        cv.ghosts.remove(key);
        let already_visible = replaced && cv.pending.get(key).map_or(true, |t| *t <= now);
        if already_visible {
            cv.pending.remove(key);
        } else {
            cv.pending.insert(key.to_string(), now + create_lag);
        }
    }

    /// Record a DELETE of an object whose final size/etag were `size`/`etag`.
    pub fn on_delete(
        &mut self,
        container: &str,
        key: &str,
        size: u64,
        etag: u64,
        now: SimInstant,
        delete_lag: SimDuration,
    ) {
        let cv = self.containers.entry(container.to_string()).or_default();
        let was_listed = cv.pending.get(key).map_or(true, |t| *t <= now);
        cv.pending.remove(key);
        if was_listed && delete_lag.as_micros() > 0 {
            cv.ghosts.insert(
                key.to_string(),
                Ghost {
                    size,
                    etag,
                    until: now + delete_lag,
                },
            );
        }
    }

    /// Apply the overlay to an authoritative listing: drop names still in
    /// their create-lag window, merge in ghosts whose delete-lag window is
    /// open. `raw` must be sorted ascending (backends guarantee it); the
    /// result is too.
    pub fn overlay(
        &self,
        container: &str,
        prefix: &str,
        now: SimInstant,
        raw: Vec<ObjectSummary>,
    ) -> Vec<ObjectSummary> {
        let Some(cv) = self.containers.get(container) else {
            return raw;
        };
        let ghosts: Vec<ObjectSummary> = cv
            .ghosts
            .range(prefix.to_string()..)
            .take_while(|(k, _)| k.starts_with(prefix))
            .filter(|(_, g)| g.until > now)
            .map(|(k, g)| ObjectSummary {
                name: k.clone(),
                size: g.size,
                etag: g.etag,
            })
            .collect();
        // Merge two sorted, disjoint streams (a key is never both live in
        // the backend and a ghost: put clears its ghost, delete removes it
        // from the backend).
        let mut out = Vec::with_capacity(raw.len() + ghosts.len());
        let mut gi = ghosts.into_iter().peekable();
        for entry in raw {
            while gi.peek().is_some_and(|g| g.name < entry.name) {
                out.push(gi.next().unwrap());
            }
            if let Some(t) = cv.pending.get(&entry.name) {
                if *t > now {
                    continue; // created, but not yet listed
                }
            }
            out.push(entry);
        }
        out.extend(gi);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const LAG5: SimDuration = SimDuration(5_000_000);
    const LAG3: SimDuration = SimDuration(3_000_000);

    fn summary(name: &str, size: u64) -> ObjectSummary {
        ObjectSummary {
            name: name.to_string(),
            size,
            etag: size ^ 0x5a5a,
        }
    }

    fn names(entries: &[ObjectSummary]) -> Vec<&str> {
        entries.iter().map(|e| e.name.as_str()).collect()
    }

    #[test]
    fn create_lag_hides_new_objects() {
        let mut v = VisibilityMap::default();
        v.on_put("c", "k", false, SimInstant(0), LAG5);
        let raw = vec![summary("k", 1)];
        assert!(v.overlay("c", "", SimInstant(0), raw.clone()).is_empty());
        assert!(v.overlay("c", "", SimInstant(4_999_999), raw.clone()).is_empty());
        assert_eq!(names(&v.overlay("c", "", SimInstant(5_000_000), raw)), ["k"]);
    }

    #[test]
    fn delete_lag_keeps_ghost_with_old_size() {
        let mut v = VisibilityMap::default();
        v.on_put("c", "k", false, SimInstant(0), SimDuration::ZERO);
        v.on_delete("c", "k", 2, 77, SimInstant(1_000_000), LAG3);
        // Backend no longer lists the key; the ghost stands in.
        let l = v.overlay("c", "", SimInstant(2_000_000), vec![]);
        assert_eq!(names(&l), ["k"]);
        assert_eq!(l[0].size, 2);
        assert_eq!(l[0].etag, 77);
        assert!(v.overlay("c", "", SimInstant(4_000_000), vec![]).is_empty());
    }

    #[test]
    fn delete_before_listed_leaves_no_ghost() {
        let mut v = VisibilityMap::default();
        v.on_put("c", "k", false, SimInstant(0), SimDuration::from_secs(10));
        v.on_delete("c", "k", 1, 0, SimInstant(1), SimDuration::from_secs(10));
        for t in [0u64, 1, 5_000_000, 20_000_000] {
            assert!(v.overlay("c", "", SimInstant(t), vec![]).is_empty(), "t={t}");
        }
    }

    #[test]
    fn replace_keeps_visibility() {
        let mut v = VisibilityMap::default();
        v.on_put("c", "k", false, SimInstant(0), LAG5);
        // Visible at t=5s; replacing at t=6s must stay visible immediately.
        v.on_put("c", "k", true, SimInstant(6_000_000), LAG5);
        let l = v.overlay("c", "", SimInstant(6_000_000), vec![summary("k", 2)]);
        assert_eq!(l.len(), 1);
        assert_eq!(l[0].size, 2);
    }

    #[test]
    fn replace_within_lag_window_restarts_lag() {
        let mut v = VisibilityMap::default();
        v.on_put("c", "k", false, SimInstant(0), LAG5);
        // Still hidden at t=3s; the replace restarts the clock.
        v.on_put("c", "k", true, SimInstant(3_000_000), LAG5);
        let raw = vec![summary("k", 1)];
        assert!(v.overlay("c", "", SimInstant(5_000_000), raw.clone()).is_empty());
        assert_eq!(v.overlay("c", "", SimInstant(8_000_000), raw).len(), 1);
    }

    #[test]
    fn recreate_after_delete_gets_fresh_lag_and_clears_ghost() {
        let mut v = VisibilityMap::default();
        v.on_put("c", "k", false, SimInstant(0), SimDuration::ZERO);
        v.on_delete("c", "k", 9, 1, SimInstant(1_000_000), LAG3);
        // Recreate while the ghost is still open: ghost replaced by the
        // (lagged) fresh object.
        v.on_put("c", "k", false, SimInstant(2_000_000), LAG5);
        let raw = vec![summary("k", 4)];
        let mid = v.overlay("c", "", SimInstant(3_000_000), raw.clone());
        assert!(mid.is_empty(), "ghost must be gone, create still lagged");
        let later = v.overlay("c", "", SimInstant(7_000_000), raw);
        assert_eq!(later[0].size, 4);
    }

    #[test]
    fn ghosts_merge_sorted_into_listing() {
        let mut v = VisibilityMap::default();
        for k in ["a", "c", "e"] {
            v.on_put("c", k, false, SimInstant(0), SimDuration::ZERO);
        }
        v.on_delete("c", "b", 1, 0, SimInstant(0), LAG3);
        v.on_delete("c", "f", 1, 0, SimInstant(0), LAG3);
        let raw = vec![summary("a", 1), summary("c", 1), summary("e", 1)];
        let l = v.overlay("c", "", SimInstant(1), raw);
        assert_eq!(names(&l), ["a", "b", "c", "e", "f"]);
    }

    #[test]
    fn prefix_restricts_ghosts() {
        let mut v = VisibilityMap::default();
        v.on_delete("c", "d/x", 1, 0, SimInstant(0), LAG3);
        v.on_delete("c", "e/y", 1, 0, SimInstant(0), LAG3);
        let l = v.overlay("c", "d/", SimInstant(1), vec![]);
        assert_eq!(names(&l), ["d/x"]);
    }

    #[test]
    fn unknown_container_passes_through() {
        let v = VisibilityMap::default();
        let raw = vec![summary("k", 1)];
        assert_eq!(v.overlay("nope", "", SimInstant(0), raw.clone()), raw);
    }
}
