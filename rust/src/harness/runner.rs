//! Run one (scenario × workload) cell with repetitions.

use super::scenarios::{build_env, Scenario, Sizing};
use crate::metrics::OpCounts;
use crate::query::datagen::StarSchema;
use crate::workloads::{copy, input, readonly, teragen, terasort, tpcds, wordcount, WorkloadReport};

/// The paper's seven workload columns (Table 4 / Table 5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Workload {
    ReadOnly50,
    ReadOnly500,
    Teragen,
    Copy,
    Wordcount,
    Terasort,
    TpcDs,
}

impl Workload {
    pub const ALL: [Workload; 7] = [
        Workload::ReadOnly50,
        Workload::ReadOnly500,
        Workload::Teragen,
        Workload::Copy,
        Workload::Wordcount,
        Workload::Terasort,
        Workload::TpcDs,
    ];

    /// Micro-benchmarks (paper Fig. 5) vs macro (Fig. 6).
    pub const MICRO: [Workload; 4] = [
        Workload::ReadOnly50,
        Workload::ReadOnly500,
        Workload::Teragen,
        Workload::Copy,
    ];
    pub const MACRO: [Workload; 3] = [Workload::Wordcount, Workload::Terasort, Workload::TpcDs];
    /// Workloads with a write phase (paper Fig. 7).
    pub const WRITE: [Workload; 4] = [
        Workload::Teragen,
        Workload::Copy,
        Workload::Wordcount,
        Workload::Terasort,
    ];

    pub fn label(self) -> &'static str {
        match self {
            Workload::ReadOnly50 => "Read-Only 50GB",
            Workload::ReadOnly500 => "Read-Only 500GB",
            Workload::Teragen => "Teragen",
            Workload::Copy => "Copy",
            Workload::Wordcount => "Wordcount",
            Workload::Terasort => "Terasort",
            Workload::TpcDs => "TPC-DS",
        }
    }

    /// The compute-rate calibration key.
    pub fn rate_key(self) -> &'static str {
        match self {
            Workload::ReadOnly50 | Workload::ReadOnly500 => "readonly",
            Workload::Teragen => "teragen",
            Workload::Copy => "copy",
            Workload::Wordcount => "wordcount",
            Workload::Terasort => "terasort",
            Workload::TpcDs => "tpcds",
        }
    }
}

/// One measured cell: mean/stddev runtime over `runs`, op counts from the
/// first run (op counts are deterministic; only latency jitter varies).
#[derive(Debug, Clone)]
pub struct CellResult {
    pub scenario: Scenario,
    pub workload: Workload,
    pub runtime_mean_s: f64,
    pub runtime_std_s: f64,
    pub ops: OpCounts,
    pub valid: bool,
    pub validation: String,
    pub runs: usize,
    /// Paper-scaled bytes stranded in orphaned multipart uploads at the
    /// end of the first run, before / after the `--multipart-ttl`
    /// lifecycle sweep (the Table 8 addendum's inputs).
    pub stranded_mp_bytes: u64,
    pub stranded_mp_bytes_after_sweep: u64,
}

/// Execute one repetition; returns the workload report (with post-run
/// stranded-multipart accounting and, when `--multipart-ttl` is set, the
/// age-based GC sweep applied).
fn run_once(scenario: Scenario, workload: Workload, sizing: &Sizing, seed: u64) -> WorkloadReport {
    let (env, mut report) = run_workload(scenario, workload, sizing, seed);
    // Stranded fast-upload debris: what crashed / transiently-exhausted
    // writers left in flight. The lifecycle sweep models the store-side
    // `AbortIncompleteMultipartUpload` rule firing `multipart_ttl_secs`
    // of virtual time later — server-side housekeeping, outside the
    // measured job window.
    report.stranded_mp_bytes = env.store.debug_stranded_multipart_bytes();
    report.stranded_mp_bytes_after_sweep = report.stranded_mp_bytes;
    if sizing.multipart_ttl_secs > 0 && report.stranded_mp_bytes > 0 {
        let ttl = crate::simclock::SimDuration::from_secs(sizing.multipart_ttl_secs);
        let sweep_at = env.driver.now() + ttl;
        let _ = env.store.sweep_stale_multiparts(sweep_at, ttl);
        report.stranded_mp_bytes_after_sweep = env.store.debug_stranded_multipart_bytes();
    }
    report
}

/// Build the environment and run the workload body once.
///
/// The `--faults` schedule is armed on the store only AFTER input
/// preparation: input datasets model pre-existing data (their uploads sit
/// outside every measured window), so fault-rule match counters start at
/// the measured workload's first operation — `put@1` means "the
/// workload's first PUT", deterministically, for every workload.
fn run_workload(
    scenario: Scenario,
    workload: Workload,
    sizing: &Sizing,
    seed: u64,
) -> (crate::workloads::WorkloadEnv, WorkloadReport) {
    let rate_key = workload.rate_key();
    // Build the environment fault-free; the schedule is armed post-prep.
    let fault_schedule = sizing.faults.clone();
    let prep = Sizing {
        faults: crate::objectstore::FaultSpec::none(),
        ..sizing.clone()
    };
    let sizing = &prep;
    match workload {
        Workload::ReadOnly50 | Workload::ReadOnly500 => {
            let parts = if workload == Workload::ReadOnly500 {
                sizing.ro500_parts
            } else {
                sizing.parts
            };
            let mut env = build_env(scenario, sizing, rate_key, sizing.data_scale, parts, seed);
            let (lines, _, _) = input::upload_text_dataset(
                &env.store,
                "res",
                "in.txt",
                parts,
                sizing.part_bytes,
                seed,
            );
            env.store.arm_faults(&fault_schedule);
            let report = readonly::run(&mut env, "in.txt", lines);
            (env, report)
        }
        Workload::Teragen => {
            let mut env = build_env(
                scenario,
                sizing,
                rate_key,
                sizing.data_scale,
                sizing.parts,
                seed,
            );
            env.store.arm_faults(&fault_schedule);
            let report = teragen::run(&mut env, "teraout");
            (env, report)
        }
        Workload::Copy => {
            let mut env = build_env(
                scenario,
                sizing,
                rate_key,
                sizing.data_scale,
                sizing.parts,
                seed,
            );
            input::upload_text_dataset(
                &env.store,
                "res",
                "src",
                sizing.parts,
                sizing.part_bytes,
                seed,
            );
            env.store.arm_faults(&fault_schedule);
            let report = copy::run(&mut env, "src", "dst");
            (env, report)
        }
        Workload::Wordcount => {
            let mut env = build_env(
                scenario,
                sizing,
                rate_key,
                sizing.data_scale,
                sizing.parts,
                seed,
            );
            let (_, words, _) = input::upload_text_dataset(
                &env.store,
                "res",
                "corpus",
                sizing.parts,
                sizing.part_bytes,
                seed,
            );
            env.store.arm_faults(&fault_schedule);
            let report = wordcount::run(&mut env, "corpus", "wc-out", words);
            (env, report)
        }
        Workload::Terasort => {
            let mut env = build_env(
                scenario,
                sizing,
                rate_key,
                sizing.data_scale,
                sizing.parts,
                seed,
            );
            input::upload_tera_dataset(
                &env.store,
                "res",
                "tin",
                sizing.parts,
                sizing.part_bytes,
                seed,
            );
            env.store.arm_faults(&fault_schedule);
            let report = terasort::run(&mut env, "tin", "tsorted");
            (env, report)
        }
        Workload::TpcDs => {
            let mut env = build_env(
                scenario,
                sizing,
                rate_key,
                sizing.tpcds_scale,
                sizing.tpcds_shards,
                seed,
            );
            let schema = StarSchema::new(seed, sizing.tpcds_shards, sizing.tpcds_rows);
            tpcds::upload_star_schema(&env, "sales", &schema);
            env.store.arm_faults(&fault_schedule);
            let report = tpcds::run(&mut env, "sales", &schema);
            (env, report)
        }
    }
}

/// Run a cell `runs` times with distinct seeds; aggregate.
pub fn run_cell(scenario: Scenario, workload: Workload, sizing: &Sizing, runs: usize) -> CellResult {
    assert!(runs >= 1);
    let mut times = Vec::with_capacity(runs);
    let mut ops = OpCounts::default();
    let mut valid = true;
    let mut validation = String::new();
    let mut stranded_mp_bytes = 0;
    let mut stranded_mp_bytes_after_sweep = 0;
    for r in 0..runs {
        let seed = 0xBEEF ^ (r as u64) << 8;
        let report = run_once(scenario, workload, sizing, seed);
        times.push(report.runtime.as_secs_f64());
        if r == 0 {
            ops = report.ops;
            valid = report.is_valid();
            validation = match &report.validation {
                Ok(s) => s.clone(),
                Err(s) => format!("INVALID: {s}"),
            };
            stranded_mp_bytes = report.stranded_mp_bytes;
            stranded_mp_bytes_after_sweep = report.stranded_mp_bytes_after_sweep;
        }
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let var = times
        .iter()
        .map(|t| (t - mean) * (t - mean))
        .sum::<f64>()
        / times.len() as f64;
    CellResult {
        scenario,
        workload,
        runtime_mean_s: mean,
        runtime_std_s: var.sqrt(),
        ops,
        valid,
        validation,
        runs,
        stranded_mp_bytes,
        stranded_mp_bytes_after_sweep,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::OpKind;

    #[test]
    fn small_cell_runs_and_validates() {
        let sizing = Sizing::small();
        let cell = run_cell(Scenario::Stocator, Workload::Teragen, &sizing, 1);
        assert!(cell.valid, "{}", cell.validation);
        assert!(cell.runtime_mean_s > 0.0);
        assert_eq!(cell.ops.get(OpKind::CopyObject), 0);
    }

    #[test]
    fn fault_schedule_spares_input_preparation() {
        use crate::objectstore::{FaultOp, FaultSpec};
        // `put@1` (the grammar's own example) must target the measured
        // workload's first PUT — never the harness's input uploads,
        // which model pre-existing data and have no retry path.
        let mut sizing = Sizing::small();
        sizing.faults = FaultSpec::one(FaultOp::Put, "", 1);
        let cell = run_cell(Scenario::Stocator, Workload::ReadOnly50, &sizing, 1);
        assert!(cell.valid, "{}", cell.validation);
    }

    #[test]
    fn faulted_fast_upload_strands_uploads_and_ttl_sweeps_them() {
        use crate::objectstore::{FaultOp, FaultRule, FaultSpec};
        let mut sizing = Sizing::small();
        // Exceed fs.s3a.multipart.size (100 MB / data_scale = 12.5 KiB
        // simulated) so fast upload actually multiparts.
        sizing.part_bytes = 16 * 1024;
        // No stream retries: the 2nd part PUT of the job exhausts
        // immediately, failing that attempt mid-upload — its initiated
        // multipart upload (first part already accepted) strands.
        sizing.faults =
            FaultSpec::none().with(FaultRule::new(FaultOp::UploadPart, "teraout/", 2, 1));
        let no_sweep = run_cell(Scenario::S3aCv2Fu, Workload::Teragen, &sizing, 1);
        assert!(no_sweep.valid, "{}", no_sweep.validation);
        assert!(
            no_sweep.stranded_mp_bytes > 0,
            "the failed attempt must strand its upload"
        );
        assert_eq!(
            no_sweep.stranded_mp_bytes, no_sweep.stranded_mp_bytes_after_sweep,
            "no TTL configured: the debris keeps billing storage"
        );

        sizing.multipart_ttl_secs = 3600;
        let swept = run_cell(Scenario::S3aCv2Fu, Workload::Teragen, &sizing, 1);
        assert!(swept.valid, "{}", swept.validation);
        assert_eq!(swept.stranded_mp_bytes, no_sweep.stranded_mp_bytes);
        assert_eq!(
            swept.stranded_mp_bytes_after_sweep, 0,
            "the lifecycle sweep reaps every stranded upload"
        );
    }

    #[test]
    fn stocator_beats_legacy_on_ops_small() {
        let sizing = Sizing::small();
        let st = run_cell(Scenario::Stocator, Workload::Teragen, &sizing, 1);
        let sw = run_cell(Scenario::HadoopSwiftBase, Workload::Teragen, &sizing, 1);
        let s3 = run_cell(Scenario::S3aBase, Workload::Teragen, &sizing, 1);
        assert!(st.valid && sw.valid && s3.valid);
        assert!(st.ops.total() < sw.ops.total());
        assert!(sw.ops.total() < s3.ops.total());
        // And on simulated runtime:
        assert!(st.runtime_mean_s < sw.runtime_mean_s);
        assert!(st.runtime_mean_s < s3.runtime_mean_s);
    }
}
