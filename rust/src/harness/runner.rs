//! Run one (scenario × workload) cell with repetitions.

use super::scenarios::{build_env, Scenario, Sizing};
use crate::metrics::OpCounts;
use crate::query::datagen::StarSchema;
use crate::workloads::{copy, input, readonly, teragen, terasort, tpcds, wordcount, WorkloadReport};

/// The paper's seven workload columns (Table 4 / Table 5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Workload {
    ReadOnly50,
    ReadOnly500,
    Teragen,
    Copy,
    Wordcount,
    Terasort,
    TpcDs,
}

impl Workload {
    pub const ALL: [Workload; 7] = [
        Workload::ReadOnly50,
        Workload::ReadOnly500,
        Workload::Teragen,
        Workload::Copy,
        Workload::Wordcount,
        Workload::Terasort,
        Workload::TpcDs,
    ];

    /// Micro-benchmarks (paper Fig. 5) vs macro (Fig. 6).
    pub const MICRO: [Workload; 4] = [
        Workload::ReadOnly50,
        Workload::ReadOnly500,
        Workload::Teragen,
        Workload::Copy,
    ];
    pub const MACRO: [Workload; 3] = [Workload::Wordcount, Workload::Terasort, Workload::TpcDs];
    /// Workloads with a write phase (paper Fig. 7).
    pub const WRITE: [Workload; 4] = [
        Workload::Teragen,
        Workload::Copy,
        Workload::Wordcount,
        Workload::Terasort,
    ];

    pub fn label(self) -> &'static str {
        match self {
            Workload::ReadOnly50 => "Read-Only 50GB",
            Workload::ReadOnly500 => "Read-Only 500GB",
            Workload::Teragen => "Teragen",
            Workload::Copy => "Copy",
            Workload::Wordcount => "Wordcount",
            Workload::Terasort => "Terasort",
            Workload::TpcDs => "TPC-DS",
        }
    }

    /// The compute-rate calibration key.
    pub fn rate_key(self) -> &'static str {
        match self {
            Workload::ReadOnly50 | Workload::ReadOnly500 => "readonly",
            Workload::Teragen => "teragen",
            Workload::Copy => "copy",
            Workload::Wordcount => "wordcount",
            Workload::Terasort => "terasort",
            Workload::TpcDs => "tpcds",
        }
    }
}

/// One measured cell: mean/stddev runtime over `runs`, op counts from the
/// first run (op counts are deterministic; only latency jitter varies).
#[derive(Debug, Clone)]
pub struct CellResult {
    pub scenario: Scenario,
    pub workload: Workload,
    pub runtime_mean_s: f64,
    pub runtime_std_s: f64,
    pub ops: OpCounts,
    pub valid: bool,
    pub validation: String,
    pub runs: usize,
}

/// Execute one repetition; returns the workload report.
fn run_once(scenario: Scenario, workload: Workload, sizing: &Sizing, seed: u64) -> WorkloadReport {
    let rate_key = workload.rate_key();
    match workload {
        Workload::ReadOnly50 | Workload::ReadOnly500 => {
            let parts = if workload == Workload::ReadOnly500 {
                sizing.ro500_parts
            } else {
                sizing.parts
            };
            let mut env = build_env(scenario, sizing, rate_key, sizing.data_scale, parts, seed);
            let (lines, _, _) = input::upload_text_dataset(
                &env.store,
                "res",
                "in.txt",
                parts,
                sizing.part_bytes,
                seed,
            );
            readonly::run(&mut env, "in.txt", lines)
        }
        Workload::Teragen => {
            let mut env = build_env(
                scenario,
                sizing,
                rate_key,
                sizing.data_scale,
                sizing.parts,
                seed,
            );
            teragen::run(&mut env, "teraout")
        }
        Workload::Copy => {
            let mut env = build_env(
                scenario,
                sizing,
                rate_key,
                sizing.data_scale,
                sizing.parts,
                seed,
            );
            input::upload_text_dataset(
                &env.store,
                "res",
                "src",
                sizing.parts,
                sizing.part_bytes,
                seed,
            );
            copy::run(&mut env, "src", "dst")
        }
        Workload::Wordcount => {
            let mut env = build_env(
                scenario,
                sizing,
                rate_key,
                sizing.data_scale,
                sizing.parts,
                seed,
            );
            let (_, words, _) = input::upload_text_dataset(
                &env.store,
                "res",
                "corpus",
                sizing.parts,
                sizing.part_bytes,
                seed,
            );
            wordcount::run(&mut env, "corpus", "wc-out", words)
        }
        Workload::Terasort => {
            let mut env = build_env(
                scenario,
                sizing,
                rate_key,
                sizing.data_scale,
                sizing.parts,
                seed,
            );
            input::upload_tera_dataset(
                &env.store,
                "res",
                "tin",
                sizing.parts,
                sizing.part_bytes,
                seed,
            );
            terasort::run(&mut env, "tin", "tsorted")
        }
        Workload::TpcDs => {
            let mut env = build_env(
                scenario,
                sizing,
                rate_key,
                sizing.tpcds_scale,
                sizing.tpcds_shards,
                seed,
            );
            let schema = StarSchema::new(seed, sizing.tpcds_shards, sizing.tpcds_rows);
            tpcds::upload_star_schema(&env, "sales", &schema);
            tpcds::run(&mut env, "sales", &schema)
        }
    }
}

/// Run a cell `runs` times with distinct seeds; aggregate.
pub fn run_cell(scenario: Scenario, workload: Workload, sizing: &Sizing, runs: usize) -> CellResult {
    assert!(runs >= 1);
    let mut times = Vec::with_capacity(runs);
    let mut ops = OpCounts::default();
    let mut valid = true;
    let mut validation = String::new();
    for r in 0..runs {
        let seed = 0xBEEF ^ (r as u64) << 8;
        let report = run_once(scenario, workload, sizing, seed);
        times.push(report.runtime.as_secs_f64());
        if r == 0 {
            ops = report.ops;
            valid = report.is_valid();
            validation = match &report.validation {
                Ok(s) => s.clone(),
                Err(s) => format!("INVALID: {s}"),
            };
        }
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let var = times
        .iter()
        .map(|t| (t - mean) * (t - mean))
        .sum::<f64>()
        / times.len() as f64;
    CellResult {
        scenario,
        workload,
        runtime_mean_s: mean,
        runtime_std_s: var.sqrt(),
        ops,
        valid,
        validation,
        runs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::OpKind;

    #[test]
    fn small_cell_runs_and_validates() {
        let sizing = Sizing::small();
        let cell = run_cell(Scenario::Stocator, Workload::Teragen, &sizing, 1);
        assert!(cell.valid, "{}", cell.validation);
        assert!(cell.runtime_mean_s > 0.0);
        assert_eq!(cell.ops.get(OpKind::CopyObject), 0);
    }

    #[test]
    fn stocator_beats_legacy_on_ops_small() {
        let sizing = Sizing::small();
        let st = run_cell(Scenario::Stocator, Workload::Teragen, &sizing, 1);
        let sw = run_cell(Scenario::HadoopSwiftBase, Workload::Teragen, &sizing, 1);
        let s3 = run_cell(Scenario::S3aBase, Workload::Teragen, &sizing, 1);
        assert!(st.valid && sw.valid && s3.valid);
        assert!(st.ops.total() < sw.ops.total());
        assert!(sw.ops.total() < s3.ops.total());
        // And on simulated runtime:
        assert!(st.runtime_mean_s < sw.runtime_mean_s);
        assert!(st.runtime_mean_s < s3.runtime_mean_s);
    }
}
