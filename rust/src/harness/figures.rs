//! Figures 5, 6 and 7: REST calls by type (micro / macro benchmarks) and
//! bytes read/written/copied, as grouped ASCII bar charts plus raw series.

use super::runner::Workload;
use super::tables::Sweep;
use crate::harness::scenarios::Scenario;
use crate::metrics::OpKind;
use crate::util::table::{BarChart, Table};

/// Figure 5 (micro) or 6 (macro): total REST calls per scenario per
/// workload, with a per-type breakdown table.
pub fn render_rest_figure(sweep: &Sweep, workloads: &[Workload], title: &str) -> String {
    let mut out = String::new();
    let series: Vec<&str> = Scenario::ALL.iter().map(|s| s.label()).collect();
    let mut chart = BarChart::new(title, &series, "REST calls");
    for &w in workloads {
        let values: Vec<f64> = Scenario::ALL
            .iter()
            .map(|&s| {
                sweep
                    .cell(s, w)
                    .map(|c| c.ops.total() as f64)
                    .unwrap_or(0.0)
            })
            .collect();
        chart.group(w.label(), values);
    }
    out.push_str(&chart.render());
    out.push('\n');

    // Per-type breakdown (the stacked composition of the paper's bars).
    for &w in workloads {
        let mut t = Table::new(
            &format!("{} — REST breakdown", w.label()),
            &["scenario", "HEAD", "GET", "PUT", "COPY", "DELETE", "GETcont", "total"],
        );
        for s in Scenario::ALL {
            if let Some(c) = sweep.cell(s, w) {
                t.row(vec![
                    s.label().to_string(),
                    c.ops.get(OpKind::HeadObject).to_string(),
                    c.ops.get(OpKind::GetObject).to_string(),
                    c.ops.get(OpKind::PutObject).to_string(),
                    c.ops.get(OpKind::CopyObject).to_string(),
                    c.ops.get(OpKind::DeleteObject).to_string(),
                    c.ops.get(OpKind::GetContainer).to_string(),
                    c.ops.total().to_string(),
                ]);
            }
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}

/// Figure 7: bytes read / written / copied per scenario for the write
/// workloads. The paper's headline: base connectors write every byte 3×
/// (PUT + two COPYs), Cv2 2×, Stocator exactly 1×.
pub fn render_fig7(sweep: &Sweep) -> String {
    let mut out = String::new();
    let series: Vec<&str> = Scenario::ALL.iter().map(|s| s.label()).collect();
    for &w in &Workload::WRITE {
        if sweep.cell(Scenario::Stocator, w).is_none() {
            continue;
        }
        let mut chart = BarChart::new(
            &format!("Figure 7 — {} bytes moved on the object store", w.label()),
            &series,
            "GiB (logical)",
        );
        let gib = |b: u64| b as f64 / (1u64 << 30) as f64;
        let mut read = Vec::new();
        let mut written = Vec::new();
        let mut copied = Vec::new();
        for s in Scenario::ALL {
            let c = sweep.cell(s, w).unwrap();
            read.push(gib(c.ops.bytes_read));
            written.push(gib(c.ops.bytes_written));
            copied.push(gib(c.ops.bytes_copied));
        }
        chart.group(
            "bytes written (PUT)",
            Scenario::ALL
                .iter()
                .enumerate()
                .map(|(i, _)| written[i])
                .collect(),
        );
        chart.group(
            "bytes copied (COPY)",
            Scenario::ALL
                .iter()
                .enumerate()
                .map(|(i, _)| copied[i])
                .collect(),
        );
        chart.group(
            "bytes read (GET)",
            Scenario::ALL
                .iter()
                .enumerate()
                .map(|(i, _)| read[i])
                .collect(),
        );
        out.push_str(&chart.render());
        out.push('\n');
    }
    out
}

/// The Fig. 7 invariant as numbers: (written+copied) / dataset bytes.
pub fn write_amplification(sweep: &Sweep, w: Workload, s: Scenario) -> Option<f64> {
    let c = sweep.cell(s, w)?;
    let dataset = sweep.cell(Scenario::Stocator, w)?.ops.bytes_written;
    if dataset == 0 {
        return None;
    }
    Some((c.ops.bytes_written + c.ops.bytes_copied) as f64 / dataset as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::scenarios::Sizing;
    use crate::harness::tables::Sweep;

    #[test]
    fn fig7_write_amplification_shape() {
        let sizing = Sizing::small();
        let sweep = Sweep::run(&sizing, 1, &[Workload::Teragen]);
        // Stocator 1x, Cv2 ≈2x, Base ≈3x (paper Fig. 7).
        let st = write_amplification(&sweep, Workload::Teragen, Scenario::Stocator).unwrap();
        let cv2 = write_amplification(&sweep, Workload::Teragen, Scenario::S3aCv2).unwrap();
        let base = write_amplification(&sweep, Workload::Teragen, Scenario::S3aBase).unwrap();
        assert!((0.99..1.1).contains(&st), "stocator {st}");
        assert!((1.8..2.3).contains(&cv2), "cv2 {cv2}");
        assert!((2.7..3.3).contains(&base), "base {base}");
        let rendered = render_fig7(&sweep);
        assert!(rendered.contains("bytes copied"));
    }

    #[test]
    fn rest_figure_renders_all_scenarios() {
        let sizing = Sizing::small();
        let sweep = Sweep::run(&sizing, 1, &[Workload::ReadOnly50]);
        let fig = render_rest_figure(&sweep, &[Workload::ReadOnly50], "Figure 5 (subset)");
        for s in Scenario::ALL {
            assert!(fig.contains(s.label()), "{}", s.label());
        }
        assert!(fig.contains("REST breakdown"));
    }
}
