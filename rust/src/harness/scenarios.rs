//! The six deployment scenarios (paper §4.2) and experiment sizing.

use crate::committer::CommitAlgorithm;
use crate::connectors::{HadoopSwift, S3a, S3aConfig, Stocator, StocatorConfig};
use crate::fs::FileSystem;
use crate::objectstore::{
    BackendKind, ConsistencyModel, FaultSpec, LatencyModel, ObjectStore, RetryPolicy, StoreConfig,
};
use crate::runtime::Kernels;
use crate::simclock::SimInstant;
use crate::spark::{ComputeModel, Driver, SparkConfig};
use crate::workloads::WorkloadEnv;
use std::rc::Rc;
use std::sync::Arc;

/// The paper's six scenarios (§4.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scenario {
    HadoopSwiftBase,
    S3aBase,
    Stocator,
    HadoopSwiftCv2,
    S3aCv2,
    S3aCv2Fu,
}

impl Scenario {
    pub const ALL: [Scenario; 6] = [
        Scenario::HadoopSwiftBase,
        Scenario::S3aBase,
        Scenario::Stocator,
        Scenario::HadoopSwiftCv2,
        Scenario::S3aCv2,
        Scenario::S3aCv2Fu,
    ];

    /// Paper row label.
    pub fn label(self) -> &'static str {
        match self {
            Scenario::HadoopSwiftBase => "Hadoop-Swift Base",
            Scenario::S3aBase => "S3a Base",
            Scenario::Stocator => "Stocator",
            Scenario::HadoopSwiftCv2 => "Hadoop-Swift Cv2",
            Scenario::S3aCv2 => "S3a Cv2",
            Scenario::S3aCv2Fu => "S3a Cv2 + FU",
        }
    }

    pub fn algorithm(self) -> CommitAlgorithm {
        match self {
            Scenario::HadoopSwiftBase | Scenario::S3aBase => CommitAlgorithm::V1,
            Scenario::Stocator => CommitAlgorithm::V1, // intercepted anyway
            Scenario::HadoopSwiftCv2 | Scenario::S3aCv2 | Scenario::S3aCv2Fu => {
                CommitAlgorithm::V2
            }
        }
    }

    pub fn scheme(self) -> &'static str {
        match self {
            Scenario::HadoopSwiftBase | Scenario::HadoopSwiftCv2 => "swift",
            Scenario::Stocator => "swift2d",
            _ => "s3a",
        }
    }

    /// Build the connector over `store`.
    pub fn connector(self, store: Arc<ObjectStore>, multipart_size: u64) -> Arc<dyn FileSystem> {
        match self {
            Scenario::HadoopSwiftBase | Scenario::HadoopSwiftCv2 => HadoopSwift::new(store),
            Scenario::Stocator => Stocator::new(store, StocatorConfig::default()),
            Scenario::S3aBase | Scenario::S3aCv2 => S3a::new(store, S3aConfig::default()),
            Scenario::S3aCv2Fu => S3a::new(
                store,
                S3aConfig {
                    fast_upload: true,
                    multipart_size,
                },
            ),
        }
    }
}

/// Experiment sizing: the paper's object counts at scaled-down bytes
/// (DESIGN.md §2: op counts scale with part count, not bytes).
#[derive(Debug, Clone)]
pub struct Sizing {
    /// Input/output parts (paper: 46.5 GB / 128 MB = 372).
    pub parts: usize,
    /// Parts for the 500 GB read-only variant (paper: 3720).
    pub ro500_parts: usize,
    /// Simulated bytes per part.
    pub part_bytes: usize,
    /// Logical bytes = simulated × data_scale (32 KiB × 4096 = 128 MiB).
    pub data_scale: u64,
    /// Task slots (paper: 144).
    pub slots: usize,
    /// TPC-DS shards (paper: 13.8 GB / 128 MB ≈ 110 objects).
    pub tpcds_shards: usize,
    /// Fact rows per TPC-DS shard.
    pub tpcds_rows: usize,
    /// TPC-DS byte scale (≈229 KiB simulated -> ≈125 MiB logical).
    pub tpcds_scale: u64,
    /// Latency jitter amplitude (paper reports stddev over 10 runs).
    pub jitter: f64,
    /// Storage backend the stores run on (`--backend` on the CLI). Op
    /// counts and virtual-clock runtimes are backend-invariant; this picks
    /// wall-clock concurrency (sharded) or persistence (fs).
    pub backend: BackendKind,
    /// Connector readahead window in simulated bytes (`--readahead` on
    /// the CLI; 0/`off` disables it). Off by default so the paper cells
    /// — Table 2 REST sequences, Table 5 runtimes — are reproduced with
    /// the one-GET-per-read behaviour the legacy stacks actually had;
    /// turning it on coalesces small sequential reads into few ranged
    /// GETs (snapshot-tested in `test_golden_opcounts.rs`).
    pub readahead: u64,
    /// Deterministic transient-REST-fault schedule (`--faults` on the
    /// CLI). Empty by default: all paper cells reproduce the fault-free
    /// stack byte-identically. The harness arms the schedule only AFTER
    /// input preparation (see `runner::run_workload`), so rule counters
    /// start at the measured workload's first operation.
    pub faults: FaultSpec,
    /// Stream-layer retries per operation (`--retries`; 0 = fail fast).
    pub retries: u32,
    /// Age, in virtual seconds, after which the post-run lifecycle sweep
    /// aborts stranded multipart uploads (`--multipart-ttl`; 0 = no
    /// sweep — stranded parts keep billing storage).
    pub multipart_ttl_secs: u64,
}

impl Sizing {
    /// Paper-faithful object counts.
    pub fn paper() -> Sizing {
        Sizing {
            parts: 372,
            ro500_parts: 3720,
            part_bytes: 32 * 1024,
            data_scale: 4096,
            slots: 144,
            tpcds_shards: 110,
            tpcds_rows: 8192,
            tpcds_scale: 560,
            jitter: 0.03,
            backend: BackendKind::default(),
            readahead: 0,
            faults: FaultSpec::none(),
            retries: 0,
            multipart_ttl_secs: 0,
        }
    }

    /// TB-scale sizing: the paper's object counts multiplied `x`-fold
    /// (`--paper-x X` on the CLI, 100–1000 is the intended band). Op
    /// counts scale with part count, so `x = 100` is a ≈4.65 TB logical
    /// terasort (37 200 parts × 128 MiB) over 14 400 task slots — the
    /// scale where the paper's 18×/30× operational-efficiency curves
    /// live. Simulated bytes per part *shrink* to 4 KiB while
    /// `data_scale` grows to keep 128 MiB logical parts, so memory stays
    /// bounded while the virtual clock and the REST-op ledger see the
    /// full TB-scale workload.
    pub fn paper_x(x: usize) -> Sizing {
        let base = Sizing::paper();
        let x = x.max(1);
        Sizing {
            parts: base.parts * x,
            ro500_parts: base.ro500_parts * x,
            part_bytes: 4096,
            data_scale: 32 * 1024,
            slots: base.slots * x,
            tpcds_shards: base.tpcds_shards * x,
            ..base
        }
    }

    /// Small sizing for tests and quick demos.
    pub fn small() -> Sizing {
        Sizing {
            parts: 8,
            ro500_parts: 16,
            part_bytes: 4 * 1024,
            data_scale: 8192,
            slots: 8,
            tpcds_shards: 4,
            tpcds_rows: 4096,
            tpcds_scale: 560,
            jitter: 0.0,
            backend: BackendKind::default(),
            readahead: 0,
            faults: FaultSpec::none(),
            retries: 0,
            multipart_ttl_secs: 0,
        }
    }
}

/// Per-workload sustained compute rate (logical bytes/sec/core),
/// calibrated so the Stocator column approximates the paper's Table 5
/// (DESIGN.md §7; EXPERIMENTS.md shows the calibration residuals).
///
/// Terasort was recalibrated (45 → 46 MB/s) when `sample_splitters`
/// switched from whole-part reads to prefix `read_range` sampling: the
/// driver phase sits outside the measured job window, but the splitter
/// *sample* shrank slightly (8 × 327 = 2616 keys → 32 × 80 = 2560), so
/// the slowest-reducer bucket — which sets the reduce-wave time — grows
/// by ~sqrt(2616/2560) ≈ 1%; the rate bump returns the Stocator cell to
/// its Table 5 value.
pub fn compute_rate(workload: &str) -> u64 {
    match workload {
        "readonly" => 19_000_000,
        "teragen" => 16_000_000,
        "copy" => 10_000_000,
        "wordcount" => 4_300_000,
        "terasort-map" | "terasort" => 46_000_000,
        "tpcds" => 14_000_000,
        _ => 20_000_000,
    }
}

/// Build a full workload environment for a scenario.
pub fn build_env(
    scenario: Scenario,
    sizing: &Sizing,
    workload: &str,
    data_scale: u64,
    parts: usize,
    seed: u64,
) -> WorkloadEnv {
    let latency = LatencyModel {
        jitter: sizing.jitter,
        ..LatencyModel::paper_testbed_scaled(data_scale)
    };
    // The sweep models the paper's *successful* runs: listings keep up
    // with mutations (the paper's clusters completed these benchmarks).
    // Eventual consistency is exercised separately by the
    // failure-injection tests and the eventual_consistency example.
    // Every environment is a fresh world (the in-memory backends start
    // empty), so the shared-storage backends are specialised per env: a
    // persistent fs root gets a unique subdirectory, and an http gateway
    // gets a unique container namespace. Repeated runs and sweep cells
    // never collide on container creation, while all data stays under
    // the user's DIR / on the served store.
    let backend = match &sizing.backend {
        BackendKind::LocalFs(Some(root)) => {
            BackendKind::LocalFs(Some(crate::objectstore::backend::unique_subroot(root)))
        }
        BackendKind::Http { addr, ns: None } => BackendKind::Http {
            addr: addr.clone(),
            ns: Some(crate::gateway::unique_namespace()),
        },
        other => other.clone(),
    };
    let store = ObjectStore::new(StoreConfig {
        latency,
        consistency: ConsistencyModel::strong(),
        min_part_size: 0,
        seed,
        backend,
        readahead: sizing.readahead,
        faults: sizing.faults.clone(),
        retry: RetryPolicy::with_retries(sizing.retries),
        ..StoreConfig::default()
    });
    store.create_container("res", SimInstant::EPOCH).0.unwrap();
    // fs.s3a.multipart.size = 100 MB logical, in simulated bytes.
    let multipart_size = (100 * 1024 * 1024) / data_scale.max(1);
    let fs = scenario.connector(store.clone(), multipart_size);
    let driver = Driver::new(
        SparkConfig {
            slots: sizing.slots,
            ..Default::default()
        },
        fs,
        Some(store.clone()),
        ComputeModel::new(compute_rate(workload), data_scale),
    );
    WorkloadEnv {
        driver,
        store,
        container: "res".into(),
        scheme: scenario.scheme().into(),
        algorithm: scenario.algorithm(),
        kernels: Rc::new(Kernels::Native(crate::runtime::fallback::Fallback)),
        parts,
        part_bytes: sizing.part_bytes,
        seed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_labels_and_configs() {
        assert_eq!(Scenario::ALL.len(), 6);
        assert_eq!(Scenario::Stocator.scheme(), "swift2d");
        assert_eq!(Scenario::S3aCv2Fu.algorithm(), CommitAlgorithm::V2);
        assert_eq!(Scenario::HadoopSwiftBase.algorithm(), CommitAlgorithm::V1);
        let labels: Vec<&str> = Scenario::ALL.iter().map(|s| s.label()).collect();
        assert!(labels.contains(&"S3a Cv2 + FU"));
    }

    #[test]
    fn build_env_wires_scenario() {
        let sizing = Sizing::small();
        let env = build_env(Scenario::Stocator, &sizing, "teragen", 8192, 4, 1);
        assert_eq!(env.scheme, "swift2d");
        assert_eq!(env.parts, 4);
        assert_eq!(env.store.config.latency.data_scale, 8192);
    }

    #[test]
    fn build_env_honours_backend_choice() {
        let mut sizing = Sizing::small();
        sizing.backend = BackendKind::Mem;
        let env = build_env(Scenario::Stocator, &sizing, "teragen", 8192, 4, 1);
        assert_eq!(env.store.backend_name(), "mem");
        assert_eq!(env.store.config.backend, BackendKind::Mem);
        assert_eq!(Sizing::small().backend, BackendKind::default());
    }

    #[test]
    fn build_env_honours_readahead_knob() {
        let mut sizing = Sizing::small();
        sizing.readahead = 4096;
        let env = build_env(Scenario::Stocator, &sizing, "teragen", 8192, 4, 1);
        assert_eq!(env.store.config.readahead, 4096);
        // Off by default in both sizings: paper cells reproduce the
        // one-GET-per-read stack byte-identically.
        assert_eq!(Sizing::small().readahead, 0);
        assert_eq!(Sizing::paper().readahead, 0);
    }

    #[test]
    fn build_env_honours_fault_plane_knobs() {
        use crate::objectstore::FaultOp;
        let mut sizing = Sizing::small();
        sizing.faults = FaultSpec::one(FaultOp::Put, "out/", 1);
        sizing.retries = 2;
        let env = build_env(Scenario::Stocator, &sizing, "teragen", 8192, 4, 1);
        assert_eq!(env.store.config.faults, sizing.faults);
        assert_eq!(env.store.config.retry.retries, 2);
        // Defaults: no faults, no retries, no sweep — the fault-free
        // stack byte-identically.
        assert!(Sizing::small().faults.is_empty());
        assert_eq!(Sizing::small().retries, 0);
        assert_eq!(Sizing::paper().multipart_ttl_secs, 0);
    }

    #[test]
    fn paper_x_scales_counts_not_bytes() {
        let base = Sizing::paper();
        let x100 = Sizing::paper_x(100);
        assert_eq!(x100.parts, base.parts * 100);
        assert_eq!(x100.ro500_parts, base.ro500_parts * 100);
        assert_eq!(x100.slots, base.slots * 100);
        assert_eq!(x100.tpcds_shards, base.tpcds_shards * 100);
        // 128 MiB logical per part is preserved: simulated bytes shrink,
        // data_scale grows — the memory footprint stays bounded.
        assert_eq!(
            x100.part_bytes as u64 * x100.data_scale,
            base.part_bytes as u64 * base.data_scale,
        );
        // ≈4.65 TB logical terasort at x=100.
        let logical = x100.parts as u64 * x100.part_bytes as u64 * x100.data_scale;
        assert!(logical > 4_000_000_000_000, "x=100 is TB-scale ({logical} B)");
        assert_eq!(Sizing::paper_x(0).parts, base.parts, "x clamps to >= 1");
    }

    #[test]
    fn compute_rates_reflect_workload_weight() {
        // Wordcount does the most CPU work per byte; readonly the least.
        assert!(compute_rate("wordcount") < compute_rate("readonly"));
    }
}
