//! The benchmark harness: regenerates every table and figure of the
//! paper's evaluation (§5) from the simulated stack.
//!
//! * [`scenarios`] — the six deployment scenarios of §4.2 and the
//!   calibrated sizing/latency parameters (DESIGN.md §7).
//! * [`runner`] — runs one (scenario × workload) cell: input prep outside
//!   the measurement window, N repetitions with jitter, validation.
//! * [`traces`] — Tables 1 and 3 (operation traces).
//! * [`tables`] — Tables 2, 5, 6, 7, 8.
//! * [`figures`] — Figures 5, 6, 7 (ASCII bar charts + CSV-ish series).

pub mod scenarios;
pub mod runner;
pub mod traces;
pub mod tables;
pub mod figures;

pub use runner::{run_cell, CellResult, Workload};
pub use scenarios::{Scenario, Sizing};
