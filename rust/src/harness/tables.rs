//! Tables 2, 5, 6, 7, 8 of the paper, rendered with measured-vs-paper
//! columns.

use super::runner::{run_cell, CellResult, Workload};
use super::scenarios::{Scenario, Sizing};
use crate::committer::{Committer, JobContext, TaskAttemptContext};
use crate::connectors::naming::AttemptId;
use crate::metrics::{OpCounts, OpKind};
use crate::objectstore::{cost_usd, ObjectStore, StoreConfig};
use crate::simclock::SimInstant;
use crate::util::table::Table;

/// Paper Table 2 reference values: (scenario, HEAD, PUT, COPY, DELETE,
/// GET Container, total).
pub const TABLE2_PAPER: [(&str, u64, u64, u64, u64, u64, u64); 3] = [
    ("Hadoop-Swift", 25, 7, 3, 8, 5, 48),
    ("S3a", 71, 5, 2, 4, 35, 117),
    ("Stocator", 4, 3, 0, 0, 1, 8),
];

/// Run the paper's Fig. 3 one-task program (single output object) on one
/// connector scenario; returns the REST op breakdown.
pub fn table2_single_object(scenario: Scenario) -> OpCounts {
    let store = ObjectStore::new(StoreConfig::instant_strong());
    store.create_container("res", SimInstant::EPOCH).0.unwrap();
    let fs = scenario.connector(store.clone(), u64::MAX);
    let before = store.counters();
    let mut ctx = crate::fs::OpCtx::new(SimInstant::EPOCH);
    let out = crate::fs::Path::parse(&format!("{}://res/data.txt", scenario.scheme())).unwrap();
    let job = JobContext::new(out.clone());
    let committer = Committer::new(scenario.algorithm());
    // Spark's checkOutputSpecs: the output must not already exist.
    assert!(!fs.exists(&out, &mut ctx));
    committer.setup_job(&*fs, &job, &mut ctx).unwrap();
    let task = TaskAttemptContext::new(&job, AttemptId::new("201702221313", "0000", 1, 1));
    committer.setup_task(&*fs, &task, &mut ctx).unwrap();
    committer
        .write_part(&*fs, &task, "part-00001", b"single object".to_vec(), &mut ctx)
        .unwrap();
    if committer.needs_task_commit(&*fs, &task, &mut ctx) {
        committer.commit_task(&*fs, &task, &mut ctx).unwrap();
    }
    committer.commit_job(&*fs, &job, &mut ctx).unwrap();
    // The consumer side: probe the dataset, check _SUCCESS, list parts —
    // the read protocol of the next job in the pipeline (paper §3.2).
    let _ = fs.get_file_status(&out, &mut ctx);
    let _ = fs.get_file_status(&out.child("_SUCCESS"), &mut ctx);
    let _ = fs.list_status(&out, &mut ctx);
    store.counters().since(&before)
}

/// Render Table 2 (measured vs paper).
pub fn render_table2() -> String {
    let mut t = Table::new(
        "Table 2 — REST ops for a one-object Spark job (measured | paper)",
        &["connector", "HEAD", "PUT", "COPY", "DELETE", "GET Cont.", "total", "paper total"],
    );
    for (scenario, paper) in [
        (Scenario::HadoopSwiftBase, &TABLE2_PAPER[0]),
        (Scenario::S3aBase, &TABLE2_PAPER[1]),
        (Scenario::Stocator, &TABLE2_PAPER[2]),
    ] {
        let c = table2_single_object(scenario);
        t.row(vec![
            paper.0.to_string(),
            c.get(OpKind::HeadObject).to_string(),
            c.get(OpKind::PutObject).to_string(),
            c.get(OpKind::CopyObject).to_string(),
            c.get(OpKind::DeleteObject).to_string(),
            c.get(OpKind::GetContainer).to_string(),
            c.total().to_string(),
            paper.6.to_string(),
        ]);
    }
    t.render()
}

/// Paper Table 5 reference runtimes (seconds): rows in Scenario::ALL
/// order, columns in Workload::ALL order.
pub const TABLE5_PAPER: [[f64; 7]; 6] = [
    [37.80, 393.10, 624.60, 622.10, 244.10, 681.90, 101.50],
    [33.30, 254.80, 699.50, 705.10, 193.50, 746.00, 104.50],
    [34.60, 254.10, 38.80, 68.20, 106.60, 84.20, 111.40],
    [37.10, 395.00, 171.30, 175.20, 166.90, 222.70, 102.30],
    [35.30, 255.10, 169.70, 185.40, 111.90, 221.90, 104.00],
    [35.20, 254.20, 56.80, 86.50, 112.00, 105.20, 103.10],
];

/// The full sweep backing Tables 5-8 and Figures 5-7.
pub struct Sweep {
    pub cells: Vec<CellResult>,
    pub sizing: Sizing,
}

impl Sweep {
    /// Run every (scenario × workload) cell.
    pub fn run(sizing: &Sizing, runs: usize, workloads: &[Workload]) -> Sweep {
        let mut cells = Vec::new();
        for &w in workloads {
            for s in Scenario::ALL {
                eprintln!("[sweep] {} / {} ...", s.label(), w.label());
                cells.push(run_cell(s, w, sizing, runs));
            }
        }
        Sweep {
            cells,
            sizing: sizing.clone(),
        }
    }

    pub fn cell(&self, s: Scenario, w: Workload) -> Option<&CellResult> {
        self.cells
            .iter()
            .find(|c| c.scenario == s && c.workload == w)
    }

    fn workloads(&self) -> Vec<Workload> {
        let mut ws = Vec::new();
        for c in &self.cells {
            if !ws.contains(&c.workload) {
                ws.push(c.workload);
            }
        }
        ws
    }

    /// Table 5: average runtimes ± std.
    pub fn render_table5(&self) -> String {
        let ws = self.workloads();
        let mut header: Vec<&str> = vec!["scenario"];
        let labels: Vec<String> = ws.iter().map(|w| w.label().to_string()).collect();
        header.extend(labels.iter().map(|s| s.as_str()));
        let mut t = Table::new(
            "Table 5 — average runtime, seconds (virtual clock; paper value in parens)",
            &header,
        );
        for (si, s) in Scenario::ALL.iter().enumerate() {
            let mut row = vec![s.label().to_string()];
            for w in &ws {
                let wi = Workload::ALL.iter().position(|x| x == w).unwrap();
                match self.cell(*s, *w) {
                    Some(c) => row.push(format!(
                        "{:.1}±{:.1} ({:.1})",
                        c.runtime_mean_s, c.runtime_std_s, TABLE5_PAPER[si][wi]
                    )),
                    None => row.push("-".into()),
                }
            }
            t.row(row);
        }
        t.render()
    }

    /// Table 6: speedup of each scenario relative to Stocator (paper in
    /// parens). Paper convention: value = scenario_time / stocator_time.
    pub fn render_table6(&self) -> String {
        let ws = self.workloads();
        let mut header: Vec<&str> = vec!["scenario"];
        let labels: Vec<String> = ws.iter().map(|w| w.label().to_string()).collect();
        header.extend(labels.iter().map(|s| s.as_str()));
        let mut t = Table::new(
            "Table 6 — workload speedups when using Stocator (paper in parens)",
            &header,
        );
        for (si, s) in Scenario::ALL.iter().enumerate() {
            let mut row = vec![s.label().to_string()];
            for w in &ws {
                let wi = Workload::ALL.iter().position(|x| x == w).unwrap();
                let stoc = self.cell(Scenario::Stocator, *w);
                let cell = self.cell(*s, *w);
                match (stoc, cell) {
                    (Some(st), Some(c)) if st.runtime_mean_s > 0.0 => {
                        let speedup = c.runtime_mean_s / st.runtime_mean_s;
                        let paper = TABLE5_PAPER[si][wi] / TABLE5_PAPER[2][wi];
                        row.push(format!("x{:.2} (x{:.2})", speedup, paper));
                    }
                    _ => row.push("-".into()),
                }
            }
            t.row(row);
        }
        t.render()
    }

    /// Table 7: ratio of REST calls vs Stocator.
    pub fn render_table7(&self) -> String {
        let ws = self.workloads();
        let mut header: Vec<&str> = vec!["scenario"];
        let labels: Vec<String> = ws.iter().map(|w| w.label().to_string()).collect();
        header.extend(labels.iter().map(|s| s.as_str()));
        let mut t = Table::new("Table 7 — REST calls relative to Stocator", &header);
        for s in Scenario::ALL {
            let mut row = vec![s.label().to_string()];
            for w in &ws {
                let stoc = self.cell(Scenario::Stocator, *w);
                let cell = self.cell(s, *w);
                match (stoc, cell) {
                    (Some(st), Some(c)) if st.ops.total() > 0 => {
                        row.push(format!(
                            "x{:.2}",
                            c.ops.total() as f64 / st.ops.total() as f64
                        ));
                    }
                    _ => row.push("-".into()),
                }
            }
            t.row(row);
        }
        t.render()
    }

    /// Table 8: REST-call *cost* relative to Stocator (average of the four
    /// providers' price sheets).
    pub fn render_table8(&self) -> String {
        let ws = self.workloads();
        let mut header: Vec<&str> = vec!["scenario"];
        let labels: Vec<String> = ws.iter().map(|w| w.label().to_string()).collect();
        header.extend(labels.iter().map(|s| s.as_str()));
        let mut t = Table::new(
            "Table 8 — REST-call cost relative to Stocator (IBM/AWS/Google/Azure avg)",
            &header,
        );
        for s in Scenario::ALL {
            let mut row = vec![s.label().to_string()];
            for w in &ws {
                let stoc = self.cell(Scenario::Stocator, *w);
                let cell = self.cell(s, *w);
                match (stoc, cell) {
                    (Some(st), Some(c)) => {
                        let base = cost_usd(&st.ops);
                        if base > 0.0 {
                            row.push(format!("x{:.2}", cost_usd(&c.ops) / base));
                        } else {
                            row.push("-".into());
                        }
                    }
                    _ => row.push("-".into()),
                }
            }
            t.row(row);
        }
        let mut out = t.render();
        // Addendum: stranded fast-upload multipart debris is billed as
        // ordinary storage until a lifecycle sweep aborts it. Only
        // rendered when some cell actually stranded bytes (a fault-free
        // sweep reproduces the stock Table 8 output).
        let before: u64 = self.cells.iter().map(|c| c.stranded_mp_bytes).sum();
        if before > 0 {
            let after: u64 = self
                .cells
                .iter()
                .map(|c| c.stranded_mp_bytes_after_sweep)
                .sum();
            out.push_str(&format!(
                "stranded multipart debris: {before} B (${:.6}/month) before sweep, \
                 {after} B (${:.6}/month) after (--multipart-ttl {})\n",
                crate::objectstore::storage_cost_usd_month(before),
                crate::objectstore::storage_cost_usd_month(after),
                if self.sizing.multipart_ttl_secs > 0 {
                    format!("{}s", self.sizing.multipart_ttl_secs)
                } else {
                    "off".to_string()
                },
            ));
        }
        out
    }

    /// Shape assertions (DESIGN.md §6) — Err lists violations.
    pub fn check_shape(&self) -> Result<(), Vec<String>> {
        let mut bad = Vec::new();
        for c in &self.cells {
            if !c.valid {
                bad.push(format!(
                    "{} / {}: {}",
                    c.scenario.label(),
                    c.workload.label(),
                    c.validation
                ));
            }
        }
        // Stocator has the fewest ops everywhere.
        for w in self.workloads() {
            if let Some(st) = self.cell(Scenario::Stocator, w) {
                for s in Scenario::ALL {
                    if s == Scenario::Stocator {
                        continue;
                    }
                    if let Some(c) = self.cell(s, w) {
                        if c.ops.total() < st.ops.total() {
                            bad.push(format!(
                                "{}: {} issued fewer ops than Stocator",
                                w.label(),
                                s.label()
                            ));
                        }
                    }
                }
            }
        }
        // Teragen speedups per DESIGN.md §6.
        if let (Some(st), Some(base), Some(cv2), Some(fu)) = (
            self.cell(Scenario::Stocator, Workload::Teragen),
            self.cell(Scenario::S3aBase, Workload::Teragen),
            self.cell(Scenario::S3aCv2, Workload::Teragen),
            self.cell(Scenario::S3aCv2Fu, Workload::Teragen),
        ) {
            let b = base.runtime_mean_s / st.runtime_mean_s;
            let c = cv2.runtime_mean_s / st.runtime_mean_s;
            let f = fu.runtime_mean_s / st.runtime_mean_s;
            if b < 10.0 {
                bad.push(format!("Teragen S3a-Base speedup {b:.1} < 10x"));
            }
            if !(2.0..=8.0).contains(&c) {
                bad.push(format!("Teragen S3a-Cv2 speedup {c:.1} outside 2-8x"));
            }
            if !(1.05..=2.5).contains(&f) {
                bad.push(format!("Teragen S3a-Cv2+FU speedup {f:.1} outside 1.05-2.5x"));
            }
        }
        // Read-only ≈ 1×.
        if let (Some(st), Some(s3)) = (
            self.cell(Scenario::Stocator, Workload::ReadOnly50),
            self.cell(Scenario::S3aBase, Workload::ReadOnly50),
        ) {
            let r = s3.runtime_mean_s / st.runtime_mean_s;
            if !(0.7..=1.4).contains(&r) {
                bad.push(format!("Read-only S3a/Stocator ratio {r:.2} not ≈1"));
            }
        }
        if bad.is_empty() {
            Ok(())
        } else {
            Err(bad)
        }
    }
}

// ---- stress load-plane tables ---------------------------------------------
//
// Unlike every table above, these report *measured wall-clock* numbers
// from the `stress` load plane, not virtual-clock simulation — the text
// rendering of what BENCH_10.json serializes.

/// Per-op-class latency table for one stress run.
pub fn render_stress_latency(run: &crate::loadgen::StressRun) -> String {
    let mut t = Table::new(
        &format!(
            "stress — {} clients, {} shards, payload ≤{} B, seed {} ({:.2}s, {:.0} ops/s)",
            run.clients,
            match run.shards {
                Some(n) => n.to_string(),
                None => "target".to_string(),
            },
            run.payload,
            run.seed,
            run.elapsed_s,
            run.ops_per_sec,
        ),
        &["op class", "count", "mean µs", "p50 µs", "p95 µs", "p99 µs", "max µs"],
    );
    for c in crate::loadgen::OpClass::ALL {
        let s = run.summary_for(c);
        t.row(vec![
            c.name().to_string(),
            s.count.to_string(),
            format!("{:.1}", s.mean_us),
            format!("{:.1}", s.p50_us),
            format!("{:.1}", s.p95_us),
            format!("{:.1}", s.p99_us),
            format!("{:.1}", s.max_us),
        ]);
    }
    t.render()
}

/// The clients × shards × payload throughput matrix.
pub fn render_stress_matrix(cells: &[crate::loadgen::MatrixCell]) -> String {
    let mut t = Table::new(
        "stress matrix — clients × shards × payload",
        &["clients", "shards", "payload B", "ops", "ops/s", "write MiB/s", "put p95 µs", "violations"],
    );
    for m in cells {
        t.row(vec![
            m.clients.to_string(),
            match m.shards {
                Some(n) => n.to_string(),
                None => "target".to_string(),
            },
            m.payload.to_string(),
            m.total_ops.to_string(),
            format!("{:.0}", m.ops_per_sec),
            format!("{:.2}", m.write_mib_per_sec),
            format!("{:.1}", m.put_p95_us),
            m.violation_count.to_string(),
        ]);
    }
    t.render()
}

/// The reactor-vs-threaded server-core head-to-head: identical fixed op
/// budgets against a fresh in-process gateway per core.
pub fn render_stress_cores(rows: &[crate::loadgen::CoreRow]) -> String {
    let mut t = Table::new(
        "server cores — same op budget, reactor vs thread-per-connection",
        &["core", "clients", "ops", "elapsed s", "ops/s", "put p95 µs", "get p95 µs", "violations"],
    );
    for r in rows {
        t.row(vec![
            r.core.clone(),
            r.clients.to_string(),
            r.total_ops.to_string(),
            format!("{:.2}", r.elapsed_s),
            format!("{:.0}", r.ops_per_sec),
            format!("{:.1}", r.put_p95_us),
            format!("{:.1}", r.get_p95_us),
            r.violation_count.to_string(),
        ]);
    }
    t.render()
}

/// The `--scrape` cross-check: the gateway's own `/metricz` truth next
/// to the client's ledger. One row per op kind the server executed; the
/// latency columns are the *server-side* serve histograms (queue/parse
/// excluded on the threaded core), so client p95 minus server p95 is
/// the wire + client-stack cost.
pub fn render_stress_scrape(s: &crate::loadgen::ScrapeSummary) -> String {
    let mut t = Table::new(
        "scrape — server-side /metricz truth vs the client ledger",
        &["op kind", "server ops", "client ops", "srv p50 µs", "srv p95 µs", "srv p99 µs", "srv max µs"],
    );
    for (i, k) in crate::metrics::OpKind::ALL.iter().enumerate() {
        if s.server_ops[i] == 0 && s.client_ops[i] == 0 {
            continue;
        }
        let lat = s.server_latency.iter().find(|r| r.op == k.name());
        let f = |v: Option<f64>| v.map_or("-".to_string(), |x| format!("{x:.1}"));
        t.row(vec![
            k.name().to_string(),
            s.server_ops[i].to_string(),
            s.client_ops[i].to_string(),
            f(lat.map(|l| l.p50_us)),
            f(lat.map(|l| l.p95_us)),
            f(lat.map(|l| l.p99_us)),
            f(lat.map(|l| l.max_us)),
        ]);
    }
    t.render()
}

/// Paper Table 8 row for quick reference in benches.
pub fn table8_paper_note() -> &'static str {
    "paper: Teragen cost ratios — H-S Base x8.23, S3a Base x27.82, \
     H-S Cv2 x5.24, S3a Cv2 x17.59, S3a Cv2+FU x17.55"
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_shape_holds() {
        let sw = table2_single_object(Scenario::HadoopSwiftBase);
        let s3 = table2_single_object(Scenario::S3aBase);
        let st = table2_single_object(Scenario::Stocator);
        // The paper's ordering: Stocator << Swift << S3a.
        assert!(st.total() < sw.total(), "stocator {st} vs swift {sw}");
        assert!(sw.total() < s3.total(), "swift {sw} vs s3a {s3}");
        // Stocator within a hair of the paper's 8 ops, zero COPY/DELETE.
        assert_eq!(st.get(OpKind::CopyObject), 0);
        assert_eq!(st.get(OpKind::DeleteObject), 0);
        assert!(st.total() <= 12, "stocator total {}", st.total());
        // Legacy connectors rename: COPYs present.
        assert!(sw.get(OpKind::CopyObject) >= 2);
        assert!(s3.get(OpKind::CopyObject) >= 2);
    }

    #[test]
    fn mini_sweep_tables_render() {
        let sizing = Sizing::small();
        let sweep = Sweep::run(&sizing, 1, &[Workload::Teragen, Workload::ReadOnly50]);
        let t5 = sweep.render_table5();
        assert!(t5.contains("Stocator"));
        assert!(t5.contains("Teragen"));
        let t6 = sweep.render_table6();
        assert!(t6.contains("x1.00"), "{t6}");
        let t7 = sweep.render_table7();
        assert!(t7.contains("x"));
        let t8 = sweep.render_table8();
        assert!(t8.contains("x"));
        // Fault-free: no stranded-debris addendum, stock output.
        assert!(!t8.contains("stranded"), "{t8}");
    }

    #[test]
    fn stress_tables_render() {
        use crate::loadgen::{aggregate, CoreRow, MatrixCell, OpClass, WorkerReport, OP_CLASSES};
        use crate::metrics::Histogram;
        let mut r = WorkerReport {
            executed: [0; OP_CLASSES],
            hists: vec![Histogram::new(); OP_CLASSES],
            violations: Vec::new(),
            violation_count: 0,
            upload_ids: Vec::new(),
            bytes_written: 4096,
            bytes_read: 0,
            throttled_429: 0,
            shed_503: 0,
            retried_sends: 0,
            replayed_responses: 0,
            wire_ops: [0; 7],
        };
        r.executed[OpClass::Put.index()] = 5;
        r.hists[OpClass::Put.index()].record_nanos(10_000);
        let run = aggregate(vec![r], 1, Some(4), 1024, 7, 1.0);
        let lat = render_stress_latency(&run);
        assert!(lat.contains("put"), "{lat}");
        assert!(lat.contains("p95"), "{lat}");
        assert!(lat.contains("seed 7"), "{lat}");
        let mat = render_stress_matrix(&[MatrixCell::of(&run)]);
        assert!(mat.contains("ops/s"), "{mat}");
        assert!(mat.contains("1024"), "{mat}");
        let cores = render_stress_cores(&[
            CoreRow::of("reactor", &run),
            CoreRow::of("threaded", &run),
        ]);
        assert!(cores.contains("reactor"), "{cores}");
        assert!(cores.contains("threaded"), "{cores}");
    }

    #[test]
    fn stress_scrape_table_renders_server_truth() {
        use crate::loadgen::{ScrapeSummary, ServerLatencyRow};
        use crate::metrics::OpKind;
        let mut s = ScrapeSummary::default();
        s.server_ops[OpKind::PutObject.index()] = 12;
        s.client_ops[OpKind::PutObject.index()] = 12;
        s.client_ops[OpKind::GetObject.index()] = 3;
        s.server_latency.push(ServerLatencyRow {
            op: "PUT Object".to_string(),
            p50_us: 40.0,
            p95_us: 90.5,
            p99_us: 120.0,
            mean_us: 48.0,
            max_us: 300.0,
        });
        let out = render_stress_scrape(&s);
        assert!(out.contains("PUT Object"), "{out}");
        assert!(out.contains("90.5"), "{out}");
        // A kind only one side saw still gets a row (the gap is the
        // point of the table); latency absent renders as '-'.
        assert!(out.contains("GET Object"), "{out}");
        assert!(out.contains('-'), "{out}");
        // Kinds neither side saw are omitted.
        assert!(!out.contains("COPY Object"), "{out}");
    }

    #[test]
    fn table8_addendum_prices_stranded_debris() {
        use crate::objectstore::{FaultOp, FaultRule, FaultSpec};
        let mut sizing = Sizing::small();
        sizing.part_bytes = 16 * 1024; // above fs.s3a.multipart.size
        sizing.faults =
            FaultSpec::none().with(FaultRule::new(FaultOp::UploadPart, "teraout/", 2, 1));
        sizing.multipart_ttl_secs = 600;
        let sweep = Sweep::run(&sizing, 1, &[Workload::Teragen]);
        let t8 = sweep.render_table8();
        assert!(t8.contains("stranded multipart debris"), "{t8}");
        assert!(t8.contains("--multipart-ttl 600s"), "{t8}");
        assert!(t8.contains(", 0 B"), "swept clean: {t8}");
    }
}
