//! Tables 1 and 3: operation traces for the paper's two worked examples.

use crate::committer::{CommitAlgorithm, Committer, JobContext, TaskAttemptContext};
use crate::connectors::naming::AttemptId;
use crate::connectors::Stocator;
use crate::fs::hdfs::Hdfs;
use crate::fs::{FileSystem, OpCtx, Path};
use crate::objectstore::{ObjectStore, StoreConfig};
use crate::simclock::SimInstant;
use std::sync::Arc;

/// Table 1: the file-system operations Spark executes for the Fig. 3
/// one-task program on HDFS. Returns the trace lines.
pub fn table1_trace() -> Vec<String> {
    let fs = Hdfs::new();
    let mut ctx = OpCtx::traced(SimInstant::EPOCH);
    let out = Path::parse("hdfs://res/data.txt").unwrap();
    let job = JobContext::new(out);
    let committer = Committer::new(CommitAlgorithm::V1);
    committer.setup_job(&*fs, &job, &mut ctx).unwrap();
    let task = TaskAttemptContext::new(&job, AttemptId::new("201702221313", "0000", 1, 1));
    committer.setup_task(&*fs, &task, &mut ctx).unwrap();
    committer
        .write_part(&*fs, &task, "part-00001", b"output".to_vec(), &mut ctx)
        .unwrap();
    if committer.needs_task_commit(&*fs, &task, &mut ctx) {
        committer.commit_task(&*fs, &task, &mut ctx).unwrap();
    }
    committer.commit_job(&*fs, &job, &mut ctx).unwrap();
    ctx.take_trace()
}

/// One scenario of Table 3 on Stocator: which REST operations reach the
/// object store for the Fig. 4 three-task program, with `extra_attempts`
/// duplicate executions of task 2 and optional cleanup of the losers.
/// Returns (trace lines, final object names).
pub fn table3_trace(extra_attempts: u32, cleanup: bool) -> (Vec<String>, Vec<String>) {
    let store = ObjectStore::new(StoreConfig::instant_strong());
    store.create_container("res", SimInstant::EPOCH).0.unwrap();
    let fs: Arc<dyn FileSystem> = Stocator::with_defaults(store.clone());
    let mut ctx = OpCtx::traced(SimInstant::EPOCH);
    let out = Path::parse("swift2d://res/data.txt").unwrap();
    let job = JobContext::new(out);
    let committer = Committer::new(CommitAlgorithm::V1);
    committer.setup_job(&*fs, &job, &mut ctx).unwrap();

    // Tasks 0 and 1 run once; task 2 runs 1 + extra_attempts times.
    let mut winners = Vec::new();
    for task_id in 0..3u32 {
        let attempts = if task_id == 2 { 1 + extra_attempts } else { 1 };
        for a in 0..attempts {
            let tac = TaskAttemptContext::new(
                &job,
                AttemptId::new("201512062056", "0000", task_id, a),
            );
            committer.setup_task(&*fs, &tac, &mut ctx).unwrap();
            committer
                .write_part(
                    &*fs,
                    &tac,
                    &format!("part-{task_id:05}"),
                    format!("data-{task_id}").into_bytes(),
                    &mut ctx,
                )
                .unwrap();
        }
        // Attempt `attempts - 2` wins when there are duplicates (mirrors
        // the paper: attempt 1 of 3 succeeds); otherwise attempt 0.
        let winner = attempts.saturating_sub(2).min(attempts - 1);
        winners.push((task_id, winner, attempts));
    }
    for &(task_id, winner, attempts) in &winners {
        let wtac = TaskAttemptContext::new(
            &job,
            AttemptId::new("201512062056", "0000", task_id, winner),
        );
        committer.commit_task(&*fs, &wtac, &mut ctx).unwrap();
        if cleanup {
            for a in 0..attempts {
                if a != winner {
                    let ltac = TaskAttemptContext::new(
                        &job,
                        AttemptId::new("201512062056", "0000", task_id, a),
                    );
                    committer.abort_task(&*fs, &ltac, &mut ctx).unwrap();
                }
            }
        }
    }
    committer.commit_job(&*fs, &job, &mut ctx).unwrap();
    let trace = ctx.take_trace();
    let names = store.debug_names("res", "data.txt/");
    (trace, names)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_the_eight_steps() {
        let trace = table1_trace();
        let joined = trace.join("\n");
        // Steps 1-2: recursive mkdirs; step 3: temp write; steps 4-7: list
        // + two renames; step 8: _SUCCESS.
        assert!(joined.contains("mkdirs: hdfs://res/data.txt/_temporary/0"));
        assert!(joined.contains("_temporary/attempt_201702221313_0000_m_000001_1"));
        assert!(joined.contains("create: hdfs://res/data.txt/_temporary"));
        assert_eq!(trace.iter().filter(|l| l.starts_with("rename:")).count(), 2);
        assert!(joined.contains("create: hdfs://res/data.txt/_SUCCESS"));
    }

    #[test]
    fn table3_simple_run_lines_1_3_8_9() {
        let (trace, names) = table3_trace(0, false);
        let puts: Vec<&String> = trace
            .iter()
            .filter(|l| l.contains("(intercept) PUT"))
            .collect();
        assert_eq!(puts.len(), 3, "{trace:?}");
        assert!(names
            .contains(&"data.txt/part-00000_attempt_201512062056_0000_m_000000_0".to_string()));
        assert!(names.contains(&"data.txt/_SUCCESS".to_string()));
        // Line 8: no COPY/DELETE during commits.
        assert!(!trace.iter().any(|l| l.contains("COPY")));
        assert!(!trace.iter().any(|l| l.contains("DELETE") && !l.contains("intercept")));
    }

    #[test]
    fn table3_speculation_with_cleanup_lines_1_9() {
        let (trace, names) = table3_trace(2, true);
        // 5 PUTs: tasks 0, 1 once; task 2 three times.
        let puts = trace.iter().filter(|l| l.contains("(intercept) PUT")).count();
        assert_eq!(puts, 5, "{trace:?}");
        // 2 DELETEs: losers of task 2 aborted.
        let dels = trace
            .iter()
            .filter(|l| l.contains("(intercept) DELETE"))
            .count();
        assert_eq!(dels, 2);
        // Exactly the winner's object remains for task 2 (attempt 1).
        let task2: Vec<&String> = names.iter().filter(|n| n.contains("part-00002")).collect();
        assert_eq!(task2.len(), 1);
        assert!(task2[0].ends_with("m_000002_1"));
    }

    #[test]
    fn table3_speculation_without_cleanup_keeps_duplicates() {
        let (_, names) = table3_trace(2, false);
        let task2 = names.iter().filter(|n| n.contains("part-00002")).count();
        assert_eq!(task2, 3, "all three attempts' objects remain");
        // But a Stocator read still sees exactly one part-2 (dedup) —
        // verified in connectors::stocator tests.
    }
}
