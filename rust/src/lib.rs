//! # stocator — a reproduction of "Stocator: A High Performance Object Store
//! # Connector for Spark" (Vernik et al., 2017)
//!
//! This crate contains the full simulated stack described in DESIGN.md:
//!
//! * [`objectstore`] — an eventually-consistent cloud object store with
//!   REST-operation accounting, a virtual-time latency model and
//!   per-provider pricing models. GETs may be **ranged** (HTTP Range
//!   semantics, priced per returned byte). Storage is pluggable behind
//!   the [`objectstore::Backend`] trait: an N-way sharded in-memory map
//!   (default; one shard reproduces the legacy single-global-lock layout)
//!   or a persistent local-filesystem layout, selected with
//!   `--backend mem|sharded[:N]|fs[:DIR]` on the CLI. Op counts, byte
//!   accounting and virtual-clock runtimes are backend-invariant — the
//!   front end owns them — so backends trade only wall-clock concurrency
//!   and durability. A deterministic **transient-fault plane**
//!   ([`objectstore::faults`], `--faults` on the CLI) injects retryable
//!   5xx failures into specific PUTs/GETs/multipart ops — priced like
//!   real requests (latency, op, wire bytes) — and an age-based
//!   multipart GC sweep (`--multipart-ttl`) reaps uploads stranded by
//!   crashed fast-upload writers, with the stranded bytes priced in the
//!   Table 8 addendum. Fault rules may be exact-Nth point faults, seeded
//!   per-op probabilities (`put@p=0.05`), or 429 throttles (`!429` —
//!   an op and base latency, zero wire bytes, flat Retry-After retry
//!   pause).
//! * [`gateway`] — the HTTP object-store gateway: a dependency-free
//!   (std `TcpListener`, hand-rolled HTTP/1.1) REST server exposing any
//!   backend over Swift/S3-style routes (`stocator-sim serve`), and
//!   [`gateway::HttpBackend`], the matching `Backend` client — so the
//!   whole simulator can run over real sockets with
//!   `--backend http:HOST:PORT`, byte-identical in op counts and
//!   virtual runtimes to the in-memory backends.
//! * [`fs`] — the Hadoop `FileSystem` abstraction (paths, statuses, the
//!   trait all connectors implement) plus an in-memory HDFS-like
//!   baseline. I/O is **stream-shaped** (`FsOutputStream` /
//!   `FsInputStream`, mirroring Hadoop's FSData streams): connectors
//!   express their §3.3 write paths — spool-then-PUT,
//!   multipart-during-write, single chunked-transfer PUT — byte by byte
//!   on the virtual clock (with a zero-copy `write_owned` fast path for
//!   whole-part writers), dropping a stream without `close` is the
//!   executor-crash abort path, and partial reads (`read_range`) reach
//!   all the way down to the backends. Streams retry transient REST
//!   failures under a shared `RetryPolicy` (`--retries`) with
//!   per-connector resume semantics: re-PUT from the local spool,
//!   re-send one multipart part, or — Stocator's chunked-transfer
//!   fragility, the paper's §3.3 footnote — restart the whole PUT from
//!   offset 0; exhausted budgets fail the task attempt and the Spark
//!   scheduler re-attempts it. An optional S3AInputStream-style
//!   readahead window ([`fs::readahead`], `--readahead BYTES` on the
//!   CLI) coalesces small sequential reads into few ranged GETs;
//!   off by default, so every paper table reproduces the legacy
//!   one-GET-per-read behaviour byte-identically.
//! * [`connectors`] — the three storage connectors under study:
//!   Hadoop-Swift, S3a (with optional fast upload) and Stocator itself.
//! * [`committer`] — Hadoop's `FileOutputCommitter` algorithm versions 1
//!   and 2, and the Databricks `DirectOutputCommitter` baseline.
//! * [`spark`] — a Spark-like execution engine: driver, stages, tasks,
//!   attempt ids, executor slots on a virtual clock, speculative execution
//!   and fault injection.
//! * [`columnar`] + [`query`] — a mini Parquet-like columnar format and the
//!   TPC-DS-subset query engine used by the TPC-DS workload.
//! * [`workloads`] — Read-only, Teragen, Copy, Wordcount, Terasort, TPC-DS.
//! * [`runtime`] — the PJRT runtime that loads the AOT-compiled JAX/Pallas
//!   kernels (`artifacts/*.hlo.txt`) and the pure-Rust fallback.
//! * [`harness`] — the benchmark harness regenerating every table and
//!   figure from the paper's evaluation section.
//! * [`loadgen`] — the real-concurrency load plane (`stocator-sim
//!   stress`): N OS threads, each with its own [`gateway::HttpBackend`],
//!   hammer a served store with a seeded mixed workload, verify
//!   correctness inline (byte/ETag round-trips, multipart-id uniqueness,
//!   listing completeness at quiesce), record measured wall-clock
//!   latency into per-worker [`metrics::Histogram`]s, and serialize
//!   every run to `BENCH_8.json` — the measured-perf trajectory.
//!
//! The paper's contribution — the Stocator commit protocol — lives in
//! [`connectors::stocator`]; everything else is the substrate it needs.

pub mod util;
pub mod simclock;
pub mod objectstore;
pub mod gateway;
pub mod fs;
pub mod connectors;
pub mod committer;
pub mod spark;
pub mod columnar;
pub mod query;
pub mod workloads;
pub mod runtime;
pub mod metrics;
pub mod harness;
pub mod loadgen;
